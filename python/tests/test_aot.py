"""AOT lowering checks: every model lowers to parseable HLO text with the
right parameter count and a tuple root."""

import pytest

from compile import aot, model


def entry_params(text: str) -> int:
    """Count parameters of the ENTRY computation only (nested pallas loop
    bodies carry their own parameter instructions)."""
    entry = text[text.index("ENTRY "):]
    return sum(1 for line in entry.splitlines() if " parameter(" in line)


@pytest.mark.parametrize("name", list(model.MODELS))
def test_lowering_produces_hlo_text(name):
    text = aot.lower_model(name)
    assert text.startswith("HloModule"), text[:60]
    # one ENTRY parameter per input
    n_params = entry_params(text)
    assert n_params == len(model.MODELS[name][1]), f"{name}: {n_params} params"
    # lowered with return_tuple=True -> root is a tuple
    assert "tuple(" in text


def test_artifact_names_are_filesystem_safe():
    assert aot.artifact_name("3-madd") == "3_madd.hlo.txt"
    assert aot.artifact_name("gemm") == "gemm.hlo.txt"


def test_unknown_kernel_fails_cli(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--only", "nope"])
    assert rc == 1


def test_cli_writes_artifact(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--only", "madd"])
    assert rc == 0
    out = tmp_path / "madd.hlo.txt"
    assert out.exists()
    assert out.read_text().startswith("HloModule")
