"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-divisible ones that exercise the
padding path) and tile sizes — the CORE correctness signal for the
compute layer."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import madd_tiled, matmul_tiled, mv_tiled
from compile.kernels.ref import ref_madd, ref_matmul, ref_mv


def _arr(rng, *shape):
    return jnp.asarray(rng.uniform(-1, 1, size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# fixed-shape smoke tests
# ---------------------------------------------------------------------------

def test_matmul_square():
    rng = np.random.default_rng(0)
    x, y = _arr(rng, 64, 64), _arr(rng, 64, 64)
    np.testing.assert_allclose(matmul_tiled(x, y), ref_matmul(x, y), rtol=1e-5, atol=1e-5)


def test_matmul_polybench_3mm_shapes():
    # the exact E = A x B of Listing 4: 180x200 @ 200x190 — none of the
    # dims divide the 64 tiles (the composite-padding path).
    rng = np.random.default_rng(1)
    a, b = _arr(rng, 180, 200), _arr(rng, 200, 190)
    np.testing.assert_allclose(matmul_tiled(a, b), ref_matmul(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_small_tiles():
    rng = np.random.default_rng(2)
    a, b = _arr(rng, 30, 50), _arr(rng, 50, 20)
    got = matmul_tiled(a, b, tm=8, tn=8, tk=16)
    np.testing.assert_allclose(got, ref_matmul(a, b), rtol=1e-5, atol=1e-5)


def test_madd_exact():
    rng = np.random.default_rng(3)
    a, b = _arr(rng, 100, 130), _arr(rng, 100, 130)
    # addition is exact elementwise — no tolerance needed
    np.testing.assert_array_equal(madd_tiled(a, b), ref_madd(a, b))


def test_mv_polybench_shape():
    rng = np.random.default_rng(4)
    a, x = _arr(rng, 390, 410), _arr(rng, 410)
    np.testing.assert_allclose(mv_tiled(a, x), ref_mv(a, x), rtol=1e-4, atol=1e-4)


def test_mv_transposed_view():
    rng = np.random.default_rng(5)
    a, x = _arr(rng, 128, 64), _arr(rng, 128)
    np.testing.assert_allclose(mv_tiled(a.T, x), ref_mv(a.T, x), rtol=1e-5, atol=1e-5)


def test_matmul_identity():
    eye = jnp.eye(96, dtype=jnp.float32)
    rng = np.random.default_rng(6)
    m = _arr(rng, 96, 40)
    np.testing.assert_allclose(matmul_tiled(eye, m), m, rtol=1e-6)


def test_matmul_zero():
    z = jnp.zeros((33, 17), jnp.float32)
    rng = np.random.default_rng(7)
    m = _arr(rng, 17, 29)
    assert float(jnp.abs(matmul_tiled(z, m)).max()) == 0.0


def test_contraction_mismatch_raises():
    rng = np.random.default_rng(8)
    with pytest.raises(AssertionError):
        matmul_tiled(_arr(rng, 8, 9), _arr(rng, 10, 8))


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------

dims = st.integers(min_value=1, max_value=96)
tiles = st.sampled_from([8, 16, 32, 64])


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, tm=tiles, tn=tiles, tk=tiles, seed=st.integers(0, 2**16))
def test_matmul_shape_tile_sweep(m, k, n, tm, tn, tk, seed):
    rng = np.random.default_rng(seed)
    x, y = _arr(rng, m, k), _arr(rng, k, n)
    got = matmul_tiled(x, y, tm=tm, tn=tn, tk=tk)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, ref_matmul(x, y), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(m=dims, n=dims, tm=tiles, tn=tiles, seed=st.integers(0, 2**16))
def test_madd_shape_tile_sweep(m, n, tm, tn, seed):
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, m, n), _arr(rng, m, n)
    got = madd_tiled(a, b, tm=tm, tn=tn)
    assert got.shape == (m, n)
    np.testing.assert_array_equal(got, ref_madd(a, b))


@settings(max_examples=20, deadline=None)
@given(m=dims, k=dims, tm=tiles, tk=tiles, seed=st.integers(0, 2**16))
def test_mv_shape_tile_sweep(m, k, tm, tk, seed):
    rng = np.random.default_rng(seed)
    a, x = _arr(rng, m, k), _arr(rng, k)
    got = mv_tiled(a, x, tm=tm, tk=tk)
    assert got.shape == (m,)
    np.testing.assert_allclose(got, ref_mv(a, x), rtol=1e-4, atol=1e-4)
