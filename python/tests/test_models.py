"""Layer-2 correctness: JAX models vs independent numpy references, on
the same deterministic inputs the rust runtime will use."""

import numpy as np
import pytest

from compile import model


def np_inputs(name):
    return [np.asarray(a) for a in model.inputs_for(name)]


def test_input_formula_spot_values():
    # must match rust/src/ir/oracle.rs::input_element
    a0 = model.input_array(0, 4)
    # n=1, a=0 -> (16807+13) % 1000 = 820 -> 0.32
    assert abs(a0[1] - np.float32(0.32)) < 1e-7
    assert a0.dtype == np.float32
    # different ordinals differ
    assert not np.allclose(model.input_array(0, 8), model.input_array(1, 8))


@pytest.mark.parametrize("name", list(model.MODELS))
def test_model_runs_and_is_finite(name):
    fn, lengths = model.MODELS[name]
    ins = model.inputs_for(name)
    assert [len(i) for i in ins] == lengths
    out = fn(*ins)
    outs = out if isinstance(out, tuple) else (out,)
    for o in outs:
        assert np.isfinite(np.asarray(o)).all(), f"{name} produced non-finite"


def test_gemm_matches_numpy():
    c, a, b = np_inputs("gemm")
    s = model.SIZES["gemm"]
    ref = 1.2 * c.reshape(s["ni"], s["nj"]) + 1.5 * (
        a.reshape(s["ni"], s["nk"]).astype(np.float64)
        @ b.reshape(s["nk"], s["nj"]).astype(np.float64)
    )
    got = np.asarray(model.gemm(*np_inputs("gemm"))).reshape(s["ni"], s["nj"])
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_3mm_matches_numpy():
    a, b, c, d = np_inputs("3mm")
    s = model.SIZES["3mm"]
    e = a.reshape(s["ni"], s["nk"]).astype(np.float64) @ b.reshape(s["nk"], s["nj"]).astype(np.float64)
    f = c.reshape(s["nj"], s["nm"]).astype(np.float64) @ d.reshape(s["nm"], s["nl"]).astype(np.float64)
    g = e @ f
    got = np.asarray(model.three_mm(*np_inputs("3mm"))).reshape(s["ni"], s["nl"])
    np.testing.assert_allclose(got, g, rtol=1e-3, atol=1e-3)


def test_bicg_matches_numpy():
    a, r, p = np_inputs("bicg")
    s = model.SIZES["bicg"]
    am = a.reshape(s["m"], s["n"])
    sv, q = model.bicg(*np_inputs("bicg"))
    np.testing.assert_allclose(np.asarray(sv), am.T @ r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(q), am @ p, rtol=1e-4, atol=1e-4)


def test_mvt_matches_numpy():
    a, x1, x2, y1, y2 = np_inputs("mvt")
    n = model.SIZES["mvt"]["n"]
    am = a.reshape(n, n)
    gx1, gx2 = model.mvt(*np_inputs("mvt"))
    np.testing.assert_allclose(np.asarray(gx1), x1 + am @ y1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gx2), x2 + am.T @ y2, rtol=1e-4, atol=1e-4)


def test_three_madd_matches_numpy():
    a, b, c, d = np_inputs("3-madd")
    got = np.asarray(model.three_madd(*np_inputs("3-madd")))
    np.testing.assert_allclose(got, (a + b) + (c + d), rtol=1e-6)


def test_registry_agrees_with_rust_specs():
    # shape table mirrored in rust/src/runtime/executor.rs — keep in sync
    expected = {
        "gemm": [200 * 220, 200 * 240, 240 * 220],
        "3mm": [180 * 200, 200 * 190, 190 * 220, 220 * 210],
        "bicg": [390 * 410, 390, 410],
        "mvt": [400 * 400, 400, 400, 400, 400],
    }
    for name, lens in expected.items():
        assert model.MODELS[name][1] == lens, name
