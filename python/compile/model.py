"""Layer-2: PolyBench kernels as JAX functions over *flat* float32 inputs.

Every model takes flat 1-D inputs (so the rust runtime can feed plain
`Literal::vec1` buffers without shape plumbing), reshapes internally, and
routes its compute hot-spots through the Layer-1 Pallas kernels. The
deterministic input generator `inputs_for` matches
`rust/src/ir/oracle.rs::input_array` bit-for-bit.

Sizes are PolyBench 4.2.1 medium — identical to `rust/src/ir/polybench.rs`.
"""

import jax.numpy as jnp
import numpy as np

from .kernels import madd_tiled, matmul_tiled, mv_tiled

# PolyBench medium sizes (must match rust/src/ir/polybench.rs)
SIZES = {
    "gemm": dict(ni=200, nj=220, nk=240),
    "2mm": dict(ni=180, nj=190, nk=210, nl=220),
    "3mm": dict(ni=180, nj=190, nk=200, nl=210, nm=220),
    "atax": dict(m=390, n=410),
    "bicg": dict(m=390, n=410),
    "mvt": dict(n=400),
    "gesummv": dict(n=250),
    "madd": dict(n=400),
    "2-madd": dict(n=400),
    "3-madd": dict(n=400),
}


def input_element(ordinal: int, n: np.ndarray) -> np.ndarray:
    """The shared deterministic input formula (see rust oracle)."""
    v = (n * 16807 + ordinal * 2671 + 13) % 1000
    return v.astype(np.float32) / np.float32(1000.0) - np.float32(0.5)


def input_array(ordinal: int, length: int) -> np.ndarray:
    return input_element(ordinal, np.arange(length, dtype=np.uint64))


# ---------------------------------------------------------------------------
# models (flat in, tuple-of-flat out)
# ---------------------------------------------------------------------------

def gemm(c_flat, a_flat, b_flat):
    s = SIZES["gemm"]
    c = c_flat.reshape(s["ni"], s["nj"])
    a = a_flat.reshape(s["ni"], s["nk"])
    b = b_flat.reshape(s["nk"], s["nj"])
    return (jnp.float32(1.2) * c + jnp.float32(1.5) * matmul_tiled(a, b)).ravel()


def two_mm(a_flat, b_flat, c_flat, d_flat):
    s = SIZES["2mm"]
    a = a_flat.reshape(s["ni"], s["nk"])
    b = b_flat.reshape(s["nk"], s["nj"])
    c = c_flat.reshape(s["nj"], s["nl"])
    d = d_flat.reshape(s["ni"], s["nl"])
    tmp = jnp.float32(1.5) * matmul_tiled(a, b)
    return (jnp.float32(1.2) * d + matmul_tiled(tmp, c)).ravel()


def three_mm(a_flat, b_flat, c_flat, d_flat):
    s = SIZES["3mm"]
    a = a_flat.reshape(s["ni"], s["nk"])
    b = b_flat.reshape(s["nk"], s["nj"])
    c = c_flat.reshape(s["nj"], s["nm"])
    d = d_flat.reshape(s["nm"], s["nl"])
    e = matmul_tiled(a, b)
    f = matmul_tiled(c, d)
    return matmul_tiled(e, f).ravel()


def atax(a_flat, x_flat):
    s = SIZES["atax"]
    a = a_flat.reshape(s["m"], s["n"])
    tmp = mv_tiled(a, x_flat)
    return mv_tiled(a.T, tmp).ravel()


def bicg(a_flat, r_flat, p_flat):
    s = SIZES["bicg"]
    a = a_flat.reshape(s["m"], s["n"])
    sv = mv_tiled(a.T, r_flat)
    q = mv_tiled(a, p_flat)
    return sv.ravel(), q.ravel()


def mvt(a_flat, x1_flat, x2_flat, y1_flat, y2_flat):
    s = SIZES["mvt"]
    a = a_flat.reshape(s["n"], s["n"])
    x1 = x1_flat + mv_tiled(a, y1_flat)
    x2 = x2_flat + mv_tiled(a.T, y2_flat)
    return x1.ravel(), x2.ravel()


def gesummv(a_flat, b_flat, x_flat):
    s = SIZES["gesummv"]
    a = a_flat.reshape(s["n"], s["n"])
    b = b_flat.reshape(s["n"], s["n"])
    tmp = mv_tiled(a, x_flat)
    y = mv_tiled(b, x_flat)
    return (jnp.float32(1.5) * tmp + jnp.float32(1.2) * y).ravel()


def madd(a_flat, b_flat):
    n = SIZES["madd"]["n"]
    return madd_tiled(a_flat.reshape(n, n), b_flat.reshape(n, n)).ravel()


def two_madd(a_flat, b_flat, c_flat):
    n = SIZES["2-madd"]["n"]
    t = madd_tiled(a_flat.reshape(n, n), b_flat.reshape(n, n))
    return madd_tiled(t, c_flat.reshape(n, n)).ravel()


def three_madd(a_flat, b_flat, c_flat, d_flat):
    n = SIZES["3-madd"]["n"]
    t1 = madd_tiled(a_flat.reshape(n, n), b_flat.reshape(n, n))
    t2 = madd_tiled(c_flat.reshape(n, n), d_flat.reshape(n, n))
    return madd_tiled(t1, t2).ravel()


# ---------------------------------------------------------------------------
# registry: name -> (fn, input lengths) — must agree with
# rust/src/runtime/executor.rs::KernelSpec::known()
# ---------------------------------------------------------------------------

def _s(name):
    return SIZES[name]


MODELS = {
    "gemm": (gemm, [_s("gemm")["ni"] * _s("gemm")["nj"],
                    _s("gemm")["ni"] * _s("gemm")["nk"],
                    _s("gemm")["nk"] * _s("gemm")["nj"]]),
    "2mm": (two_mm, [_s("2mm")["ni"] * _s("2mm")["nk"],
                     _s("2mm")["nk"] * _s("2mm")["nj"],
                     _s("2mm")["nj"] * _s("2mm")["nl"],
                     _s("2mm")["ni"] * _s("2mm")["nl"]]),
    "3mm": (three_mm, [_s("3mm")["ni"] * _s("3mm")["nk"],
                       _s("3mm")["nk"] * _s("3mm")["nj"],
                       _s("3mm")["nj"] * _s("3mm")["nm"],
                       _s("3mm")["nm"] * _s("3mm")["nl"]]),
    "atax": (atax, [_s("atax")["m"] * _s("atax")["n"], _s("atax")["n"]]),
    "bicg": (bicg, [_s("bicg")["m"] * _s("bicg")["n"], _s("bicg")["m"],
                    _s("bicg")["n"]]),
    "mvt": (mvt, [_s("mvt")["n"] ** 2] + [_s("mvt")["n"]] * 4),
    "gesummv": (gesummv, [_s("gesummv")["n"] ** 2, _s("gesummv")["n"] ** 2,
                          _s("gesummv")["n"]]),
    "madd": (madd, [_s("madd")["n"] ** 2] * 2),
    "2-madd": (two_madd, [_s("2-madd")["n"] ** 2] * 3),
    "3-madd": (three_madd, [_s("3-madd")["n"] ** 2] * 4),
}


def inputs_for(name):
    """Deterministic inputs for a model, ordinal = parameter position."""
    _, lengths = MODELS[name]
    return [input_array(i, ln) for i, ln in enumerate(lengths)]
