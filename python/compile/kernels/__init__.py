"""Layer-1 Pallas kernels.

The paper's intra-tile tasks (fully unrolled, output-stationary — Listing
7) map to Pallas tile kernels: `BlockSpec` expresses the HBM<->VMEM tile
schedule Prometheus expresses with inter-tile loops + load/read FIFO
helpers; the grid pipeline provides the ping-pong double buffering of
paper section 3.5 for free. Kernels run `interpret=True` — the CPU PJRT
plugin cannot execute Mosaic custom-calls; see DESIGN.md section 3 for
the TPU adaptation notes and estimated MXU/VMEM figures.
"""

from .matmul import matmul_tiled
from .vecops import madd_tiled, mv_tiled

__all__ = ["matmul_tiled", "madd_tiled", "mv_tiled"]
