"""Output-stationary tiled matmul — the Pallas counterpart of the paper's
fused MM task (Listing 6/7).

Prometheus's generated structure maps onto Pallas as:

* inter-tile loops ``i0, j0``      -> the first two grid axes,
* pipelined reduction loop ``k0``  -> the third (innermost) grid axis,
* the fully unrolled intra task    -> the VMEM tile ``x_tile @ y_tile``,
* output-stationary accumulation   -> a VMEM scratch accumulator written
  back on the last ``k0`` step (exactly the E/F/G tiles of Listing 6),
* composite padding (section 3.2)  -> explicit zero-padding to the tile
  grid before the call, sliced off afterwards.

TPU adaptation (DESIGN.md section 8): 64x64 f32 output tiles with 64-wide
K slabs keep the working set at ~48 KiB of VMEM (three tiles, double
buffered by the grid pipeline) and feed the MXU with lane-aligned
operands. ``interpret=True`` everywhere — correctness is checked on CPU
against ``ref.py``; real-TPU lowering would emit a Mosaic custom-call the
CPU plugin cannot run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    """One (i0, j0, k0) grid step: accumulate x_tile @ y_tile."""

    @pl.when(pl.program_id(2) == 0)
    def _init():  # S0/S2/S4 of Listing 4: zero the output tile on-chip
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():  # store_E/sent_E of Listing 6: emit the finished tile
        o_ref[...] = acc_ref[...]


def _pad_to(a: jax.Array, rows: int, cols: int) -> jax.Array:
    """Composite padding (paper section 3.2): zero-extend to tile bounds."""
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def matmul_tiled(x, y, *, tm: int = 64, tn: int = 64, tk: int = 64):
    """``x @ y`` for arbitrary (static) shapes via the tiled kernel.

    Shapes need not divide the tile sizes — inputs are zero-padded to the
    tile grid (the wasted partial-tile work the paper's padding analysis
    accounts for) and the result is sliced back.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    gm, gn, gk = -(-m // tm), -(-n // tn), -(-k // tk)
    xp = _pad_to(x, gm * tm, gk * tk)
    yp = _pad_to(y, gk * tk, gn * tn)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * tm, gn * tn), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=True,
    )(xp, yp)
    return out[:m, :n]
