"""Pure-jnp correctness oracles for the Pallas kernels.

These are the build-time ground truth: every kernel in this package must
match its `ref_*` counterpart to float32 tolerance across the shape/tile
sweep in python/tests/test_kernels.py (including non-divisible shapes,
which exercise the padding path)."""

import jax.numpy as jnp


def ref_matmul(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def ref_madd(a, b):
    return a + b


def ref_mv(a, x):
    return a @ x
