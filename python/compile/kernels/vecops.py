"""Pallas kernels for the memory-bound tasks: tiled matrix add (the
n-madd family) and tiled matrix-vector product (atax/bicg/mvt/gesummv).

These mirror the paper's memory-bound fused tasks: no reduction tiling is
needed for madd (pure streaming, the FIFO `load/read` path dominates);
mv accumulates row-block partials over K slabs exactly like the
output-stationary MM tile, with a (TM,) accumulator."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _madd_kernel(a_ref, b_ref, o_ref):
    """One (i0, j0) tile step: elementwise add in VMEM."""
    o_ref[...] = a_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def madd_tiled(a, b, *, tm: int = 64, tn: int = 64):
    """``a + b`` over 2-D tiles (zero-padded to the tile grid)."""
    m, n = a.shape
    assert a.shape == b.shape
    gm, gn = -(-m // tm), -(-n // tn)
    pad = lambda x: jnp.pad(x, ((0, gm * tm - m), (0, gn * tn - n)))
    out = pl.pallas_call(
        _madd_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * tm, gn * tn), jnp.float32),
        interpret=True,
    )(pad(a), pad(b))
    return out[:m, :n]


def _mv_kernel(a_ref, x_ref, o_ref, acc_ref, *, n_k: int):
    """One (i0, k0) step: row-block partial dot, output-stationary."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (TM, TK) @ (TK,) -> (TM,) accumulated in VMEM
    acc_ref[...] += a_ref[...] @ x_ref[...]

    @pl.when(pl.program_id(1) == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("tm", "tk"))
def mv_tiled(a, x, *, tm: int = 64, tk: int = 64):
    """``a @ x`` for a 2-D `a` and 1-D `x` via row-block tiles."""
    m, k = a.shape
    (k2,) = x.shape
    assert k == k2
    gm, gk = -(-m // tm), -(-k // tk)
    ap = jnp.pad(a, ((0, gm * tm - m), (0, gk * tk - k)))
    xp = jnp.pad(x, (0, gk * tk - k))
    out = pl.pallas_call(
        functools.partial(_mv_kernel, n_k=gk),
        grid=(gm, gk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, kk: (i, kk)),
            pl.BlockSpec((tk,), lambda i, kk: (kk,)),
        ],
        out_specs=pl.BlockSpec((tm,), lambda i, kk: (i,)),
        out_shape=jax.ShapeDtypeStruct((gm * tm,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tm,), jnp.float32)],
        interpret=True,
    )(ap, xp)
    return out[:m]
