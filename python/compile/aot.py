"""AOT lowering: JAX models -> HLO *text* artifacts for the rust runtime.

Run as ``python -m compile.aot [--out-dir ../artifacts] [--only k1,k2]``
(this is what ``make artifacts`` does). Python executes ONLY here, at
build time; the rust binary consumes the text artifacts through PJRT.

Interchange is HLO text, not a serialized HloModuleProto: jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 (the version the
rust `xla` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly. Lowered with ``return_tuple=True`` so the rust side
always unwraps a tuple.
"""

import argparse
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name: str) -> str:
    fn, lengths = model.MODELS[name]
    specs = [jax.ShapeDtypeStruct((n,), jnp.float32) for n in lengths]

    def as_tuple(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return to_hlo_text(jax.jit(as_tuple).lower(*specs))


def artifact_name(kernel: str) -> str:
    return kernel.replace("-", "_") + ".hlo.txt"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", type=pathlib.Path)
    ap.add_argument("--only", default=None, help="comma-separated kernels")
    args = ap.parse_args(argv)

    names = list(model.MODELS) if args.only is None else args.only.split(",")
    args.out_dir.mkdir(parents=True, exist_ok=True)
    for name in names:
        if name not in model.MODELS:
            print(f"unknown kernel {name}", file=sys.stderr)
            return 1
        text = lower_model(name)
        path = args.out_dir / artifact_name(name)
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
