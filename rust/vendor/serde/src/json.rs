//! JSON document model, writer and recursive-descent parser.
//!
//! Integers are kept exact (`i128`) instead of being forced through
//! `f64`, so `u64` cycle counts round-trip bit-exactly. Objects preserve
//! insertion order (a `Vec` of pairs), which keeps serialized databases
//! diffable.

use crate::Error;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Exact integer (no `.`/exponent in the source token).
    Int(i128),
    Float(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Short type name for error messages.
    pub fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Int(_) => "integer",
            Value::Float(_) => "float",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k.as_str() == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field lookup that errors with the field name.
    pub fn field(&self, key: &str) -> Result<&Value, Error> {
        self.get(key).ok_or_else(|| Error::new(format!("missing field `{key}`")))
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i128> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(fields) => Some(fields),
            _ => None,
        }
    }
}

// ---- writer ------------------------------------------------------------

/// Serialize compactly.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, None, 0, &mut out);
    out
}

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, Some(2), 0, &mut out);
    out.push('\n');
    out
}

fn write_value(v: &Value, indent: Option<usize>, depth: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Int(i) => out.push_str(&i.to_string()),
        Value::Float(f) => {
            if f.is_finite() {
                out.push_str(&f.to_string());
            } else {
                // JSON has no NaN/inf; null is the conventional fallback.
                out.push_str("null");
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(indent, depth + 1, out);
                write_value(item, indent, depth + 1, out);
            }
            newline_indent(indent, depth, out);
            out.push(']');
        }
        Value::Obj(fields) => {
            if fields.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(indent, depth + 1, out);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, indent, depth + 1, out);
            }
            newline_indent(indent, depth, out);
            out.push('}');
        }
    }
}

fn newline_indent(indent: Option<usize>, depth: usize, out: &mut String) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..depth * w {
            out.push(' ');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parser ------------------------------------------------------------

/// Parse a JSON document. The whole input must be one value (trailing
/// whitespace allowed).
pub fn parse(input: &str) -> Result<Value, Error> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::new(format!("trailing characters at byte {}", p.pos)));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), Error> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::new(format!(
                "expected `{}` at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, Error> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::new(format!("invalid literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Value, Error> {
        match self.peek() {
            Some(b'n') => self.literal("null", Value::Null),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'"') => self.string().map(Value::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::new(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ))),
        }
    }

    fn array(&mut self) -> Result<Value, Error> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                other => {
                    return Err(Error::new(format!(
                        "expected `,` or `]` in array, found {:?}",
                        other.map(|c| c as char)
                    )))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Value, Error> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(fields)),
                other => {
                    return Err(Error::new(format!(
                        "expected `,` or `}}` in object, found {:?}",
                        other.map(|c| c as char)
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, Error> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(Error::new("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs: try to combine, else replace.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bytes[self.pos..].starts_with(b"\\u") {
                                self.pos += 2;
                                let lo = self.hex4()?;
                                let combined =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo.wrapping_sub(0xDC00));
                                out.push(char::from_u32(combined).unwrap_or('\u{FFFD}'));
                            } else {
                                out.push('\u{FFFD}');
                            }
                        } else {
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                    }
                    other => {
                        return Err(Error::new(format!(
                            "invalid escape {:?}",
                            other.map(|c| c as char)
                        )))
                    }
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Re-decode the UTF-8 sequence starting at `b`.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = (start + len).min(self.bytes.len());
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.pos = end;
                        }
                        Err(_) => return Err(Error::new("invalid UTF-8 in string")),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, Error> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| Error::new("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| Error::new("bad \\u escape"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, Error> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let tok = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| Error::new("invalid number token"))?;
        if !is_float {
            if let Ok(i) = tok.parse::<i128>() {
                return Ok(Value::Int(i));
            }
        }
        tok.parse::<f64>()
            .map(Value::Float)
            .map_err(|_| Error::new(format!("invalid number `{tok}`")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(v: &Value) {
        assert_eq!(&parse(&to_string(v)).unwrap(), v);
        assert_eq!(&parse(&to_string_pretty(v)).unwrap(), v);
    }

    #[test]
    fn scalars_round_trip() {
        round_trip(&Value::Null);
        round_trip(&Value::Bool(true));
        round_trip(&Value::Int(0));
        round_trip(&Value::Int(-42));
        round_trip(&Value::Int(u64::MAX as i128));
        round_trip(&Value::Float(0.6));
        round_trip(&Value::Str("hello \"world\"\n\ttab\\slash".into()));
        round_trip(&Value::Str("unicode: é 中 🚀".into()));
    }

    #[test]
    fn containers_round_trip() {
        let v = Value::Obj(vec![
            ("a".into(), Value::Arr(vec![Value::Int(1), Value::Int(2)])),
            ("empty_arr".into(), Value::Arr(vec![])),
            ("empty_obj".into(), Value::Obj(vec![])),
            (
                "nested".into(),
                Value::Obj(vec![("x".into(), Value::Float(1.5)), ("y".into(), Value::Null)]),
            ),
        ]);
        round_trip(&v);
    }

    #[test]
    fn parses_standard_json() {
        let v = parse(r#"{ "k": [1, 2.5, true, null, "s"], "n": -3 }"#).unwrap();
        assert_eq!(v.get("n"), Some(&Value::Int(-3)));
        let arr = v.get("k").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 5);
        assert_eq!(arr[1], Value::Float(2.5));
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(parse(r#""\u0041""#).unwrap(), Value::Str("A".into()));
        // surrogate pair for U+1F600
        assert_eq!(parse(r#""\ud83d\ude00""#).unwrap(), Value::Str("\u{1F600}".into()));
        assert_eq!(parse(r#""\n\t\"\\""#).unwrap(), Value::Str("\n\t\"\\".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn integers_stay_exact() {
        let big = 9_007_199_254_740_993i128; // 2^53 + 1: not representable in f64
        match parse(&big.to_string()).unwrap() {
            Value::Int(i) => assert_eq!(i, big),
            other => panic!("expected Int, got {other:?}"),
        }
    }
}
