//! Minimal offline stand-in for the `serde` + `serde_json` crates.
//!
//! The repository's environment has no network access and no vendored
//! registry, so persistence (the QoR knowledge base) runs on this small
//! serialization framework instead of real serde:
//!
//! * [`Serialize`] / [`Deserialize`] — the trait pair, implemented for
//!   primitives, `String`, `Vec<T>`, `Option<T>` and
//!   `BTreeMap<String, V>` here, and implemented by hand for the host
//!   crate's types (manual impls stand in for `#[derive(Serialize,
//!   Deserialize)]`, which would need a proc-macro crate);
//! * [`json::Value`] — a JSON document model with exact integers
//!   (`i128`) so `u64` cycle counts survive round-trips bit-exactly;
//! * [`json::parse`] / [`json::to_string`] / [`json::to_string_pretty`]
//!   — a recursive-descent parser and a writer.
//!
//! The API is intentionally value-based (`serialize(&self) -> Value`)
//! rather than visitor-based: the QoR database is small (hundreds of
//! records) and debuggability beats zero-copy here.

pub mod json;

pub use json::{parse, to_string, to_string_pretty, Value};

use std::collections::BTreeMap;
use std::fmt;

/// Serialization error (also used by [`Deserialize`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error(String);

impl Error {
    pub fn new<S: Into<String>>(msg: S) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Types that can render themselves as a JSON [`Value`].
pub trait Serialize {
    fn serialize(&self) -> Value;
}

/// Types that can be reconstructed from a JSON [`Value`].
pub trait Deserialize: Sized {
    fn deserialize(v: &Value) -> Result<Self, Error>;
}

// ---- primitive impls ---------------------------------------------------

impl Serialize for bool {
    fn serialize(&self) -> Value {
        Value::Bool(*self)
    }
}

impl Deserialize for bool {
    fn deserialize(v: &Value) -> Result<bool, Error> {
        v.as_bool().ok_or_else(|| Error::new(format!("expected bool, got {}", v.kind())))
    }
}

impl Serialize for u64 {
    fn serialize(&self) -> Value {
        Value::Int(*self as i128)
    }
}

impl Deserialize for u64 {
    fn deserialize(v: &Value) -> Result<u64, Error> {
        match v.as_int() {
            Some(i) if i >= 0 && i <= u64::MAX as i128 => Ok(i as u64),
            Some(i) => Err(Error::new(format!("integer {i} out of u64 range"))),
            None => Err(Error::new(format!("expected integer, got {}", v.kind()))),
        }
    }
}

impl Serialize for usize {
    fn serialize(&self) -> Value {
        Value::Int(*self as i128)
    }
}

impl Deserialize for usize {
    fn deserialize(v: &Value) -> Result<usize, Error> {
        let n = u64::deserialize(v)?;
        usize::try_from(n).map_err(|_| Error::new(format!("integer {n} out of usize range")))
    }
}

impl Serialize for i64 {
    fn serialize(&self) -> Value {
        Value::Int(*self as i128)
    }
}

impl Deserialize for i64 {
    fn deserialize(v: &Value) -> Result<i64, Error> {
        match v.as_int() {
            Some(i) if i >= i64::MIN as i128 && i <= i64::MAX as i128 => Ok(i as i64),
            Some(i) => Err(Error::new(format!("integer {i} out of i64 range"))),
            None => Err(Error::new(format!("expected integer, got {}", v.kind()))),
        }
    }
}

impl Serialize for f64 {
    fn serialize(&self) -> Value {
        Value::Float(*self)
    }
}

impl Deserialize for f64 {
    fn deserialize(v: &Value) -> Result<f64, Error> {
        v.as_f64().ok_or_else(|| Error::new(format!("expected number, got {}", v.kind())))
    }
}

impl Serialize for String {
    fn serialize(&self) -> Value {
        Value::Str(self.clone())
    }
}

impl Deserialize for String {
    fn deserialize(v: &Value) -> Result<String, Error> {
        v.as_str()
            .map(str::to_string)
            .ok_or_else(|| Error::new(format!("expected string, got {}", v.kind())))
    }
}

impl<T: Serialize> Serialize for Vec<T> {
    fn serialize(&self) -> Value {
        Value::Arr(self.iter().map(Serialize::serialize).collect())
    }
}

impl<T: Deserialize> Deserialize for Vec<T> {
    fn deserialize(v: &Value) -> Result<Vec<T>, Error> {
        let items = v
            .as_arr()
            .ok_or_else(|| Error::new(format!("expected array, got {}", v.kind())))?;
        items.iter().map(T::deserialize).collect()
    }
}

impl<T: Serialize> Serialize for Option<T> {
    fn serialize(&self) -> Value {
        match self {
            Some(t) => t.serialize(),
            None => Value::Null,
        }
    }
}

impl<T: Deserialize> Deserialize for Option<T> {
    fn deserialize(v: &Value) -> Result<Option<T>, Error> {
        match v {
            Value::Null => Ok(None),
            other => T::deserialize(other).map(Some),
        }
    }
}

impl<V: Serialize> Serialize for BTreeMap<String, V> {
    fn serialize(&self) -> Value {
        // BTreeMap iteration order is sorted: the output is canonical.
        Value::Obj(self.iter().map(|(k, v)| (k.clone(), v.serialize())).collect())
    }
}

impl<V: Deserialize> Deserialize for BTreeMap<String, V> {
    fn deserialize(v: &Value) -> Result<BTreeMap<String, V>, Error> {
        let fields = v
            .as_obj()
            .ok_or_else(|| Error::new(format!("expected object, got {}", v.kind())))?;
        let mut out = BTreeMap::new();
        for (k, val) in fields {
            out.insert(k.clone(), V::deserialize(val)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_round_trip() {
        assert_eq!(u64::deserialize(&42u64.serialize()).unwrap(), 42);
        assert_eq!(u64::deserialize(&u64::MAX.serialize()).unwrap(), u64::MAX);
        assert!(bool::deserialize(&true.serialize()).unwrap());
        assert_eq!(f64::deserialize(&1.5f64.serialize()).unwrap(), 1.5);
        assert_eq!(String::deserialize(&"hi".to_string().serialize()).unwrap(), "hi");
        let v: Vec<u64> = vec![1, 2, 3];
        assert_eq!(Vec::<u64>::deserialize(&v.serialize()).unwrap(), v);
        let mut m = BTreeMap::new();
        m.insert("a".to_string(), 1u64);
        assert_eq!(BTreeMap::<String, u64>::deserialize(&m.serialize()).unwrap(), m);
    }

    #[test]
    fn type_errors_are_reported() {
        assert!(u64::deserialize(&Value::Str("x".into())).is_err());
        assert!(bool::deserialize(&Value::Int(1)).is_err());
        assert!(u64::deserialize(&Value::Int(-1)).is_err());
        assert!(Vec::<u64>::deserialize(&Value::Int(1)).is_err());
    }

    #[test]
    fn option_uses_null() {
        let some: Option<u64> = Some(3);
        let none: Option<u64> = None;
        assert_eq!(some.serialize(), Value::Int(3));
        assert_eq!(none.serialize(), Value::Null);
        assert_eq!(Option::<u64>::deserialize(&Value::Null).unwrap(), None);
        assert_eq!(Option::<u64>::deserialize(&Value::Int(3)).unwrap(), Some(3));
    }

    #[test]
    fn float_accepts_integer_tokens() {
        // `2.0` prints as `2` and must still deserialize as f64.
        assert_eq!(f64::deserialize(&Value::Int(2)).unwrap(), 2.0);
    }
}
