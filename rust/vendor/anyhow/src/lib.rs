//! Minimal offline stand-in for the `anyhow` crate.
//!
//! This environment has no network access and no vendored registry, so
//! the subset of `anyhow` the repository actually uses is reimplemented
//! here with the same names and semantics:
//!
//! * [`Error`] — an opaque error value carrying a message and a context
//!   chain (no backtraces, no source downcasting);
//! * [`Result`] — `Result<T, Error>` with a defaultable error type;
//! * [`anyhow!`] / [`bail!`] — format-style error construction;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! `Display` prints the outermost context (like real `anyhow`); the
//! alternate form `{:#}` prints the whole chain outermost-to-root
//! separated by `: `, and `Debug` prints the chain with a `Caused by:`
//! block, so `{e:#}` and `{e:?}` in the host crate behave familiarly.

use std::fmt;

/// `Result<T, anyhow::Error>` with a defaultable error parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a root message plus a stack of context strings
/// (innermost first).
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), context: Vec::new() }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.push(context.to_string());
        self
    }

    /// The context chain from outermost to the root message.
    fn chain(&self) -> impl Iterator<Item = &str> {
        self.context.iter().rev().map(String::as_str).chain(std::iter::once(self.msg.as_str()))
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let parts: Vec<&str> = self.chain().collect();
            write!(f, "{}", parts.join(": "))
        } else {
            let outer = self.context.last().map(String::as_str).unwrap_or(&self.msg);
            write!(f, "{outer}")
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut parts = self.chain();
        let outer = parts.next().unwrap_or("");
        write!(f, "{outer}")?;
        let rest: Vec<&str> = parts.collect();
        if !rest.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in rest.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which keeps this blanket conversion coherent and
// makes `?` work on any std error type.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the source chain as context entries.
        let mut msgs = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(&e);
        while let Some(err) = cur {
            msgs.push(err.to_string());
            cur = err.source();
        }
        let root = msgs.pop().unwrap_or_default();
        Error { msg: root, context: msgs.into_iter().rev().collect() }
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Context extension for `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e = anyhow!("root problem").context("while loading").context("during startup");
        assert_eq!(format!("{e}"), "during startup");
        assert_eq!(format!("{e:#}"), "during startup: while loading: root problem");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("root problem"), "{dbg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("file missing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert!(format!("{e:#}").contains("file missing"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "slot")).unwrap_err();
        assert_eq!(format!("{e}"), "missing slot");
    }

    #[test]
    fn bail_returns_early() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("nope: {}", 42);
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert_eq!(format!("{}", f(true).unwrap_err()), "nope: 42");
    }
}
