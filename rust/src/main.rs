//! `prometheus` — CLI for the Prometheus reproduction.
//!
//! Subcommands (hand-rolled parser; the environment has no clap):
//!
//! ```text
//! prometheus list                               list kernels (Table 5 data)
//! prometheus analyze  <kernel>                  task graph + fusion variants
//! prometheus optimize <kernel> [--onboard N --frac F] [--emit DIR] [--db FILE] [--jobs N]
//!                     [--fixed-fusion] [--quick] [--trace FILE]
//! prometheus report   [--kernels K,..] [--full] [--telemetry]
//!                                               chosen fusion per kernel (Table 9 shape)
//! prometheus batch    [--kernels K,..] [--scenarios S,..] [--db FILE] [--jobs N] [--trace FILE]
//! prometheus serve    [--db FILE] [--workers N] [--jobs N] [--queue N] [--quick]
//!                     [--metrics-every N] [--trace FILE]
//!                                               persistent daemon: NDJSON requests on stdin,
//!                                               responses on stdout, metrics on stderr
//! prometheus lint     [<kernel>|all] [--onboard N --frac F] [--full] [--jobs N] [--fixed-fusion]
//!                                               solve + independent static audit (DESIGN.md §12)
//! prometheus db       <FILE> [--verify]         QoR knowledge-base records + provenance
//!                                               (--verify re-audits every stored design)
//! prometheus compare  <kernel>                  all 6 frameworks (Table 3 shape)
//! prometheus codegen  <kernel> <dir>            emit HLS-C++ + host
//! prometheus validate <kernel> [--artifacts D]  PJRT functional check
//! prometheus validate-all [--artifacts D]       every lowered kernel
//! ```
//!
//! `--trace FILE` records the whole run — flow-phase spans, per-variant
//! solver counters, incumbent instants, FIFO stall attribution — and
//! writes Chrome trace-event JSON loadable in `chrome://tracing` /
//! Perfetto. See DESIGN.md §10.

use anyhow::{anyhow, Result};
use prometheus::analysis::audit;
use prometheus::analysis::fusion::{enumerate_fusions, fuse, fuse_with_plan};
use prometheus::analysis::reuse;
use prometheus::baselines::Framework;
use prometheus::coordinator::flow::{optimize_kernel, optimize_kernel_stored, OptimizeOptions};
use prometheus::dse::eval::GeometryCache;
use prometheus::dse::solver::{Scenario, SolverOptions};
use prometheus::hw::Device;
use prometheus::ir::{oracle, polybench};
use prometheus::report::{gfs, Table};
use prometheus::service::batch::{
    parse_model, parse_scenario, run_batch, BatchOptions, BatchRequest,
};
use prometheus::service::serve::{serve_lines, Daemon, ServeOptions};
use prometheus::service::{QorDb, QorStore};
use std::path::PathBuf;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
}

/// Re-audit one stored QoR record from first principles (`db --verify`).
///
/// Returns the audit-column cell text and whether the record is illegal
/// (and should fail the exit code). Canonical keys are
/// `kernel|device|scenario|model|...`, so the scenario is re-parsed from
/// the key to audit under the same resource budget the record was
/// solved for.
fn audit_record(
    key: &str,
    rec: &prometheus::service::qor_db::QorRecord,
    dev: &Device,
) -> (String, bool) {
    let Some(k) = polybench::by_name(&rec.design.kernel) else {
        return ("unknown kernel".into(), true);
    };
    let scenario = match key.split('|').nth(2).map(parse_scenario) {
        Some(Ok(s)) => s,
        _ => return ("unparsable key".into(), true),
    };
    // A fusion plan the current analyzer rejects means the record
    // predates a legality fix — stale, never warm-start from it.
    let fg = match fuse_with_plan(&k, &rec.design.fusion) {
        Ok(fg) => fg,
        Err(e) => return (format!("stale plan: {e}"), true),
    };
    let cache = GeometryCache::new(&k, &fg);
    let diags = audit::audit_design(&k, &fg, &cache, &rec.design, dev, scenario);
    let errors = diags.iter().filter(|d| d.severity == audit::Severity::Error).count();
    let warnings = diags.len() - errors;
    match (errors, warnings) {
        (0, 0) => ("clean".into(), false),
        (0, w) => (format!("clean ({w} warning(s))"), false),
        (e, _) => (format!("{e} error(s)"), true),
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let dev = Device::u55c();

    match cmd {
        "list" => {
            let mut t = Table::new(&["Kernel", "Description", "Ops", "Mem", "Reuse", "FLOPs"]);
            for k in polybench::all_kernels() {
                t.row(vec![
                    k.name.clone(),
                    k.description.clone(),
                    reuse::ops_complexity(&k),
                    reuse::mem_complexity(&k),
                    reuse::reuse_order(&k).as_str().into(),
                    format!("{:.1}M", k.total_flops() as f64 / 1e6),
                ]);
            }
            print!("{}", t.render());
        }
        "analyze" => {
            let name = args.get(1).ok_or_else(|| anyhow!("usage: analyze <kernel>"))?;
            let k = polybench::by_name(name).ok_or_else(|| anyhow!("unknown kernel {name}"))?;
            let fg = fuse(&k);
            println!(
                "kernel `{}`: {} statements, {} fused tasks (max fusion)",
                k.name,
                k.statements.len(),
                fg.tasks.len()
            );
            for t in &fg.tasks {
                println!("  FT{}: stmts {:?} -> output `{}`", t.id, t.stmts, t.output);
            }
            for (s, d, a) in &fg.edges {
                println!("  FIFO FT{s} --{a}--> FT{d}");
            }
            println!("inter-task traffic: {} elements", fg.inter_task_elems(&k));
            let variants = enumerate_fusions(&k);
            println!("legal fusion variants: {}", variants.len());
            for (vi, plan) in variants.iter().enumerate() {
                // ranged parts print as {Sj[lo:hi], ...}: the part fuses
                // over that slice of the shared outer loop, the leftover
                // iterations peel into prologue/epilogue tasks
                let tag = if vi == 0 {
                    " (max fusion)"
                } else if plan.has_ranges() {
                    " (partial/loop-range)"
                } else {
                    ""
                };
                println!("  variant {vi}{tag}: {}", plan.part_strings().join(" "));
            }
        }
        "optimize" => {
            let name = args.get(1).ok_or_else(|| anyhow!("usage: optimize <kernel>"))?;
            let scenario = match flag_value(&args, "--onboard") {
                Some(n) => Scenario::OnBoard {
                    slrs: n.parse()?,
                    frac: flag_value(&args, "--frac")
                        .map(|f| f.parse())
                        .transpose()?
                        .unwrap_or(0.6),
                },
                None => Scenario::Rtl,
            };
            // --trace FILE: record the full lifecycle and write Chrome
            // trace-event JSON. Tracing starts before the solver options
            // are built so `SolverOptions::telemetry` defaults on.
            let trace_path = flag_value(&args, "--trace").map(PathBuf::from);
            if trace_path.is_some() {
                prometheus::obs::start_trace();
            }
            // Intra-solve worker threads: --jobs beats $PROMETHEUS_JOBS
            // beats 1 (the solver's default). The answer is identical
            // for any jobs value — only the solve time changes.
            let mut solver = if args.iter().any(|a| a == "--quick") {
                prometheus::coordinator::flow::quick_solver()
            } else {
                SolverOptions::default()
            };
            solver.telemetry = solver.telemetry || trace_path.is_some();
            if let Some(j) = flag_value(&args, "--jobs") {
                solver.jobs = j.parse()?;
            }
            // --fixed-fusion pins today's max output-stationary fusion
            // (fusion is explored as a design dimension by default)
            if args.iter().any(|a| a == "--fixed-fusion") {
                solver.explore_fusion = false;
            }
            let opts = OptimizeOptions {
                scenario,
                solver,
                emit_dir: flag_value(&args, "--emit").map(PathBuf::from),
                artifacts_dir: flag_value(&args, "--artifacts").map(PathBuf::from),
            };
            let r = match flag_value(&args, "--db").map(PathBuf::from) {
                Some(db_path) => {
                    // Append-only store: a completed solve is fsync'd
                    // the moment it is recorded, so it survives e.g. an
                    // unwritable --emit dir without a save step.
                    let store = QorStore::open(&db_path)?;
                    let (r, status) = optimize_kernel_stored(name, &dev, &opts, &store)?;
                    println!(
                        "QoR DB {}: {} ({} records)",
                        db_path.display(),
                        status.as_str(),
                        store.len()
                    );
                    r
                }
                None => optimize_kernel(name, &dev, &opts)?,
            };
            println!(
                "kernel `{}`: {:.2} GF/s  ({} cycles, solve {:?}, {} points explored{})",
                name,
                r.gflops,
                r.sim.cycles,
                r.result.solve_time,
                r.result.explored,
                if r.result.timed_out { ", TIMED OUT" } else { "" }
            );
            println!(
                "  fusion: {}  ({} variant(s) explored)",
                r.fused.partition_string(),
                r.result.fusion_variants
            );
            for tc in &r.result.design.tasks {
                println!(
                    "  FT{}: perm {:?} intra {:?} padded {:?} II={} SLR{}",
                    tc.task, tc.perm, tc.intra, tc.padded_trip, tc.ii, tc.slr
                );
            }
            if let Some(b) = &r.board {
                println!(
                    "  board: bitstream={} fmax={:.0}MHz util={:.0}% time={:.2}ms",
                    if b.bitstream_ok { "OK" } else { "FAIL" },
                    b.fmhz,
                    b.peak_utilization * 100.0,
                    b.time_ms
                );
            }
            if let Some(err) = r.validation_rel_err {
                println!("  PJRT validation: max rel err {err:.2e}");
            }
            if r.result.telemetry.enabled {
                print!("{}", r.result.telemetry.render());
            }
            if let Some(path) = &trace_path {
                let (events, dropped) = prometheus::obs::stop_trace();
                prometheus::obs::write_chrome_trace(path, &events, dropped)?;
                println!("wrote Chrome trace ({} events) to {}", events.len(), path.display());
            }
        }
        "report" => {
            // Paper Table 9 shape: the fusion partition the solver
            // *chose* per kernel (`FTi = {Sj, ...}`), plus how many
            // legal variants it weighed. Quick solver knobs by default
            // (same space, smaller beam) — pass --full for the
            // default-strength search.
            let kernels: Vec<String> = match flag_value(&args, "--kernels").as_deref() {
                None | Some("all") => {
                    polybench::all_kernels().iter().map(|k| k.name.clone()).collect()
                }
                Some(list) => list.split(',').map(str::to_string).collect(),
            };
            let scenario = match flag_value(&args, "--onboard") {
                Some(n) => Scenario::OnBoard {
                    slrs: n.parse()?,
                    frac: flag_value(&args, "--frac")
                        .map(|f| f.parse())
                        .transpose()?
                        .unwrap_or(0.6),
                },
                None => Scenario::Rtl,
            };
            let mut solver = if args.iter().any(|a| a == "--full") {
                SolverOptions::default()
            } else {
                prometheus::coordinator::flow::quick_solver()
            };
            solver.scenario = scenario;
            if let Some(j) = flag_value(&args, "--jobs") {
                solver.jobs = j.parse()?;
            }
            // --telemetry: collect per-solve counters and print a second,
            // observability-shaped table next to the QoR one.
            let want_telemetry = args.iter().any(|a| a == "--telemetry");
            solver.telemetry = solver.telemetry || want_telemetry;
            let mut t = Table::new(&["Kernel", "Chosen fusion", "Variants", "GF/s"]);
            let mut tt = Table::new(&[
                "Kernel",
                "Enumerated",
                "Enum-pruned",
                "DFS nodes",
                "Leaves",
                "Bound-pruned",
                "Symmetry-pruned",
                "Model-pruned",
                "Beam-starved",
                "Prune rates b/s/r/m",
                "Stage-1 starved",
                "Deadline-killed",
                "Incumbents",
            ]);
            // per-variant prune partition, printed under the totals table
            let mut variant_lines: Vec<String> = Vec::new();
            for name in &kernels {
                let k = polybench::by_name(name)
                    .ok_or_else(|| anyhow!("unknown kernel {name}"))?;
                match prometheus::dse::solver::solve(&k, &dev, &solver) {
                    Ok(r) => {
                        // scenario-consistent throughput (board-derated
                        // for on-board), matching what `optimize`
                        // reports for the same design
                        let sim = prometheus::sim::engine::simulate(&k, &r.fused, &r.design, &dev);
                        let (_, gf) = prometheus::coordinator::flow::scenario_eval(
                            &k, &r.fused, &r.design, &dev, scenario, &sim,
                        );
                        t.row(vec![
                            name.clone(),
                            r.fused.partition_string(),
                            r.fusion_variants.to_string(),
                            gfs(gf),
                        ]);
                        if want_telemetry {
                            let c = r.telemetry.totals();
                            let (b, s, rr, m) = c.prune_rates();
                            tt.row(vec![
                                name.clone(),
                                c.enumerated.to_string(),
                                c.enum_pruned.to_string(),
                                c.dfs_nodes.to_string(),
                                c.leaves_simulated.to_string(),
                                c.bound_pruned.to_string(),
                                c.symmetry_pruned.to_string(),
                                c.model_pruned.to_string(),
                                c.beam_starved.to_string(),
                                format!("{b:.0}/{s:.0}/{rr:.0}/{m:.0}%"),
                                format!("{:.0}%", c.stage1_prune_rate()),
                                c.deadline_killed.to_string(),
                                r.telemetry.incumbents.len().to_string(),
                            ]);
                            for (vi, v) in r.telemetry.variants.iter().enumerate() {
                                let (b, s, rr, m) = v.prune_rates();
                                variant_lines.push(format!(
                                    "  {name} variant {vi}: {b:.1}% bound / {s:.1}% symmetry / \
                                     {rr:.1}% resource / {m:.1}% model pruned; {} beam-starved; \
                                     {} enum-pruned ({:.1}% of stage 1)",
                                    v.beam_starved,
                                    v.enum_pruned,
                                    v.stage1_prune_rate()
                                ));
                            }
                        }
                    }
                    Err(e) => {
                        t.row(vec![
                            name.clone(),
                            format!("error: {e}"),
                            "-".into(),
                            "-".into(),
                        ]);
                        if want_telemetry {
                            let mut row = vec![name.clone()];
                            row.extend((0..12).map(|_| "-".to_string()));
                            tt.row(row);
                        }
                    }
                };
            }
            print!("{}", t.render());
            if want_telemetry {
                println!("solver telemetry (totals across fusion variants):");
                print!("{}", tt.render());
                println!("prune partition per fusion variant:");
                for line in &variant_lines {
                    println!("{line}");
                }
            }
        }
        "batch" => {
            // Request set = kernels × scenarios × models (the service
            // layer's traffic shape). Defaults exercise the Table 6 zoo
            // subset on the RTL scenario.
            let kernels: Vec<String> = match flag_value(&args, "--kernels").as_deref() {
                None => vec!["gemm".into(), "2mm".into(), "3mm".into(), "bicg".into()],
                Some("all") => polybench::all_kernels().iter().map(|k| k.name.clone()).collect(),
                Some(list) => list.split(',').map(str::to_string).collect(),
            };
            let scenarios: Vec<Scenario> = flag_value(&args, "--scenarios")
                .unwrap_or_else(|| "rtl".into())
                .split(',')
                .map(parse_scenario)
                .collect::<Result<_>>()?;
            let models = flag_value(&args, "--models")
                .unwrap_or_else(|| "dataflow".into())
                .split(',')
                .map(parse_model)
                .collect::<Result<Vec<_>>>()?;
            let mut requests = Vec::new();
            for k in &kernels {
                for &s in &scenarios {
                    for &m in &models {
                        let mut r = BatchRequest::new(k, s);
                        r.model = m;
                        requests.push(r);
                    }
                }
            }
            let trace_path = flag_value(&args, "--trace").map(PathBuf::from);
            if trace_path.is_some() {
                prometheus::obs::start_trace();
            }
            let quick = args.iter().any(|a| a == "--quick");
            let mut opts = BatchOptions::default();
            if quick {
                opts.solver = prometheus::coordinator::flow::quick_solver();
            }
            opts.solver.telemetry = opts.solver.telemetry || trace_path.is_some();
            if let Some(j) = flag_value(&args, "--jobs") {
                opts.jobs = j.parse()?;
            }
            let db_path = flag_value(&args, "--db").map(PathBuf::from);
            let store = match &db_path {
                Some(p) => QorStore::open(p)?,
                None => QorStore::in_memory(),
            };
            let preloaded = store.len();
            // Each worker appends its record (fsync'd) as it completes,
            // so a partially-failed batch keeps its finished solves
            // with no save step to reach.
            let result = run_batch(&requests, &dev, &store, &opts);
            match &db_path {
                Some(p) => {
                    println!(
                        "QoR DB {}: {} records ({} loaded, {} new)",
                        p.display(),
                        store.len(),
                        preloaded,
                        // saturating: evicted-then-failed stale records
                        // can shrink the db below its loaded size
                        store.len().saturating_sub(preloaded)
                    );
                }
                None => println!(
                    "QoR DB: in-memory only ({} records) — pass --db FILE to persist",
                    store.len()
                ),
            }
            let report = result?;
            print!("{}", report.render());
            print!("{}", report.metrics());
            if let Some(path) = &trace_path {
                let (events, dropped) = prometheus::obs::stop_trace();
                prometheus::obs::write_chrome_trace(path, &events, dropped)?;
                println!("wrote Chrome trace ({} events) to {}", events.len(), path.display());
            }
            // The summary prints even for a partially-failed batch —
            // completed solves were kept and reported above — but the
            // exit code still flags the failures.
            println!("{}", report.summary());
            if report.failed > 0 {
                return Err(anyhow!(
                    "{} of {} batch requests failed (see FAILED rows above)",
                    report.failed,
                    report.outcomes.len()
                ));
            }
        }
        "serve" => {
            // Long-running daemon: newline-delimited JSON requests on
            // stdin, one JSON response line per request on stdout (in
            // submission order), periodic metrics tables on stderr.
            // State — fusion spaces, geometry caches, the QoR store —
            // persists for the process lifetime, so repeated and
            // related requests get cheaper over time.
            let trace_path = flag_value(&args, "--trace").map(PathBuf::from);
            if trace_path.is_some() {
                prometheus::obs::start_trace();
            }
            let mut sopts = ServeOptions::default();
            if args.iter().any(|a| a == "--quick") {
                sopts.solver = prometheus::coordinator::flow::quick_solver();
            }
            sopts.solver.telemetry = sopts.solver.telemetry || trace_path.is_some();
            if let Some(j) = flag_value(&args, "--jobs") {
                sopts.jobs = j.parse()?;
            }
            if let Some(w) = flag_value(&args, "--workers") {
                sopts.workers = w.parse()?;
            }
            if let Some(q) = flag_value(&args, "--queue") {
                sopts.queue_capacity = q.parse()?;
            }
            if let Some(m) = flag_value(&args, "--metrics-every") {
                sopts.metrics_every = m.parse()?;
            }
            let store = match flag_value(&args, "--db").map(PathBuf::from) {
                Some(p) => QorStore::open(&p)?,
                None => QorStore::in_memory(),
            };
            let daemon = Daemon::new(dev.clone(), store, sopts);
            let stdin = std::io::stdin();
            let stdout = std::io::stdout();
            let metrics = serve_lines(daemon, stdin.lock(), &mut stdout.lock())?;
            if let Some(path) = &trace_path {
                let (events, dropped) = prometheus::obs::stop_trace();
                prometheus::obs::write_chrome_trace(path, &events, dropped)?;
                eprintln!(
                    "wrote Chrome trace ({} events) to {}",
                    events.len(),
                    path.display()
                );
            }
            if metrics.failed > 0 {
                return Err(anyhow!(
                    "{} request(s) failed (see the response stream)",
                    metrics.failed
                ));
            }
        }
        "lint" => {
            // Independent static audit (DESIGN.md §12): solve each
            // kernel, then re-verify the winning design from first
            // principles — dependence preservation under the chosen
            // permutation/tiling/fusion, FIFO deadlock-freedom and
            // rate balance, resource budgets, and a structural lint
            // of the emitted HLS. The exit code fails iff any
            // Error-severity diagnostic fires; warnings are reported
            // but do not fail the run.
            let kernels: Vec<String> = match args.get(1).map(String::as_str) {
                None | Some("all") => {
                    polybench::all_kernels().iter().map(|k| k.name.clone()).collect()
                }
                // `lint --jobs 4` etc: flags in kernel position mean "all"
                Some(s) if s.starts_with("--") => {
                    polybench::all_kernels().iter().map(|k| k.name.clone()).collect()
                }
                Some(name) => vec![name.to_string()],
            };
            let scenario = match flag_value(&args, "--onboard") {
                Some(n) => Scenario::OnBoard {
                    slrs: n.parse()?,
                    frac: flag_value(&args, "--frac")
                        .map(|f| f.parse())
                        .transpose()?
                        .unwrap_or(0.6),
                },
                None => Scenario::Rtl,
            };
            // Quick solver knobs by default (same space, smaller
            // beam) — the audit verdict is about the *emitted*
            // design, whichever strength found it. --full for the
            // default-strength search.
            let mut solver = if args.iter().any(|a| a == "--full") {
                SolverOptions::default()
            } else {
                prometheus::coordinator::flow::quick_solver()
            };
            solver.scenario = scenario;
            if let Some(j) = flag_value(&args, "--jobs") {
                solver.jobs = j.parse()?;
            }
            if args.iter().any(|a| a == "--fixed-fusion") {
                solver.explore_fusion = false;
            }
            let mut t = Table::new(&["Kernel", "Code", "Severity", "Location", "Message"]);
            let (mut errors, mut warnings) = (0usize, 0usize);
            for name in &kernels {
                let k = polybench::by_name(name)
                    .ok_or_else(|| anyhow!("unknown kernel {name}"))?;
                match prometheus::dse::solver::solve(&k, &dev, &solver) {
                    Ok(r) => {
                        let cache = GeometryCache::new(&k, &r.fused);
                        let diags =
                            audit::audit_all(&k, &r.fused, &cache, &r.design, &dev, scenario);
                        let e =
                            diags.iter().filter(|d| d.severity == audit::Severity::Error).count();
                        let w = diags.len() - e;
                        errors += e;
                        warnings += w;
                        println!(
                            "{name}: {} ({e} error(s), {w} warning(s))",
                            if e == 0 { "clean" } else { "ILLEGAL" }
                        );
                        for d in &diags {
                            t.row(vec![
                                name.clone(),
                                d.code.to_string(),
                                d.severity.to_string(),
                                d.location.clone(),
                                d.message.clone(),
                            ]);
                        }
                    }
                    Err(e) => {
                        errors += 1;
                        println!("{name}: SOLVE FAILED");
                        t.row(vec![
                            name.clone(),
                            "-".into(),
                            "error".into(),
                            "solver".into(),
                            format!("solve failed: {e}"),
                        ]);
                    }
                }
            }
            if errors + warnings > 0 {
                print!("{}", t.render());
            }
            println!("lint: {} kernel(s), {errors} error(s), {warnings} warning(s)", kernels.len());
            if errors > 0 {
                return Err(anyhow!(
                    "{errors} audit error(s) across {} kernel(s)",
                    kernels.len()
                ));
            }
        }
        "db" => {
            // Knowledge-base introspection: every record with its QoR
            // *and* its provenance (how trustworthy the stored answer
            // is: explored points, fusion variants weighed, warm/cold,
            // truncation).
            //
            // `--verify` additionally re-audits every record's stored
            // design from first principles (DESIGN.md §12): unknown
            // kernels, stale fusion plans, and designs failing
            // `audit_design` count as illegal and fail the exit code,
            // so a corrupt knowledge base is caught before it
            // warm-starts future solves.
            let path = PathBuf::from(
                args.get(1)
                    .map(String::as_str)
                    .ok_or_else(|| anyhow!("usage: db <FILE> [--verify]"))?,
            );
            let verify = args.iter().any(|a| a == "--verify");
            let db = QorDb::load(&path);
            if db.is_empty() {
                println!(
                    "{}: no records (missing, corrupt, or pre-v{} file)",
                    path.display(),
                    prometheus::service::qor_db::FORMAT_VERSION
                );
            } else {
                let mut headers = vec![
                    "Key",
                    "Cycles",
                    "GF/s",
                    "Solve ms",
                    "Explored",
                    "Variants",
                    "Start",
                    "Truncated",
                ];
                if verify {
                    headers.push("Audit");
                }
                let mut t = Table::new(&headers);
                let mut illegal = 0usize;
                for (key, rec) in db.iter() {
                    let mut row = vec![
                        key.to_string(),
                        rec.latency_cycles.to_string(),
                        gfs(rec.gflops),
                        format!("{:.1}", rec.solve_time_ms),
                        rec.explored.to_string(),
                        rec.fusion_variants.to_string(),
                        if rec.warm_started { "warm" } else { "cold" }.to_string(),
                        if rec.timed_out { "yes" } else { "no" }.to_string(),
                    ];
                    if verify {
                        let (cell, bad) = audit_record(key, rec, &dev);
                        if bad {
                            illegal += 1;
                        }
                        row.push(cell);
                    }
                    t.row(row);
                }
                print!("{}", t.render());
                if verify {
                    println!(
                        "{} records (format v{}), {illegal} illegal",
                        db.len(),
                        prometheus::service::qor_db::FORMAT_VERSION
                    );
                    if illegal > 0 {
                        return Err(anyhow!(
                            "{illegal} of {} records failed the static audit",
                            db.len()
                        ));
                    }
                } else {
                    println!(
                        "{} records (format v{})",
                        db.len(),
                        prometheus::service::qor_db::FORMAT_VERSION
                    );
                }
            }
        }
        "compare" => {
            let name = args.get(1).ok_or_else(|| anyhow!("usage: compare <kernel>"))?;
            let k = polybench::by_name(name).ok_or_else(|| anyhow!("unknown kernel {name}"))?;
            let mut t = Table::new(&["Framework", "GF/s", "Solve time"]);
            for fw in Framework::all() {
                if !fw.supports_triangular() && prometheus::baselines::streamhls::unsupported(&k)
                {
                    t.row(vec![fw.name().into(), "N/A".into(), "-".into()]);
                    continue;
                }
                let r = fw.optimize(&k, &dev);
                t.row(vec![fw.name().into(), gfs(r.gflops), format!("{:.2?}", r.solve_time)]);
            }
            print!("{}", t.render());
        }
        "codegen" => {
            let name = args.get(1).ok_or_else(|| anyhow!("usage: codegen <kernel> <dir>"))?;
            let dir = args.get(2).ok_or_else(|| anyhow!("usage: codegen <kernel> <dir>"))?;
            let opts = OptimizeOptions {
                emit_dir: Some(PathBuf::from(dir)),
                ..OptimizeOptions::default()
            };
            optimize_kernel(name, &dev, &opts)?;
            println!("wrote HLS-C++ and host sources to {dir}");
        }
        "validate" => {
            let name = args.get(1).ok_or_else(|| anyhow!("usage: validate <kernel>"))?;
            let root = PathBuf::from(
                flag_value(&args, "--artifacts").unwrap_or_else(|| "artifacts".into()),
            );
            let exe = prometheus::runtime::Executor::load(&root, name)?;
            let err = exe.validate()?;
            println!("{name}: platform {} max rel err {err:.2e}", exe.platform());
            if err > 1e-3 {
                return Err(anyhow!("{name}: validation failed (err {err:.2e})"));
            }
        }
        "validate-all" => {
            let root = PathBuf::from(
                flag_value(&args, "--artifacts").unwrap_or_else(|| "artifacts".into()),
            );
            let mut failures = 0;
            for k in oracle::validated_kernels() {
                if !prometheus::runtime::artifact_path(&root, k).exists() {
                    println!("{k}: SKIP (no artifact — run `make artifacts`)");
                    continue;
                }
                let exe = prometheus::runtime::Executor::load(&root, k)?;
                let err = exe.validate()?;
                let ok = err <= 1e-3;
                println!("{k}: max rel err {err:.2e} {}", if ok { "OK" } else { "FAIL" });
                if !ok {
                    failures += 1;
                }
            }
            if failures > 0 {
                return Err(anyhow!("{failures} kernels failed validation"));
            }
        }
        _ => {
            println!(
                "prometheus — Holistic Optimization Framework for FPGA Accelerators (reproduction)\n\
                 \n\
                 usage: prometheus <command>\n\
                 \x20 list                                 kernel zoo (Table 5 data)\n\
                 \x20 analyze  <kernel>                    task graph + legal fusion variants\n\
                 \x20 optimize <kernel> [--onboard N --frac F] [--emit DIR] [--artifacts D] [--db FILE]\n\
                 \x20          [--jobs N] [--fixed-fusion] [--quick] [--trace FILE]\n\
                 \x20                                      --jobs = intra-solve worker threads;\n\
                 \x20                                      --fixed-fusion pins max fusion;\n\
                 \x20                                      --trace writes Chrome trace-event JSON\n\
                 \x20 report [--kernels K,..|all] [--onboard N --frac F] [--full] [--jobs N] [--telemetry]\n\
                 \x20                                      chosen fusion partition per kernel\n\
                 \x20                                      (paper Table 9 `FTi = {{Sj, ...}}` format;\n\
                 \x20                                      partial fusion prints `FTi = {{Sj[lo:hi], ...}}`;\n\
                 \x20                                      --telemetry adds solver counters per kernel)\n\
                 \x20 batch [--kernels K,..|all] [--scenarios rtl,onboard:N:F,..]\n\
                 \x20       [--models dataflow,sequential] [--db FILE] [--jobs N] [--quick] [--trace FILE]\n\
                 \x20                                      parallel batch service + QoR knowledge base\n\
                 \x20                                      (--jobs = total cores, split between\n\
                 \x20                                      requests and intra-solve workers);\n\
                 \x20                                      prints a service-metrics table and fails\n\
                 \x20                                      the exit code if any request failed\n\
                 \x20 serve [--db FILE] [--workers N] [--jobs N] [--queue N] [--quick]\n\
                 \x20       [--metrics-every N] [--trace FILE]\n\
                 \x20                                      persistent optimization daemon: NDJSON\n\
                 \x20                                      requests on stdin ({{\"kernel\":\"gemm\",\n\
                 \x20                                      \"scenario\":\"onboard:3:0.6\"}}), one JSON\n\
                 \x20                                      response line per request on stdout,\n\
                 \x20                                      metrics tables on stderr; dedups identical\n\
                 \x20                                      in-flight requests, answers repeats from\n\
                 \x20                                      the store, sheds load when the queue fills\n\
                 \x20 lint [<kernel>|all] [--onboard N --frac F] [--full] [--jobs N] [--fixed-fusion]\n\
                 \x20                                      solve, then independently re-verify the\n\
                 \x20                                      winning design: dependences, FIFO\n\
                 \x20                                      deadlock-freedom, budgets, HLS structure\n\
                 \x20                                      (PA0xx diagnostics, DESIGN.md §12);\n\
                 \x20                                      nonzero exit on any error-severity finding\n\
                 \x20 db <FILE> [--verify]                 QoR knowledge-base records + solve provenance;\n\
                 \x20                                      --verify re-audits every stored design and\n\
                 \x20                                      fails the exit code on illegal records\n\
                 \x20 compare  <kernel>                    all frameworks (Table 3/6 shape)\n\
                 \x20 codegen  <kernel> <dir>              emit HLS-C++ + OpenCL host\n\
                 \x20 validate <kernel> [--artifacts D]    PJRT functional check\n\
                 \x20 validate-all [--artifacts D]         all lowered kernels"
            );
        }
    }
    Ok(())
}
