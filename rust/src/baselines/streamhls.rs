//! Stream-HLS [9] — automatic dataflow generation with good loop-order
//! selection for streaming, but (Table 1 / §2.3): assumes data on-chip —
//! the paper's evaluation adds the off-chip transfers back without data
//! packing — no computation/communication overlap, no padding, and
//! multi-FIFO intra-task parallelism that does not generalize to off-chip
//! banks. It cannot handle non-constant (triangular) trip counts at all
//! (Table 6's N/A rows: symm, syr2k, syrk, trmm).

use crate::dse::config::ExecutionModel;
use crate::dse::solver::{solve, SolverOptions, SolverResult};
use crate::hw::Device;
use crate::ir::Kernel;

/// Kernels with triangular nests Stream-HLS rejects.
pub fn unsupported(k: &Kernel) -> bool {
    matches!(k.name.as_str(), "symm" | "syr2k" | "syrk" | "trmm")
}

/// Stream-HLS's effective device: off-chip access without packing is
/// limited to one 64-bit beat per cycle per stream.
fn unpacked_device(dev: &Device) -> Device {
    Device { max_bus_bits: 64, ..dev.clone() }
}

/// Solver restrictions implementing Stream-HLS's space.
pub fn options() -> SolverOptions {
    SolverOptions {
        model: ExecutionModel::Dataflow, // its core strength
        overlap: false,                  // no ping-pong double buffering
        max_pad: 0,
        permute: true, // picks streaming-friendly loop orders
        tiling: true,  // "Limit": multi-FIFO parallelism ≈ modest tiling
        max_factor_per_loop: 64,
        max_unroll: 2048,
        // fuses greedily once, never explores fusion (Table 1)
        explore_fusion: false,
        ..SolverOptions::default()
    }
}

/// Optimize `k` under Stream-HLS's restrictions (RTL scenario).
/// Returns `None` for kernels it cannot compile.
pub fn try_optimize(k: &Kernel, dev: &Device) -> Option<SolverResult> {
    if unsupported(k) {
        return None;
    }
    Some(
        solve(k, &unpacked_device(dev), &options())
            .expect("the full-device RTL baseline space is always feasible"),
    )
}

/// Panicking variant for kernels known to be supported.
pub fn optimize(k: &Kernel, dev: &Device) -> SolverResult {
    try_optimize(k, dev)
        .unwrap_or_else(|| panic!("Stream-HLS cannot handle {} (non-constant bounds)", k.name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    #[test]
    fn triangular_kernels_rejected() {
        let dev = Device::u55c();
        for name in ["symm", "syr2k", "syrk", "trmm"] {
            assert!(try_optimize(&polybench::by_name(name).unwrap(), &dev).is_none());
        }
        assert!(try_optimize(&polybench::gemm(), &dev).is_some());
    }

    #[test]
    fn dataflow_but_no_packing() {
        let dev = Device::u55c();
        let k = polybench::three_mm();
        let sh = optimize(&k, &dev);
        let ours = solve(&k, &dev, &SolverOptions::default()).unwrap();
        // Stream-HLS is competitive on compute-bound kernels (paper:
        // 174 vs 368 GF/s) but strictly below Prometheus.
        assert!(sh.gflops < ours.gflops);
        assert!(sh.gflops > ours.gflops / 20.0, "sh {} vs ours {}", sh.gflops, ours.gflops);
    }
}
