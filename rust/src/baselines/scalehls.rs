//! ScaleHLS [81] — MLIR-based multi-level transformation with heuristic
//! directives: permutes by fixed rules (reduction outermost), assumes
//! data on-chip, enumerates pragma configurations against a
//! computation-only cost model (Table 1: objective = Comp). The paper's
//! Table 6 shows two regimes: modest throughput on regular kernels
//! (gemm ≈ 40 GF/s) and a collapse on triangular kernels (symm/syr2k/
//! syrk/trmm ≈ 0.06–0.27 GF/s) where its dependence analysis fails to
//! pipeline the loop nest and the II explodes.

use crate::dse::config::ExecutionModel;
use crate::dse::solver::{solve, SolverOptions, SolverResult};
use crate::hw::Device;
use crate::ir::Kernel;

/// Triangular kernels where ScaleHLS's pipelining analysis collapses.
pub fn ii_collapse(k: &Kernel) -> bool {
    matches!(k.name.as_str(), "symm" | "syr2k" | "syrk" | "trmm")
}

/// No data packing: 32-bit off-chip beats.
fn unpacked_device(dev: &Device) -> Device {
    Device { max_bus_bits: 32, ..dev.clone() }
}

/// Solver restrictions implementing ScaleHLS's space.
pub fn options(k: &Kernel) -> SolverOptions {
    SolverOptions {
        model: ExecutionModel::Sequential,
        overlap: false,
        max_pad: 0,
        permute: false, // heuristic fixed order, not explored
        tiling: true,   // "Limit"
        max_factor_per_loop: 32,
        max_unroll: if ii_collapse(k) { 1 } else { 256 },
        // fixed fusion: ScaleHLS does not co-optimize task fusion
        explore_fusion: false,
        ..SolverOptions::default()
    }
}

/// Optimize `k` under ScaleHLS's restrictions (RTL scenario).
pub fn optimize(k: &Kernel, dev: &Device) -> SolverResult {
    let mut r = solve(k, &unpacked_device(dev), &options(k))
        .expect("the full-device RTL baseline space is always feasible");
    if ii_collapse(k) {
        // failed dependence analysis: the reduction pipeline falls to a
        // serial II ≈ 40 (the paper's Sisyphus-mvt anecdote reports the
        // same compiler behaviour at II = 36). Re-score the design.
        for tc in &mut r.design.tasks {
            tc.ii = 40;
        }
        let fg = crate::analysis::fusion::fuse(k);
        let lat = crate::dse::cost::graph_latency(k, &fg, &r.design, dev);
        r.gflops = crate::dse::cost::gflops(k, lat.total, dev);
        r.latency = lat;
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    #[test]
    fn collapse_list_matches_table6() {
        assert!(ii_collapse(&polybench::symm()));
        assert!(ii_collapse(&polybench::trmm()));
        assert!(!ii_collapse(&polybench::gemm()));
    }

    #[test]
    fn triangular_collapse_is_severe() {
        // Table 6: ScaleHLS syrk = 0.27 GF/s vs Prometheus 158 GF/s.
        let dev = Device::u55c();
        let k = polybench::syrk();
        let sc = optimize(&k, &dev);
        let ours = solve(&k, &dev, &SolverOptions::default()).unwrap();
        assert!(
            ours.gflops > sc.gflops * 50.0,
            "expected collapse: ours {} vs scalehls {}",
            ours.gflops,
            sc.gflops
        );
    }

    #[test]
    fn regular_kernels_modest() {
        let dev = Device::u55c();
        let k = polybench::gemm();
        let sc = optimize(&k, &dev);
        assert!(sc.gflops > 1.0, "gemm should still work: {}", sc.gflops);
        let ours = solve(&k, &dev, &SolverOptions::default()).unwrap();
        assert!(ours.gflops > sc.gflops);
    }
}
