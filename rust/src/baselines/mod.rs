//! Baseline framework strategies (paper §6.2–6.3).
//!
//! Each baseline is re-implemented as a *restriction* of the unified
//! design space, scored by the same cost model and simulator, so the
//! comparison isolates exactly what the paper compares: the optimization
//! strategy. Table 1 is the specification of each restriction:
//!
//! | framework   | tiling | permute | dataflow | overlap | packing | padding |
//! |-------------|--------|---------|----------|---------|---------|---------|
//! | AutoDSE     |   ✗    |    ✗    |    ✗     |    ✗    |    ✓    |    ✗    |
//! | Sisyphus    |   ✓    |    ✓    |    ✗     |    ✗    |    ✓    |    ✗    |
//! | Stream-HLS  | limit  |    ✓    |    ✓     |    ✗    |    ✗    |    ✗    |
//! | ScaleHLS    | limit  |  limit  |    ✗     |    ✗    |    ✗    |    ✗    |
//! | Allo        |   ✗    |    ✓    |    ✓     |    ✗    |    ✗    |    ✗    |

pub mod allo;
pub mod autodse;
pub mod scalehls;
pub mod sisyphus;
pub mod streamhls;

use crate::dse::solver::SolverResult;
use crate::hw::Device;
use crate::ir::Kernel;

/// The frameworks compared in Tables 3/6.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framework {
    Prometheus,
    Sisyphus,
    StreamHls,
    ScaleHls,
    Allo,
    AutoDse,
}

impl Framework {
    pub fn name(self) -> &'static str {
        match self {
            Framework::Prometheus => "Prometheus",
            Framework::Sisyphus => "Sisyphus",
            Framework::StreamHls => "Stream-HLS",
            Framework::ScaleHls => "ScaleHLS",
            Framework::Allo => "Allo",
            Framework::AutoDse => "AutoDSE",
        }
    }

    /// All frameworks in Table 6 column order.
    pub fn all() -> [Framework; 6] {
        [
            Framework::Prometheus,
            Framework::Sisyphus,
            Framework::ScaleHls,
            Framework::Allo,
            Framework::AutoDse,
            Framework::StreamHls,
        ]
    }

    /// Whether the framework handles kernels with non-constant (triangular)
    /// trip counts — Stream-HLS does not (Table 6's N/A rows).
    pub fn supports_triangular(self) -> bool {
        !matches!(self, Framework::StreamHls)
    }

    /// Run the framework's strategy on `k` for the RTL scenario.
    pub fn optimize(self, k: &Kernel, dev: &Device) -> SolverResult {
        match self {
            Framework::Prometheus => {
                crate::dse::solver::solve(k, dev, &crate::dse::solver::SolverOptions::default())
                    .expect("the full-device RTL space is always feasible")
            }
            Framework::Sisyphus => sisyphus::optimize(k, dev),
            Framework::StreamHls => streamhls::optimize(k, dev),
            Framework::ScaleHls => scalehls::optimize(k, dev),
            Framework::Allo => allo::optimize(k, dev),
            Framework::AutoDse => autodse::optimize(k, dev),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    #[test]
    fn framework_inventory() {
        assert_eq!(Framework::all().len(), 6);
        assert!(!Framework::StreamHls.supports_triangular());
        assert!(Framework::Sisyphus.supports_triangular());
    }

    #[test]
    fn prometheus_wins_on_3mm() {
        // Table 3's headline: Prometheus > Sisyphus > Stream-HLS >> rest.
        let k = polybench::three_mm();
        let dev = Device::u55c();
        let ours = Framework::Prometheus.optimize(&k, &dev);
        let sis = Framework::Sisyphus.optimize(&k, &dev);
        let auto = Framework::AutoDse.optimize(&k, &dev);
        assert!(ours.gflops > sis.gflops, "{} !> {}", ours.gflops, sis.gflops);
        assert!(sis.gflops > auto.gflops, "{} !> {}", sis.gflops, auto.gflops);
    }
}
