//! Allo [15] — a composable programming model whose artifact kernels use
//! fixed, hand-written schedules (no DSE; the paper uses the PLDI'24
//! artifact designs directly). The published schedules follow one
//! pattern: keep the original structure, place the reduction loop
//! outermost-pipelined or innermost-pipelined, fully unroll a
//! non-reduction loop, stream between kernels via dataflow. Without
//! tiling the on-chip working set limits how much of a 2-D array can be
//! buffered, so matrices fall back to row-granular streaming.

use crate::dse::config::ExecutionModel;
use crate::dse::solver::{solve, SolverOptions, SolverResult};
use crate::hw::Device;
use crate::ir::Kernel;

/// No data packing in the artifact kernels (Table 1).
fn unpacked_device(dev: &Device) -> Device {
    Device { max_bus_bits: 64, ..dev.clone() }
}

/// Solver restrictions implementing Allo's fixed-schedule space: no
/// tiling (a loop is either fully unrolled or left rolled — exactly the
/// `s.unroll(...)` schedules of the artifact), permutation allowed
/// (schedules choose loop order), dataflow across kernels.
pub fn options() -> SolverOptions {
    SolverOptions {
        model: ExecutionModel::Dataflow,
        overlap: false,
        max_pad: 0,
        permute: true,
        tiling: false, // all-or-nothing unroll, the artifact style
        max_unroll: 1024,
        // schedules are per-kernel; fusion is fixed, not explored
        explore_fusion: false,
        ..SolverOptions::default()
    }
}

/// Optimize `k` under Allo's restrictions (RTL scenario).
pub fn optimize(k: &Kernel, dev: &Device) -> SolverResult {
    solve(k, &unpacked_device(dev), &options())
        .expect("the full-device RTL baseline space is always feasible")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    #[test]
    fn all_or_nothing_unroll() {
        let dev = Device::u55c();
        let k = polybench::bicg();
        let r = optimize(&k, &dev);
        let fg = crate::analysis::fusion::fuse(&k);
        for tc in &r.design.tasks {
            let rep = fg.tasks[tc.task].representative(&k);
            for (p, l) in k.statements[rep].loops.iter().enumerate() {
                assert!(
                    tc.intra[p] == 1 || tc.intra[p] == l.trip,
                    "partial tile {} of {} leaked into Allo",
                    tc.intra[p],
                    l.trip
                );
            }
        }
    }

    #[test]
    fn competitive_on_memory_bound_weak_on_compute_bound() {
        // Paper: bicg 14.17 (close to Prometheus 15.41), gemm 37.5 (far
        // from 419).
        let dev = Device::u55c();
        let ours_opts = SolverOptions::default();
        let bicg = polybench::bicg();
        let gemm = polybench::gemm();
        let allo_bicg = optimize(&bicg, &dev);
        let ours_bicg = solve(&bicg, &dev, &ours_opts).unwrap();
        let allo_gemm = optimize(&gemm, &dev);
        let ours_gemm = solve(&gemm, &dev, &ours_opts).unwrap();
        let gap_bicg = ours_bicg.gflops / allo_bicg.gflops.max(1e-9);
        let gap_gemm = ours_gemm.gflops / allo_gemm.gflops.max(1e-9);
        assert!(gap_gemm > gap_bicg, "gemm gap {gap_gemm} !> bicg gap {gap_bicg}");
    }
}
