//! AutoDSE [69] — Merlin-based, model-free bottleneck DSE over pragmas
//! only. No code transformation (no tiling/permutation/padding), no
//! dataflow; every configuration is evaluated by invoking the HLS
//! compiler, so the search is slow and plateaus early — the paper runs it
//! with a 1,000-minute budget and still reports the weakest QoR of
//! Table 6 (pragma insertion without restructuring cannot expose enough
//! parallelism, §2.3).
//!
//! Model: single-region sequential execution, original loop order, unroll
//! factors restricted to divisors of the *original* trips, and a search
//! plateau: the bottleneck heuristic explores one pragma at a time, so
//! the reachable unroll product shrinks as the number of statements grows
//! (each statement's pragmas compete for the same HLS-run budget).

use crate::dse::config::ExecutionModel;
use crate::dse::solver::{solve, Scenario, SolverOptions, SolverResult};
use crate::hw::Device;
use crate::ir::Kernel;

/// The unroll plateau of the bottleneck search: a generous budget for
/// single-statement kernels, fragmenting across statements (the paper's
/// 3mm/2mm AutoDSE rows collapse to ≈0.4–1.7 GF/s while gemm reaches
/// ≈110 GF/s).
fn plateau_unroll(k: &Kernel) -> u64 {
    let compute_stmts = k
        .statements
        .iter()
        .filter(|s| {
            s.kind == crate::ir::StmtKind::Compute && s.ops.total() > 0 && s.loops.len() >= 2
        })
        .count() as u64;
    match compute_stmts {
        0 | 1 => 512,
        2 => 32,
        _ => 8,
    }
}

/// Solver restrictions implementing AutoDSE's space.
pub fn options(k: &Kernel) -> SolverOptions {
    SolverOptions {
        model: ExecutionModel::Sequential,
        overlap: false,
        max_pad: 0,
        permute: false, // no code transformation
        tiling: true,   // Merlin's `cache`/burst generation tiles for it
        max_unroll: plateau_unroll(k),
        max_factor_per_loop: 64,
        // pragma insertion only — no code transformation, no fusion DSE
        explore_fusion: false,
        ..SolverOptions::default()
    }
}

/// Optimize `k` under AutoDSE's restrictions (RTL scenario).
pub fn optimize(k: &Kernel, dev: &Device) -> SolverResult {
    solve(k, dev, &options(k)).expect("the full-device RTL baseline space is always feasible")
}

/// On-board: AutoDSE is single-SLR (the paper had to cap it at 15% for
/// 3mm to close timing).
pub fn optimize_onboard(k: &Kernel, dev: &Device, frac: f64) -> SolverResult {
    solve(
        k,
        dev,
        &SolverOptions {
            scenario: Scenario::OnBoard { slrs: 1, frac },
            ..options(k)
        },
    )
    .expect("the Table 8 on-board fractions are feasible for the AutoDSE space")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    #[test]
    fn plateau_shrinks_with_statements() {
        assert_eq!(plateau_unroll(&polybench::gemm()), 512);
        assert_eq!(plateau_unroll(&polybench::two_mm()), 32);
        assert_eq!(plateau_unroll(&polybench::three_mm()), 8);
    }

    #[test]
    fn autodse_far_below_prometheus_on_multi_mm() {
        let dev = Device::u55c();
        let k = polybench::two_mm();
        let auto = optimize(&k, &dev);
        let ours = solve(&k, &dev, &SolverOptions::default()).unwrap();
        assert!(
            ours.gflops > auto.gflops * 10.0,
            "expected ≫: {} vs {}",
            ours.gflops,
            auto.gflops
        );
    }

    #[test]
    fn original_loop_order_kept() {
        let dev = Device::u55c();
        let k = polybench::gemm();
        let r = optimize(&k, &dev);
        // permutation disabled -> identity order of the first legal order
        assert_eq!(r.design.tasks[0].perm, vec![0, 1, 2]);
    }
}
