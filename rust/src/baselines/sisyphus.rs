//! Sisyphus [62] — the authors' previous NLP framework: unified code
//! transformation + pragma insertion over a *shared-buffer, single-task*
//! execution model. Differences from Prometheus it cannot express
//! (Table 1): no dataflow concurrency, no computation/communication
//! overlap, no padding (unroll factors must divide trip counts), single
//! SLR.
//!
//! For solution quality (Tables 3/6/7/8) we run the shared solver with
//! exactly those restrictions. For solve-*time* (Table 10) the structural
//! difference the paper highlights (§6.4) is reproduced by
//! [`joint_space_size`]/[`probe_solver_time`]: Sisyphus's shared-buffer
//! formulation couples every statement's permutation and tiling into one
//! joint problem (the product of per-statement spaces), whereas
//! Prometheus's dataflow decomposition keeps tasks separable — on 3mm the
//! joint space explodes and Gurobi times out after 4 h.

use crate::dse::config::ExecutionModel;
use crate::dse::padding::legal_intra_factors;
use crate::dse::permutation::legal_orders;
use crate::dse::solver::{solve, Scenario, SolverOptions, SolverResult};
use crate::hw::Device;
use crate::ir::Kernel;
use std::time::{Duration, Instant};

/// Solver restrictions implementing Sisyphus's space.
pub fn options() -> SolverOptions {
    SolverOptions {
        model: ExecutionModel::Sequential,
        // Sisyphus has no *dynamic* computation/communication overlap
        // (Table 1), but its Merlin-style burst transfers are pipelined
        // within each task — without this its measured 2× gap to
        // Prometheus on 3mm (179 vs 368 GF/s) would overshoot to 6×+.
        // What it structurally cannot do is dataflow task concurrency
        // (model = Sequential) and padding (max_pad = 0).
        overlap: true,
        max_pad: 0, // no padding: divisors of the original trips only
        permute: true,
        tiling: true,
        // none of the baselines co-optimize task fusion (Table 1)
        explore_fusion: false,
        ..SolverOptions::default()
    }
}

/// Optimize `k` under Sisyphus's restrictions (RTL scenario).
pub fn optimize(k: &Kernel, dev: &Device) -> SolverResult {
    solve(k, dev, &options()).expect("the full-device RTL baseline space is always feasible")
}

/// Optimize for an on-board scenario (Sisyphus is single-SLR only).
pub fn optimize_onboard(k: &Kernel, dev: &Device, frac: f64) -> SolverResult {
    solve(
        k,
        dev,
        &SolverOptions {
            scenario: Scenario::OnBoard { slrs: 1, frac },
            ..options()
        },
    )
    .expect("the Table 8 on-board fractions are feasible for the Sisyphus space")
}

/// Size of Sisyphus's *joint* shared-buffer space: the product over all
/// statements of (tile-factor combinations × legal permutations). This is
/// what the paper's §6.4 identifies as the 3mm blow-up.
pub fn joint_space_size(k: &Kernel, dev: &Device) -> f64 {
    let opts = options();
    let mut total = 1f64;
    for s in &k.statements {
        if s.loops.is_empty() {
            continue;
        }
        let mut per_stmt = legal_orders(s).len() as f64;
        for l in &s.loops {
            per_stmt *=
                legal_intra_factors(l.trip, 0, opts.max_factor_per_loop).len() as f64;
        }
        total *= per_stmt.max(1.0);
        let _ = dev;
    }
    total
}

/// Measured (or extrapolated) time for Sisyphus's joint formulation:
/// benchmark the evaluation rate on a slice of the joint space, then
/// extrapolate to the full size, capping at `timeout` — the Table 10
/// methodology. Returns (seconds, timed_out).
pub fn probe_solver_time(k: &Kernel, dev: &Device, timeout: Duration) -> (f64, bool) {
    let start = Instant::now();
    // measure per-point evaluation cost by running the restricted solver
    // (it shares the evaluation kernel with the joint formulation)
    let r = optimize(k, dev);
    let measured = start.elapsed().as_secs_f64();
    let rate = r.explored as f64 / measured.max(1e-6); // points/s
    let joint = joint_space_size(k, dev);
    // Gurobi's spatial branch-and-bound prunes aggressively; the classic
    // rule of thumb (and what reproduces the paper's 2mm=22s / symm=7s /
    // 3mm=timeout split) is that B&B visits ~sqrt of the joint space.
    let projected = joint.sqrt() / rate.max(1.0);
    if projected > timeout.as_secs_f64() {
        (timeout.as_secs_f64(), true)
    } else {
        // small joint spaces: the measured decomposed time dominates
        (projected.max(measured), false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    #[test]
    fn restrictions_apply() {
        let o = options();
        assert_eq!(o.model, ExecutionModel::Sequential);
        assert_eq!(o.max_pad, 0);
    }

    #[test]
    fn no_padding_in_designs() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let r = optimize(&k, &dev);
        for tc in &r.design.tasks {
            let rep = crate::analysis::fusion::fuse(&k).tasks[tc.task].representative(&k);
            for (p, l) in k.statements[rep].loops.iter().enumerate() {
                assert_eq!(tc.padded_trip[p], l.trip, "padding leaked into Sisyphus");
            }
        }
    }

    #[test]
    fn joint_space_explodes_on_3mm() {
        // §6.4: 3mm's joint space ≫ gemm's — the Table 10 timeout driver.
        let dev = Device::u55c();
        let s_gemm = joint_space_size(&polybench::gemm(), &dev);
        let s_3mm = joint_space_size(&polybench::three_mm(), &dev);
        assert!(s_3mm > s_gemm * 1e6, "3mm {s_3mm:.2e} vs gemm {s_gemm:.2e}");
    }

    #[test]
    fn probe_times_out_on_3mm_but_not_mvt() {
        let dev = Device::u55c();
        let t = Duration::from_secs(60);
        let (secs_3mm, to_3mm) = probe_solver_time(&polybench::three_mm(), &dev, t);
        assert!(to_3mm, "3mm should hit the joint-space timeout");
        assert!((secs_3mm - 60.0).abs() < 1e-9);
        let (_, to_mvt) = probe_solver_time(&polybench::mvt(), &dev, t);
        assert!(!to_mvt, "mvt joint space is small");
    }
}
