//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by the
//! python build layer (`python/compile/aot.py`) and executes them on the
//! PJRT CPU client — the functional half of the three-layer architecture.
//! Python never runs here; the artifacts are self-contained HLO text.

pub mod executor;

pub use executor::{artifact_path, Executor, KernelSpec};
