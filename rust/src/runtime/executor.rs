//! HLO artifact loading and execution.
//!
//! Interchange format is HLO **text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits 64-bit instruction ids that the crate's bundled XLA
//! (xla_extension 0.5.1) rejects; the text parser reassigns ids and
//! round-trips cleanly (see /opt/xla-example/README.md).

use std::path::{Path, PathBuf};

/// Input signature of one lowered kernel: (array ordinal, flattened
/// length). Ordinals follow `python/compile/model.py::inputs_for` so the
/// rust oracle and the JAX artifact see bit-identical inputs.
#[derive(Debug, Clone)]
pub struct KernelSpec {
    pub name: &'static str,
    /// (ordinal, elems) per input parameter, in lowering order.
    pub inputs: Vec<(u64, usize)>,
    /// Number of outputs in the result tuple.
    pub outputs: usize,
}

impl KernelSpec {
    /// Signature table for every kernel the AOT layer lowers. Must match
    /// `python/compile/model.py` exactly.
    pub fn known() -> Vec<KernelSpec> {
        let spec = |name: &'static str, inputs: Vec<(u64, usize)>, outputs: usize| KernelSpec {
            name,
            inputs,
            outputs,
        };
        vec![
            spec("gemm", vec![(0, 200 * 220), (1, 200 * 240), (2, 240 * 220)], 1),
            spec(
                "2mm",
                vec![(0, 180 * 210), (1, 210 * 190), (2, 190 * 220), (3, 180 * 220)],
                1,
            ),
            spec(
                "3mm",
                vec![(0, 180 * 200), (1, 200 * 190), (2, 190 * 220), (3, 220 * 210)],
                1,
            ),
            spec("atax", vec![(0, 390 * 410), (1, 410)], 1),
            spec("bicg", vec![(0, 390 * 410), (1, 390), (2, 410)], 2),
            spec("mvt", vec![(0, 400 * 400), (1, 400), (2, 400), (3, 400), (4, 400)], 2),
            spec("gesummv", vec![(0, 250 * 250), (1, 250 * 250), (2, 250)], 1),
            spec("madd", vec![(0, 400 * 400), (1, 400 * 400)], 1),
            spec("2-madd", vec![(0, 400 * 400), (1, 400 * 400), (2, 400 * 400)], 1),
            spec(
                "3-madd",
                vec![(0, 400 * 400), (1, 400 * 400), (2, 400 * 400), (3, 400 * 400)],
                1,
            ),
        ]
    }

    pub fn for_kernel(name: &str) -> Option<KernelSpec> {
        Self::known().into_iter().find(|s| s.name == name)
    }
}

/// Path of a kernel's HLO artifact under `root` (python writes
/// `artifacts/<kernel>.hlo.txt`; `-` is mapped to `_` for filenames).
pub fn artifact_path(root: &Path, kernel: &str) -> PathBuf {
    root.join(format!("{}.hlo.txt", kernel.replace('-', "_")))
}

/// Real PJRT-backed executor — needs the `xla` crate, which is not
/// available offline; enable with `--features pjrt` after adding the
/// dependency (see Cargo.toml and DESIGN.md §Dependencies).
#[cfg(feature = "pjrt")]
mod pjrt_executor {
    use super::{artifact_path, KernelSpec};
    use crate::ir::oracle;
    use anyhow::{anyhow, Context, Result};
    use std::path::Path;

    /// A compiled, ready-to-run kernel executable on the PJRT CPU client.
    pub struct Executor {
        client: xla::PjRtClient,
        exe: xla::PjRtLoadedExecutable,
        spec: KernelSpec,
    }

    impl Executor {
        /// The real PJRT runtime is compiled in.
        pub fn available() -> bool {
            true
        }

        /// Load and compile the artifact for `kernel` from `artifacts_root`.
        pub fn load(artifacts_root: &Path, kernel: &str) -> Result<Executor> {
            let spec = KernelSpec::for_kernel(kernel)
                .ok_or_else(|| anyhow!("no KernelSpec for {kernel}"))?;
            let path = artifact_path(artifacts_root, kernel);
            let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).context("XLA compile")?;
            Ok(Executor { client, exe, spec })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Execute on the deterministic inputs; returns one flat `Vec<f32>`
        /// per output.
        pub fn run(&self) -> Result<Vec<Vec<f32>>> {
            let lits: Vec<xla::Literal> = self
                .spec
                .inputs
                .iter()
                .map(|&(ord, len)| {
                    let data = oracle::input_array(ord, len);
                    xla::Literal::vec1(&data)
                })
                .collect();
            let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True
            let tuple = result.to_tuple()?;
            let mut outs = Vec::with_capacity(tuple.len());
            for lit in tuple {
                outs.push(lit.to_vec::<f32>()?);
            }
            if outs.len() != self.spec.outputs {
                return Err(anyhow!(
                    "{}: expected {} outputs, artifact returned {}",
                    self.spec.name,
                    self.spec.outputs,
                    outs.len()
                ));
            }
            Ok(outs)
        }

        /// Execute and compare against the rust oracle. Returns the max
        /// absolute relative error across all outputs.
        pub fn validate(&self) -> Result<f64> {
            let got = self.run()?;
            let expect = oracle::run(self.spec.name)
                .ok_or_else(|| anyhow!("no oracle for {}", self.spec.name))?;
            if got.len() != expect.bufs.len() {
                return Err(anyhow!(
                    "{}: artifact outputs {} vs oracle {}",
                    self.spec.name,
                    got.len(),
                    expect.bufs.len()
                ));
            }
            let mut max_rel = 0f64;
            for (g, e) in got.iter().zip(expect.bufs.iter()) {
                if g.len() != e.len() {
                    return Err(anyhow!(
                        "{}: output length {} vs oracle {}",
                        self.spec.name,
                        g.len(),
                        e.len()
                    ));
                }
                for (a, b) in g.iter().zip(e.iter()) {
                    let denom = b.abs().max(1.0);
                    max_rel = max_rel.max(((a - b).abs() / denom) as f64);
                }
            }
            Ok(max_rel)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_executor::Executor;

/// Offline stand-in compiled when the `pjrt` feature is off: same API,
/// every operation reports that the runtime is unavailable. Runtime
/// integration tests skip because no artifacts exist in this
/// environment; the rest of the flow (solver, simulator, codegen, QoR
/// service) is unaffected.
#[cfg(not(feature = "pjrt"))]
mod stub_executor {
    use super::{artifact_path, KernelSpec};
    use anyhow::{anyhow, bail, Result};
    use std::path::Path;

    /// Stub executor: construction always fails with a diagnostic.
    pub struct Executor {
        _spec: KernelSpec,
    }

    impl Executor {
        /// The runtime is stubbed out: callers with *optional* validation
        /// (the flow) should skip it rather than call `load` and fail.
        pub fn available() -> bool {
            false
        }

        pub fn load(artifacts_root: &Path, kernel: &str) -> Result<Executor> {
            let _spec = KernelSpec::for_kernel(kernel)
                .ok_or_else(|| anyhow!("no KernelSpec for {kernel}"))?;
            let path = artifact_path(artifacts_root, kernel);
            if !path.exists() {
                bail!("artifact {} not found (run `make artifacts`)", path.display());
            }
            bail!(
                "PJRT runtime not compiled in: rebuild with `--features pjrt` \
                 (requires the `xla` crate; see DESIGN.md §Dependencies)"
            )
        }

        pub fn platform(&self) -> String {
            "unavailable (pjrt feature off)".to_string()
        }

        pub fn run(&self) -> Result<Vec<Vec<f32>>> {
            bail!("PJRT runtime not compiled in (enable the `pjrt` feature)")
        }

        pub fn validate(&self) -> Result<f64> {
            bail!("PJRT runtime not compiled in (enable the `pjrt` feature)")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub_executor::Executor;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::oracle;

    #[test]
    fn specs_cover_validated_kernels() {
        for k in oracle::validated_kernels() {
            assert!(KernelSpec::for_kernel(k).is_some(), "missing spec for {k}");
        }
    }

    #[test]
    fn spec_shapes_match_oracle_inputs() {
        // bicg inputs: A[M*N], r[M], p[N]
        let s = KernelSpec::for_kernel("bicg").unwrap();
        assert_eq!(s.inputs, vec![(0, 390 * 410), (1, 390), (2, 410)]);
        assert_eq!(s.outputs, 2);
    }

    #[test]
    fn artifact_paths_are_filesystem_safe() {
        let p = artifact_path(Path::new("artifacts"), "3-madd");
        assert_eq!(p.to_str().unwrap(), "artifacts/3_madd.hlo.txt");
    }
}
