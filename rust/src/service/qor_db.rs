//! The QoR knowledge base: a persistent store of previously-solved
//! designs and their quality-of-result metrics.
#![deny(missing_docs)]
//!
//! CollectiveHLS-style amortization: the first time a (kernel, device,
//! scenario, execution model, solver knobs) point is optimized, the
//! winning [`DesignConfig`] and its QoR metrics are recorded under a
//! canonical [`DesignKey`]. Identical future requests are answered from
//! the store without touching the solver; *related* requests (same
//! kernel, different scenario/knobs) can seed the solver's
//! branch-and-bound bound through [`QorDb::incumbent_for`] →
//! `SolverOptions::incumbent`.
//!
//! On-disk format (JSON, written pretty so databases diff cleanly):
//!
//! ```text
//! { "format_version": 4,
//!   "records": { "<canonical key>": { "design": {..}, "latency_cycles": .., .. }, .. } }
//! ```
//!
//! Loading is forgiving by design: a missing, corrupt, or
//! wrong-version file yields an *empty* database (the cache refills),
//! never an error that would take the service down.

use crate::dse::config::{DesignConfig, ExecutionModel};
use crate::dse::solver::{Scenario, SolverOptions};
use crate::hw::Device;
use anyhow::{Context, Result};
use serde::{Deserialize, Serialize, Value};
use std::collections::BTreeMap;
use std::path::Path;

/// Version of the on-disk format. Bump on any incompatible change; old
/// files then fall back to an empty database instead of misparsing.
///
/// * v2: designs carry their fusion variant (`DesignConfig::fusion`)
///   and keys carry the `explore_fusion` solver knob — v1 records have
///   neither, so they were evicted wholesale by the version check.
/// * v3: fusion plans generalize to partial (loop-range) and
///   cross-array fusion — a plan part may carry a `[lo, hi)` range
///   whose peels materialize as extra tasks, and the explored space an
///   `explore_fusion` key weighed is strictly larger. A v2 record's
///   answer is therefore stale for the *same* canonical key, so v2
///   databases are evicted wholesale, exactly as v2 evicted v1.
/// * v4: records carry solve provenance — `warm_started` (did a prior
///   record seed the branch-and-bound bound?) and `fusion_variants`
///   (how many legal fusion variants the solve weighed). Provenance
///   qualifies a record's trustworthiness (a timed-out cold solve over
///   one variant is a weaker answer than an exhaustive warm one), so a
///   v3 record without it is evicted rather than back-filled with
///   guesses.
pub const FORMAT_VERSION: u64 = 4;

/// Everything that determines a solve's outcome, canonicalized.
///
/// Two requests with equal keys are the *same* optimization problem:
/// the cached answer is exact, not approximate. The solver's `incumbent`
/// (a warm-start hint) and `jobs` (worker threads; the solver's
/// determinism contract guarantees a thread-count-independent answer)
/// are deliberately excluded — they change solve speed, never the
/// problem.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignKey {
    /// Kernel name (the zoo is the namespace).
    pub kernel: String,
    /// Device name the solve targeted.
    pub device: String,
    /// Resource scenario (RTL or on-board regions).
    pub scenario: Scenario,
    /// Execution model of the solved design.
    pub model: ExecutionModel,
    /// Whether computation/communication overlap was enabled.
    pub overlap: bool,
    /// Padding bound (Eq 2; 0 = padding disabled).
    pub max_pad: u64,
    /// Whether loop permutation was explored.
    pub permute: bool,
    /// Whether data tiling was explored.
    pub tiling: bool,
    /// Cap on per-loop intra factors.
    pub max_factor_per_loop: u64,
    /// Cap on the task unroll factor.
    pub max_unroll: u64,
    /// Stage-1 beam width.
    pub beam: usize,
    /// Anytime timeout in milliseconds.
    pub timeout_ms: u128,
    /// Whether fusion was explored as a design dimension. Part of the
    /// key (it changes the answer); which *variant* won is not — that
    /// is recorded in the stored design itself, and the hit/warm-start
    /// gates bind a record to the variant its fusion plan realizes.
    pub explore_fusion: bool,
}

impl DesignKey {
    /// Key for optimizing `kernel` on `dev` under `opts`.
    pub fn new(kernel: &str, dev: &Device, opts: &SolverOptions) -> DesignKey {
        DesignKey {
            kernel: kernel.to_string(),
            device: dev.name.clone(),
            scenario: opts.scenario,
            model: opts.model,
            overlap: opts.overlap,
            max_pad: opts.max_pad,
            permute: opts.permute,
            tiling: opts.tiling,
            max_factor_per_loop: opts.max_factor_per_loop,
            max_unroll: opts.max_unroll,
            beam: opts.beam,
            timeout_ms: opts.timeout.as_millis(),
            explore_fusion: opts.explore_fusion,
        }
    }

    /// The canonical string form used as the store key. Deterministic:
    /// equal keys ⇔ equal strings.
    pub fn canonical(&self) -> String {
        let model = match self.model {
            ExecutionModel::Dataflow => "dataflow",
            ExecutionModel::Sequential => "sequential",
        };
        format!(
            "{}|{}|{}|{}|ov{}|pad{}|perm{}|tile{}|mfl{}|uf{}|beam{}|to{}|fuse{}",
            self.kernel,
            self.device,
            self.scenario,
            model,
            self.overlap as u8,
            self.max_pad,
            self.permute as u8,
            self.tiling as u8,
            self.max_factor_per_loop,
            self.max_unroll,
            self.beam,
            self.timeout_ms,
            self.explore_fusion as u8,
        )
    }
}

/// One stored answer: the winning design plus its QoR metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct QorRecord {
    /// The winning design (carries its own fusion plan).
    pub design: DesignConfig,
    /// Simulated total latency in cycles (the authoritative metric the
    /// solver selects by).
    pub latency_cycles: u64,
    /// Scenario-consistent throughput: board-model GF/s for on-board
    /// requests, simulated GF/s at the target clock for RTL — the same
    /// number the single-kernel flow reports for this request.
    pub gflops: f64,
    /// Wall time the original solve took, in milliseconds.
    pub solve_time_ms: f64,
    /// Design points the original solve explored.
    pub explored: u64,
    /// Whether the original solve hit its anytime timeout.
    pub timed_out: bool,
    /// Whether the original solve was warm-started: a prior record
    /// (from this store or an explicit `SolverOptions::incumbent`)
    /// actually seeded the branch-and-bound bound. A truncated
    /// (`timed_out`) cold record is the weakest provenance in the
    /// store; a warm, completed one the strongest.
    pub warm_started: bool,
    /// Legal fusion variants the original solve weighed (1 = fixed
    /// fusion). Together with `explored`/`timed_out` this says how much
    /// of the holistic space stands behind the stored answer.
    pub fusion_variants: u64,
}

impl QorRecord {
    /// Build the stored record for a completed solve: simulated cycles
    /// plus scenario-consistent GF/s (via
    /// [`crate::coordinator::flow::scenario_eval`]). `fg` must be the
    /// graph of the **design's own fusion variant** (`result.fused`).
    /// The single constructor both the cached flow and the batch
    /// orchestrator use, so cached metrics cannot drift between the two
    /// paths.
    pub fn from_solve(
        k: &crate::ir::Kernel,
        fg: &crate::analysis::fusion::FusedGraph,
        result: &crate::dse::solver::SolverResult,
        scenario: Scenario,
        dev: &Device,
    ) -> QorRecord {
        let cache = crate::dse::eval::GeometryCache::new(k, fg);
        QorRecord::from_solve_with_cache(k, fg, &cache, result, scenario, dev)
    }

    /// [`QorRecord::from_solve`] over a pre-built geometry cache: one
    /// resolution feeds both the simulation and the scenario GF/s. The
    /// batch orchestrator passes its shared per-kernel cache here so
    /// record construction does not silently re-resolve per job.
    pub fn from_solve_with_cache(
        k: &crate::ir::Kernel,
        fg: &crate::analysis::fusion::FusedGraph,
        cache: &crate::dse::eval::GeometryCache,
        result: &crate::dse::solver::SolverResult,
        scenario: Scenario,
        dev: &Device,
    ) -> QorRecord {
        let rd = crate::dse::eval::ResolvedDesign::new(k, fg, cache, &result.design);
        let sim = crate::sim::engine::simulate_resolved(&rd, dev);
        let (_, gflops) =
            crate::coordinator::flow::scenario_eval_resolved(&rd, dev, scenario, &sim);
        QorRecord::from_products(result, &sim, gflops)
    }

    /// [`QorRecord::from_solve`] with the evaluation products already in
    /// hand (the cached flow computes them anyway for its own report).
    pub fn from_products(
        result: &crate::dse::solver::SolverResult,
        sim: &crate::sim::engine::SimReport,
        gflops: f64,
    ) -> QorRecord {
        QorRecord {
            design: result.design.clone(),
            latency_cycles: sim.cycles,
            gflops,
            solve_time_ms: result.solve_time.as_secs_f64() * 1e3,
            explored: result.explored,
            timed_out: result.timed_out,
            warm_started: result.warm_started,
            fusion_variants: result.fusion_variants as u64,
        }
    }
}

impl Serialize for QorRecord {
    fn serialize(&self) -> Value {
        Value::Obj(vec![
            ("design".to_string(), self.design.serialize()),
            ("latency_cycles".to_string(), self.latency_cycles.serialize()),
            ("gflops".to_string(), self.gflops.serialize()),
            ("solve_time_ms".to_string(), self.solve_time_ms.serialize()),
            ("explored".to_string(), self.explored.serialize()),
            ("timed_out".to_string(), self.timed_out.serialize()),
            ("warm_started".to_string(), self.warm_started.serialize()),
            ("fusion_variants".to_string(), self.fusion_variants.serialize()),
        ])
    }
}

impl Deserialize for QorRecord {
    fn deserialize(v: &Value) -> Result<QorRecord, serde::Error> {
        Ok(QorRecord {
            design: DesignConfig::deserialize(v.field("design")?)?,
            latency_cycles: u64::deserialize(v.field("latency_cycles")?)?,
            gflops: f64::deserialize(v.field("gflops")?)?,
            solve_time_ms: f64::deserialize(v.field("solve_time_ms")?)?,
            explored: u64::deserialize(v.field("explored")?)?,
            timed_out: bool::deserialize(v.field("timed_out")?)?,
            warm_started: bool::deserialize(v.field("warm_started")?)?,
            fusion_variants: u64::deserialize(v.field("fusion_variants")?)?,
        })
    }
}

/// The knowledge base: canonical key → record.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QorDb {
    records: BTreeMap<String, QorRecord>,
}

impl QorDb {
    /// An empty knowledge base.
    pub fn new() -> QorDb {
        QorDb::default()
    }

    /// Number of stored records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether the store holds no records.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Exact-hit lookup.
    pub fn get(&self, key: &DesignKey) -> Option<&QorRecord> {
        self.records.get(&key.canonical())
    }

    /// Exact-hit lookup by canonical string.
    pub fn get_canonical(&self, key: &str) -> Option<&QorRecord> {
        self.records.get(key)
    }

    /// Insert `rec` under `key`, keeping the better (lower-latency)
    /// record if one is already present. Returns `true` if the store
    /// changed.
    pub fn insert(&mut self, key: &DesignKey, rec: QorRecord) -> bool {
        self.insert_canonical(key.canonical(), rec)
    }

    /// Insert under a pre-canonicalized key (the batch orchestrator
    /// carries canonical strings, not [`DesignKey`]s, across threads).
    pub fn insert_canonical(&mut self, key: String, rec: QorRecord) -> bool {
        match self.records.get(&key) {
            Some(old) if old.latency_cycles <= rec.latency_cycles => false,
            _ => {
                self.records.insert(key, rec);
                true
            }
        }
    }

    /// Drop a record (e.g. a stale design that no longer validates
    /// against the current kernel zoo).
    pub fn remove_canonical(&mut self, key: &str) -> Option<QorRecord> {
        self.records.remove(key)
    }

    /// Merge another database in, keeping the better record per key.
    pub fn merge(&mut self, other: QorDb) {
        for (k, rec) in other.records {
            self.insert_canonical(k, rec);
        }
    }

    /// Iterate (canonical key, record) pairs in key order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &QorRecord)> {
        self.records.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Best stored design for warm-starting a *different* request on the
    /// same kernel: lowest-latency record whose design matches the
    /// kernel, execution model and overlap mode (the structural axes the
    /// solver requires of an incumbent). Fusion-agnostic — prefer
    /// [`QorDb::incumbent_for_space`] when the solve's fusion space is
    /// known, so a record solved under a variant outside that space
    /// (e.g. a split-fusion design offered to a `--fixed-fusion` solve)
    /// does not shadow an older, compatible record. Either way the
    /// solver's usability gate is the final word: an incumbent whose
    /// plan is not in the space is rejected, never silently crossed.
    pub fn incumbent_for(
        &self,
        kernel: &str,
        model: ExecutionModel,
        overlap: bool,
    ) -> Option<&QorRecord> {
        self.incumbent_for_space(kernel, model, overlap, |_| true)
    }

    /// [`QorDb::incumbent_for`] restricted to designs whose fusion plan
    /// the caller's solve can actually use (`usable_plan` is typically
    /// `|p| space.variant_of(p).is_some()`): the best *compatible*
    /// record warm-starts the solve instead of being rejected at the
    /// gate while a usable one sits in the store.
    pub fn incumbent_for_space(
        &self,
        kernel: &str,
        model: ExecutionModel,
        overlap: bool,
        usable_plan: impl Fn(&crate::analysis::fusion::FusionPlan) -> bool,
    ) -> Option<&QorRecord> {
        self.records
            .values()
            .filter(|r| {
                r.design.kernel == kernel
                    && r.design.model == model
                    && r.design.overlap == overlap
                    && usable_plan(&r.design.fusion)
            })
            .min_by_key(|r| r.latency_cycles)
    }

    /// Render as a JSON value (the versioned envelope).
    pub fn to_value(&self) -> Value {
        Value::Obj(vec![
            ("format_version".to_string(), FORMAT_VERSION.serialize()),
            ("records".to_string(), self.records.serialize()),
        ])
    }

    /// Parse from a JSON value; errors on shape/version mismatch.
    pub fn from_value(v: &Value) -> Result<QorDb, serde::Error> {
        let version = u64::deserialize(v.field("format_version")?)?;
        if version != FORMAT_VERSION {
            return Err(serde::Error::new(format!(
                "unsupported QoR DB format_version {version} (expected {FORMAT_VERSION})"
            )));
        }
        Ok(QorDb { records: BTreeMap::deserialize(v.field("records")?)? })
    }

    /// Load from `path`. Missing, corrupt, or wrong-version files yield
    /// an empty database — the cache simply refills.
    ///
    /// Reads **both** on-disk layouts: the legacy whole-file JSON this
    /// module writes and the append-only log layout of
    /// [`super::store::QorStore`] (replayed read-only — the file is
    /// never modified, torn tail or not). The `db` subcommand and every
    /// other read-only consumer therefore work unchanged against either
    /// format.
    pub fn load(path: &Path) -> QorDb {
        let Ok(bytes) = std::fs::read(path) else {
            return QorDb::new();
        };
        super::store::read_any_layout(&bytes).unwrap_or_default()
    }

    /// Persist to `path` (pretty JSON, atomic via a sibling temp file).
    ///
    /// **Legacy writer** — whole-file save is last-writer-wins: two
    /// writers that load, mutate, and save will silently drop each
    /// other's records. Every concurrent path (daemon, batch) writes
    /// through [`super::store::QorStore`] instead, whose append-only
    /// log has no such hazard; this method remains for single-writer
    /// tools and tests, and *refuses* to overwrite a log-layout store
    /// (that would downgrade it back onto the hazard).
    ///
    /// Never clobbers a file that [`QorDb::load`] could not have read:
    /// `load` maps corrupt or newer-format files to an empty database,
    /// so blindly saving over them would turn "cannot read" into
    /// "destroy". Such files are moved aside to `<path>.bak` first.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        if let Ok(existing) = std::fs::read(path) {
            if super::store::is_log_layout(&existing) {
                anyhow::bail!(
                    "{} is an append-only QoR store (log layout); refusing to overwrite it \
                     with the legacy whole-file format — open it with QorStore instead",
                    path.display()
                );
            }
            let readable = std::str::from_utf8(&existing)
                .ok()
                .and_then(|t| serde::parse(t).and_then(|v| QorDb::from_value(&v)).ok())
                .is_some();
            if !readable {
                let bak = sibling(path, ".bak");
                std::fs::rename(path, &bak)
                    .with_context(|| format!("backing up unreadable db to {}", bak.display()))?;
                eprintln!(
                    "warning: {} was not a readable v{FORMAT_VERSION} QoR DB; moved to {}",
                    path.display(),
                    bak.display()
                );
            }
        }
        let text = serde::to_string_pretty(&self.to_value());
        let tmp = sibling(path, ".tmp");
        std::fs::write(&tmp, text).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path).with_context(|| format!("renaming to {}", path.display()))?;
        Ok(())
    }
}

/// `<path>.suffix` with the *full* file name kept (unlike
/// `Path::with_extension`, which would make `a.db` and `a.json` collide
/// on the same sibling). Shared with [`super::store`] for its
/// `.compact` temp files and `.bak` evictions.
pub(crate) fn sibling(path: &Path, suffix: &str) -> std::path::PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(suffix);
    path.with_file_name(name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::fusion::FusionPlan;
    use crate::dse::config::{TaskConfig, TransferPlan};

    fn sample_design(kernel: &str, latency_hint: u64) -> DesignConfig {
        let mut plans = BTreeMap::new();
        plans.insert(
            "A".to_string(),
            TransferPlan { define_level: 0, transfer_level: 1, bitwidth: 256, buffers: 2 },
        );
        DesignConfig {
            kernel: kernel.to_string(),
            model: ExecutionModel::Dataflow,
            overlap: true,
            fusion: FusionPlan::new(vec![vec![0]]),
            tasks: vec![TaskConfig {
                task: 0,
                perm: vec![0, 1],
                padded_trip: vec![latency_hint.max(2), 8],
                intra: vec![1, 2],
                ii: 3,
                plans,
                slr: 0,
            }],
        }
    }

    fn sample_record(kernel: &str, latency: u64) -> QorRecord {
        QorRecord {
            design: sample_design(kernel, latency),
            latency_cycles: latency,
            gflops: 123.25,
            solve_time_ms: 45.5,
            explored: 10_000,
            timed_out: false,
            warm_started: false,
            fusion_variants: 1,
        }
    }

    fn sample_key(kernel: &str) -> DesignKey {
        DesignKey::new(kernel, &Device::u55c(), &SolverOptions::default())
    }

    #[test]
    fn insert_keeps_the_better_record() {
        let mut db = QorDb::new();
        let key = sample_key("gemm");
        assert!(db.insert(&key, sample_record("gemm", 1000)));
        assert!(!db.insert(&key, sample_record("gemm", 2000)), "worse record must not replace");
        assert_eq!(db.get(&key).unwrap().latency_cycles, 1000);
        assert!(db.insert(&key, sample_record("gemm", 500)));
        assert_eq!(db.get(&key).unwrap().latency_cycles, 500);
    }

    #[test]
    fn merge_prefers_lower_latency() {
        let mut a = QorDb::new();
        let mut b = QorDb::new();
        let key = sample_key("gemm");
        let other = sample_key("bicg");
        a.insert(&key, sample_record("gemm", 1000));
        b.insert(&key, sample_record("gemm", 800));
        b.insert(&other, sample_record("bicg", 50));
        a.merge(b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.get(&key).unwrap().latency_cycles, 800);
        assert_eq!(a.get(&other).unwrap().latency_cycles, 50);
    }

    #[test]
    fn incumbent_matches_kernel_and_model() {
        let mut db = QorDb::new();
        let mut opts = SolverOptions::default();
        db.insert(&sample_key("gemm"), sample_record("gemm", 1000));
        opts.beam = 7; // different knobs, same kernel
        db.insert(&DesignKey::new("gemm", &Device::u55c(), &opts), sample_record("gemm", 700));
        db.insert(&sample_key("bicg"), sample_record("bicg", 10));
        let inc = db.incumbent_for("gemm", ExecutionModel::Dataflow, true).unwrap();
        assert_eq!(inc.latency_cycles, 700, "best matching record wins");
        assert!(db.incumbent_for("gemm", ExecutionModel::Sequential, true).is_none());
        assert!(db.incumbent_for("3mm", ExecutionModel::Dataflow, true).is_none());
    }

    #[test]
    fn incumbent_for_space_skips_incompatible_fusion_plans() {
        let mut db = QorDb::new();
        let mut opts = SolverOptions::default();
        db.insert(&sample_key("gemm"), sample_record("gemm", 1000)); // plan [[0]]
        opts.beam = 9;
        let mut fast = sample_record("gemm", 100);
        fast.design.fusion = FusionPlan::new(vec![vec![0], vec![1]]);
        db.insert(&DesignKey::new("gemm", &Device::u55c(), &opts), fast);
        // unrestricted: the faster (split-plan) record shadows
        let any = db.incumbent_for("gemm", ExecutionModel::Dataflow, true).unwrap();
        assert_eq!(any.latency_cycles, 100);
        // restricted to the solve's space: the compatible record warm
        // starts instead of being rejected at the solver gate
        let single = FusionPlan::new(vec![vec![0]]);
        let inc = db
            .incumbent_for_space("gemm", ExecutionModel::Dataflow, true, |p| p == &single)
            .unwrap();
        assert_eq!(inc.latency_cycles, 1000);
    }
}
