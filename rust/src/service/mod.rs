//! The serving layer (ROADMAP north-star): from one-shot optimization to
//! a production-shaped service.
//!
//! The paper's Prometheus flow optimizes a single kernel per invocation
//! and re-runs the full branch-and-bound every time. This module turns
//! that into a persistent optimization service in the CollectiveHLS /
//! AutoDSE-amortization mold:
//!
//! * [`qor_db`] — the **QoR knowledge base** schema: winning
//!   [`crate::dse::DesignConfig`]s plus their quality-of-result metrics,
//!   keyed by a canonical [`qor_db::DesignKey`] (kernel × device ×
//!   scenario × execution model × solver knobs), with a versioned
//!   on-disk record format. Repeat queries skip the solver entirely;
//!   related queries warm-start it (`SolverOptions::incumbent`).
//! * [`store`] — the **concurrent, durable store** for that schema: a
//!   sharded in-memory index over an append-only, fsync'd record log
//!   with crash-safe replay and background compaction. Many threads
//!   insert records concurrently without lost updates (the legacy
//!   whole-file `QorDb::save` is read-modify-write and racy).
//! * [`batch`] — a **parallel batch orchestrator**: fans a request set
//!   (kernel × scenario × model) out over a worker pool, deduplicates
//!   identical in-flight requests, consults the store before solving,
//!   and renders an aggregate QoR report through [`crate::report`].
//! * [`serve`] — the **long-running daemon**: a bounded admission
//!   queue feeding a worker pool, cross-request in-flight dedup,
//!   process-lifetime warm state (fusion spaces, geometry caches,
//!   store incumbents), and periodic metrics — driven over
//!   newline-delimited JSON by `prometheus serve`.
//!
//! The CLI exposes this as `prometheus batch`, `prometheus serve` (and
//! `prometheus optimize --db`); `benches/service_batch.rs` measures
//! cold vs. warm batch throughput.

pub mod batch;
pub mod qor_db;
pub mod serve;
pub mod store;

pub use batch::{run_batch, BatchOptions, BatchReport, BatchRequest};
pub use qor_db::{DesignKey, QorDb, QorRecord};
pub use serve::{serve_lines, Daemon, ServeMetrics, ServeOptions, SubmitError, Ticket};
pub use store::QorStore;
