//! The serving layer (ROADMAP north-star): from one-shot optimization to
//! a production-shaped service.
//!
//! The paper's Prometheus flow optimizes a single kernel per invocation
//! and re-runs the full branch-and-bound every time. This module turns
//! that into a batch-optimization service in the CollectiveHLS /
//! AutoDSE-amortization mold:
//!
//! * [`qor_db`] — a persistent **QoR knowledge base**: winning
//!   [`crate::dse::DesignConfig`]s plus their quality-of-result metrics,
//!   keyed by a canonical [`qor_db::DesignKey`] (kernel × device ×
//!   scenario × execution model × solver knobs), JSON-persisted with a
//!   versioned on-disk format. Repeat queries skip the solver entirely;
//!   related queries warm-start it (`SolverOptions::incumbent`).
//! * [`batch`] — a **parallel batch orchestrator**: fans a request set
//!   (kernel × scenario × model) out over a worker pool, deduplicates
//!   identical in-flight requests, consults the knowledge base before
//!   solving, and renders an aggregate QoR report through
//!   [`crate::report`].
//!
//! The CLI exposes this as `prometheus batch` (and `prometheus optimize
//! --db`); `benches/service_batch.rs` measures cold vs. warm batch
//! throughput.

pub mod batch;
pub mod qor_db;

pub use batch::{run_batch, BatchOptions, BatchReport, BatchRequest};
pub use qor_db::{DesignKey, QorDb, QorRecord};
