//! Parallel batch optimization over kernel × scenario × model request
//! sets, backed by the QoR knowledge base.
//!
//! The orchestrator is the service's hot path and is built for
//! production-shaped traffic:
//!
//! 1. **cache lookup** — every request is canonicalized to a
//!    [`DesignKey`]; exact hits are answered from the
//!    [`QorStore`](super::store::QorStore) without touching the solver;
//! 2. **deduplication** — identical in-flight requests collapse to one
//!    solve (a batch of `N` equal requests costs one solve, not `N`);
//! 3. **parallel fan-out** — the remaining unique misses are solved on a
//!    scoped worker pool (hand-rolled work queue over
//!    `std::thread::scope`; rayon is not vendored in this environment,
//!    matching the in-tree criterion/proptest stand-ins). The core
//!    budget ([`BatchOptions::jobs`]) is split between this
//!    inter-request pool and each solve's own intra-solve workers
//!    (`SolverOptions::jobs`), so both a wide batch and a single heavy
//!    miss saturate the machine. Each kernel's [`FusionSpace`] — every
//!    legal fusion variant, partial (loop-range) and cross-array
//!    variants included, with its fused graph and geometry cache — is
//!    built **once** up front; every worker job for that kernel shares
//!    the space, so parallel batch jobs skip both re-fusion and the
//!    configuration-independent re-resolution;
//! 4. **warm start** — each miss seeds the solver with the best related
//!    record ([`QorStore::incumbent_for_space`]), so even cold-ish
//!    solves prune against a known-good bound;
//! 5. **aggregate QoR report** — results render as a paper-shaped table
//!    through [`crate::report::Table`].
//!
//! Since the concurrent store landed, workers write each completed
//! solve straight into the [`QorStore`] (fsync'd append) instead of
//! handing records back for a caller-side whole-file save: a batch
//! interrupted halfway keeps every solve it finished, and two batches
//! against the same store file cannot lose each other's updates the
//! way the legacy load → merge → `QorDb::save` cycle could.

use super::qor_db::{DesignKey, QorRecord};
use super::store::QorStore;
use crate::dse::config::ExecutionModel;
use crate::dse::eval::FusionSpace;
use crate::dse::solver::{solve_space, Scenario, SolverOptions};
use crate::hw::Device;
use crate::ir::polybench;
use crate::ir::Kernel;
use crate::report::{gfs, Table};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Per-kernel shared context for one batch run: the kernel and its full
/// fusion space (every legal variant's fused graph + fusion-time
/// geometry cache), built once and shared (read-only) by every worker
/// job for that kernel.
struct KernelCtx {
    kernel: Kernel,
    space: FusionSpace,
}

/// One optimization request.
#[derive(Debug, Clone)]
pub struct BatchRequest {
    pub kernel: String,
    pub scenario: Scenario,
    pub model: ExecutionModel,
    pub overlap: bool,
}

impl BatchRequest {
    /// A dataflow/overlap (full-Prometheus) request.
    pub fn new(kernel: &str, scenario: Scenario) -> BatchRequest {
        BatchRequest {
            kernel: kernel.to_string(),
            scenario,
            model: ExecutionModel::Dataflow,
            overlap: true,
        }
    }

    /// Solver options for this request on top of the batch-wide base.
    pub fn solver_options(&self, base: &SolverOptions) -> SolverOptions {
        SolverOptions {
            scenario: self.scenario,
            model: self.model,
            overlap: self.overlap,
            incumbent: None,
            ..base.clone()
        }
    }

    /// Canonical cache key for this request.
    pub fn key(&self, dev: &Device, base: &SolverOptions) -> DesignKey {
        DesignKey::new(&self.kernel, dev, &self.solver_options(base))
    }
}

/// Parse `rtl` or `onboard:<slrs>:<frac>` (CLI scenario syntax; the
/// inverse of `Scenario`'s `Display`).
pub fn parse_scenario(s: &str) -> Result<Scenario> {
    if s == "rtl" {
        return Ok(Scenario::Rtl);
    }
    if let Some(rest) = s.strip_prefix("onboard:") {
        let mut parts = rest.split(':');
        let slrs = parts
            .next()
            .filter(|p| !p.is_empty())
            .ok_or_else(|| anyhow!("onboard scenario needs `<slrs>`: `{s}`"))?
            .parse::<usize>()
            .map_err(|e| anyhow!("bad SLR count in `{s}`: {e}"))?;
        let frac = match parts.next() {
            Some(f) => f.parse::<f64>().map_err(|e| anyhow!("bad fraction in `{s}`: {e}"))?,
            None => 0.6,
        };
        if parts.next().is_some() {
            bail!("trailing fields in scenario `{s}`");
        }
        if slrs == 0 {
            bail!("SLR count must be >= 1 in `{s}`");
        }
        if !frac.is_finite() || frac <= 0.0 || frac > 1.0 {
            bail!("utilization fraction must be in (0, 1], got `{frac}` in `{s}`");
        }
        return Ok(Scenario::OnBoard { slrs, frac });
    }
    bail!("unknown scenario `{s}` (expected `rtl` or `onboard:<slrs>:<frac>`)")
}

/// Parse `dataflow` or `sequential`.
pub fn parse_model(s: &str) -> Result<ExecutionModel> {
    match s {
        "dataflow" => Ok(ExecutionModel::Dataflow),
        "sequential" => Ok(ExecutionModel::Sequential),
        _ => bail!("unknown execution model `{s}` (expected `dataflow` or `sequential`)"),
    }
}

/// Batch-wide options.
#[derive(Debug, Clone)]
pub struct BatchOptions {
    /// Base solver knobs; each request overrides scenario/model/overlap.
    pub solver: SolverOptions,
    /// Total core budget for the batch, split between inter-request and
    /// intra-solve parallelism: with `U` unique misses the orchestrator
    /// runs `min(U, jobs)` request workers and gives each solve
    /// `jobs / workers` threads (`SolverOptions::jobs`; the division
    /// remainder is spread one-extra-thread over the first misses), so
    /// a batch of one request still saturates the machine through the
    /// solver's own stage-1/stage-3 fan-out. 0 means one worker.
    /// Results are thread-count independent (the solver's determinism
    /// contract), so the split never changes what lands in the
    /// knowledge base.
    pub jobs: usize,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions {
            solver: SolverOptions::default(),
            jobs: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        }
    }
}

/// How one request was answered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Source {
    /// Exact QoR-DB hit — no solve.
    Cache,
    /// Solved, warm-started from a related record.
    WarmSolve,
    /// Solved from scratch.
    ColdSolve,
    /// Collapsed onto an identical in-flight request's solve.
    Deduped,
    /// The solve failed (infeasible budget or a solver bug); the error
    /// text is on the outcome. Includes requests that deduped onto a
    /// failed solve — they got no answer either.
    Failed,
}

impl Source {
    pub fn as_str(self) -> &'static str {
        match self {
            Source::Cache => "cache",
            Source::WarmSolve => "warm solve",
            Source::ColdSolve => "cold solve",
            Source::Deduped => "deduped",
            Source::Failed => "FAILED",
        }
    }
}

/// Per-request outcome.
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    pub request: BatchRequest,
    /// Canonical cache key the request mapped to.
    pub key: String,
    pub source: Source,
    /// Zero when the request failed.
    pub gflops: f64,
    /// Zero when the request failed.
    pub latency_cycles: u64,
    /// Time the solve took (zero for cache/dedup/failed answers).
    pub solve_time: Duration,
    /// Time from batch start until a worker picked the request's solve
    /// up (zero for cache/dedup answers, which never queue).
    pub queue_time: Duration,
    /// The solver's error text when `source` is [`Source::Failed`].
    pub error: Option<String>,
}

/// Aggregate result of one batch run.
#[derive(Debug, Clone)]
pub struct BatchReport {
    pub outcomes: Vec<BatchOutcome>,
    pub cache_hits: usize,
    pub deduped: usize,
    /// Requests answered by running the solver (warm + cold).
    pub solved: usize,
    /// Solved requests that were warm-started from a related record.
    pub warm_solves: usize,
    /// Requests that got no answer (their own solve failed, or they
    /// deduped onto one that did).
    pub failed: usize,
    pub elapsed: Duration,
}

impl BatchReport {
    /// Paper-shaped aggregate table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["Kernel", "Scenario", "Model", "GF/s", "Cycles", "Source"]);
        for o in &self.outcomes {
            let model = match o.request.model {
                ExecutionModel::Dataflow => "dataflow",
                ExecutionModel::Sequential => "sequential",
            };
            t.row(vec![
                o.request.kernel.clone(),
                o.request.scenario.to_string(),
                model.to_string(),
                if o.source == Source::Failed { "-".to_string() } else { gfs(o.gflops) },
                if o.source == Source::Failed {
                    o.error.clone().unwrap_or_default()
                } else {
                    o.latency_cycles.to_string()
                },
                o.source.as_str().to_string(),
            ]);
        }
        t.render()
    }

    /// Service-level metrics table: answer-source rates and queue/solve
    /// wall-time aggregates. The observability counterpart of
    /// [`BatchReport::render`] — about the *service*, not the designs.
    pub fn metrics(&self) -> String {
        let n = self.outcomes.len().max(1);
        let pct = |k: usize| format!("{:.1}%", 100.0 * k as f64 / n as f64);
        let solve_times: Vec<Duration> = self
            .outcomes
            .iter()
            .filter(|o| matches!(o.source, Source::WarmSolve | Source::ColdSolve))
            .map(|o| o.solve_time)
            .collect();
        let queue_times: Vec<Duration> = self
            .outcomes
            .iter()
            .filter(|o| matches!(o.source, Source::WarmSolve | Source::ColdSolve))
            .map(|o| o.queue_time)
            .collect();
        let stat = |ts: &[Duration]| {
            if ts.is_empty() {
                return "-".to_string();
            }
            let total: Duration = ts.iter().sum();
            let max = ts.iter().max().copied().unwrap_or_default();
            format!("avg {:.2?}, max {:.2?}", total / ts.len() as u32, max)
        };
        let reqs_per_s = self.outcomes.len() as f64 / self.elapsed.as_secs_f64().max(1e-9);
        let mut t = Table::new(&["Metric", "Value"]);
        t.row(vec!["requests".into(), self.outcomes.len().to_string()]);
        t.row(vec!["db hit rate".into(), format!("{} ({})", self.cache_hits, pct(self.cache_hits))]);
        t.row(vec!["dedup rate".into(), format!("{} ({})", self.deduped, pct(self.deduped))]);
        t.row(vec![
            "warm-start rate".into(),
            format!(
                "{} of {} solves ({:.1}%)",
                self.warm_solves,
                self.solved,
                100.0 * self.warm_solves as f64 / self.solved.max(1) as f64
            ),
        ]);
        t.row(vec!["failed".into(), format!("{} ({})", self.failed, pct(self.failed))]);
        t.row(vec!["queue time".into(), stat(&queue_times)]);
        t.row(vec!["solve time".into(), stat(&solve_times)]);
        t.row(vec!["throughput".into(), format!("{reqs_per_s:.2} req/s")]);
        t.render()
    }

    /// One-line summary for logs and the CLI footer. Printed even when
    /// some requests failed — partial batches still report.
    pub fn summary(&self) -> String {
        let ok = self.outcomes.len() - self.failed;
        format!(
            "{} requests: {} ok ({} cache hits, {} deduped, {} solved, {} warm), \
             {} failed in {:.2?} ({:.2} req/s)",
            self.outcomes.len(),
            ok,
            self.cache_hits,
            self.deduped,
            self.solved,
            self.warm_solves,
            self.failed,
            self.elapsed,
            self.outcomes.len() as f64 / self.elapsed.as_secs_f64().max(1e-9),
        )
    }
}

/// What one worker produced for one unique miss. The record itself is
/// already in the store (inserted, durably, by the worker); this
/// carries only the reporting metadata.
struct SolvedJob {
    canonical: String,
    warm: bool,
    solve_time: Duration,
    /// Batch-start → worker-pickup wall time for this miss.
    queue_time: Duration,
}

/// Best-effort text of a worker panic payload (shared with the serve
/// daemon's workers).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "solver panicked".to_string()
    }
}

/// Run `requests` against the knowledge base, solving misses in
/// parallel. Each completed solve is inserted into `store` *by the
/// worker that produced it* — durably (fsync'd append) when the store
/// is file-backed, so an interrupted batch keeps every finished solve.
/// Request order is preserved in the report.
///
/// A failed solve (infeasible budget, solver panic) fails *that
/// request* — it lands in the report as [`Source::Failed`] with the
/// error text, completed solves still reach the knowledge base, and
/// the call returns `Ok`. `Err` is reserved for a malformed batch
/// (an unknown kernel), detected before any solver time is spent.
pub fn run_batch(
    requests: &[BatchRequest],
    dev: &Device,
    store: &QorStore,
    opts: &BatchOptions,
) -> Result<BatchReport> {
    let t0 = Instant::now();

    // Validate every kernel up front (a typo should fail the batch
    // before any solver time is spent) and build the shared per-kernel
    // context — the fusion space with its geometry caches — exactly
    // once per kernel.
    let mut ctxs: BTreeMap<String, KernelCtx> = BTreeMap::new();
    for r in requests {
        if ctxs.contains_key(&r.kernel) {
            continue;
        }
        let Some(kernel) = polybench::by_name(&r.kernel) else {
            bail!("unknown kernel `{}` in batch request", r.kernel);
        };
        let space = FusionSpace::for_solver(&kernel, opts.solver.explore_fusion);
        ctxs.insert(r.kernel.clone(), KernelCtx { kernel, space });
    }
    let ctxs = &ctxs; // shared read-only with the worker pool

    // Canonicalize, classify hits, dedup misses. A cached record whose
    // design no longer validates against the current kernel zoo (a
    // stale db from an older code version, same FORMAT_VERSION) is
    // evicted and re-solved, mirroring `optimize_kernel_cached`.
    let canon: Vec<String> =
        requests.iter().map(|r| r.key(dev, &opts.solver).canonical()).collect();
    let mut sources: Vec<Source> = Vec::with_capacity(requests.len());
    let mut job_requests: Vec<usize> = Vec::new(); // request index per unique miss
    for (i, key) in canon.iter().enumerate() {
        let cached_valid = store.get_canonical(key).map(|rec| {
            let ctx = &ctxs[&requests[i].kernel];
            // the record is judged against its *own* fusion variant; a
            // partition that is no longer in the kernel's legal space
            // is stale by definition
            crate::dse::solver::usable_variant_in_space(
                &ctx.kernel,
                &ctx.space,
                &rec.design,
                dev,
                requests[i].scenario,
            )
            .is_some()
        });
        if cached_valid == Some(false) {
            store.remove_canonical(key)?;
        }
        if cached_valid == Some(true) {
            sources.push(Source::Cache);
        } else if canon[..i].contains(key) {
            sources.push(Source::Deduped);
        } else {
            sources.push(Source::ColdSolve); // refined to WarmSolve below
            job_requests.push(i);
        }
    }

    // Warm-start incumbents resolved up front (one consistent view per
    // miss), restricted to designs whose fusion plan is in the request
    // kernel's solve space so a compatible record is never shadowed by
    // an incompatible faster one.
    let incumbents: Vec<Option<crate::dse::config::DesignConfig>> = job_requests
        .iter()
        .map(|&ri| {
            let r = &requests[ri];
            let space = &ctxs[&r.kernel].space;
            store
                .incumbent_for_space(&r.kernel, r.model, r.overlap, |p| {
                    space.variant_of(p).is_some()
                })
                .map(|rec| rec.design)
        })
        .collect();

    // Parallel fan-out over the unique misses (the shared
    // `par::run_indexed` worker pool), splitting the core budget
    // between the two layers of parallelism: `workers` requests in
    // flight, each solve running on `intra_jobs` threads of its own
    // (a 16-core box serving 2 misses gives each solve 8 threads
    // instead of idling 14 cores). An infeasible request is a clean
    // `SolverError` that fails that request only; `catch_unwind` stays,
    // but now guards true bugs, not expected infeasibility — completed
    // solves still reach the knowledge base either way.
    let total_jobs = opts.jobs.max(1);
    let workers = total_jobs.min(job_requests.len().max(1));
    // Integer split plus remainder: the first `total % workers` misses
    // get one extra intra-solve thread, so e.g. 16 cores over 9 misses
    // run 7 solves at 2 threads + 2 at 1 instead of idling 7 cores.
    // Deterministic (a function of the job index), so re-running a
    // batch cannot flip which answer a request gets.
    let base_intra = (total_jobs / workers).max(1);
    let extra_intra = if total_jobs > workers { total_jobs % workers } else { 0 };
    let results: Vec<Result<SolvedJob, String>> =
        crate::par::run_indexed(job_requests.len(), workers, |j| {
            let req = &requests[job_requests[j]];
            let queue_time = t0.elapsed();
            let span = crate::obs::span("service", "batch.solve").map(|s| {
                s.arg("kernel", crate::obs::ArgVal::Str(req.kernel.clone()))
                    .arg("scenario", crate::obs::ArgVal::Str(req.scenario.to_string()))
                    .arg("queue_us", crate::obs::ArgVal::Int(queue_time.as_micros() as i128))
            });
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || -> Result<SolvedJob, String> {
                    let mut sopts = req.solver_options(&opts.solver);
                    sopts.incumbent = incumbents[j].clone();
                    sopts.jobs = base_intra + usize::from(j < extra_intra);
                    // One fusion space (graphs + geometry caches) per
                    // kernel, shared by every job of the batch
                    // (read-only).
                    let ctx = &ctxs[&req.kernel];
                    let r = solve_space(&ctx.kernel, &ctx.space, dev, &sopts)
                        .map_err(|e| e.to_string())?;
                    // Shared record constructor (simulated cycles +
                    // scenario-consistent GF/s) over the *winning*
                    // variant's graph and cache: identical to what
                    // `optimize --db` would store for this request.
                    let win = ctx
                        .space
                        .variant_of(&r.design.fusion)
                        .expect("winning design realizes a space variant");
                    let v = &ctx.space.variants[win];
                    let record = QorRecord::from_solve_with_cache(
                        &ctx.kernel,
                        &v.fg,
                        &v.cache,
                        &r,
                        req.scenario,
                        dev,
                    );
                    // Durable the moment the solve completes: a batch
                    // killed after this line keeps this answer. The
                    // store's never-worse merge makes concurrent
                    // writers safe; an append error fails the request.
                    store
                        .insert_canonical(&canon[job_requests[j]], record)
                        .map_err(|e| format!("storing result: {e:#}"))?;
                    Ok(SolvedJob {
                        canonical: canon[job_requests[j]].clone(),
                        warm: r.warm_started,
                        solve_time: r.solve_time,
                        queue_time,
                    })
                },
            ));
            drop(span);
            match outcome {
                Ok(res) => res,
                Err(p) => Err(panic_message(&p)),
            }
        });

    // Fold the reporting metadata (the records themselves were already
    // inserted, durably, by the workers). A failure is recorded per
    // canonical key — every request that maps onto it, dedup riders
    // included, got no answer.
    let mut solve_times: std::collections::BTreeMap<String, (Duration, Duration, bool)> =
        std::collections::BTreeMap::new();
    let mut failed_keys: std::collections::BTreeMap<String, String> =
        std::collections::BTreeMap::new();
    for (outcome, &ri) in results.into_iter().zip(&job_requests) {
        match outcome {
            Ok(job) => {
                solve_times.insert(job.canonical, (job.solve_time, job.queue_time, job.warm));
            }
            Err(msg) => {
                failed_keys.insert(canon[ri].clone(), msg);
            }
        }
    }

    let mut outcomes = Vec::with_capacity(requests.len());
    let (mut cache_hits, mut deduped, mut solved, mut warm_solves, mut failed) =
        (0usize, 0usize, 0usize, 0usize, 0usize);
    for (i, req) in requests.iter().enumerate() {
        if let Some(msg) = failed_keys.get(&canon[i]) {
            failed += 1;
            outcomes.push(BatchOutcome {
                request: req.clone(),
                key: canon[i].clone(),
                source: Source::Failed,
                gflops: 0.0,
                latency_cycles: 0,
                solve_time: Duration::ZERO,
                queue_time: Duration::ZERO,
                error: Some(msg.clone()),
            });
            continue;
        }
        let rec = store
            .get_canonical(&canon[i])
            .ok_or_else(|| anyhow!("request `{}` missing from store after batch", req.kernel))?;
        let (source, solve_time, queue_time) = match sources[i] {
            Source::Cache => {
                cache_hits += 1;
                (Source::Cache, Duration::ZERO, Duration::ZERO)
            }
            Source::Deduped => {
                deduped += 1;
                (Source::Deduped, Duration::ZERO, Duration::ZERO)
            }
            _ => {
                solved += 1;
                match solve_times.get(&canon[i]) {
                    Some(&(t, q, warm)) => {
                        warm_solves += usize::from(warm);
                        (if warm { Source::WarmSolve } else { Source::ColdSolve }, t, q)
                    }
                    None => (Source::ColdSolve, Duration::ZERO, Duration::ZERO),
                }
            }
        };
        outcomes.push(BatchOutcome {
            request: req.clone(),
            key: canon[i].clone(),
            source,
            gflops: rec.gflops,
            latency_cycles: rec.latency_cycles,
            solve_time,
            queue_time,
            error: None,
        });
    }

    let report = BatchReport {
        outcomes,
        cache_hits,
        deduped,
        solved,
        warm_solves,
        failed,
        elapsed: t0.elapsed(),
    };
    if crate::obs::trace_enabled() {
        crate::obs::counter(
            "service",
            "batch.summary",
            vec![
                ("requests".to_string(), crate::obs::ArgVal::Int(report.outcomes.len() as i128)),
                ("cache_hits".to_string(), crate::obs::ArgVal::Int(report.cache_hits as i128)),
                ("deduped".to_string(), crate::obs::ArgVal::Int(report.deduped as i128)),
                ("solved".to_string(), crate::obs::ArgVal::Int(report.solved as i128)),
                ("warm_solves".to_string(), crate::obs::ArgVal::Int(report.warm_solves as i128)),
                ("failed".to_string(), crate::obs::ArgVal::Int(report.failed as i128)),
            ],
        );
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_parsing_round_trips() {
        assert_eq!(parse_scenario("rtl").unwrap(), Scenario::Rtl);
        assert_eq!(
            parse_scenario("onboard:3:0.6").unwrap(),
            Scenario::OnBoard { slrs: 3, frac: 0.6 }
        );
        assert_eq!(
            parse_scenario("onboard:1").unwrap(),
            Scenario::OnBoard { slrs: 1, frac: 0.6 }
        );
        for s in ["rtl", "onboard:1:0.6", "onboard:3:0.15"] {
            assert_eq!(parse_scenario(s).unwrap().to_string(), s);
        }
        assert!(parse_scenario("onboard:").is_err());
        assert!(parse_scenario("onboard:x:0.6").is_err());
        assert!(parse_scenario("onboard:1:0.6:9").is_err());
        assert!(parse_scenario("board").is_err());
        // degenerate fractions / SLR counts fail fast instead of
        // panicking a solver worker later
        assert!(parse_scenario("onboard:0:0.6").is_err());
        assert!(parse_scenario("onboard:1:nan").is_err());
        assert!(parse_scenario("onboard:1:inf").is_err());
        assert!(parse_scenario("onboard:1:0").is_err());
        assert!(parse_scenario("onboard:1:-0.5").is_err());
        assert!(parse_scenario("onboard:1:1.5").is_err());
    }

    #[test]
    fn model_parsing() {
        assert_eq!(parse_model("dataflow").unwrap(), ExecutionModel::Dataflow);
        assert_eq!(parse_model("sequential").unwrap(), ExecutionModel::Sequential);
        assert!(parse_model("magic").is_err());
    }

    #[test]
    fn unknown_kernel_fails_fast() {
        let reqs = vec![BatchRequest::new("not-a-kernel", Scenario::Rtl)];
        let store = QorStore::in_memory();
        let err = run_batch(&reqs, &Device::u55c(), &store, &BatchOptions::default());
        assert!(err.is_err());
        assert!(store.is_empty(), "failed batch must not pollute the store");
    }

    #[test]
    fn infeasible_request_fails_that_request_only() {
        let dev = Device::u55c();
        let opts = BatchOptions {
            solver: SolverOptions {
                beam: 4,
                max_factor_per_loop: 8,
                max_unroll: 64,
                timeout: std::time::Duration::from_secs(20),
                ..SolverOptions::default()
            },
            jobs: 2,
        };
        let reqs = vec![
            BatchRequest::new("madd", Scenario::Rtl),
            // a budget far too small for any design: the solver returns
            // `SolverError::Infeasible`; the batch must fail exactly
            // that request, with the solver's message, not a panic's —
            // and still return `Ok` with the failure in the report
            BatchRequest::new("madd", Scenario::OnBoard { slrs: 1, frac: 1e-6 }),
        ];
        let store = QorStore::in_memory();
        let rep = run_batch(&reqs, &dev, &store, &opts).unwrap();
        assert_eq!(rep.failed, 1);
        assert_eq!(rep.solved, 1);
        assert_eq!(rep.outcomes[1].source, Source::Failed);
        let msg = rep.outcomes[1].error.as_deref().unwrap_or_default();
        assert!(msg.contains("infeasible"), "expected a clean solver error, got: {msg}");
        assert!(rep.outcomes[0].error.is_none());
        // the failure is visible in the renderings, not just the struct
        assert!(rep.render().contains("FAILED"), "{}", rep.render());
        assert!(rep.summary().contains("1 failed"), "{}", rep.summary());
        assert!(rep.metrics().contains("failed"), "{}", rep.metrics());
        // the feasible request's solve survived into the knowledge base
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn dedup_and_cache_classification() {
        // Small, fast solve: one kernel, duplicated request + a rerun.
        let dev = Device::u55c();
        let opts = BatchOptions {
            solver: SolverOptions {
                beam: 4,
                max_factor_per_loop: 8,
                max_unroll: 64,
                timeout: std::time::Duration::from_secs(20),
                ..SolverOptions::default()
            },
            jobs: 2,
        };
        let reqs = vec![
            BatchRequest::new("madd", Scenario::Rtl),
            BatchRequest::new("madd", Scenario::Rtl),
        ];
        let store = QorStore::in_memory();
        let rep = run_batch(&reqs, &dev, &store, &opts).unwrap();
        assert_eq!(rep.solved, 1, "identical requests must collapse to one solve");
        assert_eq!(rep.deduped, 1);
        assert_eq!(rep.cache_hits, 0);
        assert_eq!(store.len(), 1);
        assert_eq!(rep.outcomes[0].latency_cycles, rep.outcomes[1].latency_cycles);

        let rep2 = run_batch(&reqs, &dev, &store, &opts).unwrap();
        assert_eq!(rep2.solved, 0, "second run must be all cache hits");
        assert_eq!(rep2.cache_hits, 2);
        let table = rep2.render();
        assert!(table.contains("madd"), "{table}");
        assert!(table.contains("cache"), "{table}");
    }
}
