//! `prometheus serve`: the long-running optimization daemon.
#![deny(missing_docs)]
//!
//! Where [`super::batch`] answers one fixed request set and exits, the
//! daemon accepts a *stream* of requests for the lifetime of the
//! process and amortizes everything it learns across them:
//!
//! * **bounded admission queue** — requests pass through a
//!   fixed-capacity queue consumed by a worker pool; a full queue
//!   *rejects* the request with a structured [`SubmitError::QueueFull`]
//!   (shed, don't stall — the client can retry; an unbounded queue
//!   would hide overload until memory ran out);
//! * **cross-request in-flight dedup** — a request for a `DesignKey`
//!   that is already solving joins the in-flight solve's waiters and
//!   receives the *identical* answer (same [`QorRecord`], bit-identical
//!   design) instead of re-solving;
//! * **persistent warm state** — per-kernel fusion spaces with their
//!   geometry caches ([`crate::dse::eval::FusionSpace`]) are built once
//!   and kept for the process lifetime, and every solve warm-starts
//!   from the best compatible record in the [`QorStore`];
//! * **durable results** — every completed solve is appended (fsync'd)
//!   to the store before its waiters are released;
//! * **metrics** — req/s, queue depth, p50/p99 queue and solve
//!   latency, and db-hit/dedup/warm-start rates, built on the same
//!   [`crate::obs`] spans/counters as the rest of the system (visible
//!   in `--trace` output).
//!
//! Request lifecycle: `submit` → store hit? → in-flight dedup? →
//! admission queue → worker solve (warm-started) → store append →
//! waiters released → metrics. The transport ([`serve_lines`]) is a
//! newline-delimited-JSON loop over any `BufRead`/`Write` pair — the
//! CLI wires it to stdin/stdout, so `prometheus serve` composes with
//! pipes, sockets via `nc`/`socat`, and the smoke test alike.

use super::batch::{panic_message, BatchRequest, Source};
use super::qor_db::QorRecord;
use super::store::QorStore;
use crate::dse::config::{DesignConfig, ExecutionModel};
use crate::dse::eval::FusionSpace;
use crate::dse::solver::{solve_space, usable_variant_in_space, Scenario, SolverOptions};
use crate::hw::Device;
use crate::ir::polybench;
use crate::ir::Kernel;
use crate::obs::ArgVal;
use crate::report::Table;
use anyhow::{anyhow, bail, Context, Result};
use serde::Value;
use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Base solver knobs; each request overrides scenario/model/overlap
    /// (and, with the solver's determinism contract, `jobs` never
    /// changes an answer).
    pub solver: SolverOptions,
    /// Queue-consumer worker threads (concurrent solves). `0` is legal
    /// and means nothing is ever solved — submissions queue until
    /// shutdown fails them; the admission-control tests use this to
    /// fill the queue deterministically.
    pub workers: usize,
    /// Total core budget, split evenly across workers into each
    /// solve's own `SolverOptions::jobs`.
    pub jobs: usize,
    /// Admission queue capacity; a submit beyond it is rejected with
    /// [`SubmitError::QueueFull`].
    pub queue_capacity: usize,
    /// Emit a metrics report to stderr every N responses in
    /// [`serve_lines`] (0 = only the final report).
    pub metrics_every: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            solver: SolverOptions::default(),
            workers: 2,
            jobs: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            queue_capacity: 64,
            metrics_every: 16,
        }
    }
}

impl ServeOptions {
    /// Worker threads each solve runs on (the per-solve share of the
    /// core budget).
    fn intra_jobs(&self) -> usize {
        (self.jobs.max(1) / self.workers.max(1)).max(1)
    }
}

/// Why a submission was not accepted. Structured (not a string) so
/// transports can map each case to a distinct client-visible status.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The request names a kernel the zoo does not have.
    UnknownKernel(String),
    /// The admission queue is at capacity: the daemon sheds the
    /// request instead of blocking the submitter. Retry later.
    QueueFull {
        /// Configured queue capacity.
        capacity: usize,
        /// Queue depth observed at rejection (== capacity).
        depth: usize,
    },
    /// The daemon is shutting down and accepts no new work.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::UnknownKernel(k) => write!(f, "unknown kernel `{k}`"),
            SubmitError::QueueFull { capacity, depth } => {
                write!(f, "admission queue full (capacity {capacity}, depth {depth})")
            }
            SubmitError::ShuttingDown => write!(f, "daemon is shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Per-kernel state kept warm for the process lifetime: the kernel and
/// its full fusion space (every legal variant's fused graph + geometry
/// cache). Built on first request for the kernel, then shared
/// read-only by every subsequent solve.
struct KernelCtx {
    kernel: Kernel,
    space: FusionSpace,
}

/// What one solve produced, shared verbatim (same allocation) with
/// every deduped waiter — bit-identical answers by construction.
struct Solved {
    record: QorRecord,
    warm: bool,
    solve_time: Duration,
    queue_time: Duration,
}

type Answer = Result<Arc<Solved>, String>;

/// Rendezvous between one in-flight solve and its waiters.
struct InFlight {
    slot: Mutex<Option<Answer>>,
    cv: Condvar,
}

impl InFlight {
    fn new() -> InFlight {
        InFlight { slot: Mutex::new(None), cv: Condvar::new() }
    }
}

/// One queued unit of work.
struct Job {
    key: String,
    request: BatchRequest,
    inflight: Arc<InFlight>,
    enqueued: Instant,
}

struct QueueState {
    jobs: VecDeque<Job>,
    closed: bool,
}

#[derive(Default)]
struct MetricsState {
    received: AtomicU64,
    cache_hits: AtomicU64,
    deduped: AtomicU64,
    solved: AtomicU64,
    warm_solves: AtomicU64,
    failed: AtomicU64,
    rejected: AtomicU64,
    queue_us: Mutex<Vec<u64>>,
    solve_us: Mutex<Vec<u64>>,
    /// Solves *started* per canonical key — the dedup oracle: a key
    /// never has two concurrent solves, so under a burst of identical
    /// requests this stays at 1.
    per_key_solves: Mutex<BTreeMap<String, u64>>,
}

struct ServeState {
    dev: Device,
    opts: ServeOptions,
    store: QorStore,
    ctxs: Mutex<BTreeMap<String, Arc<KernelCtx>>>,
    inflight: Mutex<BTreeMap<String, Arc<InFlight>>>,
    queue: Mutex<QueueState>,
    queue_cv: Condvar,
    metrics: MetricsState,
    started: Instant,
}

/// The daemon: worker pool + shared state. Create with [`Daemon::new`],
/// feed it with [`Daemon::submit`], stop it with [`Daemon::shutdown`].
pub struct Daemon {
    state: Arc<ServeState>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// A claim on a submitted request's eventual answer.
///
/// Cache hits are born ready; queued and deduped submissions become
/// ready when the (shared) solve finishes. [`Ticket::wait`] blocks;
/// [`Ticket::ready`] polls.
pub struct Ticket {
    request: BatchRequest,
    key: String,
    kind: TicketKind,
}

enum TicketKind {
    Ready(Box<ServeOutcome>),
    Waiter { inflight: Arc<InFlight>, rider: bool },
}

impl Ticket {
    /// Canonical `DesignKey` string the request mapped to.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Whether [`Ticket::wait`] would return without blocking.
    pub fn ready(&self) -> bool {
        match &self.kind {
            TicketKind::Ready(_) => true,
            TicketKind::Waiter { inflight, .. } => inflight.slot.lock().unwrap().is_some(),
        }
    }

    /// Block until the answer is available and return it. Idempotent —
    /// deduped waiters all receive clones of the same shared record.
    pub fn wait(&self) -> ServeOutcome {
        let (inflight, rider) = match &self.kind {
            TicketKind::Ready(o) => return (**o).clone(),
            TicketKind::Waiter { inflight, rider } => (inflight, *rider),
        };
        let mut slot = inflight.slot.lock().unwrap();
        while slot.is_none() {
            slot = inflight.cv.wait(slot).unwrap();
        }
        match slot.as_ref().expect("slot filled") {
            Ok(s) => ServeOutcome {
                request: self.request.clone(),
                key: self.key.clone(),
                source: if rider {
                    Source::Deduped
                } else if s.warm {
                    Source::WarmSolve
                } else {
                    Source::ColdSolve
                },
                gflops: s.record.gflops,
                latency_cycles: s.record.latency_cycles,
                solve_time: if rider { Duration::ZERO } else { s.solve_time },
                queue_time: if rider { Duration::ZERO } else { s.queue_time },
                design: Some(s.record.design.clone()),
                error: None,
            },
            Err(msg) => ServeOutcome {
                request: self.request.clone(),
                key: self.key.clone(),
                source: Source::Failed,
                gflops: 0.0,
                latency_cycles: 0,
                solve_time: Duration::ZERO,
                queue_time: Duration::ZERO,
                design: None,
                error: Some(msg.clone()),
            },
        }
    }
}

/// One answered request.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    /// The request as submitted.
    pub request: BatchRequest,
    /// Canonical `DesignKey` string.
    pub key: String,
    /// How the request was answered (same taxonomy as batch).
    pub source: Source,
    /// Scenario-consistent GF/s (0 on failure).
    pub gflops: f64,
    /// Simulated latency in cycles (0 on failure).
    pub latency_cycles: u64,
    /// Solve wall time (zero for cache/dedup answers).
    pub solve_time: Duration,
    /// Enqueue → worker-pickup wall time (zero for cache/dedup).
    pub queue_time: Duration,
    /// The winning design (deduped waiters see the bit-identical
    /// design their primary's solve produced). `None` on failure.
    pub design: Option<DesignConfig>,
    /// Error text when `source` is [`Source::Failed`].
    pub error: Option<String>,
}

impl Daemon {
    /// Start the daemon: spawn `opts.workers` queue consumers over
    /// `store`.
    pub fn new(dev: Device, store: QorStore, opts: ServeOptions) -> Daemon {
        let n = opts.workers;
        let state = Arc::new(ServeState {
            dev,
            opts,
            store,
            ctxs: Mutex::new(BTreeMap::new()),
            inflight: Mutex::new(BTreeMap::new()),
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false }),
            queue_cv: Condvar::new(),
            metrics: MetricsState::default(),
            started: Instant::now(),
        });
        let workers = (0..n)
            .map(|i| {
                let st = Arc::clone(&state);
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(&st))
                    .expect("spawning serve worker")
            })
            .collect();
        Daemon { state, workers }
    }

    /// The daemon's store (e.g. to compact or snapshot it from the
    /// transport layer).
    pub fn store(&self) -> &QorStore {
        &self.state.store
    }

    /// Submit one request. Non-blocking: a store hit returns a ready
    /// [`Ticket`]; a key already in flight joins its waiters; otherwise
    /// the request is enqueued — or rejected, never silently stalled,
    /// when the queue is at capacity.
    pub fn submit(&self, request: BatchRequest) -> Result<Ticket, SubmitError> {
        submit(&self.state, request)
    }

    /// Point-in-time metrics snapshot.
    pub fn metrics(&self) -> ServeMetrics {
        snapshot(&self.state)
    }

    /// Stop accepting work, let the workers drain the queue, fail
    /// whatever never ran (only possible with `workers == 0`), and
    /// return the final metrics.
    pub fn shutdown(mut self) -> ServeMetrics {
        {
            let mut q = self.state.queue.lock().unwrap();
            q.closed = true;
        }
        self.state.queue_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        let leftovers: Vec<Job> = {
            let mut q = self.state.queue.lock().unwrap();
            q.jobs.drain(..).collect()
        };
        for job in leftovers {
            self.state.metrics.failed.fetch_add(1, Ordering::Relaxed);
            finish(&self.state, &job, Err("daemon shut down before the solve ran".to_string()));
        }
        snapshot(&self.state)
    }
}

/// Look up (or build, once) the warm per-kernel context.
fn ctx_for(state: &ServeState, name: &str) -> Result<Arc<KernelCtx>, SubmitError> {
    if let Some(c) = state.ctxs.lock().unwrap().get(name) {
        return Ok(Arc::clone(c));
    }
    let Some(kernel) = polybench::by_name(name) else {
        return Err(SubmitError::UnknownKernel(name.to_string()));
    };
    // Built outside the lock (fusion-space construction is the
    // expensive part); a racing builder is harmless — first insert
    // wins and the loser's space is dropped.
    let space = FusionSpace::for_solver(&kernel, state.opts.solver.explore_fusion);
    let ctx = Arc::new(KernelCtx { kernel, space });
    let mut ctxs = state.ctxs.lock().unwrap();
    Ok(Arc::clone(ctxs.entry(name.to_string()).or_insert(ctx)))
}

fn submit(state: &Arc<ServeState>, request: BatchRequest) -> Result<Ticket, SubmitError> {
    state.metrics.received.fetch_add(1, Ordering::Relaxed);
    let ctx = ctx_for(state, &request.kernel)?;
    let key = request.key(&state.dev, &state.opts.solver).canonical();

    // Store hit, gated on the record still validating against the
    // current zoo (same staleness rule as batch / the cached flow).
    if let Some(rec) = state.store.get_canonical(&key) {
        let valid = usable_variant_in_space(
            &ctx.kernel,
            &ctx.space,
            &rec.design,
            &state.dev,
            request.scenario,
        )
        .is_some();
        if valid {
            state.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
            crate::obs::instant(
                "service",
                "serve.cache_hit",
                vec![("key".to_string(), ArgVal::Str(key.clone()))],
            );
            return Ok(ready_ticket(request, key, &rec, Source::Cache, None));
        }
        // Stale: evict with a tombstone before re-solving. No solve for
        // this key can be in flight (it would have produced a valid
        // record), so the tombstone cannot race an insert.
        if let Err(e) = state.store.remove_canonical(&key) {
            let err = format!("evicting stale record: {e:#}");
            return Ok(failed_ticket(request, key, err));
        }
    }

    let mut inflight = state.inflight.lock().unwrap();
    if let Some(arc) = inflight.get(&key) {
        state.metrics.deduped.fetch_add(1, Ordering::Relaxed);
        crate::obs::instant(
            "service",
            "serve.dedup",
            vec![("key".to_string(), ArgVal::Str(key.clone()))],
        );
        let inflight = Arc::clone(arc);
        return Ok(Ticket { request, key, kind: TicketKind::Waiter { inflight, rider: true } });
    }
    // Re-check the store *under the in-flight lock*: a solve for this
    // key may have finished between the lookup above and taking the
    // lock. The worker inserts into the store before removing the
    // in-flight entry, so one of the two checks must see it. A record
    // found here was just produced by this process — no staleness gate
    // needed.
    if let Some(rec) = state.store.get_canonical(&key) {
        state.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
        return Ok(ready_ticket(request, key, &rec, Source::Cache, None));
    }

    let mut q = state.queue.lock().unwrap();
    if q.closed {
        return Err(SubmitError::ShuttingDown);
    }
    if q.jobs.len() >= state.opts.queue_capacity {
        state.metrics.rejected.fetch_add(1, Ordering::Relaxed);
        crate::obs::instant(
            "service",
            "serve.reject",
            vec![("depth".to_string(), ArgVal::Int(q.jobs.len() as i128))],
        );
        return Err(SubmitError::QueueFull {
            capacity: state.opts.queue_capacity,
            depth: q.jobs.len(),
        });
    }
    let arc = Arc::new(InFlight::new());
    inflight.insert(key.clone(), Arc::clone(&arc));
    q.jobs.push_back(Job {
        key: key.clone(),
        request: request.clone(),
        inflight: Arc::clone(&arc),
        enqueued: Instant::now(),
    });
    drop(q);
    drop(inflight);
    state.queue_cv.notify_one();
    Ok(Ticket { request, key, kind: TicketKind::Waiter { inflight: arc, rider: false } })
}

fn ready_ticket(
    request: BatchRequest,
    key: String,
    rec: &QorRecord,
    source: Source,
    error: Option<String>,
) -> Ticket {
    let outcome = ServeOutcome {
        request: request.clone(),
        key: key.clone(),
        source,
        gflops: rec.gflops,
        latency_cycles: rec.latency_cycles,
        solve_time: Duration::ZERO,
        queue_time: Duration::ZERO,
        design: Some(rec.design.clone()),
        error,
    };
    Ticket { request, key, kind: TicketKind::Ready(Box::new(outcome)) }
}

fn failed_ticket(request: BatchRequest, key: String, error: String) -> Ticket {
    let outcome = ServeOutcome {
        request: request.clone(),
        key: key.clone(),
        source: Source::Failed,
        gflops: 0.0,
        latency_cycles: 0,
        solve_time: Duration::ZERO,
        queue_time: Duration::ZERO,
        design: None,
        error: Some(error),
    };
    Ticket { request, key, kind: TicketKind::Ready(Box::new(outcome)) }
}

fn worker_loop(state: &ServeState) {
    loop {
        let job = {
            let mut q = state.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break job;
                }
                if q.closed {
                    return;
                }
                q = state.queue_cv.wait(q).unwrap();
            }
        };
        process_job(state, job);
    }
}

fn process_job(state: &ServeState, job: Job) {
    let queue_time = job.enqueued.elapsed();
    push_sample(&state.metrics.queue_us, queue_time);
    {
        let mut per = state.metrics.per_key_solves.lock().unwrap();
        *per.entry(job.key.clone()).or_insert(0) += 1;
    }
    let span = crate::obs::span("service", "serve.solve").map(|s| {
        s.arg("kernel", ArgVal::Str(job.request.kernel.clone()))
            .arg("scenario", ArgVal::Str(job.request.scenario.to_string()))
            .arg("queue_us", ArgVal::Int(queue_time.as_micros() as i128))
    });
    let answer = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        solve_job(state, &job, queue_time)
    }));
    drop(span);
    let answer: Answer = match answer {
        Ok(a) => a,
        Err(p) => Err(panic_message(&p)),
    };
    match &answer {
        Ok(s) => {
            state.metrics.solved.fetch_add(1, Ordering::Relaxed);
            if s.warm {
                state.metrics.warm_solves.fetch_add(1, Ordering::Relaxed);
            }
            push_sample(&state.metrics.solve_us, s.solve_time);
        }
        Err(_) => {
            state.metrics.failed.fetch_add(1, Ordering::Relaxed);
        }
    }
    finish(state, &job, answer);
}

fn solve_job(state: &ServeState, job: &Job, queue_time: Duration) -> Answer {
    let ctx = ctx_for(state, &job.request.kernel).map_err(|e| e.to_string())?;
    let mut sopts = job.request.solver_options(&state.opts.solver);
    sopts.incumbent = state
        .store
        .incumbent_for_space(&job.request.kernel, job.request.model, job.request.overlap, |p| {
            ctx.space.variant_of(p).is_some()
        })
        .map(|rec| rec.design);
    sopts.jobs = state.opts.intra_jobs();
    let r = solve_space(&ctx.kernel, &ctx.space, &state.dev, &sopts).map_err(|e| e.to_string())?;
    let win = ctx
        .space
        .variant_of(&r.design.fusion)
        .expect("winning design realizes a space variant");
    let v = &ctx.space.variants[win];
    let record = QorRecord::from_solve_with_cache(
        &ctx.kernel,
        &v.fg,
        &v.cache,
        &r,
        job.request.scenario,
        &state.dev,
    );
    // Durable before any waiter is released: append + fsync, then
    // publish. A daemon killed after this line answers the same key
    // from the store on restart.
    state
        .store
        .insert_canonical(&job.key, record.clone())
        .map_err(|e| format!("storing result: {e:#}"))?;
    Ok(Arc::new(Solved { record, warm: r.warm_started, solve_time: r.solve_time, queue_time }))
}

/// Publish `answer` to the job's waiters. Order matters: the store
/// insert already happened (success path), so the in-flight entry is
/// removed *after* it — a racing submit sees the record or the entry,
/// never neither.
fn finish(state: &ServeState, job: &Job, answer: Answer) {
    state.inflight.lock().unwrap().remove(&job.key);
    let mut slot = job.inflight.slot.lock().unwrap();
    *slot = Some(answer);
    job.inflight.cv.notify_all();
}

fn push_sample(samples: &Mutex<Vec<u64>>, d: Duration) {
    samples.lock().unwrap().push(d.as_micros() as u64);
}

// ---- metrics -----------------------------------------------------------

/// Point-in-time daemon metrics.
#[derive(Debug, Clone)]
pub struct ServeMetrics {
    /// Requests submitted (accepted or not).
    pub received: u64,
    /// Answered from the store without solving.
    pub cache_hits: u64,
    /// Joined an in-flight solve's waiters.
    pub deduped: u64,
    /// Solves completed.
    pub solved: u64,
    /// Completed solves that were warm-started.
    pub warm_solves: u64,
    /// Solves that failed (plus jobs failed at shutdown).
    pub failed: u64,
    /// Submissions rejected by admission control.
    pub rejected: u64,
    /// Jobs waiting in the queue right now.
    pub queue_depth: usize,
    /// Median enqueue → pickup latency.
    pub p50_queue: Duration,
    /// 99th-percentile enqueue → pickup latency.
    pub p99_queue: Duration,
    /// Median solve wall time.
    pub p50_solve: Duration,
    /// 99th-percentile solve wall time.
    pub p99_solve: Duration,
    /// Daemon uptime at snapshot.
    pub elapsed: Duration,
    /// Live records in the store.
    pub store_records: usize,
    /// Ops in the store's log file (`None` for in-memory stores).
    pub store_log_ops: Option<u64>,
    /// Log compactions since open.
    pub store_compactions: u64,
    /// Solves *started* per canonical key. The dedup oracle: in-flight
    /// dedup guarantees at most one concurrent solve per key, so a
    /// burst of identical requests leaves the key's count at 1.
    pub per_key_solves: BTreeMap<String, u64>,
}

impl ServeMetrics {
    /// Requests per second of uptime.
    pub fn reqs_per_s(&self) -> f64 {
        self.received as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// Human-readable metrics table (the periodic stderr report).
    pub fn render(&self) -> String {
        let pct = |k: u64| format!("{:.1}%", 100.0 * k as f64 / self.received.max(1) as f64);
        let mut t = Table::new(&["Serve metric", "Value"]);
        t.row(vec!["uptime".into(), format!("{:.2?}", self.elapsed)]);
        t.row(vec!["requests received".into(), self.received.to_string()]);
        t.row(vec!["throughput".into(), format!("{:.2} req/s", self.reqs_per_s())]);
        t.row(vec!["queue depth".into(), self.queue_depth.to_string()]);
        t.row(vec![
            "db hit rate".into(),
            format!("{} ({})", self.cache_hits, pct(self.cache_hits)),
        ]);
        t.row(vec!["dedup rate".into(), format!("{} ({})", self.deduped, pct(self.deduped))]);
        t.row(vec![
            "warm-start rate".into(),
            format!(
                "{} of {} solves ({:.1}%)",
                self.warm_solves,
                self.solved,
                100.0 * self.warm_solves as f64 / self.solved.max(1) as f64
            ),
        ]);
        t.row(vec!["failed".into(), self.failed.to_string()]);
        t.row(vec!["rejected (queue full)".into(), self.rejected.to_string()]);
        t.row(vec![
            "queue latency".into(),
            format!("p50 {:.2?}, p99 {:.2?}", self.p50_queue, self.p99_queue),
        ]);
        t.row(vec![
            "solve latency".into(),
            format!("p50 {:.2?}, p99 {:.2?}", self.p50_solve, self.p99_solve),
        ]);
        let log = match self.store_log_ops {
            Some(ops) => format!(
                "{} records, {} log ops, {} compactions",
                self.store_records, ops, self.store_compactions
            ),
            None => format!("{} records (in-memory)", self.store_records),
        };
        t.row(vec!["store".into(), log]);
        t.render()
    }

    /// The snapshot as a JSON value (the `{"cmd":"metrics"}` response).
    pub fn to_value(&self) -> Value {
        let dur_ms = |d: Duration| Value::Float(d.as_secs_f64() * 1e3);
        Value::Obj(vec![
            ("received".to_string(), Value::Int(self.received as i128)),
            ("cache_hits".to_string(), Value::Int(self.cache_hits as i128)),
            ("deduped".to_string(), Value::Int(self.deduped as i128)),
            ("solved".to_string(), Value::Int(self.solved as i128)),
            ("warm_solves".to_string(), Value::Int(self.warm_solves as i128)),
            ("failed".to_string(), Value::Int(self.failed as i128)),
            ("rejected".to_string(), Value::Int(self.rejected as i128)),
            ("queue_depth".to_string(), Value::Int(self.queue_depth as i128)),
            ("reqs_per_s".to_string(), Value::Float(self.reqs_per_s())),
            ("p50_queue_ms".to_string(), dur_ms(self.p50_queue)),
            ("p99_queue_ms".to_string(), dur_ms(self.p99_queue)),
            ("p50_solve_ms".to_string(), dur_ms(self.p50_solve)),
            ("p99_solve_ms".to_string(), dur_ms(self.p99_solve)),
            ("store_records".to_string(), Value::Int(self.store_records as i128)),
        ])
    }
}

fn snapshot(state: &ServeState) -> ServeMetrics {
    let percentiles = |m: &Mutex<Vec<u64>>| {
        let mut v = m.lock().unwrap().clone();
        v.sort_unstable();
        (
            Duration::from_micros(crate::obs::percentile(&v, 50.0)),
            Duration::from_micros(crate::obs::percentile(&v, 99.0)),
        )
    };
    let (p50_queue, p99_queue) = percentiles(&state.metrics.queue_us);
    let (p50_solve, p99_solve) = percentiles(&state.metrics.solve_us);
    let m = ServeMetrics {
        received: state.metrics.received.load(Ordering::Relaxed),
        cache_hits: state.metrics.cache_hits.load(Ordering::Relaxed),
        deduped: state.metrics.deduped.load(Ordering::Relaxed),
        solved: state.metrics.solved.load(Ordering::Relaxed),
        warm_solves: state.metrics.warm_solves.load(Ordering::Relaxed),
        failed: state.metrics.failed.load(Ordering::Relaxed),
        rejected: state.metrics.rejected.load(Ordering::Relaxed),
        queue_depth: state.queue.lock().unwrap().jobs.len(),
        p50_queue,
        p99_queue,
        p50_solve,
        p99_solve,
        elapsed: state.started.elapsed(),
        store_records: state.store.len(),
        store_log_ops: state.store.log_ops(),
        store_compactions: state.store.compactions(),
        per_key_solves: state.metrics.per_key_solves.lock().unwrap().clone(),
    };
    if crate::obs::trace_enabled() {
        crate::obs::counter(
            "service",
            "serve.metrics",
            vec![
                ("received".to_string(), ArgVal::Int(m.received as i128)),
                ("cache_hits".to_string(), ArgVal::Int(m.cache_hits as i128)),
                ("deduped".to_string(), ArgVal::Int(m.deduped as i128)),
                ("solved".to_string(), ArgVal::Int(m.solved as i128)),
                ("queue_depth".to_string(), ArgVal::Int(m.queue_depth as i128)),
                ("rejected".to_string(), ArgVal::Int(m.rejected as i128)),
            ],
        );
    }
    m
}

// ---- NDJSON transport --------------------------------------------------

/// One parsed input line.
enum Line {
    Request(BatchRequest),
    Metrics,
    Shutdown,
}

/// Parse one NDJSON input line: a request object
/// `{"kernel":"gemm","scenario":"onboard:3:0.6","model":"dataflow","overlap":true}`
/// (scenario/model/overlap optional, defaulting to `rtl`/`dataflow`/
/// `true`) or a command `{"cmd":"metrics"}` / `{"cmd":"shutdown"}`.
fn parse_line(line: &str) -> Result<Line> {
    let v = serde::parse(line).map_err(|e| anyhow!("bad request JSON: {e}"))?;
    if let Some(cmd) = v.get("cmd") {
        let cmd = cmd.as_str().ok_or_else(|| anyhow!("`cmd` must be a string"))?;
        return match cmd {
            "metrics" => Ok(Line::Metrics),
            "shutdown" => Ok(Line::Shutdown),
            other => bail!("unknown cmd `{other}` (expected `metrics` or `shutdown`)"),
        };
    }
    let kernel = v
        .field("kernel")
        .map_err(|e| anyhow!("{e}"))?
        .as_str()
        .ok_or_else(|| anyhow!("`kernel` must be a string"))?
        .to_string();
    let scenario = match v.get("scenario") {
        Some(s) => super::batch::parse_scenario(
            s.as_str().ok_or_else(|| anyhow!("`scenario` must be a string"))?,
        )?,
        None => Scenario::Rtl,
    };
    let model = match v.get("model") {
        Some(s) => super::batch::parse_model(
            s.as_str().ok_or_else(|| anyhow!("`model` must be a string"))?,
        )?,
        None => ExecutionModel::Dataflow,
    };
    let overlap = match v.get("overlap") {
        Some(b) => b.as_bool().ok_or_else(|| anyhow!("`overlap` must be a bool"))?,
        None => true,
    };
    Ok(Line::Request(BatchRequest { kernel, scenario, model, overlap }))
}

fn outcome_json(id: u64, o: &ServeOutcome) -> String {
    let status = if o.source == Source::Failed { "failed" } else { "ok" };
    let mut fields = vec![
        ("id".to_string(), Value::Int(id as i128)),
        ("kernel".to_string(), Value::Str(o.request.kernel.clone())),
        ("scenario".to_string(), Value::Str(o.request.scenario.to_string())),
        ("status".to_string(), Value::Str(status.to_string())),
        ("source".to_string(), Value::Str(o.source.as_str().to_string())),
    ];
    if o.source == Source::Failed {
        fields.push((
            "error".to_string(),
            Value::Str(o.error.clone().unwrap_or_else(|| "unknown error".to_string())),
        ));
    } else {
        fields.push(("gflops".to_string(), Value::Float(o.gflops)));
        fields.push(("latency_cycles".to_string(), Value::Int(o.latency_cycles as i128)));
        fields.push((
            "solve_ms".to_string(),
            Value::Float(o.solve_time.as_secs_f64() * 1e3),
        ));
        fields.push((
            "queue_ms".to_string(),
            Value::Float(o.queue_time.as_secs_f64() * 1e3),
        ));
    }
    serde::to_string(&Value::Obj(fields))
}

fn error_json(id: u64, kernel: Option<&str>, status: &str, error: &str) -> String {
    let mut fields = vec![("id".to_string(), Value::Int(id as i128))];
    if let Some(k) = kernel {
        fields.push(("kernel".to_string(), Value::Str(k.to_string())));
    }
    fields.push(("status".to_string(), Value::Str(status.to_string())));
    fields.push(("error".to_string(), Value::Str(error.to_string())));
    serde::to_string(&Value::Obj(fields))
}

/// Answer every ticket at the front of `pending` that is already done
/// (responses stay in submission order; solves still overlap freely
/// behind the queue).
fn drain_ready<W: Write>(
    pending: &mut VecDeque<(u64, Ticket)>,
    out: &mut W,
    responded: &mut u64,
) -> Result<()> {
    while pending.front().is_some_and(|(_, t)| t.ready()) {
        let (id, t) = pending.pop_front().expect("front checked");
        let o = t.wait();
        writeln!(out, "{}", outcome_json(id, &o)).context("writing response")?;
        *responded += 1;
    }
    out.flush().context("flushing responses")
}

/// Drive a [`Daemon`] from a newline-delimited-JSON request stream.
///
/// Reads request lines from `input` (see [`parse_line`] for the
/// format; blank lines and `#` comments are skipped), writes one JSON
/// response line per request to `out` *in submission order*, emits a
/// metrics table to stderr every `metrics_every` responses and at
/// shutdown, and consumes the daemon on EOF or `{"cmd":"shutdown"}`.
/// Rejected submissions (queue full, unknown kernel) and unparseable
/// lines get immediate `"rejected"`/`"failed"` response lines; they
/// never stall the stream.
pub fn serve_lines<R: BufRead, W: Write>(
    daemon: Daemon,
    input: R,
    out: &mut W,
) -> Result<ServeMetrics> {
    let metrics_every = daemon.state.opts.metrics_every as u64;
    let mut pending: VecDeque<(u64, Ticket)> = VecDeque::new();
    let mut next_id = 0u64;
    let mut responded = 0u64;
    let mut last_report = 0u64;
    for line in input.lines() {
        let line = line.context("reading request stream")?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        match parse_line(line) {
            Ok(Line::Shutdown) => break,
            Ok(Line::Metrics) => {
                drain_ready(&mut pending, out, &mut responded)?;
                writeln!(out, "{}", serde::to_string(&daemon.metrics().to_value()))
                    .context("writing metrics")?;
                out.flush().context("flushing metrics")?;
            }
            Ok(Line::Request(req)) => {
                let id = next_id;
                next_id += 1;
                match daemon.submit(req.clone()) {
                    Ok(t) => pending.push_back((id, t)),
                    Err(e) => {
                        let status = match e {
                            SubmitError::QueueFull { .. } => "rejected",
                            _ => "failed",
                        };
                        writeln!(
                            out,
                            "{}",
                            error_json(id, Some(&req.kernel), status, &e.to_string())
                        )
                        .context("writing rejection")?;
                        out.flush().context("flushing rejection")?;
                        responded += 1;
                    }
                }
            }
            Err(e) => {
                let id = next_id;
                next_id += 1;
                writeln!(out, "{}", error_json(id, None, "failed", &format!("{e:#}")))
                    .context("writing parse error")?;
                out.flush().context("flushing parse error")?;
                responded += 1;
            }
        }
        drain_ready(&mut pending, out, &mut responded)?;
        if metrics_every > 0 && responded.saturating_sub(last_report) >= metrics_every {
            eprintln!("{}", daemon.metrics().render());
            last_report = responded;
        }
    }
    // EOF (or shutdown command): answer the backlog in order.
    while let Some((id, t)) = pending.pop_front() {
        let o = t.wait();
        writeln!(out, "{}", outcome_json(id, &o)).context("writing response")?;
        responded += 1;
    }
    out.flush().context("flushing responses")?;
    let metrics = daemon.shutdown();
    eprintln!("{}", metrics.render());
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_line_request_defaults() {
        let Line::Request(r) = parse_line(r#"{"kernel":"gemm"}"#).unwrap() else {
            panic!("expected a request");
        };
        assert_eq!(r.kernel, "gemm");
        assert_eq!(r.scenario, Scenario::Rtl);
        assert_eq!(r.model, ExecutionModel::Dataflow);
        assert!(r.overlap);
    }

    #[test]
    fn parse_line_full_request_and_cmds() {
        let line =
            r#"{"kernel":"bicg","scenario":"onboard:2:0.6","model":"sequential","overlap":false}"#;
        let Line::Request(r) = parse_line(line).unwrap() else {
            panic!("expected a request");
        };
        assert_eq!(r.scenario, Scenario::OnBoard { slrs: 2, frac: 0.6 });
        assert_eq!(r.model, ExecutionModel::Sequential);
        assert!(!r.overlap);
        assert!(matches!(parse_line(r#"{"cmd":"metrics"}"#).unwrap(), Line::Metrics));
        assert!(matches!(parse_line(r#"{"cmd":"shutdown"}"#).unwrap(), Line::Shutdown));
        assert!(parse_line(r#"{"cmd":"reboot"}"#).is_err());
        assert!(parse_line("not json").is_err());
        assert!(parse_line(r#"{"scenario":"rtl"}"#).is_err(), "kernel is required");
        assert!(parse_line(r#"{"kernel":"gemm","scenario":"mars"}"#).is_err());
    }

    #[test]
    fn submit_error_display_is_structured() {
        let e = SubmitError::QueueFull { capacity: 4, depth: 4 };
        assert_eq!(e.to_string(), "admission queue full (capacity 4, depth 4)");
        assert_eq!(
            SubmitError::UnknownKernel("nope".into()).to_string(),
            "unknown kernel `nope`"
        );
    }

    #[test]
    fn unknown_kernel_is_rejected_at_submit() {
        let daemon = Daemon::new(
            Device::u55c(),
            QorStore::in_memory(),
            ServeOptions { workers: 0, ..ServeOptions::default() },
        );
        let err = daemon.submit(BatchRequest::new("not-a-kernel", Scenario::Rtl)).unwrap_err();
        assert_eq!(err, SubmitError::UnknownKernel("not-a-kernel".to_string()));
        let m = daemon.shutdown();
        assert_eq!(m.received, 1);
        assert_eq!(m.solved, 0);
    }
}
