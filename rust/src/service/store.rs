//! Concurrent, crash-safe QoR store: a sharded in-memory index over an
//! append-only record log.
#![deny(missing_docs)]
//!
//! The legacy [`QorDb`] persistence (`load` → mutate → `save` of one
//! whole-file JSON document) is a serialization bottleneck and a
//! lost-update hazard under concurrent writers: two processes (or two
//! threads sharing a `&mut QorDb` by turns) that load, solve, and save
//! will each overwrite the other's records last-writer-wins. This
//! module replaces it for every writing path. [`QorStore`] keeps the
//! records in `SHARD_COUNT` independently-locked shards (readers and
//! writers on different keys never contend) and persists every accepted
//! mutation as one appended, fsync'd line — a crash can lose at most
//! the append in flight, never a previously-acknowledged record.
//!
//! ## On-disk log layout
//!
//! Line 1 is a header, then one compact JSON object per line:
//!
//! ```text
//! {"format_version":4,"layout":"qor-log"}
//! {"key":"<canonical key>","record":{"design":{..},"latency_cycles":..,..}}
//! {"key":"<canonical key>","record":null}
//! ```
//!
//! An op with a `record` object is an upsert; `"record":null` is a
//! tombstone (stale-design eviction). The record schema is exactly the
//! [`QorRecord`] JSON of the legacy layout, so `FORMAT_VERSION`
//! versioning carries over unchanged: the header's `format_version`
//! gates the whole log, and a version bump evicts old logs wholesale
//! the same way it evicts old whole-file databases.
//!
//! ## Replay rules (crash safety)
//!
//! [`QorStore::open`] replays the log in order: upserts apply the same
//! never-worse merge as live inserts ([`QorDb::insert_canonical`]), so
//! replay is insensitive to the order in which racing writers reached
//! the log — accepting a worse-but-logged-later record is a no-op.
//! Replay stops at the first line that does not parse as a complete op:
//! a torn tail (the append in flight when the process died, cut at any
//! byte) can never parse as valid JSON, because the parser rejects both
//! truncated documents and trailing garbage. The intact prefix is kept;
//! a writable open truncates the file back to it (and re-terminates a
//! final line that parsed but lost only its newline) so the next append
//! cannot concatenate onto debris. A corrupt *middle* line is treated
//! the same way — everything from the first bad line on is dropped —
//! which only loses data under external corruption, never under a torn
//! append.
//!
//! ## Compaction invariants
//!
//! Superseded upserts and tombstones accumulate; when the log holds
//! more than [`COMPACT_RATIO`]× the live record count (and at least
//! [`COMPACT_MIN_OPS`] ops), the store rewrites it as header + one
//! upsert per live record, atomically (temp sibling + fsync + rename),
//! and keeps appending to the renamed file. Compaction runs with the
//! log lock held (appends wait; reads do not) and changes nothing
//! visible: the replayed state of the compacted log equals the live
//! index at the moment of the snapshot.
//!
//! ## Locking
//!
//! Two lock families, with a strict order: an insert decides
//! acceptance under its *shard* lock, releases it, then appends under
//! the *log* lock — no thread ever waits on the log while holding a
//! shard. Compaction takes the log lock first, then visits shards.
//! One invariant the callers uphold: a tombstone for a key is never
//! issued concurrently with an upsert of the same key (eviction happens
//! on the submit path, before the re-solve that would write the key is
//! enqueued), so log order and index order cannot disagree about
//! whether a key exists.

use super::qor_db::{sibling, DesignKey, QorDb, QorRecord, FORMAT_VERSION};
use crate::dse::config::ExecutionModel;
use anyhow::{Context, Result};
use serde::{Deserialize, Serialize, Value};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Number of index shards. Requests hash to a shard by canonical key;
/// 16 is comfortably past the worker counts the daemon runs with.
const SHARD_COUNT: usize = 16;

/// Auto-compaction floor: never compact a log with fewer total ops
/// than this (tiny logs are cheap to replay and the rewrite would
/// dominate).
pub const COMPACT_MIN_OPS: u64 = 256;

/// Auto-compaction trigger: compact when the log holds more than this
/// many times the live record count (the excess is superseded upserts
/// and tombstones that replay only to be overwritten or dropped).
pub const COMPACT_RATIO: u64 = 4;

/// The concurrent QoR store. Shared by reference across daemon workers
/// and batch threads (`&QorStore` is `Sync`); all methods take `&self`.
pub struct QorStore {
    shards: Vec<Mutex<BTreeMap<String, QorRecord>>>,
    log: Mutex<Option<LogWriter>>,
    compactions: AtomicU64,
}

struct LogWriter {
    path: PathBuf,
    file: File,
    /// Total ops (upserts + tombstones) in the log file right now.
    /// Set to the replayed op count on open and to the live record
    /// count after a compaction.
    ops_in_log: u64,
}

impl QorStore {
    /// An empty, memory-only store (no log; nothing survives drop).
    /// The batch orchestrator uses this when no `--db` is given.
    pub fn in_memory() -> QorStore {
        QorStore::from_db(QorDb::new(), None)
    }

    /// Open (or create) the store at `path`.
    ///
    /// * A log-layout file is replayed (see module docs); a torn tail
    ///   is truncated away.
    /// * A legacy whole-file v`FORMAT_VERSION` JSON database is
    ///   migrated in place to the log layout (atomic rewrite) — the
    ///   one-way door off the lost-update-prone format.
    /// * A corrupt or wrong-version file is moved aside to
    ///   `<path>.bak` (never destroyed) and the store starts empty,
    ///   matching [`QorDb::save`]'s philosophy.
    pub fn open(path: &Path) -> Result<QorStore> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        match sniff(&bytes) {
            Layout::Empty => QorStore::create_fresh(path, QorDb::new()),
            Layout::Log(rep) => {
                let mut file = OpenOptions::new()
                    .write(true)
                    .open(path)
                    .with_context(|| format!("opening {} for append", path.display()))?;
                let mut dirty = false;
                if rep.intact_len < bytes.len() as u64 {
                    file.set_len(rep.intact_len)
                        .with_context(|| format!("truncating torn tail of {}", path.display()))?;
                    eprintln!(
                        "warning: {}: dropped torn log tail ({} of {} bytes intact)",
                        path.display(),
                        rep.intact_len,
                        bytes.len()
                    );
                    dirty = true;
                }
                file.seek(SeekFrom::End(0))
                    .with_context(|| format!("seeking to end of {}", path.display()))?;
                if !rep.terminated {
                    // Final line parsed as a complete op but lost its
                    // newline to the crash: re-terminate it so the next
                    // append starts a fresh line.
                    file.write_all(b"\n")
                        .with_context(|| format!("re-terminating {}", path.display()))?;
                    dirty = true;
                }
                if dirty {
                    file.sync_data()
                        .with_context(|| format!("syncing recovered {}", path.display()))?;
                }
                let writer =
                    LogWriter { path: path.to_path_buf(), file, ops_in_log: rep.ops };
                Ok(QorStore::from_db(rep.db, Some(writer)))
            }
            Layout::Legacy(db) => {
                let n = db.len();
                let store = QorStore::create_fresh(path, db)?;
                eprintln!(
                    "note: {}: migrated legacy whole-file QoR DB ({n} records) to the \
                     append-only log layout",
                    path.display()
                );
                Ok(store)
            }
            Layout::LogWrongVersion(v) => {
                let bak = back_up(path, &format!("v{v} log"))?;
                eprintln!(
                    "warning: {} is a v{v} QoR log (expected v{FORMAT_VERSION}); moved to {} \
                     and starting empty",
                    path.display(),
                    bak.display()
                );
                QorStore::create_fresh(path, QorDb::new())
            }
            Layout::Unreadable => {
                let bak = back_up(path, "unreadable file")?;
                eprintln!(
                    "warning: {} was not a readable QoR store; moved to {} and starting empty",
                    path.display(),
                    bak.display()
                );
                QorStore::create_fresh(path, QorDb::new())
            }
        }
    }

    /// Build a store over `db`'s records with a freshly (re)written log
    /// at `path` containing exactly those records.
    fn create_fresh(path: &Path, db: QorDb) -> Result<QorStore> {
        let records: Vec<(String, QorRecord)> =
            db.iter().map(|(k, r)| (k.to_string(), r.clone())).collect();
        let file = write_log_file(path, &records)?;
        let writer =
            LogWriter { path: path.to_path_buf(), file, ops_in_log: records.len() as u64 };
        Ok(QorStore::from_db(db, Some(writer)))
    }

    fn from_db(db: QorDb, writer: Option<LogWriter>) -> QorStore {
        let store = QorStore {
            shards: (0..SHARD_COUNT).map(|_| Mutex::new(BTreeMap::new())).collect(),
            log: Mutex::new(writer),
            compactions: AtomicU64::new(0),
        };
        for (k, r) in db.iter() {
            store.shard(k).lock().unwrap().insert(k.to_string(), r.clone());
        }
        store
    }

    fn shard(&self, key: &str) -> &Mutex<BTreeMap<String, QorRecord>> {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % SHARD_COUNT]
    }

    /// Whether the store is backed by a log file (false for
    /// [`QorStore::in_memory`]).
    pub fn is_persistent(&self) -> bool {
        self.log.lock().unwrap().is_some()
    }

    /// Number of live records.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Whether the store holds no records.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.lock().unwrap().is_empty())
    }

    /// Exact-hit lookup (cloned out of the shard; records are small
    /// next to a solve).
    pub fn get(&self, key: &DesignKey) -> Option<QorRecord> {
        self.get_canonical(&key.canonical())
    }

    /// Exact-hit lookup by canonical string.
    pub fn get_canonical(&self, key: &str) -> Option<QorRecord> {
        self.shard(key).lock().unwrap().get(key).cloned()
    }

    /// Insert `rec` under `key`, keeping the better (lower-latency)
    /// record if one is already present — the same never-worse merge as
    /// [`QorDb::insert_canonical`]. Returns `Ok(true)` if the store
    /// changed; an accepted record is fsync'd to the log before the
    /// call returns (durable once acknowledged).
    pub fn insert(&self, key: &DesignKey, rec: QorRecord) -> Result<bool> {
        self.insert_canonical(&key.canonical(), rec)
    }

    /// [`QorStore::insert`] under a pre-canonicalized key (the service
    /// paths carry canonical strings across threads).
    pub fn insert_canonical(&self, key: &str, rec: QorRecord) -> Result<bool> {
        // Serialize before taking any lock: the append line is built
        // outside both the shard and log critical sections.
        let line = op_line(key, Some(&rec));
        let accepted = {
            let mut shard = self.shard(key).lock().unwrap();
            match shard.get(key) {
                Some(old) if old.latency_cycles <= rec.latency_cycles => false,
                _ => {
                    shard.insert(key.to_string(), rec);
                    true
                }
            }
        };
        if accepted {
            self.append(&line)?;
            self.maybe_compact()?;
        }
        Ok(accepted)
    }

    /// Drop a record (stale-design eviction), logging a tombstone.
    /// Returns `Ok(true)` if a record was present. Callers must not
    /// race this against an insert of the same key (see module docs).
    pub fn remove_canonical(&self, key: &str) -> Result<bool> {
        let removed = self.shard(key).lock().unwrap().remove(key).is_some();
        if removed {
            self.append(&op_line(key, None))?;
            self.maybe_compact()?;
        }
        Ok(removed)
    }

    /// Best stored design for warm-starting a request on `kernel` whose
    /// fusion plan the caller's solve can use — the concurrent
    /// counterpart of [`QorDb::incumbent_for_space`]. Scans all shards;
    /// the snapshot is per-shard consistent, which is all warm-starting
    /// needs (the solver's usability gate re-checks the winner anyway).
    pub fn incumbent_for_space(
        &self,
        kernel: &str,
        model: ExecutionModel,
        overlap: bool,
        usable_plan: impl Fn(&crate::analysis::fusion::FusionPlan) -> bool,
    ) -> Option<QorRecord> {
        let mut best: Option<QorRecord> = None;
        for s in &self.shards {
            let shard = s.lock().unwrap();
            for r in shard.values() {
                let matches = r.design.kernel == kernel
                    && r.design.model == model
                    && r.design.overlap == overlap
                    && usable_plan(&r.design.fusion);
                let better = match &best {
                    None => true,
                    Some(b) => r.latency_cycles < b.latency_cycles,
                };
                if matches && better {
                    best = Some(r.clone());
                }
            }
        }
        best
    }

    /// A point-in-time copy of the live index as a legacy [`QorDb`]
    /// (per-shard consistent). Read paths that want one coherent view —
    /// reports, `db` listings — go through this.
    pub fn snapshot(&self) -> QorDb {
        let mut db = QorDb::new();
        for s in &self.shards {
            let shard = s.lock().unwrap();
            for (k, r) in shard.iter() {
                db.insert_canonical(k.clone(), r.clone());
            }
        }
        db
    }

    /// Total ops currently in the log file, or `None` for an in-memory
    /// store. Feeds the daemon metrics report.
    pub fn log_ops(&self) -> Option<u64> {
        self.log.lock().unwrap().as_ref().map(|w| w.ops_in_log)
    }

    /// Compactions performed since open.
    pub fn compactions(&self) -> u64 {
        self.compactions.load(Ordering::Relaxed)
    }

    /// Rewrite the log as header + one upsert per live record, atomic
    /// via a temp sibling + rename. No-op for in-memory stores. The
    /// replayed state of the compacted log equals the live index at the
    /// snapshot (see module docs).
    pub fn compact(&self) -> Result<()> {
        self.compact_inner(false)
    }

    fn maybe_compact(&self) -> Result<()> {
        self.compact_inner(true)
    }

    fn compact_inner(&self, only_if_due: bool) -> Result<()> {
        // Lock order: log first, then shards (never the reverse).
        let mut guard = self.log.lock().unwrap();
        let Some(w) = guard.as_mut() else { return Ok(()) };
        if only_if_due {
            let live = self.len() as u64;
            if w.ops_in_log < COMPACT_MIN_OPS || w.ops_in_log <= COMPACT_RATIO.saturating_mul(live)
            {
                return Ok(());
            }
        }
        let mut records: Vec<(String, QorRecord)> = Vec::new();
        for s in &self.shards {
            let shard = s.lock().unwrap();
            records.extend(shard.iter().map(|(k, r)| (k.clone(), r.clone())));
        }
        records.sort_by(|a, b| a.0.cmp(&b.0));
        let file = write_log_file(&w.path, &records)
            .with_context(|| format!("compacting {}", w.path.display()))?;
        w.file = file;
        w.ops_in_log = records.len() as u64;
        self.compactions.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn append(&self, line: &str) -> Result<()> {
        let mut guard = self.log.lock().unwrap();
        let Some(w) = guard.as_mut() else { return Ok(()) };
        w.file
            .write_all(line.as_bytes())
            .and_then(|()| w.file.sync_data())
            .with_context(|| format!("appending to {}", w.path.display()))?;
        w.ops_in_log += 1;
        Ok(())
    }
}

impl std::fmt::Debug for QorStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("QorStore")
            .field("len", &self.len())
            .field("persistent", &self.is_persistent())
            .finish()
    }
}

// ---- log lines ---------------------------------------------------------

fn header_line() -> String {
    let v = Value::Obj(vec![
        ("format_version".to_string(), FORMAT_VERSION.serialize()),
        ("layout".to_string(), Value::Str("qor-log".to_string())),
    ]);
    let mut s = serde::to_string(&v);
    s.push('\n');
    s
}

/// One op line, newline-terminated. `None` record = tombstone.
fn op_line(key: &str, rec: Option<&QorRecord>) -> String {
    let record = match rec {
        Some(r) => r.serialize(),
        None => Value::Null,
    };
    let v = Value::Obj(vec![
        ("key".to_string(), Value::Str(key.to_string())),
        ("record".to_string(), record),
    ]);
    let mut s = serde::to_string(&v);
    s.push('\n');
    s
}

fn parse_op(line: &str) -> Result<(String, Option<QorRecord>), serde::Error> {
    let v = serde::parse(line)?;
    let key = String::deserialize(v.field("key")?)?;
    let rec = match v.field("record")? {
        Value::Null => None,
        other => Some(QorRecord::deserialize(other)?),
    };
    Ok((key, rec))
}

/// Write `records` as a complete log file at `path`, atomically, and
/// return the file handle (positioned at end) for further appends.
fn write_log_file(path: &Path, records: &[(String, QorRecord)]) -> Result<File> {
    let tmp = sibling(path, ".compact");
    let mut buf = header_line();
    for (k, r) in records {
        buf.push_str(&op_line(k, Some(r)));
    }
    let mut file =
        File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
    file.write_all(buf.as_bytes())
        .and_then(|()| file.sync_all())
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} to {}", tmp.display(), path.display()))?;
    // Durability of the rename itself: fsync the directory, best-effort
    // (not all platforms allow opening a directory for sync).
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            if let Ok(dir) = File::open(parent) {
                let _ = dir.sync_all();
            }
        }
    }
    Ok(file)
}

fn back_up(path: &Path, what: &str) -> Result<PathBuf> {
    let bak = sibling(path, ".bak");
    std::fs::rename(path, &bak)
        .with_context(|| format!("backing up {what} to {}", bak.display()))?;
    Ok(bak)
}

// ---- layout sniffing (shared with QorDb::load) -------------------------

/// What a QoR file on disk turned out to be.
enum Layout {
    /// Current-version append-only log; carries the replayed state.
    Log(Replay),
    /// A log header with a different `format_version`.
    LogWrongVersion(u64),
    /// Legacy whole-file v`FORMAT_VERSION` JSON database.
    Legacy(QorDb),
    /// Missing, empty, or whitespace-only.
    Empty,
    /// Neither layout parses.
    Unreadable,
}

/// Result of replaying a log's intact prefix.
struct Replay {
    /// State after applying every intact op in order.
    db: QorDb,
    /// Ops applied.
    ops: u64,
    /// Bytes of intact prefix (truncation target for a writable open).
    intact_len: u64,
    /// Whether the intact prefix ends with a newline.
    terminated: bool,
}

fn sniff(bytes: &[u8]) -> Layout {
    if bytes.iter().all(|b| b.is_ascii_whitespace()) {
        return Layout::Empty;
    }
    let first_end = bytes.iter().position(|&b| b == b'\n').unwrap_or(bytes.len());
    if let Ok(first) = std::str::from_utf8(&bytes[..first_end]) {
        if let Ok(v) = serde::parse(first.trim()) {
            if v.get("layout").and_then(Value::as_str) == Some("qor-log") {
                let version =
                    v.get("format_version").and_then(Value::as_int).unwrap_or(-1);
                if version != FORMAT_VERSION as i128 {
                    return Layout::LogWrongVersion(version.max(0) as u64);
                }
                return Layout::Log(replay(bytes, first_end));
            }
        }
    }
    if let Ok(text) = std::str::from_utf8(bytes) {
        if let Ok(db) = serde::parse(text).and_then(|v| QorDb::from_value(&v)) {
            return Layout::Legacy(db);
        }
    }
    Layout::Unreadable
}

fn replay(bytes: &[u8], header_end: usize) -> Replay {
    let mut db = QorDb::new();
    let mut ops = 0u64;
    let mut pos = (header_end + 1).min(bytes.len());
    let mut intact = pos as u64;
    let mut terminated = header_end < bytes.len();
    while pos < bytes.len() {
        let (slice, next, has_nl) = match bytes[pos..].iter().position(|&b| b == b'\n') {
            Some(i) => (&bytes[pos..pos + i], pos + i + 1, true),
            None => (&bytes[pos..], bytes.len(), false),
        };
        let Ok(text) = std::str::from_utf8(slice) else { break };
        let trimmed = text.trim();
        if !trimmed.is_empty() {
            let Ok((key, rec)) = parse_op(trimmed) else { break };
            match rec {
                Some(r) => {
                    db.insert_canonical(key, r);
                }
                None => {
                    db.remove_canonical(&key);
                }
            }
            ops += 1;
        }
        intact = next as u64;
        terminated = has_nl;
        pos = next;
    }
    Replay { db, ops, intact_len: intact, terminated }
}

/// Read a QoR file in *either* layout into a legacy [`QorDb`], without
/// touching the file. `None` when neither layout parses (including a
/// wrong-version log — same eviction semantics as the whole-file
/// version check). [`QorDb::load`] delegates here so the `db`
/// subcommand and every legacy read path understand log-layout stores.
pub(crate) fn read_any_layout(bytes: &[u8]) -> Option<QorDb> {
    match sniff(bytes) {
        Layout::Log(rep) => Some(rep.db),
        Layout::Legacy(db) => Some(db),
        Layout::LogWrongVersion(_) | Layout::Empty | Layout::Unreadable => None,
    }
}

/// Whether `bytes` carry a log-layout header (any version).
/// [`QorDb::save`] refuses to overwrite such files — that would
/// silently downgrade a concurrent-safe store to the lost-update-prone
/// whole-file format.
pub(crate) fn is_log_layout(bytes: &[u8]) -> bool {
    matches!(sniff(bytes), Layout::Log(_) | Layout::LogWrongVersion(_))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::fusion::FusionPlan;
    use crate::dse::config::{DesignConfig, TaskConfig, TransferPlan};

    fn sample_record(kernel: &str, latency: u64) -> QorRecord {
        let mut plans = BTreeMap::new();
        plans.insert(
            "A".to_string(),
            TransferPlan { define_level: 0, transfer_level: 1, bitwidth: 256, buffers: 2 },
        );
        QorRecord {
            design: DesignConfig {
                kernel: kernel.to_string(),
                model: ExecutionModel::Dataflow,
                overlap: true,
                fusion: FusionPlan::new(vec![vec![0]]),
                tasks: vec![TaskConfig {
                    task: 0,
                    perm: vec![0, 1],
                    padded_trip: vec![latency.max(2), 8],
                    intra: vec![1, 2],
                    ii: 3,
                    plans,
                    slr: 0,
                }],
            },
            latency_cycles: latency,
            gflops: 10.5,
            solve_time_ms: 1.0,
            explored: 100,
            timed_out: false,
            warm_started: false,
            fusion_variants: 1,
        }
    }

    fn tmp_path(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("prometheus_store_{}_{}.qordb", tag, std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn in_memory_never_worse_merge() {
        let store = QorStore::in_memory();
        assert!(store.insert_canonical("k", sample_record("gemm", 1000)).unwrap());
        assert!(!store.insert_canonical("k", sample_record("gemm", 2000)).unwrap());
        assert_eq!(store.get_canonical("k").unwrap().latency_cycles, 1000);
        assert!(store.insert_canonical("k", sample_record("gemm", 500)).unwrap());
        assert_eq!(store.len(), 1);
        assert!(!store.is_persistent());
        assert!(store.log_ops().is_none());
    }

    #[test]
    fn open_insert_reopen_round_trips() {
        let path = tmp_path("roundtrip");
        {
            let store = QorStore::open(&path).unwrap();
            assert!(store.is_empty());
            store.insert_canonical("a", sample_record("gemm", 100)).unwrap();
            store.insert_canonical("b", sample_record("bicg", 200)).unwrap();
            store.insert_canonical("a", sample_record("gemm", 50)).unwrap();
            assert_eq!(store.log_ops(), Some(3));
        }
        let store = QorStore::open(&path).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.get_canonical("a").unwrap().latency_cycles, 50);
        assert_eq!(store.get_canonical("b").unwrap().latency_cycles, 200);
        assert_eq!(store.log_ops(), Some(3), "replay counts every logged op");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tombstones_survive_reopen() {
        let path = tmp_path("tombstone");
        {
            let store = QorStore::open(&path).unwrap();
            store.insert_canonical("a", sample_record("gemm", 100)).unwrap();
            assert!(store.remove_canonical("a").unwrap());
            assert!(!store.remove_canonical("a").unwrap(), "double-remove is a no-op");
        }
        let store = QorStore::open(&path).unwrap();
        assert!(store.get_canonical("a").is_none(), "tombstone replays");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compaction_preserves_visible_state_and_shrinks_log() {
        let path = tmp_path("compact");
        let store = QorStore::open(&path).unwrap();
        for i in 0..20u64 {
            store.insert_canonical("hot", sample_record("gemm", 1000 - i)).unwrap();
        }
        store.insert_canonical("cold", sample_record("bicg", 7)).unwrap();
        store.remove_canonical("cold").unwrap();
        let before = store.snapshot();
        assert_eq!(store.log_ops(), Some(22));
        store.compact().unwrap();
        assert_eq!(store.compactions(), 1);
        assert_eq!(store.log_ops(), Some(1), "one live record after compaction");
        assert_eq!(store.snapshot(), before, "compaction changes nothing visible");
        drop(store);
        let store = QorStore::open(&path).unwrap();
        assert_eq!(store.snapshot(), before, "compacted log replays to same state");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn legacy_whole_file_db_migrates_to_log() {
        let path = tmp_path("migrate");
        let mut db = QorDb::new();
        db.insert_canonical("k1".to_string(), sample_record("gemm", 10));
        db.insert_canonical("k2".to_string(), sample_record("bicg", 20));
        db.save(&path).unwrap();
        let store = QorStore::open(&path).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.get_canonical("k1").unwrap().latency_cycles, 10);
        drop(store);
        let bytes = std::fs::read(&path).unwrap();
        assert!(is_log_layout(&bytes), "migration rewrote the file as a log");
        // and the legacy read path still understands the new layout
        let db = QorDb::load(&path);
        assert_eq!(db.len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unreadable_files_are_moved_aside_not_destroyed() {
        let path = tmp_path("unreadable");
        std::fs::write(&path, "not json at all").unwrap();
        let store = QorStore::open(&path).unwrap();
        assert!(store.is_empty());
        let bak = sibling(&path, ".bak");
        assert_eq!(std::fs::read_to_string(&bak).unwrap(), "not json at all");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&bak);
    }

    #[test]
    fn wrong_version_log_is_evicted_wholesale() {
        let path = tmp_path("wrongver");
        std::fs::write(
            &path,
            "{\"format_version\":3,\"layout\":\"qor-log\"}\n",
        )
        .unwrap();
        let store = QorStore::open(&path).unwrap();
        assert!(store.is_empty());
        assert!(sibling(&path, ".bak").exists());
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&sibling(&path, ".bak"));
    }

    #[test]
    fn incumbent_for_space_matches_legacy_semantics() {
        let store = QorStore::in_memory();
        store.insert_canonical("a", sample_record("gemm", 1000)).unwrap();
        store.insert_canonical("b", sample_record("gemm", 700)).unwrap();
        store.insert_canonical("c", sample_record("bicg", 10)).unwrap();
        let inc = store
            .incumbent_for_space("gemm", ExecutionModel::Dataflow, true, |_| true)
            .unwrap();
        assert_eq!(inc.latency_cycles, 700);
        assert!(store
            .incumbent_for_space("gemm", ExecutionModel::Sequential, true, |_| true)
            .is_none());
    }
}
