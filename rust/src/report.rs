//! Paper-shaped table formatting: every bench target renders its rows
//! through these helpers so the output mirrors the paper's tables.

/// A simple fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let sep: String = width
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

/// Format a GF/s value the way the paper prints them.
pub fn gfs(v: f64) -> String {
    format!("{v:.2}")
}

/// Format a ratio as `N.NNx`.
pub fn ratio(v: f64) -> String {
    format!("{v:.2}x")
}

/// Geometric mean of positive values.
pub fn gmean(vals: &[f64]) -> f64 {
    if vals.is_empty() {
        return 0.0;
    }
    let s: f64 = vals.iter().map(|v| v.max(1e-12).ln()).sum();
    (s / vals.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(vals: &[f64]) -> f64 {
    if vals.is_empty() {
        return 0.0;
    }
    vals.iter().sum::<f64>() / vals.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Kernel", "GF/s"]);
        t.row(vec!["3mm".into(), "368.36".into()]);
        t.row(vec!["gemm-long-name".into(), "419.14".into()]);
        let s = t.render();
        assert!(s.contains("Kernel"));
        assert!(s.contains("368.36"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all rows equal width
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn stats() {
        assert!((gmean(&[1.0, 4.0]) - 2.0).abs() < 1e-9);
        assert!((mean(&[1.0, 3.0]) - 2.0).abs() < 1e-9);
        assert_eq!(gmean(&[]), 0.0);
    }
}
