//! Padding machinery (paper §2.1.6, Fig 1, Listing 1, Eqs 1–2).
//!
//! Computation padding expands the set of legal unroll factors: a loop of
//! trip 190 admits `UF ∈ {1,2,5,10,19,38,95,190}`, but padded to 192 it
//! admits `{1,2,3,4,6,8,12,16,24,32,48,64,96,192}`. Communication padding
//! aligns last-dimension tile sizes so wider power-of-two bursts divide
//! the transfer.

/// All divisors of `n`, ascending.
pub fn divisors(n: u64) -> Vec<u64> {
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d != n / d {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// A legal (intra-tile factor, padded trip) pair for one loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FactorChoice {
    /// Intra-tile trip count = unroll contribution.
    pub intra: u64,
    /// The padded total trip this factor divides (= original when no
    /// padding is needed).
    pub padded: u64,
}

/// Enumerate legal intra-tile factors for a loop of original trip `trip`,
/// padding by at most `max_pad` extra iterations (Eq 2's user bound `N`).
/// For each candidate factor the *smallest* sufficient padding is chosen,
/// so the wasted work term is minimal. Factors above `max_factor` are
/// dropped (they exceed any practical unroll budget).
pub fn legal_intra_factors(trip: u64, max_pad: u64, max_factor: u64) -> Vec<FactorChoice> {
    let mut best: Vec<FactorChoice> = Vec::new();
    for pad in 0..=max_pad {
        let t = trip + pad;
        for d in divisors(t) {
            if d > max_factor {
                continue;
            }
            match best.iter_mut().find(|c| c.intra == d) {
                Some(c) => {
                    if t < c.padded {
                        c.padded = t;
                    }
                }
                None => best.push(FactorChoice { intra: d, padded: t }),
            }
        }
    }
    best.sort_by_key(|c| c.intra);
    best
}

/// Smallest padded extent `≥ n` such that `extent * elem_bits` is
/// divisible by `burst_bits` — communication padding (Fig 1). Returns the
/// padded extent; the caller decides whether the extra traffic is worth
/// the wider burst.
pub fn pad_for_burst(n: u64, elem_bits: u64, burst_bits: u64) -> u64 {
    let elems_per_burst = burst_bits / elem_bits; // e.g. 512/32 = 16
    if elems_per_burst == 0 {
        return n;
    }
    n.div_ceil(elems_per_burst) * elems_per_burst
}

/// The widest burst (from `candidates`, descending trial) whose element
/// count divides `extent` — Eq 3's max-b rule.
pub fn best_bitwidth(extent: u64, elem_bits: u64, max_bits: u64) -> u64 {
    let mut bits = max_bits;
    while bits > elem_bits {
        if extent % (bits / elem_bits) == 0 {
            return bits;
        }
        bits /= 2;
    }
    elem_bits
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(190), vec![1, 2, 5, 10, 19, 38, 95, 190]);
    }

    #[test]
    fn listing1_unroll_space() {
        // Paper Listing 1: trip 190 unpadded vs padded to 192.
        let unpadded: Vec<u64> =
            legal_intra_factors(190, 0, 190).into_iter().map(|c| c.intra).collect();
        assert_eq!(unpadded, vec![1, 2, 5, 10, 19, 38, 95, 190]);

        let padded = legal_intra_factors(190, 2, 192);
        let factors: Vec<u64> = padded.iter().map(|c| c.intra).collect();
        for f in [3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 192] {
            assert!(factors.contains(&f), "factor {f} missing after padding");
        }
        // the factor 32 should use the minimal pad (192)
        let c32 = padded.iter().find(|c| c.intra == 32).unwrap();
        assert_eq!(c32.padded, 192);
        // factors that were already legal keep zero padding
        let c19 = padded.iter().find(|c| c.intra == 19).unwrap();
        assert_eq!(c19.padded, 190);
    }

    #[test]
    fn fig1_communication_padding() {
        // Paper §2.1.6: J=190 floats — 190*32 divisible by 64 not 128; with
        // P=2 → 192*32 divisible by 512.
        assert_eq!(best_bitwidth(190, 32, 512), 64);
        assert_eq!(pad_for_burst(190, 32, 512), 192);
        assert_eq!(best_bitwidth(192, 32, 512), 512);
    }

    #[test]
    fn max_factor_is_enforced() {
        let f = legal_intra_factors(1024, 0, 64);
        assert!(f.iter().all(|c| c.intra <= 64));
    }

    #[test]
    fn minimal_padding_is_chosen() {
        // trip=10, factor 4 needs pad to 12 even if 16 also divisible.
        let f = legal_intra_factors(10, 8, 16);
        let c4 = f.iter().find(|c| c.intra == 4).unwrap();
        assert_eq!(c4.padded, 12);
    }
}
