//! Design-space exploration: the unified optimization space of Table 2,
//! the constraints of Eqs 1–11, the latency cost model of Eqs 12–16, and
//! the solver that replaces AMPL+Gurobi with an exact combinatorial
//! branch-and-bound over the same (finite, discrete) space.

pub mod config;
pub mod constraints;
pub mod cost;
pub mod padding;
pub mod permutation;
pub mod solver;
pub mod space;

pub use config::{DesignConfig, ExecutionModel, TaskConfig, TransferPlan};
pub use solver::{solve, SolverOptions, SolverResult};
