//! Design-space exploration: the unified optimization space of Table 2,
//! the constraints of Eqs 1–11, the latency cost model of Eqs 12–16, the
//! shared evaluation core ([`eval`]) every consumer reads its resolved
//! design from, and the solver that replaces AMPL+Gurobi with an exact
//! combinatorial branch-and-bound over the same (finite, discrete) space.

pub mod config;
pub mod constraints;
pub mod cost;
// The evaluation core is the one place plans are resolved; it is held
// to a stricter bar than the inherited tree (CI runs clippy blocking
// for this module, advisory elsewhere).
#[deny(clippy::all)]
pub mod eval;
pub mod padding;
pub mod permutation;
pub mod solver;
pub mod space;

pub use config::{DesignConfig, ExecutionModel, TaskConfig, TransferPlan};
pub use eval::{FusionSpace, FusionVariant, GeometryCache, ResolvedDesign, ResolvedTask};
pub use solver::{solve, solve_space, solve_with_cache, SolverError, SolverOptions, SolverResult};
