//! The design-space solver — the reproduction's substitute for
//! AMPL + Gurobi (paper §6.1).
//!
//! The paper's "NLP" is a nonconvex quadratic program over *discrete*
//! decision variables (divisor-constrained tile factors, permutation
//! choices, transfer levels, SLR ids); Gurobi solves it by spatial
//! branch-and-bound. We solve the same space with an explicit two-stage
//! combinatorial branch-and-bound:
//!
//! 1. **per-task enumeration** — tile factors (with padding, Eqs 1–2) ×
//!    legal permutations × transfer plans (Eqs 5–6), filtered by the
//!    resource constraints (Eqs 7–10), reduced to a Pareto front over
//!    (latency, DSP, BRAM);
//! 2. **global assembly** — DFS over per-task candidates and SLR
//!    assignments (Eq 11) minimizing the DAG latency (Eqs 12–13) under
//!    per-region budgets, with branch-and-bound pruning.
//!
//! The inner loop is incremental on top of the shared evaluation core
//! ([`super::eval`]): the configuration-independent parts (array infos,
//! access translations, legal orders) are memoized at fusion time in a
//! [`GeometryCache`], so per-candidate evaluation only recomputes what
//! a changed tile factor/permutation/plan invalidates. `solve` builds
//! the cache itself; [`solve_with_cache`] lets callers (the coordinator
//! flow, `service::batch` worker pools) share one cache per kernel
//! across solves.
//!
//! A timeout makes the solver *anytime*: it returns the incumbent with
//! `timed_out = true`, mirroring the paper's Gurobi-timeout mode (§6.4).

use super::config::{DesignConfig, ExecutionModel, TaskConfig, TransferPlan};
use super::constraints::task_resources;
use super::cost::{gflops, graph_latency_resolved, task_latency, GraphLatency};
use super::eval::{self, GeometryCache, ResolvedDesign, TaskStatics};
use super::padding::legal_intra_factors;
use crate::analysis::fusion::{fuse, FusedGraph};
use crate::hw::resources::ResourceVec;
use crate::hw::{Device, SlrBudget};
use crate::ir::Kernel;
use crate::sim::engine::simulate_resolved;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Resource scenario the solver targets.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scenario {
    /// RTL simulation: the whole device as one region (paper §6.2 gives
    /// every framework all U55C resources for RTL comparison).
    Rtl,
    /// On-board: `slrs` usable regions, each capped at `frac` utilization.
    OnBoard { slrs: usize, frac: f64 },
}

impl std::fmt::Display for Scenario {
    /// Canonical text form, also used by the QoR-DB cache key:
    /// `rtl` or `onboard:<slrs>:<frac>`.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Scenario::Rtl => write!(f, "rtl"),
            Scenario::OnBoard { slrs, frac } => write!(f, "onboard:{slrs}:{frac}"),
        }
    }
}

// Manual `serde` impls (the vendored serde has no derive proc-macro):
// part of the serde coverage for the design-space types (DesignConfig,
// TaskConfig, TransferPlan, ExecutionModel, Scenario). Today's QoR-DB
// records reach Scenario only through the canonical key string, but the
// impls keep the type ready for richer record schemas; the round-trip
// is pinned by `scenario_serde_round_trip` below.
impl serde::Serialize for Scenario {
    fn serialize(&self) -> serde::Value {
        match self {
            Scenario::Rtl => serde::Value::Obj(vec![(
                "kind".to_string(),
                serde::Value::Str("rtl".to_string()),
            )]),
            Scenario::OnBoard { slrs, frac } => serde::Value::Obj(vec![
                ("kind".to_string(), serde::Value::Str("onboard".to_string())),
                ("slrs".to_string(), serde::Serialize::serialize(slrs)),
                ("frac".to_string(), serde::Serialize::serialize(frac)),
            ]),
        }
    }
}

impl serde::Deserialize for Scenario {
    fn deserialize(v: &serde::Value) -> Result<Scenario, serde::Error> {
        match v.field("kind")?.as_str() {
            Some("rtl") => Ok(Scenario::Rtl),
            Some("onboard") => Ok(Scenario::OnBoard {
                slrs: serde::Deserialize::deserialize(v.field("slrs")?)?,
                frac: serde::Deserialize::deserialize(v.field("frac")?)?,
            }),
            other => Err(serde::Error::new(format!("invalid scenario kind {other:?}"))),
        }
    }
}

/// Solver knobs. Baselines restrict this space to mimic each framework.
#[derive(Debug, Clone)]
pub struct SolverOptions {
    pub scenario: Scenario,
    pub model: ExecutionModel,
    /// Computation/communication overlap (ping-pong buffering).
    pub overlap: bool,
    /// Allow computation padding (Eq 2 bound; 0 disables).
    pub max_pad: u64,
    /// Allow loop permutation.
    pub permute: bool,
    /// Allow data tiling (false = whole-array buffers, on-chip style).
    pub tiling: bool,
    /// Cap on per-loop intra factors.
    pub max_factor_per_loop: u64,
    /// Cap on the task unroll factor (product of intra factors).
    pub max_unroll: u64,
    /// Candidates kept per task after stage 1.
    pub beam: usize,
    /// Anytime timeout.
    pub timeout: Duration,
    /// Warm-start incumbent (service layer: a previously-solved design
    /// from the QoR knowledge base). When structurally valid and feasible
    /// for this scenario it seeds the branch-and-bound bound, so the DFS
    /// prunes against it from the first node and the solver can never
    /// return a worse design than the incumbent. Ignored (never copied
    /// into the result blindly) when it does not fit the scenario.
    pub incumbent: Option<DesignConfig>,
}

impl Default for SolverOptions {
    fn default() -> Self {
        SolverOptions {
            scenario: Scenario::Rtl,
            model: ExecutionModel::Dataflow,
            overlap: true,
            max_pad: 16,
            permute: true,
            tiling: true,
            max_factor_per_loop: 128,
            max_unroll: 4096,
            beam: 192,
            timeout: Duration::from_secs(120),
            incumbent: None,
        }
    }
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct SolverResult {
    pub design: DesignConfig,
    pub latency: GraphLatency,
    pub gflops: f64,
    pub solve_time: Duration,
    /// Design points evaluated.
    pub explored: u64,
    pub timed_out: bool,
    /// Whether a usable `SolverOptions::incumbent` actually seeded the
    /// branch-and-bound bound (false when no incumbent was given *or*
    /// the given one was rejected as structurally invalid/infeasible).
    pub warm_started: bool,
}

/// One per-task candidate with its standalone metrics.
#[derive(Debug, Clone)]
struct Candidate {
    cfg: TaskConfig,
    latency: u64,
    res: ResourceVec,
}

/// Region budget for the scenario.
pub fn region_budget(dev: &Device, scenario: Scenario) -> (usize, SlrBudget) {
    match scenario {
        Scenario::Rtl => (1, dev.total()),
        Scenario::OnBoard { slrs, frac } => (slrs.min(dev.slrs), dev.slr.scaled(frac)),
    }
}

/// Whether `design` is servable under `scenario` on the *current*
/// resource model: structural validation, SLR ids within the scenario's
/// regions, and per-region feasibility. The single predicate behind
/// both the solver's warm-start incumbent gate and the QoR cache's
/// hit/stale check — keep them from drifting by construction.
pub fn design_usable(
    k: &Kernel,
    fg: &FusedGraph,
    design: &DesignConfig,
    dev: &Device,
    scenario: Scenario,
) -> bool {
    let cache = GeometryCache::new(k, fg);
    design_usable_with_cache(k, fg, &cache, design, dev, scenario)
}

/// [`design_usable`] over a pre-built geometry cache — the warm-start
/// gate, the cached flow and the batch orchestrator all hold one.
pub fn design_usable_with_cache(
    k: &Kernel,
    fg: &FusedGraph,
    cache: &GeometryCache,
    design: &DesignConfig,
    dev: &Device,
    scenario: Scenario,
) -> bool {
    let (regions, budget) = region_budget(dev, scenario);
    // structural validation first: resolution indexes the cache by task
    // id, which is only safe on a validated design
    design.validate(k, fg, dev.slrs).is_ok()
        && design.tasks.iter().all(|t| t.slr < regions)
        && {
            let rd = ResolvedDesign::new(k, fg, cache, design);
            crate::dse::constraints::feasible_resolved(&rd, dev, &budget)
        }
}

/// Solve the design space for `k`. Returns the best feasible design
/// found. Builds the fusion and geometry cache itself; callers that
/// solve the same kernel repeatedly should build both once and use
/// [`solve_with_cache`].
pub fn solve(k: &Kernel, dev: &Device, opts: &SolverOptions) -> SolverResult {
    let fg = fuse(k);
    let cache = GeometryCache::new(k, &fg);
    solve_with_cache(k, &fg, &cache, dev, opts)
}

/// [`solve`] over a pre-built fusion + geometry cache. The cache is
/// read-only and thread-safe: `service::batch` shares one per kernel
/// across its worker pool.
pub fn solve_with_cache(
    k: &Kernel,
    fg: &FusedGraph,
    cache: &GeometryCache,
    dev: &Device,
    opts: &SolverOptions,
) -> SolverResult {
    let start = Instant::now();
    let (regions, budget) = region_budget(dev, opts.scenario);
    let mut explored = 0u64;
    let mut timed_out = false;

    // ---- stage 1 + 2: per-task Pareto candidates -----------------------
    // Tasks placed in the same region share its budget; enumerate each
    // task against a fair share (regions spread tasks, so the share is
    // n_tasks / regions per region) — the global DFS re-checks the true
    // summed feasibility.
    let n_tasks = fg.tasks.len();
    let per_region_tasks = n_tasks.div_ceil(regions).max(1);
    let share = budget.scaled(1.0 / per_region_tasks as f64);
    let mut per_task: Vec<Vec<Candidate>> = Vec::with_capacity(n_tasks);
    for t in 0..n_tasks {
        let mut cands = enumerate_task(
            k,
            cache,
            t,
            dev,
            opts,
            &share,
            start,
            &mut explored,
            &mut timed_out,
        );
        // Restart pass without padding: padded variants can flood the
        // stage-1 beam and bury the unpadded optimum (the beam proxy uses
        // default transfer plans). A second, padding-free enumeration is
        // cheap and guarantees the Prometheus space dominates the
        // Sisyphus (no-padding) subspace.
        if opts.max_pad > 0 {
            let nopad = SolverOptions { max_pad: 0, ..opts.clone() };
            cands.extend(enumerate_task(
                k,
                cache,
                t,
                dev,
                &nopad,
                &share,
                start,
                &mut explored,
                &mut timed_out,
            ));
            cands = pareto(cands);
        }
        assert!(
            !cands.is_empty(),
            "no feasible candidate for task {t} of {} — budget too small",
            k.name
        );
        per_task.push(cands);
    }

    // ---- stage 3: global assembly over candidates × SLRs ---------------
    // Warm start: a valid, feasible incumbent (e.g. a QoR-DB design from
    // a previous run) becomes the initial bound, so the DFS prunes
    // against it immediately and the anytime result can never be worse.
    let mut best: Option<(u64, DesignConfig)> = None; // (simulated latency, design)
    let mut warm_started = false;
    if let Some(inc) = &opts.incumbent {
        let usable = inc.kernel == k.name
            && inc.model == opts.model
            && inc.overlap == opts.overlap
            && design_usable_with_cache(k, fg, cache, inc, dev, opts.scenario);
        if usable {
            let rd = ResolvedDesign::new(k, fg, cache, inc);
            let lat = simulate_resolved(&rd, dev).cycles;
            best = Some((lat, inc.clone()));
            warm_started = true;
        }
    }
    let mut assign: Vec<(usize, usize)> = Vec::new();
    dfs_assign(
        k,
        fg,
        cache,
        dev,
        opts,
        &budget,
        regions,
        &per_task,
        &mut assign,
        &mut best,
        start,
        &mut explored,
        &mut timed_out,
    );

    let (_, design) = best.expect("at least one feasible assembly");
    let rd = ResolvedDesign::new(k, fg, cache, &design);
    let latency = graph_latency_resolved(&rd, dev);
    drop(rd);
    let gf = gflops(k, latency.total, dev);
    SolverResult {
        design,
        latency,
        gflops: gf,
        solve_time: start.elapsed(),
        explored,
        timed_out,
        warm_started,
    }
}

/// Enumerate tile factors × permutations × transfer plans for one fused
/// task and reduce to a Pareto front. All configuration-independent
/// inputs (representative nest, legal orders, array statics) come from
/// the [`GeometryCache`]; per candidate, only the resolution of the
/// changed configuration is recomputed.
#[allow(clippy::too_many_arguments)]
fn enumerate_task(
    k: &Kernel,
    cache: &GeometryCache,
    t: usize,
    dev: &Device,
    opts: &SolverOptions,
    budget: &SlrBudget,
    start: Instant,
    explored: &mut u64,
    timed_out: &mut bool,
) -> Vec<Candidate> {
    let st = &cache.tasks[t];
    let rep_stmt = &k.statements[st.rep];
    let nest = &rep_stmt.loops;
    let has_red = nest.iter().any(|l| l.reduction);
    let ii = if has_red { dev.fadd_latency } else { 1 };

    // per-loop factor options
    let per_loop: Vec<Vec<super::padding::FactorChoice>> = nest
        .iter()
        .map(|l| {
            if !opts.tiling {
                // no tiling: intra = full loop (everything on-chip,
                // Stream-HLS/ScaleHLS style) — but cap reductions to keep
                // partitioning legal.
                let f = legal_intra_factors(l.trip, 0, l.trip);
                vec![*f.last().unwrap(), f[0]]
            } else {
                legal_intra_factors(l.trip, opts.max_pad, opts.max_factor_per_loop)
            }
        })
        .collect();

    // permutations (inter-tile order, memoized at fusion time);
    // reduction loops pinned innermost
    let pinned;
    let orders: &[Vec<usize>] = if opts.permute {
        &st.orders
    } else {
        pinned = vec![st.orders[0].clone()];
        &pinned
    };

    // ---- stage 1: factor combos scored with a default transfer plan ----
    let mut combos: Vec<(Vec<u64>, Vec<u64>)> = Vec::new(); // (intra, padded)
    let mut stack_intra = vec![0u64; nest.len()];
    let mut stack_pad = vec![0u64; nest.len()];
    enum_factors(
        &per_loop,
        0,
        1,
        opts.max_unroll,
        &mut stack_intra,
        &mut stack_pad,
        &mut combos,
    );

    // Compact stage-1 scoring: (latency, unroll, combo idx, order idx).
    // A reusable TaskConfig avoids per-point allocations; sort keys stay
    // 24 bytes so the beam sort doesn't shuffle fat tuples.
    let mut scored: Vec<(u64, u64, u32, u32)> = Vec::new();
    let mut cfg = TaskConfig {
        task: t,
        perm: Vec::new(),
        padded_trip: Vec::new(),
        intra: Vec::new(),
        ii,
        plans: BTreeMap::new(),
        slr: 0,
    };
    'outer: for (oi, ord) in orders.iter().enumerate() {
        for (ci, (intra, padded)) in combos.iter().enumerate() {
            if start.elapsed() > opts.timeout {
                *timed_out = true;
                break 'outer;
            }
            *explored += 1;
            cfg.perm.clone_from(ord);
            cfg.padded_trip.clone_from(padded);
            cfg.intra.clone_from(intra);
            let rt = eval::resolve_task(k, st, &cfg);
            // partition constraint (Eq 8)
            if rt.plans.iter().any(|rp| rp.partitions > dev.max_partition) {
                continue;
            }
            let res = task_resources(&rt, dev);
            if !res.fits(budget) {
                continue;
            }
            let lat = task_latency(&rt, dev, opts.overlap);
            scored.push((lat, intra.iter().product(), ci as u32, oi as u32));
        }
    }
    // anytime guarantee: a tiny timeout may have cut enumeration short —
    // always keep the trivial (untiled, unrolled-by-1) combo as a floor.
    if scored.is_empty() {
        let intra: Vec<u64> = vec![1; nest.len()];
        let padded: Vec<u64> = nest.iter().map(|l| l.trip).collect();
        combos.push((intra, padded));
        scored.push((u64::MAX, 1, (combos.len() - 1) as u32, 0));
    }
    scored.sort_unstable_by_key(|(lat, ..)| *lat);
    // Beam diversity: the stage-1 proxy (default transfer plans) can
    // misrank high-unroll combos whose refined plans win in stage 2, so
    // keep the top-`beam` by proxy latency PLUS the largest-unroll combos
    // (compute-bound kernels are DSP-limited — UF/II is the steady-state
    // throughput bound).
    let mut kept: Vec<(u64, u64, u32, u32)> = scored.iter().take(opts.beam).copied().collect();
    let mut by_uf = scored.clone();
    by_uf.sort_unstable_by_key(|&(_, uf, ..)| std::cmp::Reverse(uf));
    for cand in by_uf.into_iter().take(opts.beam / 3) {
        if !kept.iter().any(|&(_, _, ci, oi)| ci == cand.2 && oi == cand.3) {
            kept.push(cand);
        }
    }
    let scored = kept;

    // ---- stage 2: refine transfer plans for surviving combos -----------
    let mut cands: Vec<Candidate> = Vec::new();
    for &(_, _, ci, oi) in &scored {
        if start.elapsed() > opts.timeout {
            *timed_out = true;
            break;
        }
        let (intra, padded) = &combos[ci as usize];
        let base = TaskConfig {
            task: t,
            perm: orders[oi as usize].clone(),
            padded_trip: padded.clone(),
            intra: intra.clone(),
            ii,
            plans: BTreeMap::new(),
            slr: 0,
        };
        let cfg = choose_transfer_plans(k, st, base, dev, opts, budget, explored);
        let rt = eval::resolve_task(k, st, &cfg);
        let res = task_resources(&rt, dev);
        if !res.fits(budget) {
            continue;
        }
        let lat = task_latency(&rt, dev, opts.overlap);
        cands.push(Candidate { cfg, latency: lat, res });
    }

    // anytime guarantee, stage 2: fall back to the best stage-1 combo
    // with its (feasible) default plans.
    if cands.is_empty() {
        if let Some(&(_, _, ci, oi)) = scored.first() {
            let (intra, padded) = &combos[ci as usize];
            let cfg = TaskConfig {
                task: t,
                perm: orders[oi as usize].clone(),
                padded_trip: padded.clone(),
                intra: intra.clone(),
                ii,
                plans: BTreeMap::new(),
                slr: 0,
            };
            let rt = eval::resolve_task(k, st, &cfg);
            let res = task_resources(&rt, dev);
            let lat = task_latency(&rt, dev, opts.overlap);
            cands.push(Candidate { cfg, latency: lat, res });
        }
    }

    pareto(cands)
}

/// Cartesian enumeration of per-loop factor choices with an unroll cap.
fn enum_factors(
    per_loop: &[Vec<super::padding::FactorChoice>],
    depth: usize,
    product: u64,
    max_unroll: u64,
    intra: &mut Vec<u64>,
    padded: &mut Vec<u64>,
    out: &mut Vec<(Vec<u64>, Vec<u64>)>,
) {
    if depth == per_loop.len() {
        out.push((intra.clone(), padded.clone()));
        return;
    }
    for c in &per_loop[depth] {
        if product * c.intra > max_unroll {
            continue;
        }
        intra[depth] = c.intra;
        padded[depth] = c.padded;
        enum_factors(per_loop, depth + 1, product * c.intra, max_unroll, intra, padded, out);
    }
}

/// Pick the (define, transfer) level and bit width per array: enumerate
/// the diagonal plans (define = transfer at each level) plus the
/// buffer-whole/stream-deep plan ([`eval::plan_options`]), choose
/// per-array the one minimizing the task latency, then demote buffers
/// greedily if BRAM overflows.
fn choose_transfer_plans(
    k: &Kernel,
    st: &TaskStatics,
    mut cfg: TaskConfig,
    dev: &Device,
    opts: &SolverOptions,
    budget: &SlrBudget,
    explored: &mut u64,
) -> TaskConfig {
    // seed: everything at its deepest level (smallest buffers) — exactly
    // the defaults resolution applies to a plan-less config
    {
        let rt = eval::resolve_task(k, st, &cfg);
        let seeded: Vec<(String, TransferPlan)> =
            rt.arrays().map(|(a, rp)| (a.name.clone(), rp.as_plan())).collect();
        drop(rt);
        for (a, p) in seeded {
            cfg.plans.insert(a, p);
        }
    }

    // coordinate descent, one array at a time (two sweeps converge for
    // the plan structures in this zoo)
    for _sweep in 0..2 {
        for ai in 0..st.arrays.len() {
            let a_name = st.arrays[ai].name.clone();
            let options: Vec<TransferPlan> = {
                let geo = super::space::TaskGeometry::new(k, st, &cfg);
                eval::plan_options(&geo, &st.arrays[ai])
            };
            let mut best_plan = cfg.plans[&a_name];
            let mut best_lat = u64::MAX;
            for p in options {
                *explored += 1;
                cfg.plans.insert(a_name.clone(), p);
                let rt = eval::resolve_task(k, st, &cfg);
                let res = task_resources(&rt, dev);
                if !res.fits(budget) {
                    continue;
                }
                let lat = task_latency(&rt, dev, opts.overlap);
                if lat < best_lat {
                    best_lat = lat;
                    best_plan = p;
                }
            }
            cfg.plans.insert(a_name, best_plan);
        }
    }
    cfg
}

/// Keep the Pareto front over (latency, dsp, bram18), sorted by latency.
fn pareto(mut cands: Vec<Candidate>) -> Vec<Candidate> {
    cands.sort_by_key(|c| c.latency);
    let mut front: Vec<Candidate> = Vec::new();
    for c in cands {
        let dominated = front.iter().any(|f| {
            f.latency <= c.latency && f.res.dsp <= c.res.dsp && f.res.bram18 <= c.res.bram18
        });
        if !dominated {
            front.push(c);
        }
    }
    front.truncate(16);
    front
}

/// DFS over per-task candidate picks and SLR ids with branch-and-bound.
#[allow(clippy::too_many_arguments)]
fn dfs_assign(
    k: &Kernel,
    fg: &FusedGraph,
    cache: &GeometryCache,
    dev: &Device,
    opts: &SolverOptions,
    budget: &SlrBudget,
    regions: usize,
    per_task: &[Vec<Candidate>],
    assign: &mut Vec<(usize, usize)>,
    best: &mut Option<(u64, DesignConfig)>,
    start: Instant,
    explored: &mut u64,
    timed_out: &mut bool,
) {
    let t = assign.len();
    if t == per_task.len() {
        *explored += 1;
        // feasibility per region
        let mut per_region = vec![ResourceVec::ZERO; regions];
        for (ti, &(c, slr)) in assign.iter().enumerate() {
            per_region[slr] += per_task[ti][c].res;
        }
        if per_region.iter().any(|r| !r.fits(budget)) {
            return;
        }
        let design = DesignConfig {
            kernel: k.name.clone(),
            model: opts.model,
            overlap: opts.overlap,
            tasks: assign
                .iter()
                .enumerate()
                .map(|(ti, &(c, slr))| {
                    let mut cfg = per_task[ti][c].cfg.clone();
                    cfg.slr = slr;
                    cfg
                })
                .collect(),
        };
        // Final selection is scored by the *executing* simulator, not the
        // analytic model: the model (Eqs 12–16) guides enumeration, but
        // picking the winner with the authoritative latency keeps
        // heuristic-beam local optima from inverting feature ablations.
        let rd = ResolvedDesign::new(k, fg, cache, &design);
        let lat = simulate_resolved(&rd, dev).cycles;
        drop(rd);
        if best.as_ref().map(|(b, _)| lat < *b).unwrap_or(true) {
            *best = Some((lat, design));
        }
        return;
    }
    if start.elapsed() > opts.timeout && best.is_some() {
        *timed_out = true;
        return;
    }
    // bound: any task's standalone latency lower-bounds the total
    for (c, cand) in per_task[t].iter().enumerate() {
        if let Some((b, _)) = best {
            if cand.latency >= *b {
                continue; // this candidate alone already exceeds incumbent
            }
        }
        for slr in 0..regions {
            assign.push((c, slr));
            dfs_assign(
                k, fg, cache, dev, opts, budget, regions, per_task, assign, best, start,
                explored, timed_out,
            );
            assign.pop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    fn quick_opts() -> SolverOptions {
        SolverOptions {
            beam: 12,
            max_factor_per_loop: 32,
            max_unroll: 1024,
            timeout: Duration::from_secs(20),
            ..SolverOptions::default()
        }
    }

    #[test]
    fn gemm_solves_and_is_valid() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &quick_opts());
        let fg = fuse(&k);
        r.design.validate(&k, &fg, dev.slrs).unwrap();
        assert!(r.gflops > 50.0, "gemm RTL gflops too low: {}", r.gflops);
        assert!(r.explored > 100);
    }

    #[test]
    fn solve_with_shared_cache_matches_cold_solve() {
        // The shared GeometryCache must not change what the solver finds:
        // same design, same latency, point for point.
        let k = polybench::gemm();
        let dev = Device::u55c();
        let cold = solve(&k, &dev, &quick_opts());
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let warm = solve_with_cache(&k, &fg, &cache, &dev, &quick_opts());
        assert_eq!(cold.design, warm.design);
        assert_eq!(cold.latency.total, warm.latency.total);
        assert_eq!(cold.explored, warm.explored);
    }

    #[test]
    fn three_madd_uses_concurrency() {
        let k = polybench::three_madd();
        let dev = Device::u55c();
        let df = solve(&k, &dev, &quick_opts());
        let seq = solve(
            &k,
            &dev,
            &SolverOptions {
                model: ExecutionModel::Sequential,
                overlap: false,
                ..quick_opts()
            },
        );
        assert!(
            df.latency.total < seq.latency.total,
            "dataflow {} !< sequential {}",
            df.latency.total,
            seq.latency.total
        );
    }

    #[test]
    fn onboard_budget_shrinks_design() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let rtl = solve(&k, &dev, &quick_opts());
        let board = solve(
            &k,
            &dev,
            &SolverOptions {
                scenario: Scenario::OnBoard { slrs: 1, frac: 0.6 },
                ..quick_opts()
            },
        );
        assert!(board.gflops <= rtl.gflops * 1.05);
        // on-board design must fit the scaled budget
        let fg = fuse(&k);
        let budget = dev.slr.scaled(0.6);
        assert!(crate::dse::constraints::feasible(&k, &fg, &board.design, &dev, &budget));
    }

    #[test]
    fn scenario_serde_round_trip() {
        use serde::{Deserialize, Serialize};
        for s in [Scenario::Rtl, Scenario::OnBoard { slrs: 3, frac: 0.6 }] {
            let v = s.serialize();
            assert_eq!(Scenario::deserialize(&v).unwrap(), s);
        }
        assert!(Scenario::deserialize(&serde::Value::Null).is_err());
    }

    #[test]
    fn warm_start_never_worse() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let fg = fuse(&k);
        let cold = solve(&k, &dev, &quick_opts());
        let inc_cycles = crate::sim::engine::simulate(&k, &fg, &cold.design, &dev).cycles;
        // a much weaker search, warm-started from the cold design, may
        // not beat the incumbent but can never fall below it
        let warm = solve(
            &k,
            &dev,
            &SolverOptions { incumbent: Some(cold.design.clone()), beam: 2, ..quick_opts() },
        );
        let warm_cycles = crate::sim::engine::simulate(&k, &fg, &warm.design, &dev).cycles;
        assert!(warm_cycles <= inc_cycles, "warm {warm_cycles} > incumbent {inc_cycles}");
        assert!(warm.warm_started, "usable incumbent must be reported as a warm start");
    }

    #[test]
    fn mismatched_incumbent_is_ignored() {
        let k = polybench::gemm();
        let other = polybench::bicg();
        let dev = Device::u55c();
        let inc = solve(&other, &dev, &quick_opts()).design;
        // an incumbent from another kernel must not leak into the result
        let r = solve(&k, &dev, &SolverOptions { incumbent: Some(inc), ..quick_opts() });
        assert_eq!(r.design.kernel, "gemm");
        assert!(!r.warm_started, "rejected incumbent must not count as a warm start");
        let fg = fuse(&k);
        r.design.validate(&k, &fg, dev.slrs).unwrap();
    }

    #[test]
    fn timeout_is_anytime() {
        let k = polybench::three_mm();
        let dev = Device::u55c();
        let r = solve(
            &k,
            &dev,
            &SolverOptions { timeout: Duration::from_millis(50), ..quick_opts() },
        );
        // even with a tiny timeout we get *a* design
        assert!(r.latency.total > 0);
    }
}
