//! The design-space solver — the reproduction's substitute for
//! AMPL + Gurobi (paper §6.1).
#![deny(missing_docs)]
//!
//! The paper's "NLP" is a nonconvex quadratic program over *discrete*
//! decision variables (divisor-constrained tile factors, permutation
//! choices, transfer levels, SLR ids); Gurobi solves it by spatial
//! branch-and-bound. We solve the same space with an explicit two-stage
//! combinatorial branch-and-bound:
//!
//! 1. **per-task enumeration** — tile factors (with padding, Eqs 1–2) ×
//!    legal permutations × transfer plans (Eqs 5–6), filtered by the
//!    resource constraints (Eqs 7–10), reduced to a Pareto front over
//!    (latency, full resource vector);
//! 2. **global assembly** — DFS over per-task candidates and SLR
//!    assignments (Eq 11) minimizing the DAG latency (Eqs 12–13) under
//!    per-region budgets, with branch-and-bound pruning.
//!
//! The inner loop is incremental on top of the shared evaluation core
//! ([`super::eval`]): the configuration-independent parts (array infos,
//! access translations, legal orders) are memoized at fusion time in a
//! [`GeometryCache`], so per-candidate evaluation only recomputes what
//! a changed tile factor/permutation/plan invalidates. `solve` builds
//! the cache itself; [`solve_with_cache`] lets callers (the coordinator
//! flow, `service::batch` worker pools) share one cache per kernel
//! across solves.
//!
//! **Parallelism.** One solve can use several cores
//! ([`SolverOptions::jobs`]): stage 1/2 fans the per-task enumeration
//! passes (padded + padding-free restart) across a scoped worker pool
//! sharing the read-only [`GeometryCache`] and one [`Deadline`], and
//! stage 3 distributes the top of the DFS tree across the same pool
//! with a shared atomic incumbent bound (`SharedBest`), so every
//! worker prunes against the globally best design. Region-renamed
//! duplicate assignments are never explored (SLR symmetry breaking:
//! task *t* may reuse an open region or open exactly the next fresh
//! one — regions are interchangeable, latency only compares SLR ids
//! for equality). Results are **deterministic and thread-count
//! independent** for solves that finish within the timeout: candidate
//! lists merge in a fixed order, complete assignments are compared by
//! the total order (simulated latency, then candidate index, then
//! assignment order), and workers prune only *strictly* above the
//! shared bound, so `jobs = 1` and `jobs = N` return bit-identical
//! designs (see DESIGN.md §Parallel solver).
//!
//! **Fusion as a dimension.** Task fusion is explored jointly with the
//! rest of the space ([`SolverOptions::explore_fusion`]): every
//! dependence-legal statement partition between full fission and max
//! output-stationary fusion ([`crate::analysis::fusion::enumerate_fusions`])
//! becomes a *variant* with its own [`FusedGraph`] and
//! [`GeometryCache`]. The space covers the paper's §3.1 full
//! generality: partial (loop-range) fusions materialize peeled
//! prologue/epilogue sub-tasks that are solved like any other task
//! (their geometry runs over the narrowed outer trip), and cross-array
//! merges fold unifying sibling nests into one engine. Stage-1
//! enumeration units are flattened across
//! variants onto the same worker pool, and all variants share one
//! `SharedBest` incumbent — a finished variant's simulated latency
//! prunes its siblings' DFS from the first node. The total order
//! extends to `(latency, variant index, candidate index, assignment)`,
//! so the result stays deterministic and thread-count independent, and
//! latency ties prefer the max-fusion variant (variant 0).
//!
//! **Telemetry.** With [`SolverOptions::telemetry`] on, the solve
//! threads a [`crate::obs::SolveCounters`] block through all three
//! stages and returns it frozen as [`SolverResult::telemetry`]:
//! per-variant enumeration/Pareto/prune counters, a DFS depth
//! histogram, and the incumbent timeline (every [`SharedBest`]
//! improvement as `(elapsed, latency, variant)`). Collection is
//! observational only — it never changes search order, pruning or the
//! returned design — and when off every hook is one predictable branch
//! (bench-bounded in `benches/solver_eval.rs`).
//!
//! Infeasible budgets are a user input, not a bug: the solver returns
//! [`SolverError::Infeasible`] instead of panicking, and the service
//! layer surfaces it as a per-request error.
//!
//! A timeout makes the solver *anytime*: it returns the incumbent with
//! `timed_out = true`, mirroring the paper's Gurobi-timeout mode (§6.4).

use super::config::{DesignConfig, ExecutionModel, TaskConfig, TransferPlan};
use super::constraints::task_resources;
use super::cost::{gflops, graph_latency_resolved, task_latency, GraphLatency};
use super::eval::{self, FusionSpace, GeometryCache, ResolvedDesign, TaskStatics};
use super::padding::legal_intra_factors;
use crate::analysis::fusion::{FusedGraph, FusionPlan};
use crate::hw::resources::ResourceVec;
use crate::hw::{Device, SlrBudget};
use crate::ir::Kernel;
use crate::obs;
use crate::par::run_indexed;
use crate::sim::engine::simulate_resolved;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Resource scenario the solver targets.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scenario {
    /// RTL simulation: the whole device as one region (paper §6.2 gives
    /// every framework all U55C resources for RTL comparison).
    Rtl,
    /// On-board: `slrs` usable regions, each capped at `frac` utilization.
    OnBoard {
        /// Number of usable SLR regions.
        slrs: usize,
        /// Per-region utilization cap in (0, 1].
        frac: f64,
    },
}

impl std::fmt::Display for Scenario {
    /// Canonical text form, also used by the QoR-DB cache key:
    /// `rtl` or `onboard:<slrs>:<frac>`.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Scenario::Rtl => write!(f, "rtl"),
            Scenario::OnBoard { slrs, frac } => write!(f, "onboard:{slrs}:{frac}"),
        }
    }
}

// Manual `serde` impls (the vendored serde has no derive proc-macro):
// part of the serde coverage for the design-space types (DesignConfig,
// TaskConfig, TransferPlan, ExecutionModel, Scenario). Today's QoR-DB
// records reach Scenario only through the canonical key string, but the
// impls keep the type ready for richer record schemas; the round-trip
// is pinned by `scenario_serde_round_trip` below.
impl serde::Serialize for Scenario {
    fn serialize(&self) -> serde::Value {
        match self {
            Scenario::Rtl => serde::Value::Obj(vec![(
                "kind".to_string(),
                serde::Value::Str("rtl".to_string()),
            )]),
            Scenario::OnBoard { slrs, frac } => serde::Value::Obj(vec![
                ("kind".to_string(), serde::Value::Str("onboard".to_string())),
                ("slrs".to_string(), serde::Serialize::serialize(slrs)),
                ("frac".to_string(), serde::Serialize::serialize(frac)),
            ]),
        }
    }
}

impl serde::Deserialize for Scenario {
    fn deserialize(v: &serde::Value) -> Result<Scenario, serde::Error> {
        match v.field("kind")?.as_str() {
            Some("rtl") => Ok(Scenario::Rtl),
            Some("onboard") => Ok(Scenario::OnBoard {
                slrs: serde::Deserialize::deserialize(v.field("slrs")?)?,
                frac: serde::Deserialize::deserialize(v.field("frac")?)?,
            }),
            other => Err(serde::Error::new(format!("invalid scenario kind {other:?}"))),
        }
    }
}

/// Why a solve produced no design. Infeasibility is an expected outcome
/// of user-chosen budgets (a tiny `OnBoard` fraction, an over-restricted
/// baseline space), never a panic: it flows as an `Err` through the
/// coordinator flow, `service::batch` and the CLI.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SolverError {
    /// No design satisfies the scenario's per-region resource budget.
    /// `task` names the first task with no individually-fitting
    /// candidate when the infeasibility is attributable to one task;
    /// `None` means every task fits alone but no global assembly does.
    Infeasible {
        /// First task with no fitting candidate, when attributable.
        task: Option<usize>,
        /// Human-readable description of the violated budget.
        detail: String,
    },
}

impl std::fmt::Display for SolverError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolverError::Infeasible { task: Some(t), detail } => {
                write!(f, "infeasible budget: task {t}: {detail}")
            }
            SolverError::Infeasible { task: None, detail } => {
                write!(f, "infeasible budget: {detail}")
            }
        }
    }
}

impl std::error::Error for SolverError {}

/// Shared solve deadline: one `Instant` fixed at solve start, read by
/// every stage-1/2/3 worker. Replaces the old per-call `start` /
/// `&mut timed_out` out-params, which could not be shared across a
/// worker pool.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    start: Instant,
    timeout: Duration,
}

impl Deadline {
    /// Start the deadline clock now, expiring after `timeout`.
    pub fn new(timeout: Duration) -> Deadline {
        Deadline { start: Instant::now(), timeout }
    }

    /// Whether the deadline has passed.
    pub fn expired(&self) -> bool {
        self.start.elapsed() > self.timeout
    }

    /// Wall time since the solve started.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

/// Worker count for a fresh `SolverOptions`: `$PROMETHEUS_JOBS` when set
/// to a positive integer (CI runs the suite under both `1` and `4` to
/// enforce thread-count independence), else 1. Parallelism is opt-in —
/// `optimize --jobs`/`batch --jobs` and the service layer raise it
/// explicitly.
pub fn default_jobs() -> usize {
    std::env::var("PROMETHEUS_JOBS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&j| j >= 1)
        .unwrap_or(1)
}

/// Solver knobs. Baselines restrict this space to mimic each framework.
#[derive(Debug, Clone)]
pub struct SolverOptions {
    /// Resource scenario the solve targets (RTL or on-board regions).
    pub scenario: Scenario,
    /// Execution model of the generated design (dataflow/sequential).
    pub model: ExecutionModel,
    /// Computation/communication overlap (ping-pong buffering).
    pub overlap: bool,
    /// Allow computation padding (Eq 2 bound; 0 disables).
    pub max_pad: u64,
    /// Allow loop permutation.
    pub permute: bool,
    /// Allow data tiling (false = whole-array buffers, on-chip style).
    pub tiling: bool,
    /// Cap on per-loop intra factors.
    pub max_factor_per_loop: u64,
    /// Cap on the task unroll factor (product of intra factors).
    pub max_unroll: u64,
    /// Candidates kept per task after stage 1.
    pub beam: usize,
    /// Anytime timeout.
    pub timeout: Duration,
    /// Warm-start incumbent (service layer: a previously-solved design
    /// from the QoR knowledge base). When structurally valid and feasible
    /// for this scenario it seeds the branch-and-bound bound, so the DFS
    /// prunes against it from the first node and the solver can never
    /// return a worse design than the incumbent. Ignored (never copied
    /// into the result blindly) when it does not fit the scenario.
    pub incumbent: Option<DesignConfig>,
    /// Worker threads for *this* solve (stage-1/2 enumeration fan-out
    /// and stage-3 DFS branch distribution). The returned design is
    /// thread-count independent — like `incumbent`, `jobs` changes
    /// solve speed, never the answer — so it is excluded from the QoR
    /// cache key. 0 is treated as 1.
    pub jobs: usize,
    /// Explore task fusion as a design dimension: [`solve`] enumerates
    /// every legal fusion variant and solves them jointly under one
    /// shared incumbent. `false` pins the max output-stationary fusion
    /// (the pre-fusion-DSE behaviour; every baseline restricts to it).
    /// Changes the answer, so it *is* part of the QoR cache key.
    pub explore_fusion: bool,
    /// Collect structured telemetry for this solve
    /// ([`SolverResult::telemetry`]): per-variant/per-stage counters,
    /// the DFS depth histogram and the incumbent timeline.
    /// Observational only — search order, pruning and the returned
    /// design are bit-identical with it on or off (property-tested in
    /// `tests/telemetry.rs`) — so, like `jobs`, it is excluded from
    /// the QoR cache key. Defaults to whether tracing is active
    /// ([`crate::obs::trace_enabled`]); the disabled per-hook cost is
    /// bench-bounded in `benches/solver_eval.rs`.
    pub telemetry: bool,
}

impl Default for SolverOptions {
    fn default() -> Self {
        SolverOptions {
            scenario: Scenario::Rtl,
            model: ExecutionModel::Dataflow,
            overlap: true,
            max_pad: 16,
            permute: true,
            tiling: true,
            max_factor_per_loop: 128,
            max_unroll: 4096,
            beam: 192,
            timeout: Duration::from_secs(120),
            incumbent: None,
            jobs: default_jobs(),
            explore_fusion: true,
            telemetry: obs::trace_enabled(),
        }
    }
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct SolverResult {
    /// The best feasible design found.
    pub design: DesignConfig,
    /// The fused-task graph of the **winning fusion variant** — the one
    /// `design.tasks` indexes. Downstream consumers (simulation, board
    /// model, codegen, reports) must evaluate the design against this
    /// graph, never against a freshly recomputed `fuse()`.
    pub fused: FusedGraph,
    /// Fusion variants this solve considered (1 = fixed fusion).
    pub fusion_variants: usize,
    /// Analytic DAG latency of the winning design.
    pub latency: GraphLatency,
    /// Simulated throughput at the device's target clock.
    pub gflops: f64,
    /// Wall time the solve took.
    pub solve_time: Duration,
    /// Design points evaluated. Deterministic for `jobs = 1`; with more
    /// workers the count varies slightly run to run (pruning races),
    /// while `design`/`latency` stay bit-identical.
    pub explored: u64,
    /// Whether the anytime timeout cut the search short.
    pub timed_out: bool,
    /// Whether a usable `SolverOptions::incumbent` actually seeded the
    /// branch-and-bound bound (false when no incumbent was given *or*
    /// the given one was rejected as structurally invalid/infeasible).
    pub warm_started: bool,
    /// Structured solve telemetry: per-variant counters, DFS depth
    /// histogram and incumbent timeline. All-empty unless
    /// [`SolverOptions::telemetry`] was on.
    pub telemetry: obs::SolveTelemetry,
}

/// One per-task candidate with its standalone metrics. Public so tests
/// can exercise [`pareto`] directly on synthetic fronts.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// The per-task configuration.
    pub cfg: TaskConfig,
    /// Standalone task latency under the analytic model.
    pub latency: u64,
    /// Resource usage of the configured task.
    pub res: ResourceVec,
}

/// Region budget for the scenario.
pub fn region_budget(dev: &Device, scenario: Scenario) -> (usize, SlrBudget) {
    match scenario {
        Scenario::Rtl => (1, dev.total()),
        Scenario::OnBoard { slrs, frac } => (slrs.min(dev.slrs), dev.slr.scaled(frac)),
    }
}

/// Whether `design` is servable under `scenario` on the *current*
/// resource model: structural validation, SLR ids within the scenario's
/// regions, and per-region feasibility. The single predicate behind
/// both the solver's warm-start incumbent gate and the QoR cache's
/// hit/stale check — keep them from drifting by construction.
pub fn design_usable(
    k: &Kernel,
    fg: &FusedGraph,
    design: &DesignConfig,
    dev: &Device,
    scenario: Scenario,
) -> bool {
    let cache = GeometryCache::new(k, fg);
    design_usable_with_cache(k, fg, &cache, design, dev, scenario)
}

/// The index of the fusion variant in `space` that `design` realizes,
/// when the design is also servable against that variant under
/// `scenario` — the one predicate behind the QoR-cache validity checks,
/// so the service paths cannot drift on what "usable record" means.
pub fn usable_variant_in_space(
    k: &Kernel,
    space: &FusionSpace,
    design: &DesignConfig,
    dev: &Device,
    scenario: Scenario,
) -> Option<usize> {
    space.variant_of(&design.fusion).filter(|&vi| {
        let v = &space.variants[vi];
        design_usable_with_cache(k, &v.fg, &v.cache, design, dev, scenario)
    })
}

/// [`design_usable`] over a pre-built geometry cache — the warm-start
/// gate, the cached flow and the batch orchestrator all hold one.
pub fn design_usable_with_cache(
    k: &Kernel,
    fg: &FusedGraph,
    cache: &GeometryCache,
    design: &DesignConfig,
    dev: &Device,
    scenario: Scenario,
) -> bool {
    let (regions, budget) = region_budget(dev, scenario);
    // structural validation first: resolution indexes the cache by task
    // id, which is only safe on a validated design
    design.validate(k, fg, dev.slrs).is_ok()
        && design.tasks.iter().all(|t| t.slr < regions)
        && {
            let rd = ResolvedDesign::new(k, fg, cache, design);
            crate::dse::constraints::feasible_resolved(&rd, dev, &budget)
        }
}

/// Solve the design space for `k`. Returns the best feasible design
/// found, or [`SolverError::Infeasible`] when the scenario's budget
/// admits no design at all. Builds the fusion space (all legal
/// variants under `opts.explore_fusion`) and its geometry caches
/// itself; callers that solve the same kernel repeatedly should build
/// a [`FusionSpace`] once and use [`solve_space`].
pub fn solve(k: &Kernel, dev: &Device, opts: &SolverOptions) -> Result<SolverResult, SolverError> {
    let space = FusionSpace::for_solver(k, opts.explore_fusion);
    solve_space(k, &space, dev, opts)
}

/// [`solve`] over a pre-built fusion space (the coordinator flow and
/// `service::batch` build one space per kernel and share it, read-only,
/// across requests and workers).
pub fn solve_space(
    k: &Kernel,
    space: &FusionSpace,
    dev: &Device,
    opts: &SolverOptions,
) -> Result<SolverResult, SolverError> {
    let variants: Vec<(&FusedGraph, &GeometryCache)> =
        space.variants.iter().map(|v| (&v.fg, &v.cache)).collect();
    solve_variants(k, &variants, dev, opts)
}

/// Globally shared branch-and-bound incumbent for stage 3: a lock-free
/// latency bound for pruning plus the full deterministic tie-break
/// state under a mutex.
struct SharedBest {
    /// Best simulated latency so far (`u64::MAX` = none). Workers prune
    /// with a *strict* compare against this relaxed-loaded value: the
    /// bound only ever decreases, so a stale read can only under-prune,
    /// never cut off a branch that could still win a tie.
    bound: AtomicU64,
    /// `(latency, assignment key, design)`. The assignment key — a
    /// leading `(fusion variant index, 0)` element followed by the
    /// `(candidate index, region)` sequence — breaks latency ties by
    /// lexicographic order, which is exactly the order the sequential
    /// outer-variant loop + DFS enumerates leaves in, making the winner
    /// independent of which worker reached it first (ties between
    /// fusion variants fall to the lower variant index, i.e. max fusion
    /// first). The warm-start incumbent gets the empty key, so it wins
    /// all ties and the solve can never return a design worse than (or
    /// a tied re-discovery of) the incumbent.
    best: Mutex<Option<(u64, Vec<(usize, usize)>, DesignConfig)>>,
}

impl SharedBest {
    fn new() -> SharedBest {
        SharedBest { bound: AtomicU64::new(u64::MAX), best: Mutex::new(None) }
    }

    fn bound(&self) -> u64 {
        self.bound.load(Ordering::Relaxed)
    }

    fn has_best(&self) -> bool {
        self.bound() != u64::MAX
    }

    /// Offer a complete design. Keeps the minimum under the total order
    /// `(latency, key)`; the fast path rejects anything strictly above
    /// the current bound without taking the lock (such a design can
    /// neither win nor tie the final minimum). An accepted improvement
    /// is appended to the incumbent timeline (`counters`) under the
    /// lock, so the recorded `(latency, variant)` sequence is totally
    /// ordered — telemetry observes the decision, never shapes it.
    fn offer(
        &self,
        lat: u64,
        key: Vec<(usize, usize)>,
        design: DesignConfig,
        variant: usize,
        deadline: Deadline,
        counters: &obs::SolveCounters,
    ) {
        if lat > self.bound.load(Ordering::Relaxed) {
            return;
        }
        let mut best = self.best.lock().unwrap();
        let better = match &*best {
            None => true,
            Some((blat, bkey, _)) => lat < *blat || (lat == *blat && key < *bkey),
        };
        if better {
            self.bound.store(lat, Ordering::Relaxed);
            *best = Some((lat, key, design));
            counters.incumbent(deadline.elapsed().as_micros() as u64, lat, variant);
        }
    }
}

/// [`solve`] over a pre-built fusion + geometry cache for **one pinned
/// fusion variant** (the given `fg` — `explore_fusion` is not
/// consulted). The cache is read-only and thread-safe: callers holding
/// one per kernel share it across solves, and this solve's own workers
/// share it again. To explore fusion with shared caches, build a
/// [`FusionSpace`] and call [`solve_space`].
pub fn solve_with_cache(
    k: &Kernel,
    fg: &FusedGraph,
    cache: &GeometryCache,
    dev: &Device,
    opts: &SolverOptions,
) -> Result<SolverResult, SolverError> {
    solve_variants(k, &[(fg, cache)], dev, opts)
}

/// The multi-variant solver core: one branch-and-bound across every
/// given fusion variant, under a single shared deadline, worker pool
/// and incumbent.
fn solve_variants(
    k: &Kernel,
    variants: &[(&FusedGraph, &GeometryCache)],
    dev: &Device,
    opts: &SolverOptions,
) -> Result<SolverResult, SolverError> {
    let deadline = Deadline::new(opts.timeout);
    let jobs = opts.jobs.max(1);
    let n_variants = variants.len();
    let (regions, budget) = region_budget(dev, opts.scenario);
    let plans: Vec<FusionPlan> = variants.iter().map(|(fg, _)| fg.plan()).collect();
    // depth slots cover 0..=n_tasks: dfs_node fires at leaves too
    let max_tasks = variants.iter().map(|(fg, _)| fg.tasks.len()).max().unwrap_or(0);
    let counters = obs::SolveCounters::new(opts.telemetry, n_variants, max_tasks + 1);

    // ---- stage 1 + 2: per-variant, per-task Pareto candidates ----------
    // Tasks placed in the same region share its budget; enumerate each
    // task against a fair share (regions spread tasks, so the share is
    // n_tasks / regions per region, per variant) — the global DFS
    // re-checks the true summed feasibility.
    //
    // Work units are (variant, task, pass) triples: the padded
    // enumeration, plus a restart pass without padding when padding is
    // on (padded variants can flood the stage-1 beam and bury the
    // unpadded optimum — the beam proxy uses default transfer plans;
    // the second pass is cheap and guarantees the Prometheus space
    // dominates the Sisyphus no-padding subspace). Units from *all*
    // fusion variants fan out across one worker pool; the per-task
    // merge (padded list, then no-pad list, then one Pareto reduction)
    // is a fixed fold, so the candidate fronts are identical for any
    // thread count.
    let nopad_opts = SolverOptions { max_pad: 0, ..opts.clone() };
    let mut units: Vec<(usize, usize, bool)> = Vec::new();
    for (vi, (fg, _)) in variants.iter().enumerate() {
        for t in 0..fg.tasks.len() {
            units.push((vi, t, false));
            if opts.max_pad > 0 {
                units.push((vi, t, true));
            }
        }
    }
    let shares: Vec<SlrBudget> = variants
        .iter()
        .map(|(fg, _)| {
            let per_region_tasks = fg.tasks.len().div_ceil(regions).max(1);
            budget.scaled(1.0 / per_region_tasks as f64)
        })
        .collect();
    let stage1_span = obs::span("solver", "solve.enumerate");
    let unit_results = run_indexed(units.len(), jobs, |i| {
        let (vi, t, nopad) = units[i];
        let o = if nopad { &nopad_opts } else { opts };
        enumerate_task(k, variants[vi].1, t, dev, o, &shares[vi], deadline)
    });
    let mut explored = 0u64;
    let mut stage1_timed_out = false;
    let mut per_variant: Vec<Vec<Vec<Candidate>>> =
        variants.iter().map(|(fg, _)| vec![Vec::new(); fg.tasks.len()]).collect();
    for (&(vi, t, _), (cands, ex, to)) in units.iter().zip(unit_results) {
        per_variant[vi][t].extend(cands);
        counters.enumerated(vi, ex);
        explored += ex;
        stage1_timed_out |= to;
    }
    let per_variant: Vec<Vec<Vec<Candidate>>> = per_variant
        .into_iter()
        .enumerate()
        .map(|(vi, pt)| {
            pt.into_iter()
                .map(|raw| {
                    let raw_len = raw.len() as u64;
                    let front = pareto(raw);
                    counters.pareto(vi, front.len() as u64, raw_len - front.len() as u64);
                    front
                })
                .collect()
        })
        .collect();
    drop(stage1_span);

    // ---- stage 3: global assembly over variants × candidates × SLRs ----
    // Warm start: a valid, feasible incumbent (e.g. a QoR-DB design
    // from a previous run) becomes the initial bound, so every
    // variant's DFS prunes against it immediately and the anytime
    // result can never be worse. The incumbent binds only to the
    // variant realizing its own fusion plan — a design from an
    // incompatible partition is rejected by the same usability gate the
    // QoR cache uses (`design.validate` checks fusion == fg.plan()).
    let shared = SharedBest::new();
    let mut warm_started = false;
    let mut inc_variant: Option<usize> = None;
    if let Some(inc) = &opts.incumbent {
        if let Some(vi) = plans.iter().position(|p| p == &inc.fusion) {
            let (fg_v, cache_v) = variants[vi];
            let usable = inc.kernel == k.name
                && inc.model == opts.model
                && inc.overlap == opts.overlap
                && design_usable_with_cache(k, fg_v, cache_v, inc, dev, opts.scenario);
            if usable {
                let rd = ResolvedDesign::new(k, fg_v, cache_v, inc);
                let lat = simulate_resolved(&rd, dev).cycles;
                drop(rd);
                shared.offer(lat, Vec::new(), inc.clone(), vi, deadline, &counters);
                warm_started = true;
                inc_variant = Some(vi);
            }
        }
    }

    // Per-variant feasibility gate. An empty candidate list would be a
    // solver bug, not an infeasible input: enumerate_task's anytime
    // fallbacks always yield >= 1 candidate. The anytime fallbacks keep
    // unfiltered candidates around, so an impossibly small budget shows
    // up here: not even the cheapest enumerated configuration of a task
    // fits one whole region. A variant failing the gate is *skipped*
    // (its siblings may still fit); only when every variant fails is
    // the problem infeasible, reported with the max-fusion (variant 0)
    // detail so single-variant solves keep the pre-fusion message. The
    // gate is waived per variant after a stage-1 timeout (fitting
    // configurations may simply not have been scored yet) and for the
    // incumbent's variant (a usable incumbent *proves* feasibility —
    // the fair-share filter inside enumerate_task can starve a task's
    // list on budgets between share and region, and the anytime
    // contract says the incumbent must come back, not an error).
    let mut dfsable = vec![false; n_variants];
    let mut variant0_fail: Option<(usize, String)> = None;
    for (vi, per_task) in per_variant.iter().enumerate() {
        let mut fits = true;
        for (t, cands) in per_task.iter().enumerate() {
            debug_assert!(!cands.is_empty(), "anytime fallbacks guarantee a candidate per task");
            if !cands.iter().any(|c| c.res.fits(&budget)) {
                fits = false;
                if vi == 0 && variant0_fail.is_none() {
                    variant0_fail = Some((
                        t,
                        format!(
                            "no configuration of task {t} of {} fits a single region budget \
                             (DSP {}, BRAM18 {}, LUT {}, FF {})",
                            k.name, budget.dsp, budget.bram18, budget.lut, budget.ff
                        ),
                    ));
                }
                break;
            }
        }
        dfsable[vi] = stage1_timed_out || inc_variant == Some(vi) || fits;
    }
    if !dfsable.iter().any(|&d| d) {
        let (task, detail) = variant0_fail.expect("all variants failed, so variant 0 did");
        return Err(SolverError::Infeasible { task: Some(task), detail });
    }

    let timed_out_flag = AtomicBool::new(stage1_timed_out);
    let ctxs: Vec<DfsCtx> = variants
        .iter()
        .enumerate()
        .map(|(vi, &(fg, cache))| DfsCtx {
            k,
            fg,
            cache,
            dev,
            opts,
            budget: &budget,
            regions,
            per_task: &per_variant[vi],
            deadline,
            shared: &shared,
            timed_out: &timed_out_flag,
            vi,
            plan: &plans[vi],
            counters: &counters,
        })
        .collect();

    // Distribute the top of the DFS forest: per DFS-able variant,
    // expand prefixes breadth-first in lexicographic order until there
    // is enough work to spread across the pool, then let workers pull
    // (variant, prefix) pairs from an atomic cursor and run the
    // ordinary DFS below each. Which worker finishes first does not
    // matter: the final design is the `(latency, key)` minimum over
    // every non-pruned leaf of every variant, and pruning is strictly
    // above the shared bound, so no potential winner is ever cut off —
    // and a variant finishing early tightens the bound its siblings
    // prune against.
    let mut frontier: Vec<(usize, Vec<(usize, usize)>)> = Vec::new();
    for (vi, ctx) in ctxs.iter().enumerate() {
        if !dfsable[vi] {
            continue;
        }
        let mut fr: Vec<Vec<(usize, usize)>> = vec![Vec::new()];
        if jobs > 1 {
            let target = jobs * 4;
            let n_tasks = ctx.per_task.len();
            let mut depth = 0usize;
            while depth < n_tasks && fr.len() < target {
                let mut next = Vec::new();
                for prefix in &fr {
                    let max_slr = open_regions(prefix, regions);
                    for c in 0..ctx.per_task[depth].len() {
                        for slr in 0..max_slr {
                            let mut p = prefix.clone();
                            p.push((c, slr));
                            next.push(p);
                        }
                    }
                }
                fr = next;
                depth += 1;
            }
        }
        frontier.extend(fr.into_iter().map(|p| (vi, p)));
    }
    let dfs_span = obs::span("solver", "solve.dfs");
    let prefix_explored = run_indexed(frontier.len(), jobs, |i| {
        let (vi, prefix) = &frontier[i];
        let mut ex = 0u64;
        run_prefix(&ctxs[*vi], prefix, &mut ex);
        ex
    });
    drop(dfs_span);
    explored += prefix_explored.into_iter().sum::<u64>();
    let timed_out = timed_out_flag.load(Ordering::Relaxed);
    drop(ctxs);
    let telemetry = counters.finish();
    if obs::trace_enabled() {
        for (vi, vc) in telemetry.variants.iter().enumerate() {
            obs::counter(
                "solver",
                &format!("solve.variant{vi}"),
                vec![
                    ("enumerated".to_string(), obs::ArgVal::Int(vc.enumerated as i128)),
                    ("dfs_nodes".to_string(), obs::ArgVal::Int(vc.dfs_nodes as i128)),
                    (
                        "leaves_simulated".to_string(),
                        obs::ArgVal::Int(vc.leaves_simulated as i128),
                    ),
                    ("bound_pruned".to_string(), obs::ArgVal::Int(vc.bound_pruned as i128)),
                    (
                        "symmetry_pruned".to_string(),
                        obs::ArgVal::Int(vc.symmetry_pruned as i128),
                    ),
                    (
                        "resource_pruned".to_string(),
                        obs::ArgVal::Int(vc.resource_pruned as i128),
                    ),
                    (
                        "deadline_killed".to_string(),
                        obs::ArgVal::Int(vc.deadline_killed as i128),
                    ),
                ],
            );
        }
    }

    let best = shared.best.into_inner().unwrap();
    let Some((_, _, design)) = best else {
        return Err(SolverError::Infeasible {
            task: None,
            detail: format!(
                "no task assignment of any of the {n_variants} fusion variant(s) of {} onto \
                 {regions} region(s) satisfies the per-region budget{}",
                k.name,
                if timed_out { " (search timed out; infeasibility unproven)" } else { "" }
            ),
        });
    };
    let win = plans
        .iter()
        .position(|p| p == &design.fusion)
        .expect("the winning design realizes one of the solved variants");
    let (win_fg, win_cache) = variants[win];
    let rd = ResolvedDesign::new(k, win_fg, win_cache, &design);
    let latency = graph_latency_resolved(&rd, dev);
    drop(rd);
    let gf = gflops(k, latency.total, dev);
    Ok(SolverResult {
        design,
        fused: win_fg.clone(),
        fusion_variants: n_variants,
        latency,
        gflops: gf,
        solve_time: deadline.elapsed(),
        explored,
        timed_out,
        warm_started,
        telemetry,
    })
}

/// Resume the DFS below a distributed prefix, re-deriving what the
/// in-tree DFS would have pruned before reaching it: per-region usage
/// (sums only grow with depth, so an overfull prefix dooms the whole
/// subtree) and the standalone-latency bound (strict, like
/// [`dfs_assign`], so ties stay reachable).
fn run_prefix(ctx: &DfsCtx<'_>, prefix: &[(usize, usize)], explored: &mut u64) {
    let bound = ctx.shared.bound();
    if prefix.iter().enumerate().any(|(ti, &(c, _))| ctx.per_task[ti][c].latency > bound) {
        ctx.counters.bound_pruned(ctx.vi, 1);
        return;
    }
    let mut used = vec![ResourceVec::ZERO; ctx.regions];
    for (ti, &(c, slr)) in prefix.iter().enumerate() {
        used[slr] += ctx.per_task[ti][c].res;
    }
    if used.iter().any(|r| !r.fits(ctx.budget)) {
        ctx.counters.resource_pruned(ctx.vi, 1);
        return;
    }
    let mut assign = prefix.to_vec();
    dfs_assign(ctx, &mut assign, &mut used, explored);
}

/// Enumerate tile factors × permutations × transfer plans for one fused
/// task. All configuration-independent inputs (representative nest,
/// legal orders, array statics) come from the [`GeometryCache`]; per
/// candidate, only the resolution of the changed configuration is
/// recomputed. Returns the raw (un-Pareto'd) candidates plus this
/// unit's explored count and whether it hit the deadline — the caller
/// merges passes in a fixed order and Pareto-reduces once, so the
/// result is identical however the units were scheduled.
fn enumerate_task(
    k: &Kernel,
    cache: &GeometryCache,
    t: usize,
    dev: &Device,
    opts: &SolverOptions,
    budget: &SlrBudget,
    deadline: Deadline,
) -> (Vec<Candidate>, u64, bool) {
    let mut explored = 0u64;
    let mut timed_out = false;
    let st = &cache.tasks[t];
    let rep_stmt = &k.statements[st.rep];
    let nest = &rep_stmt.loops;
    let has_red = nest.iter().any(|l| l.reduction);
    let ii = if has_red { dev.fadd_latency } else { 1 };

    // per-loop factor options, over the task's *effective* trips (a
    // ranged/peeled task's outermost loop spans only its [lo, hi)
    // slice — st.trips narrows position 0 accordingly, so every peel
    // gets its own tiling geometry)
    let per_loop: Vec<Vec<super::padding::FactorChoice>> = st
        .trips
        .iter()
        .map(|&trip| {
            if !opts.tiling {
                // no tiling: intra = full loop (everything on-chip,
                // Stream-HLS/ScaleHLS style) — but cap reductions to keep
                // partitioning legal.
                let f = legal_intra_factors(trip, 0, trip);
                vec![*f.last().unwrap(), f[0]]
            } else {
                legal_intra_factors(trip, opts.max_pad, opts.max_factor_per_loop)
            }
        })
        .collect();

    // permutations (inter-tile order, memoized at fusion time);
    // reduction loops pinned innermost
    let pinned;
    let orders: &[Vec<usize>] = if opts.permute {
        &st.orders
    } else {
        pinned = vec![st.orders[0].clone()];
        &pinned
    };

    // ---- stage 1: factor combos scored with a default transfer plan ----
    let mut combos: Vec<(Vec<u64>, Vec<u64>)> = Vec::new(); // (intra, padded)
    let mut stack_intra = vec![0u64; nest.len()];
    let mut stack_pad = vec![0u64; nest.len()];
    enum_factors(
        &per_loop,
        0,
        1,
        opts.max_unroll,
        &mut stack_intra,
        &mut stack_pad,
        &mut combos,
    );

    // Compact stage-1 scoring: (latency, unroll, combo idx, order idx).
    // A reusable TaskConfig avoids per-point allocations; sort keys stay
    // 24 bytes so the beam sort doesn't shuffle fat tuples.
    let mut scored: Vec<(u64, u64, u32, u32)> = Vec::new();
    let mut cfg = TaskConfig {
        task: t,
        perm: Vec::new(),
        padded_trip: Vec::new(),
        intra: Vec::new(),
        ii,
        plans: BTreeMap::new(),
        slr: 0,
    };
    'outer: for (oi, ord) in orders.iter().enumerate() {
        for (ci, (intra, padded)) in combos.iter().enumerate() {
            if deadline.expired() {
                timed_out = true;
                break 'outer;
            }
            explored += 1;
            cfg.perm.clone_from(ord);
            cfg.padded_trip.clone_from(padded);
            cfg.intra.clone_from(intra);
            let rt = eval::resolve_task(k, st, &cfg);
            // partition constraint (Eq 8)
            if rt.plans.iter().any(|rp| rp.partitions > dev.max_partition) {
                continue;
            }
            let res = task_resources(&rt, dev);
            if !res.fits(budget) {
                continue;
            }
            let lat = task_latency(&rt, dev, opts.overlap);
            scored.push((lat, intra.iter().product(), ci as u32, oi as u32));
        }
    }
    // anytime guarantee: a tiny timeout may have cut enumeration short —
    // always keep the trivial (untiled, unrolled-by-1) combo as a floor.
    if scored.is_empty() {
        let intra: Vec<u64> = vec![1; nest.len()];
        let padded: Vec<u64> = st.trips.clone();
        combos.push((intra, padded));
        scored.push((u64::MAX, 1, (combos.len() - 1) as u32, 0));
    }
    scored.sort_unstable_by_key(|(lat, ..)| *lat);
    // Beam diversity: the stage-1 proxy (default transfer plans) can
    // misrank high-unroll combos whose refined plans win in stage 2, so
    // keep the top-`beam` by proxy latency PLUS the largest-unroll combos
    // (compute-bound kernels are DSP-limited — UF/II is the steady-state
    // throughput bound).
    let mut kept: Vec<(u64, u64, u32, u32)> = scored.iter().take(opts.beam).copied().collect();
    let mut by_uf = scored.clone();
    by_uf.sort_unstable_by_key(|&(_, uf, ..)| std::cmp::Reverse(uf));
    for cand in by_uf.into_iter().take(opts.beam / 3) {
        if !kept.iter().any(|&(_, _, ci, oi)| ci == cand.2 && oi == cand.3) {
            kept.push(cand);
        }
    }
    let scored = kept;

    // ---- stage 2: refine transfer plans for surviving combos -----------
    let mut cands: Vec<Candidate> = Vec::new();
    for &(_, _, ci, oi) in &scored {
        if deadline.expired() {
            timed_out = true;
            break;
        }
        let (intra, padded) = &combos[ci as usize];
        let base = TaskConfig {
            task: t,
            perm: orders[oi as usize].clone(),
            padded_trip: padded.clone(),
            intra: intra.clone(),
            ii,
            plans: BTreeMap::new(),
            slr: 0,
        };
        let cfg = choose_transfer_plans(k, st, base, dev, opts, budget, &mut explored);
        let rt = eval::resolve_task(k, st, &cfg);
        let res = task_resources(&rt, dev);
        if !res.fits(budget) {
            continue;
        }
        let lat = task_latency(&rt, dev, opts.overlap);
        cands.push(Candidate { cfg, latency: lat, res });
    }

    // anytime guarantee, stage 2: fall back to the best stage-1 combo
    // with its (feasible) default plans.
    if cands.is_empty() {
        if let Some(&(_, _, ci, oi)) = scored.first() {
            let (intra, padded) = &combos[ci as usize];
            let cfg = TaskConfig {
                task: t,
                perm: orders[oi as usize].clone(),
                padded_trip: padded.clone(),
                intra: intra.clone(),
                ii,
                plans: BTreeMap::new(),
                slr: 0,
            };
            let rt = eval::resolve_task(k, st, &cfg);
            let res = task_resources(&rt, dev);
            let lat = task_latency(&rt, dev, opts.overlap);
            cands.push(Candidate { cfg, latency: lat, res });
        }
    }

    (cands, explored, timed_out)
}

/// Cartesian enumeration of per-loop factor choices with an unroll cap.
fn enum_factors(
    per_loop: &[Vec<super::padding::FactorChoice>],
    depth: usize,
    product: u64,
    max_unroll: u64,
    intra: &mut Vec<u64>,
    padded: &mut Vec<u64>,
    out: &mut Vec<(Vec<u64>, Vec<u64>)>,
) {
    if depth == per_loop.len() {
        out.push((intra.clone(), padded.clone()));
        return;
    }
    for c in &per_loop[depth] {
        if product * c.intra > max_unroll {
            continue;
        }
        intra[depth] = c.intra;
        padded[depth] = c.padded;
        enum_factors(per_loop, depth + 1, product * c.intra, max_unroll, intra, padded, out);
    }
}

/// Pick the (define, transfer) level and bit width per array: enumerate
/// the diagonal plans (define = transfer at each level) plus the
/// buffer-whole/stream-deep plan ([`eval::plan_options`]), choose
/// per-array the one minimizing the task latency, then demote buffers
/// greedily if BRAM overflows.
fn choose_transfer_plans(
    k: &Kernel,
    st: &TaskStatics,
    mut cfg: TaskConfig,
    dev: &Device,
    opts: &SolverOptions,
    budget: &SlrBudget,
    explored: &mut u64,
) -> TaskConfig {
    // seed: everything at its deepest level (smallest buffers) — exactly
    // the defaults resolution applies to a plan-less config
    {
        let rt = eval::resolve_task(k, st, &cfg);
        let seeded: Vec<(String, TransferPlan)> =
            rt.arrays().map(|(a, rp)| (a.name.clone(), rp.as_plan())).collect();
        drop(rt);
        for (a, p) in seeded {
            cfg.plans.insert(a, p);
        }
    }

    // coordinate descent, one array at a time (two sweeps converge for
    // the plan structures in this zoo)
    for _sweep in 0..2 {
        for ai in 0..st.arrays.len() {
            let a_name = st.arrays[ai].name.clone();
            let options: Vec<TransferPlan> = {
                let geo = super::space::TaskGeometry::new(k, st, &cfg);
                eval::plan_options(&geo, &st.arrays[ai])
            };
            let mut best_plan = cfg.plans[&a_name];
            let mut best_lat = u64::MAX;
            for p in options {
                *explored += 1;
                cfg.plans.insert(a_name.clone(), p);
                let rt = eval::resolve_task(k, st, &cfg);
                let res = task_resources(&rt, dev);
                if !res.fits(budget) {
                    continue;
                }
                let lat = task_latency(&rt, dev, opts.overlap);
                if lat < best_lat {
                    best_lat = lat;
                    best_plan = p;
                }
            }
            cfg.plans.insert(a_name, best_plan);
        }
    }
    cfg
}

/// Latency-sorted front size kept per task after the Pareto reduction
/// (resource-diversity witnesses ride on top).
const PARETO_KEEP: usize = 16;

/// Keep the Pareto front over (latency, **full** resource vector),
/// sorted by latency. A candidate is dominated only when another one is
/// no worse in latency *and every* resource class — DSP, BRAM18, LUT
/// and FF — so a LUT- or FF-cheap configuration survives even when a
/// faster candidate beats it on DSP/BRAM (the old three-field filter
/// silently dropped those, starving stage-3 assembly on LUT-tight
/// budgets).
///
/// The front is then cut to `PARETO_KEEP` (16) by latency, but the
/// cheapest-per-resource witnesses (min-LUT, min-BRAM18, min-FF,
/// min-DSP) are never dropped: when stage 3 has to trade speed for
/// resources, the extreme points are exactly the candidates it needs.
/// Fully deterministic: stable latency sort, first-wins witnesses.
pub fn pareto(mut cands: Vec<Candidate>) -> Vec<Candidate> {
    cands.sort_by_key(|c| c.latency);
    let mut front: Vec<Candidate> = Vec::new();
    for c in cands {
        let dominated = front.iter().any(|f| {
            f.latency <= c.latency
                && f.res.dsp <= c.res.dsp
                && f.res.bram18 <= c.res.bram18
                && f.res.lut <= c.res.lut
                && f.res.ff <= c.res.ff
        });
        if !dominated {
            front.push(c);
        }
    }
    if front.len() > PARETO_KEEP {
        let min_idx = |key: fn(&Candidate) -> f64| {
            let mut best = 0usize;
            for i in 1..front.len() {
                if key(&front[i]) < key(&front[best]) {
                    best = i;
                }
            }
            best
        };
        let mut witnesses = [
            min_idx(|c| c.res.lut),
            min_idx(|c| c.res.bram18),
            min_idx(|c| c.res.ff),
            min_idx(|c| c.res.dsp),
        ];
        witnesses.sort_unstable();
        let mut tail: Vec<Candidate> = Vec::new();
        for (j, &w) in witnesses.iter().enumerate() {
            if w >= PARETO_KEEP && witnesses[..j].last() != Some(&w) {
                tail.push(front[w].clone());
            }
        }
        front.truncate(PARETO_KEEP);
        front.extend(tail);
    }
    front
}

/// SLR symmetry breaking — the one child-generation rule, shared by
/// `dfs_assign` and the stage-3 frontier expansion so the two can
/// never drift. Regions are interchangeable (identical budgets;
/// latency compares region ids only for equality), so the next task
/// may reuse an already-open region or open exactly the next fresh
/// one: region-renamed duplicates are never explored, and the kept
/// representative (first-use-ordered region ids) is the
/// lexicographically smallest of its class, preserving the
/// deterministic tie-break. Returns the exclusive upper bound on the
/// region id the next task may take.
fn open_regions(assign: &[(usize, usize)], regions: usize) -> usize {
    let next_fresh = assign.iter().map(|&(_, s)| s + 1).max().unwrap_or(0);
    regions.min(next_fresh + 1)
}

/// Read-only context shared by every stage-3 DFS worker **of one
/// fusion variant** — the `SharedBest` behind it spans all variants.
struct DfsCtx<'a> {
    k: &'a Kernel,
    fg: &'a FusedGraph,
    cache: &'a GeometryCache,
    dev: &'a Device,
    opts: &'a SolverOptions,
    budget: &'a SlrBudget,
    regions: usize,
    per_task: &'a [Vec<Candidate>],
    deadline: Deadline,
    shared: &'a SharedBest,
    timed_out: &'a AtomicBool,
    /// This variant's index in the solve's variant list (the leading
    /// element of every leaf's deterministic tie-break key).
    vi: usize,
    /// This variant's canonical fusion plan, stamped into every design
    /// the DFS assembles.
    plan: &'a FusionPlan,
    /// The solve's shared telemetry counter block (no-op when
    /// `SolverOptions::telemetry` is off).
    counters: &'a obs::SolveCounters,
}

/// DFS over per-task candidate picks and SLR ids with branch-and-bound.
/// `assign` holds the (candidate, region) prefix, `used` the prefix's
/// per-region resource sums (kept incrementally — sums only grow, so an
/// overfull region prunes the whole subtree).
fn dfs_assign(
    ctx: &DfsCtx<'_>,
    assign: &mut Vec<(usize, usize)>,
    used: &mut [ResourceVec],
    explored: &mut u64,
) {
    let t = assign.len();
    ctx.counters.dfs_node(ctx.vi, t);
    // Anytime gate, checked at node entry AND before the (expensive)
    // leaf simulation: once the deadline passed and *some* design is in
    // hand — a found leaf or the warm-start incumbent — stop scoring.
    // With no design in hand yet, the search degrades to a greedy dive
    // (see the bottom of the loop) instead of running the exponential
    // tree arbitrarily far past the deadline.
    let expired = ctx.deadline.expired();
    if expired {
        ctx.timed_out.store(true, Ordering::Relaxed);
        if ctx.shared.has_best() {
            ctx.counters.deadline_killed(ctx.vi);
            return;
        }
    }
    if t == ctx.per_task.len() {
        *explored += 1;
        ctx.counters.leaf(ctx.vi);
        let design = DesignConfig {
            kernel: ctx.k.name.clone(),
            model: ctx.opts.model,
            overlap: ctx.opts.overlap,
            fusion: ctx.plan.clone(),
            tasks: assign
                .iter()
                .enumerate()
                .map(|(ti, &(c, slr))| {
                    let mut cfg = ctx.per_task[ti][c].cfg.clone();
                    cfg.slr = slr;
                    cfg
                })
                .collect(),
        };
        // Final selection is scored by the *executing* simulator, not the
        // analytic model: the model (Eqs 12–16) guides enumeration, but
        // picking the winner with the authoritative latency keeps
        // heuristic-beam local optima from inverting feature ablations.
        let rd = ResolvedDesign::new(ctx.k, ctx.fg, ctx.cache, &design);
        let lat = simulate_resolved(&rd, ctx.dev).cycles;
        drop(rd);
        let mut key = Vec::with_capacity(assign.len() + 1);
        key.push((ctx.vi, 0usize));
        key.extend_from_slice(assign);
        ctx.shared.offer(lat, key, design, ctx.vi, ctx.deadline, ctx.counters);
        return;
    }
    let max_slr = open_regions(assign, ctx.regions);
    if ctx.counters.enabled() && max_slr < ctx.regions {
        // children in the renamed regions [max_slr, regions) are never
        // generated — count them so prune totals partition the tree
        ctx.counters
            .symmetry_pruned(ctx.vi, ((ctx.regions - max_slr) * ctx.per_task[t].len()) as u64);
    }
    for (c, cand) in ctx.per_task[t].iter().enumerate() {
        // bound: any task's standalone latency lower-bounds the total.
        // STRICTLY above the shared bound only — an equal-latency leaf
        // may still win the deterministic tie-break, so it must stay
        // reachable from every worker.
        if cand.latency > ctx.shared.bound() {
            ctx.counters.bound_pruned(ctx.vi, 1);
            continue;
        }
        for slr in 0..max_slr {
            let prev = used[slr];
            let acc = prev + cand.res;
            if !acc.fits(ctx.budget) {
                ctx.counters.resource_pruned(ctx.vi, 1);
                continue;
            }
            used[slr] = acc;
            assign.push((c, slr));
            dfs_assign(ctx, assign, used, explored);
            assign.pop();
            used[slr] = prev;
            // Post-deadline with no design yet: one greedy dive down
            // the first viable branch (which either just produced the
            // anytime design, or dead-ended). Give up on the siblings
            // rather than exhaust the tree past the deadline — the
            // caller reports the timeout in the Infeasible detail.
            if expired {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::fusion::fuse;
    use crate::ir::polybench;

    fn quick_opts() -> SolverOptions {
        SolverOptions {
            beam: 12,
            max_factor_per_loop: 32,
            max_unroll: 1024,
            timeout: Duration::from_secs(20),
            ..SolverOptions::default()
        }
    }

    #[test]
    fn gemm_solves_and_is_valid() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &quick_opts()).unwrap();
        r.design.validate(&k, &r.fused, dev.slrs).unwrap();
        assert!(r.gflops > 50.0, "gemm RTL gflops too low: {}", r.gflops);
        assert!(r.explored > 100);
    }

    #[test]
    fn solve_with_shared_cache_matches_cold_solve() {
        // The shared GeometryCache must not change what the solver finds:
        // same design, same latency, point for point. (gemm's fusion
        // space has a single variant — its init/update pair cannot
        // split — so the exploring solve and the pinned-variant solve
        // see the same space.)
        let k = polybench::gemm();
        let dev = Device::u55c();
        let cold = solve(&k, &dev, &quick_opts()).unwrap();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let warm = solve_with_cache(&k, &fg, &cache, &dev, &quick_opts()).unwrap();
        assert_eq!(cold.design, warm.design);
        assert_eq!(cold.latency.total, warm.latency.total);
        // explored counts are only exactly reproducible single-threaded
        // (parallel pruning races change them, never the design)
        if quick_opts().jobs == 1 {
            assert_eq!(cold.explored, warm.explored);
        }
    }

    #[test]
    fn three_madd_uses_concurrency() {
        let k = polybench::three_madd();
        let dev = Device::u55c();
        let df = solve(&k, &dev, &quick_opts()).unwrap();
        let seq = solve(
            &k,
            &dev,
            &SolverOptions {
                model: ExecutionModel::Sequential,
                overlap: false,
                ..quick_opts()
            },
        )
        .unwrap();
        assert!(
            df.latency.total < seq.latency.total,
            "dataflow {} !< sequential {}",
            df.latency.total,
            seq.latency.total
        );
    }

    #[test]
    fn onboard_budget_shrinks_design() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let rtl = solve(&k, &dev, &quick_opts()).unwrap();
        let board = solve(
            &k,
            &dev,
            &SolverOptions {
                scenario: Scenario::OnBoard { slrs: 1, frac: 0.6 },
                ..quick_opts()
            },
        )
        .unwrap();
        assert!(board.gflops <= rtl.gflops * 1.05);
        // on-board design must fit the scaled budget
        let budget = dev.slr.scaled(0.6);
        assert!(crate::dse::constraints::feasible(&k, &board.fused, &board.design, &dev, &budget));
    }

    #[test]
    fn scenario_serde_round_trip() {
        use serde::{Deserialize, Serialize};
        for s in [Scenario::Rtl, Scenario::OnBoard { slrs: 3, frac: 0.6 }] {
            let v = s.serialize();
            assert_eq!(Scenario::deserialize(&v).unwrap(), s);
        }
        assert!(Scenario::deserialize(&serde::Value::Null).is_err());
    }

    #[test]
    fn warm_start_never_worse() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let cold = solve(&k, &dev, &quick_opts()).unwrap();
        let inc_cycles = crate::sim::engine::simulate(&k, &cold.fused, &cold.design, &dev).cycles;
        // a much weaker search, warm-started from the cold design, may
        // not beat the incumbent but can never fall below it
        let warm = solve(
            &k,
            &dev,
            &SolverOptions { incumbent: Some(cold.design.clone()), beam: 2, ..quick_opts() },
        )
        .unwrap();
        let warm_cycles = crate::sim::engine::simulate(&k, &warm.fused, &warm.design, &dev).cycles;
        assert!(warm_cycles <= inc_cycles, "warm {warm_cycles} > incumbent {inc_cycles}");
        assert!(warm.warm_started, "usable incumbent must be reported as a warm start");
    }

    #[test]
    fn mismatched_incumbent_is_ignored() {
        let k = polybench::gemm();
        let other = polybench::bicg();
        let dev = Device::u55c();
        let inc = solve(&other, &dev, &quick_opts()).unwrap().design;
        // an incumbent from another kernel must not leak into the result
        let r = solve(&k, &dev, &SolverOptions { incumbent: Some(inc), ..quick_opts() }).unwrap();
        assert_eq!(r.design.kernel, "gemm");
        assert!(!r.warm_started, "rejected incumbent must not count as a warm start");
        r.design.validate(&k, &r.fused, dev.slrs).unwrap();
    }

    #[test]
    fn timeout_is_anytime() {
        let k = polybench::three_mm();
        let dev = Device::u55c();
        let r = solve(
            &k,
            &dev,
            &SolverOptions { timeout: Duration::from_millis(50), ..quick_opts() },
        )
        .unwrap();
        // even with a tiny timeout we get *a* design
        assert!(r.latency.total > 0);
    }

    #[test]
    fn infeasible_budget_is_an_error() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let err = solve(
            &k,
            &dev,
            &SolverOptions {
                scenario: Scenario::OnBoard { slrs: 1, frac: 1e-6 },
                ..quick_opts()
            },
        )
        .unwrap_err();
        let SolverError::Infeasible { task, detail } = err;
        assert!(task.is_some(), "a single-region overflow names the task");
        assert!(detail.contains("gemm"), "{detail}");
    }

    #[test]
    fn multi_slr_solves_are_symmetry_broken() {
        // Region ids appear in first-use order: the renamed duplicates
        // are pruned, so region r can only appear after 0..r did.
        let k = polybench::three_mm();
        let dev = Device::u55c();
        let r = solve(
            &k,
            &dev,
            &SolverOptions {
                scenario: Scenario::OnBoard { slrs: 3, frac: 0.6 },
                ..quick_opts()
            },
        )
        .unwrap();
        let mut seen = 0usize;
        for tc in &r.design.tasks {
            assert!(tc.slr <= seen, "region {} opened before {}", tc.slr, seen);
            seen = seen.max(tc.slr + 1);
        }
    }

    #[test]
    fn fixed_fusion_pins_the_max_fusion_variant() {
        let k = polybench::gemver();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &SolverOptions { explore_fusion: false, ..quick_opts() }).unwrap();
        assert_eq!(r.fusion_variants, 1);
        assert_eq!(r.design.fusion, FusionPlan::max_fusion(&k));
        assert_eq!(r.fused.plan(), FusionPlan::max_fusion(&k));
        r.design.validate(&k, &r.fused, dev.slrs).unwrap();
    }

    #[test]
    fn fusion_exploration_never_worse_than_fixed() {
        // gemver's x-update chain is the splittable group: the explored
        // space is a superset of the fixed space, and both are scored
        // by the same simulator, so the explored winner can never be
        // slower. (The zoo-wide version of this property lives in
        // tests/property_fusion.rs.)
        let k = polybench::gemver();
        let dev = Device::u55c();
        let fixed = solve(&k, &dev, &SolverOptions { explore_fusion: false, ..quick_opts() })
            .unwrap();
        let explored = solve(&k, &dev, &quick_opts()).unwrap();
        assert!(explored.fusion_variants > 1, "gemver must have a split variant");
        let fixed_cycles =
            crate::sim::engine::simulate(&k, &fixed.fused, &fixed.design, &dev).cycles;
        let explored_cycles =
            crate::sim::engine::simulate(&k, &explored.fused, &explored.design, &dev).cycles;
        // superset argument needs completed searches (anytime results
        // of a timed-out explored solve are exempt)
        if !fixed.timed_out && !explored.timed_out {
            assert!(
                explored_cycles <= fixed_cycles,
                "fusion-explored {explored_cycles} worse than fixed {fixed_cycles}"
            );
        }
        explored.design.validate(&k, &explored.fused, dev.slrs).unwrap();
    }

    #[test]
    fn cross_variant_incumbent_is_rejected_by_the_gate() {
        // An incumbent solved under the split variant must not seed a
        // solve that only considers the max-fusion variant: the
        // usability gate (design.validate checks fusion == fg.plan())
        // rejects it, exactly like the QoR cache's hit check.
        let k = polybench::gemver();
        let dev = Device::u55c();
        let explored = solve(&k, &dev, &quick_opts()).unwrap();
        let split_design = explored.design.clone();
        if split_design.fusion == FusionPlan::max_fusion(&k) {
            // the split variant did not win — synthesize the rejection
            // the other way: a max-fusion incumbent into a space that
            // does not contain it cannot happen (max fusion is always
            // variant 0), so the property is vacuously covered by the
            // pinned-variant check below.
            let fixed = solve(
                &k,
                &dev,
                &SolverOptions {
                    explore_fusion: false,
                    incumbent: Some(split_design),
                    beam: 2,
                    ..quick_opts()
                },
            )
            .unwrap();
            assert!(fixed.warm_started, "matching-variant incumbent must warm start");
            return;
        }
        let fixed = solve(
            &k,
            &dev,
            &SolverOptions {
                explore_fusion: false,
                incumbent: Some(split_design),
                beam: 2,
                ..quick_opts()
            },
        )
        .unwrap();
        assert!(
            !fixed.warm_started,
            "incumbent from a different fusion variant must be rejected"
        );
        assert_eq!(fixed.design.fusion, FusionPlan::max_fusion(&k));
    }
}
