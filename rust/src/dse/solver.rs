//! The design-space solver — the reproduction's substitute for
//! AMPL + Gurobi (paper §6.1).
#![deny(missing_docs)]
//!
//! The paper's "NLP" is a nonconvex quadratic program over *discrete*
//! decision variables (divisor-constrained tile factors, permutation
//! choices, transfer levels, SLR ids); Gurobi solves it by spatial
//! branch-and-bound. We solve the same space with an explicit two-stage
//! combinatorial branch-and-bound:
//!
//! 1. **per-task enumeration** — tile factors (with padding, Eqs 1–2) ×
//!    legal permutations × transfer plans (Eqs 5–6), filtered by the
//!    resource constraints (Eqs 7–10), reduced to a Pareto front over
//!    (latency, full resource vector);
//! 2. **global assembly** — DFS over per-task candidates and SLR
//!    assignments (Eq 11) minimizing the DAG latency (Eqs 12–13) under
//!    per-region budgets, with branch-and-bound pruning.
//!
//! The inner loop is incremental on top of the shared evaluation core
//! ([`super::eval`]): the configuration-independent parts (array infos,
//! access translations, legal orders) are memoized at fusion time in a
//! [`GeometryCache`], so per-candidate evaluation only recomputes what
//! a changed tile factor/permutation/plan invalidates. `solve` builds
//! the cache itself; [`solve_with_cache`] lets callers (the coordinator
//! flow, `service::batch` worker pools) share one cache per kernel
//! across solves.
//!
//! **Parallelism.** One solve can use several cores
//! ([`SolverOptions::jobs`]): stage 1/2 fans the per-task enumeration
//! passes (padded + padding-free restart) across a scoped worker pool
//! sharing the read-only [`GeometryCache`] and one [`Deadline`], and
//! stage 3 distributes the top of the DFS tree across the same pool
//! with a shared atomic incumbent bound (`SharedBest`), so every
//! worker prunes against the globally best design. Region-renamed
//! duplicate assignments are never explored (SLR symmetry breaking:
//! task *t* may reuse an open region or open exactly the next fresh
//! one — regions are interchangeable, latency only compares SLR ids
//! for equality). Results are **deterministic and thread-count
//! independent** for solves that finish within the timeout: candidate
//! lists merge in a fixed order, complete assignments are compared by
//! the total order (simulated latency, then candidate index, then
//! assignment order), and workers prune only *strictly* above the
//! shared bound, so `jobs = 1` and `jobs = N` return bit-identical
//! designs (see DESIGN.md §Parallel solver).
//!
//! **Fusion as a dimension.** Task fusion is explored jointly with the
//! rest of the space ([`SolverOptions::explore_fusion`]): every
//! dependence-legal statement partition between full fission and max
//! output-stationary fusion ([`crate::analysis::fusion::enumerate_fusions`])
//! becomes a *variant* with its own [`FusedGraph`] and
//! [`GeometryCache`]. The space covers the paper's §3.1 full
//! generality: partial (loop-range) fusions materialize peeled
//! prologue/epilogue sub-tasks that are solved like any other task
//! (their geometry runs over the narrowed outer trip), and cross-array
//! merges fold unifying sibling nests into one engine. Stage-1
//! enumeration units are flattened across
//! variants onto the same worker pool, and all variants share one
//! `SharedBest` incumbent — a finished variant's simulated latency
//! prunes its siblings' DFS from the first node. The total order
//! extends to `(latency, variant index, candidate index, assignment)`,
//! so the result stays deterministic and thread-count independent, and
//! latency ties prefer the max-fusion variant (variant 0).
//!
//! **Fast path.** The stage-3 inner loop is allocation-free: per-task
//! candidates live in a flat arena and are referenced by index, the
//! per-region `used` vectors update incrementally, and each DFS worker
//! iterates a *profile-guided order* (standalone latency, then
//! resource footprint) so the first dive lands near the optimum and
//! the shared bound prunes early. Leaves are scored without building a
//! `DesignConfig`: an analytic pre-filter (the same standalone-latency
//! lower bound the branch pruning uses; the exact closed form for
//! Sequential) drops leaves strictly above the shared bound before any
//! assembly or simulation (`model_pruned`), and surviving dataflow
//! leaves run the simulator's own step loop
//! ([`crate::sim::engine::run_dataflow`]) over per-candidate step
//! specs precomputed once per arena, on reusable scratch. A
//! *fusion-aware shared beam* ([`SolverOptions::shared_beam`]) probes
//! one greedy leaf per variant up front and then starves every
//! candidate list against the resulting cross-variant bound, shrinking
//! losing variants before their DFS starts (`beam_starved`). All of it
//! is answer-preserving and property-pinned
//! (`tests/solver_fastpath.rs`); see DESIGN.md §Solver fast path.
//!
//! **Stage-1/2 fast path.** The same playbook one stage earlier, so
//! enumeration is allocation-free per point too. An
//! [`eval::ResolveArena`] ([`SolverOptions::resolve_arena`]) retains
//! the permuted orders, transfer counts and per-array resolution
//! buffers across the Cartesian walk and re-resolves only the arrays
//! whose geometry the step actually changed (`enum_factors` varies the
//! deepest position fastest; a transfer-plan flip in the stage-2
//! descent re-resolves exactly the flipped array). The per-task Pareto
//! reduction dispatches to rank-bitset acceptance
//! ([`SolverOptions::pareto_bitsets`]): word-parallel prefix-mask
//! intersection instead of a per-candidate scan over the front. And
//! the warm-start incumbent — seeded *before* the stage-1 fan-out —
//! starves enumeration itself ([`SolverOptions::enum_starvation`]): an
//! analytic per-subtree latency floor lets `enum_factors` skip whole
//! factor subtrees (and with them every permutation of those combos)
//! that provably cannot beat the incumbent, exactly counted in
//! `enum_pruned` against the invariant `stage1_points + enum_pruned ==`
//! the reference run's `stage1_points`. All three knobs are
//! answer-preserving and property-pinned (`tests/solver_stage12.rs`);
//! see DESIGN.md §13.
//!
//! **Telemetry.** With [`SolverOptions::telemetry`] on, the solve
//! threads a [`crate::obs::SolveCounters`] block through all three
//! stages and returns it frozen as [`SolverResult::telemetry`]:
//! per-variant enumeration/Pareto/prune counters, a DFS depth
//! histogram, and the incumbent timeline (every [`SharedBest`]
//! improvement as `(elapsed, latency, variant)`). Collection is
//! observational only — it never changes search order, pruning or the
//! returned design — and when off every hook is one predictable branch
//! (bench-bounded in `benches/solver_eval.rs`).
//!
//! Infeasible budgets are a user input, not a bug: the solver returns
//! [`SolverError::Infeasible`] instead of panicking, and the service
//! layer surfaces it as a per-request error.
//!
//! A timeout makes the solver *anytime*: it returns the incumbent with
//! `timed_out = true`, mirroring the paper's Gurobi-timeout mode (§6.4).

use super::config::{DesignConfig, ExecutionModel, TaskConfig, TransferPlan};
use super::constraints::task_resources;
use super::cost::{gflops, graph_latency_resolved, sequential_total, task_latency, GraphLatency};
use super::eval::{self, FusionSpace, GeometryCache, ResolvedDesign, TaskStatics};
use super::padding::legal_intra_factors;
use crate::analysis::fusion::{FusedGraph, FusionPlan};
use crate::hw::resources::ResourceVec;
use crate::hw::{Device, SlrBudget};
use crate::ir::Kernel;
use crate::obs;
use crate::par::run_indexed;
use crate::sim::engine::{candidate_steps, run_dataflow, simulate_resolved, DataflowScratch, TaskSteps};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Resource scenario the solver targets.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scenario {
    /// RTL simulation: the whole device as one region (paper §6.2 gives
    /// every framework all U55C resources for RTL comparison).
    Rtl,
    /// On-board: `slrs` usable regions, each capped at `frac` utilization.
    OnBoard {
        /// Number of usable SLR regions.
        slrs: usize,
        /// Per-region utilization cap in (0, 1].
        frac: f64,
    },
}

impl std::fmt::Display for Scenario {
    /// Canonical text form, also used by the QoR-DB cache key:
    /// `rtl` or `onboard:<slrs>:<frac>`.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Scenario::Rtl => write!(f, "rtl"),
            Scenario::OnBoard { slrs, frac } => write!(f, "onboard:{slrs}:{frac}"),
        }
    }
}

// Manual `serde` impls (the vendored serde has no derive proc-macro):
// part of the serde coverage for the design-space types (DesignConfig,
// TaskConfig, TransferPlan, ExecutionModel, Scenario). Today's QoR-DB
// records reach Scenario only through the canonical key string, but the
// impls keep the type ready for richer record schemas; the round-trip
// is pinned by `scenario_serde_round_trip` below.
impl serde::Serialize for Scenario {
    fn serialize(&self) -> serde::Value {
        match self {
            Scenario::Rtl => serde::Value::Obj(vec![(
                "kind".to_string(),
                serde::Value::Str("rtl".to_string()),
            )]),
            Scenario::OnBoard { slrs, frac } => serde::Value::Obj(vec![
                ("kind".to_string(), serde::Value::Str("onboard".to_string())),
                ("slrs".to_string(), serde::Serialize::serialize(slrs)),
                ("frac".to_string(), serde::Serialize::serialize(frac)),
            ]),
        }
    }
}

impl serde::Deserialize for Scenario {
    fn deserialize(v: &serde::Value) -> Result<Scenario, serde::Error> {
        match v.field("kind")?.as_str() {
            Some("rtl") => Ok(Scenario::Rtl),
            Some("onboard") => Ok(Scenario::OnBoard {
                slrs: serde::Deserialize::deserialize(v.field("slrs")?)?,
                frac: serde::Deserialize::deserialize(v.field("frac")?)?,
            }),
            other => Err(serde::Error::new(format!("invalid scenario kind {other:?}"))),
        }
    }
}

/// Why a solve produced no design. Infeasibility is an expected outcome
/// of user-chosen budgets (a tiny `OnBoard` fraction, an over-restricted
/// baseline space), never a panic: it flows as an `Err` through the
/// coordinator flow, `service::batch` and the CLI.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SolverError {
    /// No design satisfies the scenario's per-region resource budget.
    /// `task` names the first task with no individually-fitting
    /// candidate when the infeasibility is attributable to one task;
    /// `None` means every task fits alone but no global assembly does.
    Infeasible {
        /// First task with no fitting candidate, when attributable.
        task: Option<usize>,
        /// Human-readable description of the violated budget.
        detail: String,
    },
}

impl std::fmt::Display for SolverError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolverError::Infeasible { task: Some(t), detail } => {
                write!(f, "infeasible budget: task {t}: {detail}")
            }
            SolverError::Infeasible { task: None, detail } => {
                write!(f, "infeasible budget: {detail}")
            }
        }
    }
}

impl std::error::Error for SolverError {}

/// Shared solve deadline: one `Instant` fixed at solve start, read by
/// every stage-1/2/3 worker. Replaces the old per-call `start` /
/// `&mut timed_out` out-params, which could not be shared across a
/// worker pool.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    start: Instant,
    timeout: Duration,
}

impl Deadline {
    /// Start the deadline clock now, expiring after `timeout`.
    pub fn new(timeout: Duration) -> Deadline {
        Deadline { start: Instant::now(), timeout }
    }

    /// Whether the deadline has passed.
    pub fn expired(&self) -> bool {
        self.start.elapsed() > self.timeout
    }

    /// Wall time since the solve started.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

/// Worker count for a fresh `SolverOptions`: `$PROMETHEUS_JOBS` when set
/// to a positive integer (CI runs the suite under both `1` and `4` to
/// enforce thread-count independence), else 1. Parallelism is opt-in —
/// `optimize --jobs`/`batch --jobs` and the service layer raise it
/// explicitly.
pub fn default_jobs() -> usize {
    std::env::var("PROMETHEUS_JOBS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&j| j >= 1)
        .unwrap_or(1)
}

/// Solver knobs. Baselines restrict this space to mimic each framework.
#[derive(Debug, Clone)]
pub struct SolverOptions {
    /// Resource scenario the solve targets (RTL or on-board regions).
    pub scenario: Scenario,
    /// Execution model of the generated design (dataflow/sequential).
    pub model: ExecutionModel,
    /// Computation/communication overlap (ping-pong buffering).
    pub overlap: bool,
    /// Allow computation padding (Eq 2 bound; 0 disables).
    pub max_pad: u64,
    /// Allow loop permutation.
    pub permute: bool,
    /// Allow data tiling (false = whole-array buffers, on-chip style).
    pub tiling: bool,
    /// Cap on per-loop intra factors.
    pub max_factor_per_loop: u64,
    /// Cap on the task unroll factor (product of intra factors).
    pub max_unroll: u64,
    /// Candidates kept per task after stage 1.
    pub beam: usize,
    /// Anytime timeout.
    pub timeout: Duration,
    /// Warm-start incumbent (service layer: a previously-solved design
    /// from the QoR knowledge base). When structurally valid and feasible
    /// for this scenario it seeds the branch-and-bound bound, so the DFS
    /// prunes against it from the first node and the solver can never
    /// return a worse design than the incumbent. Ignored (never copied
    /// into the result blindly) when it does not fit the scenario.
    pub incumbent: Option<DesignConfig>,
    /// Worker threads for *this* solve (stage-1/2 enumeration fan-out
    /// and stage-3 DFS branch distribution). The returned design is
    /// thread-count independent — like `incumbent`, `jobs` changes
    /// solve speed, never the answer — so it is excluded from the QoR
    /// cache key. 0 is treated as 1.
    pub jobs: usize,
    /// Explore task fusion as a design dimension: [`solve`] enumerates
    /// every legal fusion variant and solves them jointly under one
    /// shared incumbent. `false` pins the max output-stationary fusion
    /// (the pre-fusion-DSE behaviour; every baseline restricts to it).
    /// Changes the answer, so it *is* part of the QoR cache key.
    pub explore_fusion: bool,
    /// Collect structured telemetry for this solve
    /// ([`SolverResult::telemetry`]): per-variant/per-stage counters,
    /// the DFS depth histogram and the incumbent timeline.
    /// Observational only — search order, pruning and the returned
    /// design are bit-identical with it on or off (property-tested in
    /// `tests/telemetry.rs`) — so, like `jobs`, it is excluded from
    /// the QoR cache key. Defaults to whether tracing is active
    /// ([`crate::obs::trace_enabled`]); the disabled per-hook cost is
    /// bench-bounded in `benches/solver_eval.rs`.
    pub telemetry: bool,
    /// Leaf fast path (on by default): score complete assignments
    /// through per-candidate step specs precomputed once per variant,
    /// on reusable scratch, after an analytic pre-filter — a leaf
    /// whose lower bound (max standalone candidate latency; for
    /// Sequential the exact closed form) is strictly above the shared
    /// bound is dropped before any `DesignConfig` assembly,
    /// `ResolvedDesign::new` or simulation (counted as
    /// `model_pruned`). Answer-preserving by the same lower-bound
    /// invariant the DFS branch pruning relies on (property-pinned in
    /// `tests/solver_fastpath.rs`), so — like `jobs` and `telemetry`
    /// — it is excluded from the QoR cache key. `false` restores the
    /// pre-fast-path leaf (full design assembly + resolve + simulate
    /// per leaf), kept as the bench baseline and drift oracle.
    pub leaf_prefilter: bool,
    /// Fusion-aware shared stage-1 beam (on by default): before stage
    /// 3, dive each variant to one greedy profile-ordered leaf (a
    /// genuine DFS leaf, offered with its real tie-break key) to
    /// tighten the cross-variant incumbent, then *starve* every
    /// candidate list against the resulting bound — candidates whose
    /// standalone latency already exceeds it cannot appear in any
    /// winning or tying leaf and are dropped from the DFS iteration
    /// order (`beam_starved`); a variant starved to an empty task
    /// list skips its DFS entirely. Only strictly-worse leaves are
    /// removed, so the `(latency, key)` minimum — the returned design
    /// — is unchanged (property-pinned); excluded from the QoR cache
    /// key.
    pub shared_beam: bool,
    /// Stage-1/2 arena resolution (on by default): per-(variant, task)
    /// enumeration resolves candidates through a reusable
    /// [`eval::ResolveArena`] — permuted orders, transfer counts and
    /// per-array plan/tile buffers allocated once and rewritten in
    /// place, recomputing only geometry downstream of the factor
    /// position that changed between consecutive Cartesian points.
    /// Byte-identical to fresh [`eval::resolve_task`] resolution
    /// (pinned per (kernel, variant, task) in
    /// `tests/solver_stage12.rs`), so — like `jobs` and `telemetry` —
    /// it is excluded from the QoR cache key. `false` restores the
    /// per-point fresh resolution, kept as the bench baseline and
    /// drift oracle.
    pub resolve_arena: bool,
    /// Dominance bitsets for the stage-2 Pareto reduction (on by
    /// default): per-resource-dimension rank bitsets make each
    /// acceptance test a word-parallel mask intersection instead of a
    /// scan over the kept front. Acceptance, front order and
    /// truncation are byte-identical to the reference scan
    /// (property-pinned), so it is excluded from the QoR cache key.
    pub pareto_bitsets: bool,
    /// Bound-driven enumeration starvation (on by default): the
    /// cross-variant incumbent established *before* stage 1 (the
    /// warm-start gate) starves enumeration itself — an analytic
    /// per-subtree latency floor (the product of inter-tile trips, the
    /// best achievable latency at unbounded unroll given the remaining
    /// budget; the same invariant the DFS branch pruning relies on)
    /// lets `enum_factors` skip whole factor subtrees and
    /// `enumerate_task` skip whole permutations that provably lose,
    /// counted as `enum_pruned`. Only points whose standalone floor
    /// is *strictly* above the incumbent bound are skipped — none of
    /// them can appear in any winning or tying design — and the floor
    /// *filter* itself applies under either setting whenever an
    /// incumbent exists: with the knob off, every point is resolved
    /// first (counted in `stage1_points`) and then dropped by the
    /// identical per-point test, so the emitted candidate set — and
    /// the returned design — is unchanged (property-pinned) and the
    /// knob is excluded from the QoR cache key. The bound is fixed
    /// before the stage-1 fan-out, keeping results thread-count
    /// independent.
    pub enum_starvation: bool,
}

impl Default for SolverOptions {
    fn default() -> Self {
        SolverOptions {
            scenario: Scenario::Rtl,
            model: ExecutionModel::Dataflow,
            overlap: true,
            max_pad: 16,
            permute: true,
            tiling: true,
            max_factor_per_loop: 128,
            max_unroll: 4096,
            beam: 192,
            timeout: Duration::from_secs(120),
            incumbent: None,
            jobs: default_jobs(),
            explore_fusion: true,
            telemetry: obs::trace_enabled(),
            leaf_prefilter: true,
            shared_beam: true,
            resolve_arena: true,
            pareto_bitsets: true,
            enum_starvation: true,
        }
    }
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct SolverResult {
    /// The best feasible design found.
    pub design: DesignConfig,
    /// The fused-task graph of the **winning fusion variant** — the one
    /// `design.tasks` indexes. Downstream consumers (simulation, board
    /// model, codegen, reports) must evaluate the design against this
    /// graph, never against a freshly recomputed `fuse()`.
    pub fused: FusedGraph,
    /// Fusion variants this solve considered (1 = fixed fusion).
    pub fusion_variants: usize,
    /// Analytic DAG latency of the winning design.
    pub latency: GraphLatency,
    /// Simulated throughput at the device's target clock.
    pub gflops: f64,
    /// Wall time the solve took.
    pub solve_time: Duration,
    /// Design points evaluated. Deterministic for `jobs = 1`; with more
    /// workers the count varies slightly run to run (pruning races),
    /// while `design`/`latency` stay bit-identical.
    pub explored: u64,
    /// Whether the anytime timeout cut the search short.
    pub timed_out: bool,
    /// Whether a usable `SolverOptions::incumbent` actually seeded the
    /// branch-and-bound bound (false when no incumbent was given *or*
    /// the given one was rejected as structurally invalid/infeasible).
    pub warm_started: bool,
    /// Structured solve telemetry: per-variant counters, DFS depth
    /// histogram and incumbent timeline. All-empty unless
    /// [`SolverOptions::telemetry`] was on.
    pub telemetry: obs::SolveTelemetry,
}

/// One per-task candidate with its standalone metrics. Public so tests
/// can exercise [`pareto`] directly on synthetic fronts.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// The per-task configuration.
    pub cfg: TaskConfig,
    /// Standalone task latency under the analytic model.
    pub latency: u64,
    /// Resource usage of the configured task.
    pub res: ResourceVec,
}

/// Region budget for the scenario.
pub fn region_budget(dev: &Device, scenario: Scenario) -> (usize, SlrBudget) {
    match scenario {
        Scenario::Rtl => (1, dev.total()),
        Scenario::OnBoard { slrs, frac } => (slrs.min(dev.slrs), dev.slr.scaled(frac)),
    }
}

/// Whether `design` is servable under `scenario` on the *current*
/// resource model: structural validation, SLR ids within the scenario's
/// regions, and per-region feasibility. The single predicate behind
/// both the solver's warm-start incumbent gate and the QoR cache's
/// hit/stale check — keep them from drifting by construction.
pub fn design_usable(
    k: &Kernel,
    fg: &FusedGraph,
    design: &DesignConfig,
    dev: &Device,
    scenario: Scenario,
) -> bool {
    let cache = GeometryCache::new(k, fg);
    design_usable_with_cache(k, fg, &cache, design, dev, scenario)
}

/// The index of the fusion variant in `space` that `design` realizes,
/// when the design is also servable against that variant under
/// `scenario` — the one predicate behind the QoR-cache validity checks,
/// so the service paths cannot drift on what "usable record" means.
pub fn usable_variant_in_space(
    k: &Kernel,
    space: &FusionSpace,
    design: &DesignConfig,
    dev: &Device,
    scenario: Scenario,
) -> Option<usize> {
    space.variant_of(&design.fusion).filter(|&vi| {
        let v = &space.variants[vi];
        design_usable_with_cache(k, &v.fg, &v.cache, design, dev, scenario)
    })
}

/// [`design_usable`] over a pre-built geometry cache — the warm-start
/// gate, the cached flow and the batch orchestrator all hold one.
pub fn design_usable_with_cache(
    k: &Kernel,
    fg: &FusedGraph,
    cache: &GeometryCache,
    design: &DesignConfig,
    dev: &Device,
    scenario: Scenario,
) -> bool {
    let (regions, budget) = region_budget(dev, scenario);
    // structural validation first: resolution indexes the cache by task
    // id, which is only safe on a validated design
    design.validate(k, fg, dev.slrs).is_ok()
        && design.tasks.iter().all(|t| t.slr < regions)
        && {
            let rd = ResolvedDesign::new(k, fg, cache, design);
            crate::dse::constraints::feasible_resolved(&rd, dev, &budget)
        }
}

/// Solve the design space for `k`. Returns the best feasible design
/// found, or [`SolverError::Infeasible`] when the scenario's budget
/// admits no design at all. Builds the fusion space (all legal
/// variants under `opts.explore_fusion`) and its geometry caches
/// itself; callers that solve the same kernel repeatedly should build
/// a [`FusionSpace`] once and use [`solve_space`].
pub fn solve(k: &Kernel, dev: &Device, opts: &SolverOptions) -> Result<SolverResult, SolverError> {
    let space = FusionSpace::for_solver(k, opts.explore_fusion);
    solve_space(k, &space, dev, opts)
}

/// [`solve`] over a pre-built fusion space (the coordinator flow and
/// `service::batch` build one space per kernel and share it, read-only,
/// across requests and workers).
pub fn solve_space(
    k: &Kernel,
    space: &FusionSpace,
    dev: &Device,
    opts: &SolverOptions,
) -> Result<SolverResult, SolverError> {
    let variants: Vec<(&FusedGraph, &GeometryCache)> =
        space.variants.iter().map(|v| (&v.fg, &v.cache)).collect();
    solve_variants(k, &variants, dev, opts)
}

/// Globally shared branch-and-bound incumbent for stage 3: a lock-free
/// latency bound for pruning plus the full deterministic tie-break
/// state under a mutex.
struct SharedBest {
    /// Best simulated latency so far (`u64::MAX` = none). Workers prune
    /// with a *strict* compare against this relaxed-loaded value: the
    /// bound only ever decreases, so a stale read can only under-prune,
    /// never cut off a branch that could still win a tie.
    bound: AtomicU64,
    /// `(latency, assignment key, design)`. The assignment key — a
    /// leading `(fusion variant index, 0)` element followed by the
    /// `(candidate index, region)` sequence — breaks latency ties by
    /// lexicographic order, which is exactly the order the sequential
    /// outer-variant loop + DFS enumerates leaves in, making the winner
    /// independent of which worker reached it first (ties between
    /// fusion variants fall to the lower variant index, i.e. max fusion
    /// first). The warm-start incumbent gets the empty key, so it wins
    /// all ties and the solve can never return a design worse than (or
    /// a tied re-discovery of) the incumbent.
    best: Mutex<Option<(u64, Vec<(usize, usize)>, DesignConfig)>>,
}

impl SharedBest {
    fn new() -> SharedBest {
        SharedBest { bound: AtomicU64::new(u64::MAX), best: Mutex::new(None) }
    }

    fn bound(&self) -> u64 {
        self.bound.load(Ordering::Relaxed)
    }

    fn has_best(&self) -> bool {
        self.bound() != u64::MAX
    }

    /// Offer a complete design. Keeps the minimum under the total order
    /// `(latency, key)`; the fast path rejects anything strictly above
    /// the current bound without taking the lock (such a design can
    /// neither win nor tie the final minimum). An accepted improvement
    /// is appended to the incumbent timeline (`counters`) under the
    /// lock, so the recorded `(latency, variant)` sequence is totally
    /// ordered — telemetry observes the decision, never shapes it.
    fn offer(
        &self,
        lat: u64,
        key: Vec<(usize, usize)>,
        design: DesignConfig,
        variant: usize,
        deadline: Deadline,
        counters: &obs::SolveCounters,
    ) {
        if lat > self.bound.load(Ordering::Relaxed) {
            return;
        }
        let mut best = self.best.lock().unwrap();
        let better = match &*best {
            None => true,
            Some((blat, bkey, _)) => lat < *blat || (lat == *blat && key < *bkey),
        };
        if better {
            self.bound.store(lat, Ordering::Relaxed);
            *best = Some((lat, key, design));
            counters.incumbent(deadline.elapsed().as_micros() as u64, lat, variant);
        }
    }
}

/// [`solve`] over a pre-built fusion + geometry cache for **one pinned
/// fusion variant** (the given `fg` — `explore_fusion` is not
/// consulted). The cache is read-only and thread-safe: callers holding
/// one per kernel share it across solves, and this solve's own workers
/// share it again. To explore fusion with shared caches, build a
/// [`FusionSpace`] and call [`solve_space`].
pub fn solve_with_cache(
    k: &Kernel,
    fg: &FusedGraph,
    cache: &GeometryCache,
    dev: &Device,
    opts: &SolverOptions,
) -> Result<SolverResult, SolverError> {
    solve_variants(k, &[(fg, cache)], dev, opts)
}

/// The multi-variant solver core: one branch-and-bound across every
/// given fusion variant, under a single shared deadline, worker pool
/// and incumbent.
fn solve_variants(
    k: &Kernel,
    variants: &[(&FusedGraph, &GeometryCache)],
    dev: &Device,
    opts: &SolverOptions,
) -> Result<SolverResult, SolverError> {
    let deadline = Deadline::new(opts.timeout);
    let jobs = opts.jobs.max(1);
    let n_variants = variants.len();
    let (regions, budget) = region_budget(dev, opts.scenario);
    let plans: Vec<FusionPlan> = variants.iter().map(|(fg, _)| fg.plan()).collect();
    // depth slots cover 0..=n_tasks: dfs_node fires at leaves too
    let max_tasks = variants.iter().map(|(fg, _)| fg.tasks.len()).max().unwrap_or(0);
    let counters = obs::SolveCounters::new(opts.telemetry, n_variants, max_tasks + 1);

    // Warm start: a valid, feasible incumbent (e.g. a QoR-DB design
    // from a previous run) becomes the initial bound, so every
    // variant's DFS prunes against it immediately and the anytime
    // result can never be worse. The incumbent binds only to the
    // variant realizing its own fusion plan — a design from an
    // incompatible partition is rejected by the same usability gate the
    // QoR cache uses (`design.validate` checks fusion == fg.plan()).
    // Seeded *before* the stage-1 fan-out so the enumeration-starvation
    // floor (below) sees the same bound on every worker regardless of
    // thread count.
    let shared = SharedBest::new();
    let mut warm_started = false;
    let mut inc_variant: Option<usize> = None;
    if let Some(inc) = &opts.incumbent {
        if let Some(vi) = plans.iter().position(|p| p == &inc.fusion) {
            let (fg_v, cache_v) = variants[vi];
            let usable = inc.kernel == k.name
                && inc.model == opts.model
                && inc.overlap == opts.overlap
                && design_usable_with_cache(k, fg_v, cache_v, inc, dev, opts.scenario);
            if usable {
                let rd = ResolvedDesign::new(k, fg_v, cache_v, inc);
                let lat = simulate_resolved(&rd, dev).cycles;
                drop(rd);
                shared.offer(lat, Vec::new(), inc.clone(), vi, deadline, &counters);
                warm_started = true;
                inc_variant = Some(vi);
            }
        }
    }
    // Enumeration-starvation bound: a full-design incumbent latency is
    // an upper bound on the winner's total, and every task of the
    // winner has standalone latency <= that total under both execution
    // models, so any stage-1 point whose analytic latency floor already
    // exceeds it can never appear in the winning design. Fixed here,
    // before the fan-out, so the pruned set is identical for any
    // `jobs` value. Armed regardless of the `enum_starvation` knob —
    // the floor *filter* is part of the algorithm whenever an incumbent
    // exists (see `enumerate_task`); the knob only decides whether it
    // runs before resolution (subtree skipping) or after (the oracle
    // baseline), which is what keeps it answer-preserving.
    let enum_bound = shared.bound();

    // ---- stage 1 + 2: per-variant, per-task Pareto candidates ----------
    // Tasks placed in the same region share its budget; enumerate each
    // task against a fair share (regions spread tasks, so the share is
    // n_tasks / regions per region, per variant) — the global DFS
    // re-checks the true summed feasibility.
    //
    // Work units are (variant, task, pass) triples: the padded
    // enumeration, plus a restart pass without padding when padding is
    // on (padded variants can flood the stage-1 beam and bury the
    // unpadded optimum — the beam proxy uses default transfer plans;
    // the second pass is cheap and guarantees the Prometheus space
    // dominates the Sisyphus no-padding subspace). Units from *all*
    // fusion variants fan out across one worker pool; the per-task
    // merge (padded list, then no-pad list, then one Pareto reduction)
    // is a fixed fold, so the candidate fronts are identical for any
    // thread count.
    let nopad_opts = SolverOptions { max_pad: 0, ..opts.clone() };
    let mut units: Vec<(usize, usize, bool)> = Vec::new();
    for (vi, (fg, _)) in variants.iter().enumerate() {
        for t in 0..fg.tasks.len() {
            units.push((vi, t, false));
            if opts.max_pad > 0 {
                units.push((vi, t, true));
            }
        }
    }
    let shares: Vec<SlrBudget> = variants
        .iter()
        .map(|(fg, _)| {
            let per_region_tasks = fg.tasks.len().div_ceil(regions).max(1);
            budget.scaled(1.0 / per_region_tasks as f64)
        })
        .collect();
    let stage1_span = obs::span("solver", "solve.enumerate");
    let unit_results = run_indexed(units.len(), jobs, |i| {
        let (vi, t, nopad) = units[i];
        let o = if nopad { &nopad_opts } else { opts };
        enumerate_task(k, variants[vi].1, t, dev, o, &shares[vi], enum_bound, deadline)
    });
    let mut explored = 0u64;
    let mut stage1_timed_out = false;
    let mut per_variant: Vec<Vec<Vec<Candidate>>> =
        variants.iter().map(|(fg, _)| vec![Vec::new(); fg.tasks.len()]).collect();
    for (&(vi, t, _), out) in units.iter().zip(unit_results) {
        per_variant[vi][t].extend(out.cands);
        counters.enumerated(vi, out.explored);
        counters.stage1_points(vi, out.stage1_points);
        counters.enum_pruned(vi, out.enum_pruned);
        explored += out.explored;
        stage1_timed_out |= out.timed_out;
    }
    let per_variant: Vec<Vec<Vec<Candidate>>> = per_variant
        .into_iter()
        .enumerate()
        .map(|(vi, pt)| {
            pt.into_iter()
                .map(|raw| {
                    let raw_len = raw.len() as u64;
                    let front = pareto_with(raw, opts.pareto_bitsets);
                    counters.pareto(vi, front.len() as u64, raw_len - front.len() as u64);
                    front
                })
                .collect()
        })
        .collect();
    drop(stage1_span);

    // ---- stage 3: global assembly over variants × candidates × SLRs ----
    // (The warm-start incumbent was already offered to `shared` above,
    // before the stage-1 fan-out, so the DFS bound below starts from
    // it exactly as before.)

    // Per-variant feasibility gate. An empty candidate list would be a
    // solver bug, not an infeasible input: enumerate_task's anytime
    // fallbacks always yield >= 1 candidate. The anytime fallbacks keep
    // unfiltered candidates around, so an impossibly small budget shows
    // up here: not even the cheapest enumerated configuration of a task
    // fits one whole region. A variant failing the gate is *skipped*
    // (its siblings may still fit); only when every variant fails is
    // the problem infeasible, reported with the max-fusion (variant 0)
    // detail so single-variant solves keep the pre-fusion message. The
    // gate is waived per variant after a stage-1 timeout (fitting
    // configurations may simply not have been scored yet) and for the
    // incumbent's variant (a usable incumbent *proves* feasibility —
    // the fair-share filter inside enumerate_task can starve a task's
    // list on budgets between share and region, and the anytime
    // contract says the incumbent must come back, not an error).
    let mut dfsable = vec![false; n_variants];
    let mut variant0_fail: Option<(usize, String)> = None;
    for (vi, per_task) in per_variant.iter().enumerate() {
        let mut fits = true;
        for (t, cands) in per_task.iter().enumerate() {
            debug_assert!(!cands.is_empty(), "anytime fallbacks guarantee a candidate per task");
            if !cands.iter().any(|c| c.res.fits(&budget)) {
                fits = false;
                if vi == 0 && variant0_fail.is_none() {
                    variant0_fail = Some((
                        t,
                        format!(
                            "no configuration of task {t} of {} fits a single region budget \
                             (DSP {}, BRAM18 {}, LUT {}, FF {})",
                            k.name, budget.dsp, budget.bram18, budget.lut, budget.ff
                        ),
                    ));
                }
                break;
            }
        }
        dfsable[vi] = stage1_timed_out || inc_variant == Some(vi) || fits;
    }
    if !dfsable.iter().any(|&d| d) {
        let (task, detail) = variant0_fail.expect("all variants failed, so variant 0 did");
        return Err(SolverError::Infeasible { task: Some(task), detail });
    }

    // ---- stage-3 fast-path arenas --------------------------------------
    // Per variant: the leaf arena (sinks, predecessor lists, and — for
    // the dataflow leaf fast path — one precomputed step spec per
    // candidate, resolved once here instead of once per leaf) and the
    // profile-guided DFS iteration order: each task's candidates sorted
    // by standalone latency, then resource footprint, then original
    // Pareto index. Tie-break keys keep using the original indices, so
    // reordering the iteration permutes the DFS traversal but cannot
    // change the `(latency, key)` minimum over the leaf set — only how
    // fast the search reaches it.
    let arenas: Vec<LeafArena> = variants
        .iter()
        .enumerate()
        .map(|(vi, &(fg, cache))| {
            let n_tasks = fg.tasks.len();
            let want_specs = dfsable[vi]
                && opts.leaf_prefilter
                && opts.model == ExecutionModel::Dataflow;
            LeafArena {
                specs: if want_specs {
                    per_variant[vi]
                        .iter()
                        .enumerate()
                        .map(|(t, cands)| {
                            cands
                                .iter()
                                .map(|c| {
                                    let rt = eval::resolve_task(k, &cache.tasks[t], &c.cfg);
                                    candidate_steps(k, cache, &rt, opts.overlap, dev)
                                })
                                .collect()
                        })
                        .collect()
                } else {
                    Vec::new()
                },
                sinks: fg.sinks(),
                preds: (0..n_tasks).map(|t| fg.predecessors(t)).collect(),
            }
        })
        .collect();
    let mut orders: Vec<Vec<Vec<u32>>> = per_variant
        .iter()
        .map(|per_task| {
            per_task
                .iter()
                .map(|cands| {
                    let mut ord: Vec<u32> = (0..cands.len() as u32).collect();
                    ord.sort_by(|&x, &y| {
                        let (a, b) = (&cands[x as usize], &cands[y as usize]);
                        a.latency
                            .cmp(&b.latency)
                            .then(a.res.dsp.total_cmp(&b.res.dsp))
                            .then(a.res.bram18.total_cmp(&b.res.bram18))
                            .then(a.res.lut.total_cmp(&b.res.lut))
                            .then(a.res.ff.total_cmp(&b.res.ff))
                            .then(x.cmp(&y))
                    });
                    ord
                })
                .collect()
        })
        .collect();

    let timed_out_flag = AtomicBool::new(stage1_timed_out);
    let ctxs: Vec<DfsCtx> = variants
        .iter()
        .enumerate()
        .map(|(vi, &(fg, cache))| DfsCtx {
            k,
            fg,
            cache,
            dev,
            opts,
            budget: &budget,
            regions,
            per_task: &per_variant[vi],
            arena: &arenas[vi],
            deadline,
            shared: &shared,
            timed_out: &timed_out_flag,
            vi,
            plan: &plans[vi],
            counters: &counters,
        })
        .collect();

    // ---- fusion-aware shared beam --------------------------------------
    // One deterministic greedy probe per DFS-able variant — its first
    // profile-ordered leaf, offered with its real tie-break key — runs
    // sequentially in variant order, so the cross-variant bound is
    // tight before any DFS work and identical for every thread count.
    // Then each variant's candidate lists are starved against that
    // bound: a candidate whose standalone latency is strictly above it
    // cannot appear in any winning or tying leaf (the same lower-bound
    // invariant the DFS branch pruning uses), so it is removed from
    // the iteration order up front (`beam_starved`); losing variants
    // shrink toward — possibly to — an empty list, which skips their
    // DFS entirely. Only strictly-worse leaves are removed, so the
    // `(latency, key)` minimum over the remaining forest — the
    // returned design — is unchanged (shared-beam on/off bit-identity
    // is pinned in `tests/solver_fastpath.rs`).
    if opts.shared_beam {
        let mut probe_scratch = DfsScratch::new();
        for (vi, ctx) in ctxs.iter().enumerate() {
            if dfsable[vi] {
                probe_variant(ctx, &orders[vi], &mut probe_scratch, &mut explored);
            }
        }
        let bound = shared.bound();
        if bound != u64::MAX {
            for (vi, per_task) in per_variant.iter().enumerate() {
                if !dfsable[vi] {
                    continue;
                }
                let mut starved = 0u64;
                let mut emptied = false;
                for (t, ord) in orders[vi].iter_mut().enumerate() {
                    let before = ord.len();
                    ord.retain(|&c| per_task[t][c as usize].latency <= bound);
                    starved += (before - ord.len()) as u64;
                    emptied |= ord.is_empty();
                }
                if starved > 0 {
                    counters.beam_starved(vi, starved);
                }
                if emptied {
                    dfsable[vi] = false;
                }
            }
        }
    }

    // Distribute the top of the DFS forest: per DFS-able variant,
    // expand prefixes breadth-first in lexicographic order until there
    // is enough work to spread across the pool, then let workers pull
    // (variant, prefix) pairs from an atomic cursor and run the
    // ordinary DFS below each. Which worker finishes first does not
    // matter: the final design is the `(latency, key)` minimum over
    // every non-pruned leaf of every variant, and pruning is strictly
    // above the shared bound, so no potential winner is ever cut off —
    // and a variant finishing early tightens the bound its siblings
    // prune against.
    let mut frontier: Vec<(usize, Vec<(usize, usize)>)> = Vec::new();
    for (vi, ctx) in ctxs.iter().enumerate() {
        if !dfsable[vi] {
            continue;
        }
        let mut fr: Vec<Vec<(usize, usize)>> = vec![Vec::new()];
        if jobs > 1 {
            let target = jobs * 4;
            let n_tasks = ctx.per_task.len();
            let mut depth = 0usize;
            while depth < n_tasks && fr.len() < target {
                let mut next = Vec::new();
                for prefix in &fr {
                    let max_slr = open_regions(prefix, regions);
                    for &c in &orders[vi][depth] {
                        for slr in 0..max_slr {
                            let mut p = prefix.clone();
                            p.push((c as usize, slr));
                            next.push(p);
                        }
                    }
                }
                fr = next;
                depth += 1;
            }
        }
        frontier.extend(fr.into_iter().map(|p| (vi, p)));
    }
    let dfs_span = obs::span("solver", "solve.dfs");
    let prefix_explored = run_indexed(frontier.len(), jobs, |i| {
        let (vi, prefix) = &frontier[i];
        let mut ex = 0u64;
        run_prefix(&ctxs[*vi], &orders[*vi], prefix, &mut ex);
        ex
    });
    drop(dfs_span);
    explored += prefix_explored.into_iter().sum::<u64>();
    let timed_out = timed_out_flag.load(Ordering::Relaxed);
    drop(ctxs);
    let telemetry = counters.finish();
    if obs::trace_enabled() {
        for (vi, vc) in telemetry.variants.iter().enumerate() {
            obs::counter(
                "solver",
                &format!("solve.variant{vi}"),
                vec![
                    ("enumerated".to_string(), obs::ArgVal::Int(vc.enumerated as i128)),
                    (
                        "stage1_points".to_string(),
                        obs::ArgVal::Int(vc.stage1_points as i128),
                    ),
                    ("enum_pruned".to_string(), obs::ArgVal::Int(vc.enum_pruned as i128)),
                    ("dfs_nodes".to_string(), obs::ArgVal::Int(vc.dfs_nodes as i128)),
                    (
                        "leaves_simulated".to_string(),
                        obs::ArgVal::Int(vc.leaves_simulated as i128),
                    ),
                    ("bound_pruned".to_string(), obs::ArgVal::Int(vc.bound_pruned as i128)),
                    (
                        "symmetry_pruned".to_string(),
                        obs::ArgVal::Int(vc.symmetry_pruned as i128),
                    ),
                    (
                        "resource_pruned".to_string(),
                        obs::ArgVal::Int(vc.resource_pruned as i128),
                    ),
                    ("model_pruned".to_string(), obs::ArgVal::Int(vc.model_pruned as i128)),
                    ("beam_starved".to_string(), obs::ArgVal::Int(vc.beam_starved as i128)),
                    (
                        "deadline_killed".to_string(),
                        obs::ArgVal::Int(vc.deadline_killed as i128),
                    ),
                ],
            );
        }
    }

    let best = shared.best.into_inner().unwrap();
    let Some((_, _, design)) = best else {
        return Err(SolverError::Infeasible {
            task: None,
            detail: format!(
                "no task assignment of any of the {n_variants} fusion variant(s) of {} onto \
                 {regions} region(s) satisfies the per-region budget{}",
                k.name,
                if timed_out { " (search timed out; infeasibility unproven)" } else { "" }
            ),
        });
    };
    let win = plans
        .iter()
        .position(|p| p == &design.fusion)
        .expect("the winning design realizes one of the solved variants");
    let (win_fg, win_cache) = variants[win];
    let rd = ResolvedDesign::new(k, win_fg, win_cache, &design);
    let latency = graph_latency_resolved(&rd, dev);
    drop(rd);
    let gf = gflops(k, latency.total, dev);
    Ok(SolverResult {
        design,
        fused: win_fg.clone(),
        fusion_variants: n_variants,
        latency,
        gflops: gf,
        solve_time: deadline.elapsed(),
        explored,
        timed_out,
        warm_started,
        telemetry,
    })
}

/// Resume the DFS below a distributed prefix, re-deriving what the
/// in-tree DFS would have pruned before reaching it: per-region usage
/// (sums only grow with depth, so an overfull prefix dooms the whole
/// subtree) and the standalone-latency bound (strict, like
/// [`dfs_assign`], so ties stay reachable). Each prefix gets its own
/// [`DfsScratch`] — the reusable sim buffers and the strided deadline
/// state — seeded with one fresh deadline poll so an already-expired
/// solve goes straight into the anytime greedy dive.
fn run_prefix<'a>(
    ctx: &DfsCtx<'a>,
    order: &[Vec<u32>],
    prefix: &[(usize, usize)],
    explored: &mut u64,
) {
    let bound = ctx.shared.bound();
    if prefix.iter().enumerate().any(|(ti, &(c, _))| ctx.per_task[ti][c].latency > bound) {
        ctx.counters.bound_pruned(ctx.vi, 1);
        return;
    }
    let mut used = vec![ResourceVec::ZERO; ctx.regions];
    for (ti, &(c, slr)) in prefix.iter().enumerate() {
        used[slr] += ctx.per_task[ti][c].res;
    }
    if used.iter().any(|r| !r.fits(ctx.budget)) {
        ctx.counters.resource_pruned(ctx.vi, 1);
        return;
    }
    let mut assign = prefix.to_vec();
    let mut scratch = DfsScratch::new();
    if ctx.deadline.expired() {
        scratch.expired = true;
        ctx.timed_out.store(true, Ordering::Relaxed);
    }
    dfs_assign(ctx, order, &mut scratch, &mut assign, &mut used, explored);
}

/// One stage-1/2 work unit's result: the raw (un-Pareto'd) candidates
/// plus the telemetry the merge loop folds into the per-variant
/// counters.
struct EnumOut {
    /// Raw candidates (the caller merges passes in a fixed order and
    /// Pareto-reduces once, so the result is identical however the
    /// units were scheduled).
    cands: Vec<Candidate>,
    /// Every resolution performed, stage 1 and stage 2 — the historical
    /// explored stream.
    explored: u64,
    /// The stage-1 subset of `explored` (see
    /// [`obs::VariantCounters::stage1_points`]).
    stage1_points: u64,
    /// Stage-1 points starved by the enumeration floor before being
    /// resolved at all.
    enum_pruned: u64,
    /// Whether this unit hit the shared deadline.
    timed_out: bool,
}

/// Enumerate tile factors × permutations × transfer plans for one fused
/// task. All configuration-independent inputs (representative nest,
/// legal orders, array statics) come from the [`GeometryCache`]; per
/// candidate, only the resolution of the changed configuration is
/// recomputed — under `opts.resolve_arena` via an [`eval::ResolveArena`]
/// that rewrites retained buffers in place and re-resolves only the
/// arrays whose geometry a point actually changed.
///
/// `enum_bound` is the enumeration-floor bound (`u64::MAX` when no
/// incumbent exists): points whose analytic latency floor exceeds it
/// are dropped under either `enum_starvation` setting; with the knob
/// on, whole factor subtrees are skipped before resolution and counted
/// in `enum_pruned`. The floor is permutation-independent (a product
/// over loop positions), so a starved combo is starved for *every*
/// permutation — skipped permutations ride the same counter via the
/// combos × orders product.
#[allow(clippy::too_many_arguments)]
fn enumerate_task(
    k: &Kernel,
    cache: &GeometryCache,
    t: usize,
    dev: &Device,
    opts: &SolverOptions,
    budget: &SlrBudget,
    enum_bound: u64,
    deadline: Deadline,
) -> EnumOut {
    let mut explored = 0u64;
    let mut stage1_points = 0u64;
    let mut timed_out = false;
    let st = &cache.tasks[t];
    let rep_stmt = &k.statements[st.rep];
    let nest = &rep_stmt.loops;
    let has_red = nest.iter().any(|l| l.reduction);
    let ii = if has_red { dev.fadd_latency } else { 1 };

    // per-loop factor options, over the task's *effective* trips (a
    // ranged/peeled task's outermost loop spans only its [lo, hi)
    // slice — st.trips narrows position 0 accordingly, so every peel
    // gets its own tiling geometry)
    let per_loop: Vec<Vec<super::padding::FactorChoice>> = st
        .trips
        .iter()
        .map(|&trip| {
            if !opts.tiling {
                // no tiling: intra = full loop (everything on-chip,
                // Stream-HLS/ScaleHLS style) — but cap reductions to keep
                // partitioning legal.
                let f = legal_intra_factors(trip, 0, trip);
                vec![*f.last().unwrap(), f[0]]
            } else {
                legal_intra_factors(trip, opts.max_pad, opts.max_factor_per_loop)
            }
        })
        .collect();

    // permutations (inter-tile order, memoized at fusion time);
    // reduction loops pinned innermost
    let pinned;
    let orders: &[Vec<usize>] = if opts.permute {
        &st.orders
    } else {
        pinned = vec![st.orders[0].clone()];
        &pinned
    };

    // ---- enumeration starvation: analytic per-subtree latency floor ----
    // Lower bound on `task_latency` of any point: the pipelined compute
    // body is >= Π_red inter_trip (Eq 16 at II = fadd_latency >= 1) and
    // every non-reduction level multiplies the body by its inter trip
    // (both the overlapped and the serial recursion in `task_latency`
    // scale by at least T_l), so latency >= Π_p contrib(p) with
    // contrib(p) = inter_trip(p) for counted positions. Reduction
    // positions stop counting on a zero-latency adder (Eq 16
    // collapses), and a device with fmul + fadd < 1 invalidates the
    // compute floor entirely, so starvation is disabled there. A point
    // whose floor exceeds the incumbent bound cannot be a task of any
    // design that beats (or ties) it — each task's standalone latency
    // is <= the design total — so the whole factor subtree is skipped
    // before resolution, exactly counted in `enum_pruned`.
    let floor = (enum_bound < u64::MAX && dev.fmul_latency + dev.fadd_latency >= 1).then(|| {
        let counted: Vec<bool> =
            nest.iter().map(|l| !l.reduction || dev.fadd_latency >= 1).collect();
        let n = nest.len();
        let mut trip_suffix = vec![1u128; n + 1];
        let mut max_intra_suffix = vec![1u128; n + 1];
        for p in (0..n).rev() {
            let contrib = if counted[p] { u128::from(st.trips[p].max(1)) } else { 1 };
            trip_suffix[p] = trip_suffix[p + 1].saturating_mul(contrib);
            let mx = per_loop[p].iter().map(|c| c.intra).max().unwrap_or(1);
            max_intra_suffix[p] = max_intra_suffix[p + 1].saturating_mul(u128::from(mx));
        }
        EnumFloor { bound: enum_bound, counted, trip_suffix, max_intra_suffix }
    });

    // The floor *filter* is part of the algorithm whenever an incumbent
    // exists: points that provably cannot beat it never enter the
    // stage-1 beam (they could only waste beam slots on dead-end
    // refinements). The `enum_starvation` knob decides only *where* the
    // filter runs — on (fast path), `enum_factors` skips whole factor
    // subtrees before any resolution; off (the oracle baseline), every
    // point is resolved first and then dropped by the identical
    // point-floor test. The leaf-level subtree check *is* the
    // point-floor test (suffix trip product 1, unroll headroom >= 1),
    // so both settings drop exactly the same set and the winning
    // designs stay bit-identical.
    let starve = opts.enum_starvation;

    // ---- stage 1: factor combos scored with a default transfer plan ----
    let mut scratch = EnumScratch {
        intra: vec![0u64; nest.len()],
        padded: vec![0u64; nest.len()],
        combos: Vec::new(),
        pruned: 0,
    };
    enum_factors(
        &per_loop,
        if starve { floor.as_ref() } else { None },
        opts.max_unroll,
        0,
        1,
        1,
        &mut scratch,
    );
    let EnumScratch { mut combos, pruned, .. } = scratch;
    // a starved combo is starved under every permutation (the floor is
    // permutation-independent), so the skipped stage-1 points are the
    // pruned combos times the permutation count
    let enum_pruned = pruned * orders.len() as u64;

    // Compact stage-1 scoring: (latency, unroll, combo idx, order idx).
    // A reusable TaskConfig avoids per-point allocations; sort keys stay
    // 24 bytes so the beam sort doesn't shuffle fat tuples. Under
    // `opts.resolve_arena` the resolution itself is allocation-free
    // too: the arena rewrites its retained buffers in place and
    // re-resolves only the arrays touching nest positions at or below
    // the first one that differs from the previous combo (enum_factors
    // varies the deepest position fastest, so that prefix is long).
    let mut scored: Vec<(u64, u64, u32, u32)> = Vec::new();
    let mut arena = eval::ResolveArena::new();
    let use_arena = opts.resolve_arena;
    let mut cfg = TaskConfig {
        task: t,
        perm: Vec::new(),
        padded_trip: Vec::new(),
        intra: Vec::new(),
        ii,
        plans: BTreeMap::new(),
        slr: 0,
    };
    'outer: for (oi, ord) in orders.iter().enumerate() {
        // a new permutation invalidates every retained order/tile buffer
        arena.invalidate();
        cfg.perm.clone_from(ord);
        let mut prev_ci: Option<usize> = None;
        for (ci, (intra, padded)) in combos.iter().enumerate() {
            // strided deadline poll (`Instant::now` is not free at this
            // rate): every DEADLINE_STRIDE combos, starting with the
            // first. A late break leaves a longer — never shorter —
            // candidate list, so the anytime contract is unaffected.
            if explored % DEADLINE_STRIDE == 0 && deadline.expired() {
                timed_out = true;
                break 'outer;
            }
            explored += 1;
            stage1_points += 1;
            // first nest position whose (intra, padded) differs from
            // the previous combo: geometry above it is untouched
            let changed = match prev_ci {
                Some(pci) => {
                    let (pi, pp) = &combos[pci];
                    (0..nest.len())
                        .find(|&x| intra[x] != pi[x] || padded[x] != pp[x])
                        .unwrap_or(nest.len())
                }
                None => 0,
            };
            prev_ci = Some(ci);
            cfg.padded_trip.clone_from(padded);
            cfg.intra.clone_from(intra);
            let (ok, res, lat) = if use_arena {
                let rt = arena.resolve(k, st, &cfg, changed);
                let out = score_point(&rt, dev, opts);
                arena.reclaim(rt);
                out
            } else {
                score_point(&eval::resolve_task(k, st, &cfg), dev, opts)
            };
            if !ok || !res.fits(budget) {
                continue;
            }
            // knob-off oracle path of the floor filter: the point was
            // resolved (and counted) like the reference demands, and is
            // dropped by exactly the test the subtree walk applies at
            // its leaves, keeping the scored sets — and the winners —
            // bit-identical across the knob
            if !starve
                && floor.as_ref().is_some_and(|fl| {
                    combo_floor(intra, padded, &fl.counted) > u128::from(fl.bound)
                })
            {
                continue;
            }
            scored.push((lat, intra.iter().product(), ci as u32, oi as u32));
        }
    }
    // anytime guarantee: a tiny timeout may have cut enumeration short —
    // always keep the trivial (untiled, unrolled-by-1) combo as a floor.
    if scored.is_empty() {
        let intra: Vec<u64> = vec![1; nest.len()];
        let padded: Vec<u64> = st.trips.clone();
        combos.push((intra, padded));
        scored.push((u64::MAX, 1, (combos.len() - 1) as u32, 0));
    }
    scored.sort_unstable_by_key(|(lat, ..)| *lat);
    // Beam diversity: the stage-1 proxy (default transfer plans) can
    // misrank high-unroll combos whose refined plans win in stage 2, so
    // keep the top-`beam` by proxy latency PLUS the largest-unroll combos
    // (compute-bound kernels are DSP-limited — UF/II is the steady-state
    // throughput bound). Sorting an index vector by (unroll desc,
    // latency rank asc) replaces the old full tuple clone + O(beam²)
    // (ci, oi) dedup: (ci, oi) pairs are unique across `scored`, so
    // "already kept" is exactly the index test `i < cut`.
    let cut = scored.len().min(opts.beam);
    let mut kept: Vec<(u64, u64, u32, u32)> = scored[..cut].to_vec();
    let mut by_uf: Vec<usize> = (0..scored.len()).collect();
    by_uf.sort_unstable_by_key(|&i| (std::cmp::Reverse(scored[i].1), i));
    for &i in by_uf.iter().take(opts.beam / 3) {
        if i >= cut {
            kept.push(scored[i]);
        }
    }
    let scored = kept;

    // ---- stage 2: refine transfer plans for surviving combos -----------
    // One scratch TaskConfig serves every survivor: perm/padded/intra
    // are rewritten in place (clone_from reuses the buffers) and the
    // emitted candidate clones the scratch exactly once, instead of the
    // old fresh-TaskConfig-per-survivor construction.
    let mut cands: Vec<Candidate> = Vec::new();
    let mut stage2 = TaskConfig {
        task: t,
        perm: Vec::new(),
        padded_trip: Vec::new(),
        intra: Vec::new(),
        ii,
        plans: BTreeMap::new(),
        slr: 0,
    };
    for &(_, _, ci, oi) in &scored {
        if deadline.expired() {
            timed_out = true;
            break;
        }
        let (intra, padded) = &combos[ci as usize];
        stage2.perm.clone_from(&orders[oi as usize]);
        stage2.padded_trip.clone_from(padded);
        stage2.intra.clone_from(intra);
        let stats = choose_transfer_plans(
            k,
            st,
            &mut stage2,
            dev,
            opts,
            budget,
            &mut arena,
            &mut explored,
        );
        // the descent already evaluated the final plan combination for
        // most combos and returns its (resources, latency); only when it
        // could not (e.g. no feasible option for the last array) is the
        // final configuration re-resolved here
        let (res, lat) = match stats {
            Some(rl) => rl,
            None => {
                let rt = eval::resolve_task(k, st, &stage2);
                (task_resources(&rt, dev), task_latency(&rt, dev, opts.overlap))
            }
        };
        if !res.fits(budget) {
            continue;
        }
        cands.push(Candidate { cfg: stage2.clone(), latency: lat, res });
    }

    // anytime guarantee, stage 2: fall back to the best stage-1 combo
    // with its (feasible) default plans.
    if cands.is_empty() {
        if let Some(&(_, _, ci, oi)) = scored.first() {
            let (intra, padded) = &combos[ci as usize];
            let cfg = TaskConfig {
                task: t,
                perm: orders[oi as usize].clone(),
                padded_trip: padded.clone(),
                intra: intra.clone(),
                ii,
                plans: BTreeMap::new(),
                slr: 0,
            };
            let rt = eval::resolve_task(k, st, &cfg);
            let res = task_resources(&rt, dev);
            let lat = task_latency(&rt, dev, opts.overlap);
            cands.push(Candidate { cfg, latency: lat, res });
        }
    }

    EnumOut { cands, explored, stage1_points, enum_pruned, timed_out }
}

/// Score one resolved stage-1 point: partition legality (Eq 8), then
/// resources and the default-plan proxy latency. One body shared by
/// the arena and fresh-resolution paths so the two stay byte-identical
/// by construction.
fn score_point(
    rt: &eval::ResolvedTask<'_>,
    dev: &Device,
    opts: &SolverOptions,
) -> (bool, ResourceVec, u64) {
    if rt.plans.iter().any(|rp| rp.partitions > dev.max_partition) {
        return (false, ResourceVec::ZERO, 0);
    }
    (true, task_resources(rt, dev), task_latency(rt, dev, opts.overlap))
}

/// The enumeration-starvation floor state, precomputed once per task
/// (see the derivation at its construction site in [`enumerate_task`]).
/// All products are u128 with saturation — a saturated floor only ever
/// *over*-states a latency that already exceeds `u64::MAX` cycles, so
/// pruning on it stays sound.
struct EnumFloor {
    /// The incumbent bound fixed before the stage-1 fan-out.
    bound: u64,
    /// Whether position `p` contributes its inter trip to the floor
    /// (non-reduction always; reduction only when `fadd_latency >= 1`).
    counted: Vec<bool>,
    /// `trip_suffix[d]` = Π over counted positions `p >= d` of the
    /// effective trip — a lower bound on the suffix's inter-trip
    /// product before dividing out the intra factors.
    trip_suffix: Vec<u128>,
    /// `max_intra_suffix[d]` = Π over positions `p >= d` of the largest
    /// legal intra factor — caps how much unrolling the suffix can
    /// still divide out of `trip_suffix[d]`.
    max_intra_suffix: Vec<u128>,
}

/// Mutable state threaded through [`enum_factors`]: the per-position
/// choice stacks, the emitted combos, and the starved-combo count.
struct EnumScratch {
    intra: Vec<u64>,
    padded: Vec<u64>,
    combos: Vec<(Vec<u64>, Vec<u64>)>,
    pruned: u64,
}

/// Cartesian enumeration of per-loop factor choices with an unroll cap.
///
/// With a floor, a choice is pruned when even the best completion of
/// its subtree provably exceeds the bound: `a` is the running product
/// of the assigned positions' exact inter trips (counted positions
/// only), the suffix contributes at least `trip_suffix / B` where `B`
/// bounds the remaining unroll (the tighter of the unroll budget left
/// and the suffix's max intra product), so the subtree is dead iff
/// `a · trip_suffix > bound · B`. Pruned subtrees are counted by their
/// exact number of unroll-legal completions, keeping the `enum_pruned`
/// accounting invariant (`stage1_points + enum_pruned` == the
/// reference run's `stage1_points`) exact rather than approximate.
fn enum_factors(
    per_loop: &[Vec<super::padding::FactorChoice>],
    floor: Option<&EnumFloor>,
    max_unroll: u64,
    depth: usize,
    product: u64,
    a: u128,
    s: &mut EnumScratch,
) {
    if depth == per_loop.len() {
        s.combos.push((s.intra.clone(), s.padded.clone()));
        return;
    }
    for c in &per_loop[depth] {
        if product * c.intra > max_unroll {
            continue;
        }
        let product2 = product * c.intra;
        let mut a2 = a;
        if let Some(fl) = floor {
            if fl.counted[depth] {
                a2 = a.saturating_mul(u128::from(c.padded / c.intra));
            }
            let lhs = a2.saturating_mul(fl.trip_suffix[depth + 1]);
            let b = u128::from(max_unroll / product2).min(fl.max_intra_suffix[depth + 1]);
            // strict (`>`): a point tying the bound exactly stays
            // reachable, mirroring dfs_assign's strictly-above pruning
            let dead = match u128::from(fl.bound).checked_mul(b) {
                Some(rhs) => lhs > rhs,
                None => false,
            };
            if dead {
                s.pruned += count_unroll_legal(per_loop, depth + 1, max_unroll / product2);
                continue;
            }
        }
        s.intra[depth] = c.intra;
        s.padded[depth] = c.padded;
        enum_factors(per_loop, floor, max_unroll, depth + 1, product2, a2, s);
    }
}

/// Exact number of unroll-legal completions of a factor subtree: how
/// many combos the un-starved enumeration would emit from
/// `per_loop[depth..]` with `budget` unroll headroom left (nested floor
/// division chains exactly, so the count matches the reference's
/// `product * intra <= max_unroll` test choice for choice). A pure
/// integer walk — no geometry — so even a depth-0 starvation pays
/// nanoseconds per skipped point instead of a full resolution.
fn count_unroll_legal(
    per_loop: &[Vec<super::padding::FactorChoice>],
    depth: usize,
    budget: u64,
) -> u64 {
    if depth == per_loop.len() {
        return 1;
    }
    per_loop[depth]
        .iter()
        .filter(|c| c.intra <= budget)
        .map(|c| count_unroll_legal(per_loop, depth + 1, budget / c.intra))
        .sum()
}

/// Exact enumeration floor of one complete factor point: the product
/// over counted positions of the inter trip `padded / intra` — the
/// same fold (saturation included) the subtree walk accumulates into
/// `a`, used by the knob-off oracle path to drop exactly the points
/// the fast path starves.
fn combo_floor(intra: &[u64], padded: &[u64], counted: &[bool]) -> u128 {
    counted
        .iter()
        .zip(intra.iter().zip(padded))
        .filter(|(c, _)| **c)
        .map(|(_, (i, p))| u128::from(p / i))
        .fold(1u128, u128::saturating_mul)
}

/// Pick the (define, transfer) level and bit width per array: enumerate
/// the diagonal plans (define = transfer at each level) plus the
/// buffer-whole/stream-deep plan ([`eval::plan_options`]), choose
/// per-array the one minimizing the task latency, then demote buffers
/// greedily if BRAM overflows.
///
/// Also returns the final configuration's `(resources, latency)` when
/// the descent provably evaluated it already — the last array's best
/// option was scored with every other array at its final plan, so that
/// evaluation *is* the final configuration's. `None` (the last array
/// had no feasible option, or the task has no arrays) sends the caller
/// down the old re-resolve path; either way the emitted candidate is
/// bit-identical.
///
/// `cfg` is the caller's reusable stage-2 scratch: its factor fields
/// must already describe the survivor, and any plans left from a
/// previous survivor are cleared here before reseeding. The descent
/// itself evaluates plan options in place through the shared arena
/// (under `opts.resolve_arena`): a plan flip changes no factor
/// geometry, so the arena re-resolves only the flipped array.
#[allow(clippy::too_many_arguments)]
fn choose_transfer_plans(
    k: &Kernel,
    st: &TaskStatics,
    cfg: &mut TaskConfig,
    dev: &Device,
    opts: &SolverOptions,
    budget: &SlrBudget,
    arena: &mut eval::ResolveArena,
    explored: &mut u64,
) -> Option<(ResourceVec, u64)> {
    let use_arena = opts.resolve_arena;
    // seed: everything at its deepest level (smallest buffers) — exactly
    // the defaults resolution applies to a plan-less config
    cfg.plans.clear();
    arena.invalidate();
    {
        let seeded: Vec<(String, TransferPlan)> = if use_arena {
            let rt = arena.resolve(k, st, cfg, 0);
            let s = rt.arrays().map(|(a, rp)| (a.name.clone(), rp.as_plan())).collect();
            arena.reclaim(rt);
            s
        } else {
            let rt = eval::resolve_task(k, st, cfg);
            rt.arrays().map(|(a, rp)| (a.name.clone(), rp.as_plan())).collect()
        };
        for (a, p) in seeded {
            cfg.plans.insert(a, p);
        }
    }
    // the plan inserts above changed no factor geometry, but the arena
    // snapshotted a plan-less config — re-resolve everything once
    arena.invalidate();

    // coordinate descent, one array at a time (two sweeps converge for
    // the plan structures in this zoo). The per-array option lists
    // depend only on the factor geometry (`plan_options` never reads
    // `cfg.plans`), so they are computed once per survivor rather than
    // once per (sweep, array).
    let all_options: Vec<Vec<TransferPlan>> = {
        let geo = super::space::TaskGeometry::new(k, st, cfg);
        st.arrays.iter().map(|a| eval::plan_options(&geo, a)).collect()
    };
    let n = k.statements[st.rep].loops.len();
    let mut final_stats: Option<(ResourceVec, u64)> = None;
    for _sweep in 0..2 {
        for (ai, options) in all_options.iter().enumerate() {
            let a_name = &st.arrays[ai].name;
            let mut best_plan = cfg.plans[a_name];
            let mut best_lat = u64::MAX;
            let mut best_stats: Option<(ResourceVec, u64)> = None;
            for &p in options {
                *explored += 1;
                *cfg.plans.get_mut(a_name).expect("seeded above") = p;
                let (res, lat) = if use_arena {
                    // changed_from = n: no nest position changed, only
                    // the one explicit plan — the arena re-resolves
                    // exactly the flipped array
                    let rt = arena.resolve(k, st, cfg, n);
                    let out = (task_resources(&rt, dev), task_latency(&rt, dev, opts.overlap));
                    arena.reclaim(rt);
                    out
                } else {
                    let rt = eval::resolve_task(k, st, cfg);
                    (task_resources(&rt, dev), task_latency(&rt, dev, opts.overlap))
                };
                if !res.fits(budget) {
                    continue;
                }
                if lat < best_lat {
                    best_lat = lat;
                    best_plan = p;
                    best_stats = Some((res, lat));
                }
            }
            *cfg.plans.get_mut(a_name).expect("seeded above") = best_plan;
            final_stats = best_stats;
        }
    }
    final_stats
}

/// Latency-sorted front size kept per task after the Pareto reduction
/// (resource-diversity witnesses ride on top).
const PARETO_KEEP: usize = 16;

/// Keep the Pareto front over (latency, **full** resource vector),
/// sorted by latency. A candidate is dominated only when another one is
/// no worse in latency *and every* resource class — DSP, BRAM18, LUT
/// and FF — so a LUT- or FF-cheap configuration survives even when a
/// faster candidate beats it on DSP/BRAM (the old three-field filter
/// silently dropped those, starving stage-3 assembly on LUT-tight
/// budgets).
///
/// The front is then cut to `PARETO_KEEP` (16) by latency, but the
/// cheapest-per-resource witnesses (min-LUT, min-BRAM18, min-FF,
/// min-DSP) are never dropped: when stage 3 has to trade speed for
/// resources, the extreme points are exactly the candidates it needs.
/// Fully deterministic: stable latency sort, first-wins witnesses.
///
/// Dominance is sort-based: candidates are visited in latency order, so
/// every front member already has `latency <= c.latency` and only the
/// resource comparison remains. Running per-dimension minima over the
/// front give an O(1) early accept — a candidate strictly below the
/// front's minimum in *any* resource class cannot be dominated (a
/// dominator would have to sit at or below it there, beating the
/// minimum) — so the inner scan only runs for points inside the front's
/// resource envelope, replacing the old always-quadratic loop with
/// byte-identical output (acceptance decisions and order unchanged).
pub fn pareto(mut cands: Vec<Candidate>) -> Vec<Candidate> {
    cands.sort_by_key(|c| c.latency);
    let mut front: Vec<Candidate> = Vec::new();
    let mut min = [f64::INFINITY; 4];
    for c in cands {
        let dims = [c.res.dsp, c.res.bram18, c.res.lut, c.res.ff];
        let clear = dims.iter().zip(&min).any(|(d, m)| d < m);
        let dominated = !clear
            && front.iter().any(|f| {
                f.latency <= c.latency
                    && f.res.dsp <= c.res.dsp
                    && f.res.bram18 <= c.res.bram18
                    && f.res.lut <= c.res.lut
                    && f.res.ff <= c.res.ff
            });
        if !dominated {
            for (m, d) in min.iter_mut().zip(dims) {
                if d < *m {
                    *m = d;
                }
            }
            front.push(c);
        }
    }
    truncate_front(front)
}

/// The `PARETO_KEEP` cut with resource-diversity witnesses, shared by
/// the scan and bitset acceptance paths so the two can never drift.
fn truncate_front(mut front: Vec<Candidate>) -> Vec<Candidate> {
    if front.len() > PARETO_KEEP {
        let min_idx = |key: fn(&Candidate) -> f64| {
            let mut best = 0usize;
            for i in 1..front.len() {
                if key(&front[i]) < key(&front[best]) {
                    best = i;
                }
            }
            best
        };
        let mut witnesses = [
            min_idx(|c| c.res.lut),
            min_idx(|c| c.res.bram18),
            min_idx(|c| c.res.ff),
            min_idx(|c| c.res.dsp),
        ];
        witnesses.sort_unstable();
        let mut tail: Vec<Candidate> = Vec::new();
        for (j, &w) in witnesses.iter().enumerate() {
            if w >= PARETO_KEEP && witnesses[..j].last() != Some(&w) {
                tail.push(front[w].clone());
            }
        }
        front.truncate(PARETO_KEEP);
        front.extend(tail);
    }
    front
}

/// Knob dispatch for the per-task Pareto reduction: the reference scan
/// ([`pareto`]) or the rank-bitset acceptance ([`pareto_bitsets`]).
/// Byte-identical output either way — acceptance decisions, front
/// order and truncation are pinned against each other by the stage-1/2
/// property tests.
pub fn pareto_with(cands: Vec<Candidate>, bitsets: bool) -> Vec<Candidate> {
    if bitsets {
        pareto_bitsets(cands)
    } else {
        pareto(cands)
    }
}

/// Rank-bitset Pareto acceptance (the `pareto_bitsets` knob). Front
/// members are numbered by acceptance order; for each resource
/// dimension the front is kept sorted by value alongside *prefix
/// masks* — `prefix[j]` is the bit-OR of the `j` smallest members in
/// that dimension. Candidates arrive latency-sorted (every front
/// member already satisfies `f.latency <= c.latency`), so the
/// dominator set of a candidate is exactly
/// `∩_d prefix_d[#(members ≤ c in d)]`: four `partition_point`s and a
/// word-parallel AND replace the per-candidate scan over the front,
/// with acceptance decisions — and therefore the emitted front —
/// byte-identical to [`pareto`].
fn pareto_bitsets(mut cands: Vec<Candidate>) -> Vec<Candidate> {
    cands.sort_by_key(|c| c.latency);
    let words = cands.len().div_ceil(64).max(1);
    let mut front: Vec<Candidate> = Vec::new();
    let mut vals: [Vec<f64>; 4] = Default::default();
    let mut members: [Vec<usize>; 4] = Default::default();
    let mut prefix: [Vec<Vec<u64>>; 4] = std::array::from_fn(|_| vec![vec![0u64; words]]);
    let mut meet = vec![0u64; words];
    for c in cands {
        let dims = [c.res.dsp, c.res.bram18, c.res.lut, c.res.ff];
        meet.fill(u64::MAX);
        let mut nonempty = !front.is_empty();
        for (d, v) in dims.iter().enumerate() {
            let cnt = vals[d].partition_point(|x| x <= v);
            if cnt == 0 {
                nonempty = false;
                break;
            }
            for (m, p) in meet.iter_mut().zip(&prefix[d][cnt]) {
                *m &= p;
            }
        }
        if nonempty && meet.iter().any(|&w| w != 0) {
            continue; // dominated
        }
        // accept: insert into each dimension's sorted column and
        // rebuild the prefix masks from the insertion point down
        let bit = front.len();
        for (d, v) in dims.iter().enumerate() {
            let pos = vals[d].partition_point(|x| x <= v);
            vals[d].insert(pos, *v);
            members[d].insert(pos, bit);
            prefix[d].truncate(pos + 1);
            for j in pos..vals[d].len() {
                let mut row = prefix[d][j].clone();
                let b = members[d][j];
                row[b / 64] |= 1u64 << (b % 64);
                prefix[d].push(row);
            }
        }
        front.push(c);
    }
    truncate_front(front)
}

/// SLR symmetry breaking — the one child-generation rule, shared by
/// `dfs_assign` and the stage-3 frontier expansion so the two can
/// never drift. Regions are interchangeable (identical budgets;
/// latency compares region ids only for equality), so the next task
/// may reuse an already-open region or open exactly the next fresh
/// one: region-renamed duplicates are never explored, and the kept
/// representative (first-use-ordered region ids) is the
/// lexicographically smallest of its class, preserving the
/// deterministic tie-break. Returns the exclusive upper bound on the
/// region id the next task may take.
fn open_regions(assign: &[(usize, usize)], regions: usize) -> usize {
    let next_fresh = assign.iter().map(|&(_, s)| s + 1).max().unwrap_or(0);
    regions.min(next_fresh + 1)
}

/// Read-only context shared by every stage-3 DFS worker **of one
/// fusion variant** — the `SharedBest` behind it spans all variants.
struct DfsCtx<'a> {
    k: &'a Kernel,
    fg: &'a FusedGraph,
    cache: &'a GeometryCache,
    dev: &'a Device,
    opts: &'a SolverOptions,
    budget: &'a SlrBudget,
    regions: usize,
    per_task: &'a [Vec<Candidate>],
    /// This variant's immutable leaf arena (precomputed step specs,
    /// sinks, predecessor lists) for the allocation-free leaf path.
    arena: &'a LeafArena,
    deadline: Deadline,
    shared: &'a SharedBest,
    timed_out: &'a AtomicBool,
    /// This variant's index in the solve's variant list (the leading
    /// element of every leaf's deterministic tie-break key).
    vi: usize,
    /// This variant's canonical fusion plan, stamped into every design
    /// the DFS assembles.
    plan: &'a FusionPlan,
    /// The solve's shared telemetry counter block (no-op when
    /// `SolverOptions::telemetry` is off).
    counters: &'a obs::SolveCounters,
}

/// One fusion variant's immutable stage-3 arena, built once after the
/// Pareto reduction. The DFS references candidates by `(task, index)`
/// into `DfsCtx::per_task` and scores leaves entirely from this arena:
/// no per-leaf `DesignConfig`, `ResolvedDesign` or graph traversal.
struct LeafArena {
    /// Per task, per candidate: the candidate's dataflow step spec
    /// ([`candidate_steps`] — assignment-independent by construction),
    /// resolved once here instead of once per leaf. Empty when the leaf
    /// pre-filter is off or the model is Sequential (which needs no
    /// specs: its closed form *is* the simulator).
    specs: Vec<Vec<TaskSteps>>,
    /// The variant graph's sink tasks ([`FusedGraph::sinks`]).
    sinks: Vec<usize>,
    /// Per task: its predecessor tasks ([`FusedGraph::predecessors`]),
    /// for the leaf's inter-SLR penalty — both allocate per call, so
    /// they are hoisted out of the leaf entirely.
    preds: Vec<Vec<usize>>,
}

/// DFS deadline-poll stride: `Instant::now()` once per this many node
/// entries (and stage-1 combos) instead of every one. Completed
/// searches are unaffected — polling frequency only changes *when* a
/// timeout is noticed, and the anytime contract (return the incumbent,
/// greedy-dive if there is none) holds at whichever node notices it.
const DEADLINE_STRIDE: u64 = 64;

/// Per-worker mutable DFS state: the reusable leaf-scoring buffers and
/// the strided deadline poll. One per distributed prefix — nothing in
/// here is shared or observable across workers.
struct DfsScratch<'a> {
    /// Reusable buffers for the simulator's step loop.
    sim: DataflowScratch,
    /// Leaf spec view: the assigned candidates' step specs, task-indexed.
    spec_view: Vec<&'a TaskSteps>,
    /// Leaf inter-SLR penalties, task-indexed.
    slr_pen: Vec<u64>,
    /// Leaf standalone durations (Sequential closed form), task-indexed.
    durations: Vec<u64>,
    /// Node entries since the last deadline poll.
    nodes_since_poll: u64,
    /// Sticky deadline flag: set at the poll that notices expiry, never
    /// cleared (the deadline cannot un-expire).
    expired: bool,
}

impl<'a> DfsScratch<'a> {
    fn new() -> DfsScratch<'a> {
        DfsScratch {
            sim: DataflowScratch::new(),
            spec_view: Vec::new(),
            slr_pen: Vec::new(),
            durations: Vec::new(),
            nodes_since_poll: 0,
            expired: false,
        }
    }
}

/// The shared beam's deterministic probe: dive straight to this
/// variant's first profile-ordered DFS leaf — first candidate in
/// iteration order per task, lowest usable region, exactly the first
/// leaf `dfs_assign` itself would reach — and offer it with its real
/// tie-break key. Runs on the solve thread before any DFS fan-out, so
/// every variant contributes an incumbent and the shared bound can
/// starve losing variants' candidate lists up front. A greedy dive can
/// dead-end where the backtracking DFS would not (then nothing is
/// offered and the DFS decides feasibility as before).
fn probe_variant<'a>(
    ctx: &DfsCtx<'a>,
    order: &[Vec<u32>],
    scratch: &mut DfsScratch<'a>,
    explored: &mut u64,
) {
    let n_tasks = ctx.per_task.len();
    let mut assign: Vec<(usize, usize)> = Vec::with_capacity(n_tasks);
    let mut used = vec![ResourceVec::ZERO; ctx.regions];
    for t in 0..n_tasks {
        let max_slr = open_regions(&assign, ctx.regions);
        let mut placed = false;
        'cands: for &ci in &order[t] {
            let cand = &ctx.per_task[t][ci as usize];
            for slr in 0..max_slr {
                let acc = used[slr] + cand.res;
                if acc.fits(ctx.budget) {
                    used[slr] = acc;
                    assign.push((ci as usize, slr));
                    placed = true;
                    break 'cands;
                }
            }
        }
        if !placed {
            return;
        }
    }
    offer_leaf(ctx, scratch, &assign, explored);
}

/// Score one complete assignment and offer it to the shared incumbent.
///
/// Fast path ([`SolverOptions::leaf_prefilter`] on): the leaf is scored
/// without assembling a `DesignConfig` — Sequential uses the exact
/// closed form ([`sequential_total`], *the* simulator semantics by
/// construction), Dataflow first applies the standalone-latency lower
/// bound (a leaf strictly above the shared bound cannot win or tie;
/// counted as `model_pruned`, nothing resolved or simulated) and then
/// runs the simulator's own step loop ([`run_dataflow`]) over the
/// arena's precomputed specs on reusable scratch — bit-identical cycles
/// to `simulate_resolved` because it *is* the same loop over the same
/// per-candidate inputs. The design is materialized only when its
/// latency can actually improve or tie the incumbent (a worse offer was
/// always rejected anyway).
///
/// Reference path (off): the pre-fast-path leaf — full design assembly,
/// `ResolvedDesign::new`, `simulate_resolved`, unconditional offer —
/// kept as the bench baseline and the fast path's drift oracle
/// (bit-identity pinned in `tests/solver_fastpath.rs`).
fn offer_leaf<'a>(
    ctx: &DfsCtx<'a>,
    scratch: &mut DfsScratch<'a>,
    assign: &[(usize, usize)],
    explored: &mut u64,
) {
    if !ctx.opts.leaf_prefilter {
        *explored += 1;
        ctx.counters.leaf(ctx.vi);
        // Final selection is scored by the *executing* simulator, not the
        // analytic model: the model (Eqs 12–16) guides enumeration, but
        // picking the winner with the authoritative latency keeps
        // heuristic-beam local optima from inverting feature ablations.
        let design = build_design(ctx, assign);
        let rd = ResolvedDesign::new(ctx.k, ctx.fg, ctx.cache, &design);
        let lat = simulate_resolved(&rd, ctx.dev).cycles;
        drop(rd);
        let mut key = Vec::with_capacity(assign.len() + 1);
        key.push((ctx.vi, 0usize));
        key.extend_from_slice(assign);
        ctx.shared.offer(lat, key, design, ctx.vi, ctx.deadline, ctx.counters);
        return;
    }
    let bound = ctx.shared.bound();
    let lat = match ctx.opts.model {
        ExecutionModel::Sequential => {
            // the closed form is exact (cost::sequential_total IS the
            // sequential simulator), so no pre-filter/simulate split
            scratch.durations.clear();
            scratch
                .durations
                .extend(assign.iter().enumerate().map(|(ti, &(c, _))| ctx.per_task[ti][c].latency));
            let lat = sequential_total(&scratch.durations, &ctx.arena.sinks);
            if lat > bound {
                ctx.counters.model_pruned(ctx.vi);
                return;
            }
            *explored += 1;
            ctx.counters.leaf(ctx.vi);
            lat
        }
        ExecutionModel::Dataflow => {
            // pre-filter: any task's standalone latency lower-bounds the
            // simulated total — the same invariant the branch pruning in
            // dfs_assign relies on. Strictly above the bound ⇒ this leaf
            // can neither win nor tie, so skip scoring it entirely.
            let lb = assign
                .iter()
                .enumerate()
                .map(|(ti, &(c, _))| ctx.per_task[ti][c].latency)
                .max()
                .unwrap_or(0);
            if lb > bound {
                ctx.counters.model_pruned(ctx.vi);
                return;
            }
            *explored += 1;
            ctx.counters.leaf(ctx.vi);
            scratch.spec_view.clear();
            scratch.slr_pen.clear();
            for (ti, &(c, slr)) in assign.iter().enumerate() {
                scratch.spec_view.push(&ctx.arena.specs[ti][c]);
                let cut = ctx.arena.preds[ti].iter().filter(|&&p| assign[p].1 != slr).count();
                scratch.slr_pen.push(cut as u64 * ctx.dev.inter_slr_latency);
            }
            run_dataflow(&scratch.spec_view, &scratch.slr_pen, &ctx.arena.sinks, false, &mut scratch.sim)
        }
    };
    if lat > ctx.shared.bound() {
        // cannot win or tie — the offer would be rejected, so the
        // design is never materialized
        return;
    }
    let design = build_design(ctx, assign);
    let mut key = Vec::with_capacity(assign.len() + 1);
    key.push((ctx.vi, 0usize));
    key.extend_from_slice(assign);
    ctx.shared.offer(lat, key, design, ctx.vi, ctx.deadline, ctx.counters);
}

/// Materialize a complete assignment as a `DesignConfig` (clones the
/// chosen candidates' task configs and stamps the region ids).
fn build_design(ctx: &DfsCtx<'_>, assign: &[(usize, usize)]) -> DesignConfig {
    DesignConfig {
        kernel: ctx.k.name.clone(),
        model: ctx.opts.model,
        overlap: ctx.opts.overlap,
        fusion: ctx.plan.clone(),
        tasks: assign
            .iter()
            .enumerate()
            .map(|(ti, &(c, slr))| {
                let mut cfg = ctx.per_task[ti][c].cfg.clone();
                cfg.slr = slr;
                cfg
            })
            .collect(),
    }
}

/// DFS over per-task candidate picks and SLR ids with branch-and-bound.
/// `assign` holds the (candidate, region) prefix, `used` the prefix's
/// per-region resource sums (kept incrementally — sums only grow, so an
/// overfull region prunes the whole subtree). Candidates are visited in
/// the profile-guided `order` (tie-break keys keep the original
/// indices, so the traversal permutation never changes the winner).
fn dfs_assign<'a>(
    ctx: &DfsCtx<'a>,
    order: &[Vec<u32>],
    scratch: &mut DfsScratch<'a>,
    assign: &mut Vec<(usize, usize)>,
    used: &mut [ResourceVec],
    explored: &mut u64,
) {
    let t = assign.len();
    ctx.counters.dfs_node(ctx.vi, t);
    // Anytime gate: once the deadline passed and *some* design is in
    // hand — a found leaf or the warm-start incumbent — stop scoring.
    // With no design in hand yet, the search degrades to a greedy dive
    // (see the bottom of the loop) instead of running the exponential
    // tree arbitrarily far past the deadline. The poll itself is
    // strided (`Instant::now()` every DEADLINE_STRIDE node entries) and
    // sticky once expired.
    if !scratch.expired {
        scratch.nodes_since_poll += 1;
        if scratch.nodes_since_poll >= DEADLINE_STRIDE {
            scratch.nodes_since_poll = 0;
            if ctx.deadline.expired() {
                scratch.expired = true;
                ctx.timed_out.store(true, Ordering::Relaxed);
            }
        }
    }
    let expired = scratch.expired;
    if expired && ctx.shared.has_best() {
        ctx.counters.deadline_killed(ctx.vi);
        return;
    }
    if t == ctx.per_task.len() {
        offer_leaf(ctx, scratch, assign, explored);
        return;
    }
    let max_slr = open_regions(assign, ctx.regions);
    if ctx.counters.enabled() && max_slr < ctx.regions {
        // children in the renamed regions [max_slr, regions) are never
        // generated — count them so prune totals partition the tree
        ctx.counters
            .symmetry_pruned(ctx.vi, ((ctx.regions - max_slr) * order[t].len()) as u64);
    }
    for &ci in &order[t] {
        let c = ci as usize;
        let cand = &ctx.per_task[t][c];
        // bound: any task's standalone latency lower-bounds the total.
        // STRICTLY above the shared bound only — an equal-latency leaf
        // may still win the deterministic tie-break, so it must stay
        // reachable from every worker.
        if cand.latency > ctx.shared.bound() {
            ctx.counters.bound_pruned(ctx.vi, 1);
            continue;
        }
        for slr in 0..max_slr {
            let prev = used[slr];
            let acc = prev + cand.res;
            if !acc.fits(ctx.budget) {
                ctx.counters.resource_pruned(ctx.vi, 1);
                continue;
            }
            used[slr] = acc;
            assign.push((c, slr));
            dfs_assign(ctx, order, scratch, assign, used, explored);
            assign.pop();
            used[slr] = prev;
            // Post-deadline with no design yet: one greedy dive down
            // the first viable branch (which either just produced the
            // anytime design, or dead-ended). Give up on the siblings
            // rather than exhaust the tree past the deadline — the
            // caller reports the timeout in the Infeasible detail.
            if expired {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::fusion::fuse;
    use crate::ir::polybench;

    fn quick_opts() -> SolverOptions {
        SolverOptions {
            beam: 12,
            max_factor_per_loop: 32,
            max_unroll: 1024,
            timeout: Duration::from_secs(20),
            ..SolverOptions::default()
        }
    }

    #[test]
    fn gemm_solves_and_is_valid() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &quick_opts()).unwrap();
        r.design.validate(&k, &r.fused, dev.slrs).unwrap();
        assert!(r.gflops > 50.0, "gemm RTL gflops too low: {}", r.gflops);
        assert!(r.explored > 100);
    }

    #[test]
    fn solve_with_shared_cache_matches_cold_solve() {
        // The shared GeometryCache must not change what the solver finds:
        // same design, same latency, point for point. (gemm's fusion
        // space has a single variant — its init/update pair cannot
        // split — so the exploring solve and the pinned-variant solve
        // see the same space.)
        let k = polybench::gemm();
        let dev = Device::u55c();
        let cold = solve(&k, &dev, &quick_opts()).unwrap();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let warm = solve_with_cache(&k, &fg, &cache, &dev, &quick_opts()).unwrap();
        assert_eq!(cold.design, warm.design);
        assert_eq!(cold.latency.total, warm.latency.total);
        // explored counts are only exactly reproducible single-threaded
        // (parallel pruning races change them, never the design)
        if quick_opts().jobs == 1 {
            assert_eq!(cold.explored, warm.explored);
        }
    }

    #[test]
    fn three_madd_uses_concurrency() {
        let k = polybench::three_madd();
        let dev = Device::u55c();
        let df = solve(&k, &dev, &quick_opts()).unwrap();
        let seq = solve(
            &k,
            &dev,
            &SolverOptions {
                model: ExecutionModel::Sequential,
                overlap: false,
                ..quick_opts()
            },
        )
        .unwrap();
        assert!(
            df.latency.total < seq.latency.total,
            "dataflow {} !< sequential {}",
            df.latency.total,
            seq.latency.total
        );
    }

    #[test]
    fn onboard_budget_shrinks_design() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let rtl = solve(&k, &dev, &quick_opts()).unwrap();
        let board = solve(
            &k,
            &dev,
            &SolverOptions {
                scenario: Scenario::OnBoard { slrs: 1, frac: 0.6 },
                ..quick_opts()
            },
        )
        .unwrap();
        assert!(board.gflops <= rtl.gflops * 1.05);
        // on-board design must fit the scaled budget
        let budget = dev.slr.scaled(0.6);
        assert!(crate::dse::constraints::feasible(&k, &board.fused, &board.design, &dev, &budget));
    }

    #[test]
    fn scenario_serde_round_trip() {
        use serde::{Deserialize, Serialize};
        for s in [Scenario::Rtl, Scenario::OnBoard { slrs: 3, frac: 0.6 }] {
            let v = s.serialize();
            assert_eq!(Scenario::deserialize(&v).unwrap(), s);
        }
        assert!(Scenario::deserialize(&serde::Value::Null).is_err());
    }

    #[test]
    fn warm_start_never_worse() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let cold = solve(&k, &dev, &quick_opts()).unwrap();
        let inc_cycles = crate::sim::engine::simulate(&k, &cold.fused, &cold.design, &dev).cycles;
        // a much weaker search, warm-started from the cold design, may
        // not beat the incumbent but can never fall below it
        let warm = solve(
            &k,
            &dev,
            &SolverOptions { incumbent: Some(cold.design.clone()), beam: 2, ..quick_opts() },
        )
        .unwrap();
        let warm_cycles = crate::sim::engine::simulate(&k, &warm.fused, &warm.design, &dev).cycles;
        assert!(warm_cycles <= inc_cycles, "warm {warm_cycles} > incumbent {inc_cycles}");
        assert!(warm.warm_started, "usable incumbent must be reported as a warm start");
    }

    #[test]
    fn mismatched_incumbent_is_ignored() {
        let k = polybench::gemm();
        let other = polybench::bicg();
        let dev = Device::u55c();
        let inc = solve(&other, &dev, &quick_opts()).unwrap().design;
        // an incumbent from another kernel must not leak into the result
        let r = solve(&k, &dev, &SolverOptions { incumbent: Some(inc), ..quick_opts() }).unwrap();
        assert_eq!(r.design.kernel, "gemm");
        assert!(!r.warm_started, "rejected incumbent must not count as a warm start");
        r.design.validate(&k, &r.fused, dev.slrs).unwrap();
    }

    #[test]
    fn timeout_is_anytime() {
        let k = polybench::three_mm();
        let dev = Device::u55c();
        let r = solve(
            &k,
            &dev,
            &SolverOptions { timeout: Duration::from_millis(50), ..quick_opts() },
        )
        .unwrap();
        // even with a tiny timeout we get *a* design
        assert!(r.latency.total > 0);
    }

    #[test]
    fn infeasible_budget_is_an_error() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let err = solve(
            &k,
            &dev,
            &SolverOptions {
                scenario: Scenario::OnBoard { slrs: 1, frac: 1e-6 },
                ..quick_opts()
            },
        )
        .unwrap_err();
        let SolverError::Infeasible { task, detail } = err;
        assert!(task.is_some(), "a single-region overflow names the task");
        assert!(detail.contains("gemm"), "{detail}");
    }

    #[test]
    fn multi_slr_solves_are_symmetry_broken() {
        // Region ids appear in first-use order: the renamed duplicates
        // are pruned, so region r can only appear after 0..r did.
        let k = polybench::three_mm();
        let dev = Device::u55c();
        let r = solve(
            &k,
            &dev,
            &SolverOptions {
                scenario: Scenario::OnBoard { slrs: 3, frac: 0.6 },
                ..quick_opts()
            },
        )
        .unwrap();
        let mut seen = 0usize;
        for tc in &r.design.tasks {
            assert!(tc.slr <= seen, "region {} opened before {}", tc.slr, seen);
            seen = seen.max(tc.slr + 1);
        }
    }

    #[test]
    fn fixed_fusion_pins_the_max_fusion_variant() {
        let k = polybench::gemver();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &SolverOptions { explore_fusion: false, ..quick_opts() }).unwrap();
        assert_eq!(r.fusion_variants, 1);
        assert_eq!(r.design.fusion, FusionPlan::max_fusion(&k));
        assert_eq!(r.fused.plan(), FusionPlan::max_fusion(&k));
        r.design.validate(&k, &r.fused, dev.slrs).unwrap();
    }

    #[test]
    fn fusion_exploration_never_worse_than_fixed() {
        // gemver's x-update chain is the splittable group: the explored
        // space is a superset of the fixed space, and both are scored
        // by the same simulator, so the explored winner can never be
        // slower. (The zoo-wide version of this property lives in
        // tests/property_fusion.rs.)
        let k = polybench::gemver();
        let dev = Device::u55c();
        let fixed = solve(&k, &dev, &SolverOptions { explore_fusion: false, ..quick_opts() })
            .unwrap();
        let explored = solve(&k, &dev, &quick_opts()).unwrap();
        assert!(explored.fusion_variants > 1, "gemver must have a split variant");
        let fixed_cycles =
            crate::sim::engine::simulate(&k, &fixed.fused, &fixed.design, &dev).cycles;
        let explored_cycles =
            crate::sim::engine::simulate(&k, &explored.fused, &explored.design, &dev).cycles;
        // superset argument needs completed searches (anytime results
        // of a timed-out explored solve are exempt)
        if !fixed.timed_out && !explored.timed_out {
            assert!(
                explored_cycles <= fixed_cycles,
                "fusion-explored {explored_cycles} worse than fixed {fixed_cycles}"
            );
        }
        explored.design.validate(&k, &explored.fused, dev.slrs).unwrap();
    }

    #[test]
    fn cross_variant_incumbent_is_rejected_by_the_gate() {
        // An incumbent solved under the split variant must not seed a
        // solve that only considers the max-fusion variant: the
        // usability gate (design.validate checks fusion == fg.plan())
        // rejects it, exactly like the QoR cache's hit check.
        let k = polybench::gemver();
        let dev = Device::u55c();
        let explored = solve(&k, &dev, &quick_opts()).unwrap();
        let split_design = explored.design.clone();
        if split_design.fusion == FusionPlan::max_fusion(&k) {
            // the split variant did not win — synthesize the rejection
            // the other way: a max-fusion incumbent into a space that
            // does not contain it cannot happen (max fusion is always
            // variant 0), so the property is vacuously covered by the
            // pinned-variant check below.
            let fixed = solve(
                &k,
                &dev,
                &SolverOptions {
                    explore_fusion: false,
                    incumbent: Some(split_design),
                    beam: 2,
                    ..quick_opts()
                },
            )
            .unwrap();
            assert!(fixed.warm_started, "matching-variant incumbent must warm start");
            return;
        }
        let fixed = solve(
            &k,
            &dev,
            &SolverOptions {
                explore_fusion: false,
                incumbent: Some(split_design),
                beam: 2,
                ..quick_opts()
            },
        )
        .unwrap();
        assert!(
            !fixed.warm_started,
            "incumbent from a different fusion variant must be rejected"
        );
        assert_eq!(fixed.design.fusion, FusionPlan::max_fusion(&k));
    }
}
