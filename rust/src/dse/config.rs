//! The design configuration — the NLP's decision variables (Table 2),
//! bound to one kernel. A [`DesignConfig`] fully determines the generated
//! HLS design, the simulator input and the analytic latency.

use crate::analysis::fusion::FusedGraph;
use crate::ir::Kernel;
use std::collections::BTreeMap;

/// How tasks execute relative to each other — the axis that separates
/// Prometheus (dataflow, concurrent) from shared-buffer frameworks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutionModel {
    /// `#pragma HLS dataflow`: fused tasks run concurrently, FIFOs carry
    /// intermediates, computation/communication overlap via ping-pong
    /// buffers (Prometheus).
    Dataflow,
    /// Tasks run back-to-back sharing on-chip buffers; transfers may still
    /// overlap compute within a task if `overlap` is set on the plan
    /// (Sisyphus = no overlap, sequential).
    Sequential,
}

/// Where an array's on-chip buffer is defined and where data is moved
/// (paper Eqs 5–6): `define_level ≤ transfer_level`, level 0 = before any
/// inter-tile loop, level `i ≥ 1` = under the `i`-th non-reduction
/// inter-tile loop of the owning task (in permuted order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferPlan {
    pub define_level: usize,
    pub transfer_level: usize,
    /// Selected burst width in bits (Eq 3).
    pub bitwidth: u64,
    /// Number of buffers: 1 = no overlap, 2 = double (read xor write),
    /// 3 = triple (read and write).
    pub buffers: u64,
}

impl TransferPlan {
    pub fn validate(&self) -> Result<(), String> {
        if self.define_level > self.transfer_level {
            return Err(format!(
                "define level {} deeper than transfer level {} (Eq 6)",
                self.define_level, self.transfer_level
            ));
        }
        if !matches!(self.buffers, 1..=3) {
            return Err(format!("buffer count {} outside 1..=3", self.buffers));
        }
        if !self.bitwidth.is_power_of_two() || self.bitwidth < 32 || self.bitwidth > 512 {
            return Err(format!("bitwidth {} not a power of two in 32..=512", self.bitwidth));
        }
        Ok(())
    }
}

/// Per fused task decisions.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskConfig {
    /// Fused task id this config belongs to.
    pub task: usize,
    /// Loop order of the representative statement's nest: a permutation of
    /// loop positions with non-reduction loops first (inter-tile order)
    /// and reduction loops last (pipelined directly above the intra task,
    /// largest trip innermost — §3.4).
    pub perm: Vec<usize>,
    /// Padded trip count per loop position (≥ original; Eqs 1–2).
    pub padded_trip: Vec<u64>,
    /// Intra-tile trip count (= unroll factor contribution) per loop
    /// position; divides `padded_trip`.
    pub intra: Vec<u64>,
    /// Initiation interval of the pipelined reduction inter-tile loop
    /// (= fadd latency when a reduction exists, else 1).
    pub ii: u64,
    /// Transfer/definition plan per array touched by the task.
    pub plans: BTreeMap<String, TransferPlan>,
    /// SLR the task is mapped to (Eq 11).
    pub slr: usize,
}

impl TaskConfig {
    /// Unroll factor = product of intra trips (the fully unrolled
    /// intra-tile workload, §3.3).
    pub fn unroll_factor(&self) -> u64 {
        self.intra.iter().product()
    }

    /// Inter-tile trip of loop position `p`.
    pub fn inter_trip(&self, p: usize) -> u64 {
        self.padded_trip[p] / self.intra[p]
    }

    /// Positions of the non-reduction loops in permuted (outer→inner)
    /// order, given the representative statement's reduction mask.
    pub fn nonred_order(&self, red_mask: &[bool]) -> Vec<usize> {
        self.perm.iter().copied().filter(|&p| !red_mask[p]).collect()
    }

    /// Positions of reduction loops (pipelined, innermost).
    pub fn red_order(&self, red_mask: &[bool]) -> Vec<usize> {
        self.perm.iter().copied().filter(|&p| red_mask[p]).collect()
    }
}

/// A complete design for one kernel.
#[derive(Debug, Clone)]
pub struct DesignConfig {
    pub kernel: String,
    pub model: ExecutionModel,
    /// Whether load/compute/store overlap (ping-pong) is enabled.
    pub overlap: bool,
    pub tasks: Vec<TaskConfig>,
}

impl DesignConfig {
    pub fn task(&self, id: usize) -> &TaskConfig {
        &self.tasks[id]
    }

    /// Structural validation against the kernel/fused graph: permutation
    /// is a permutation, intra divides padded trip, padded ≥ original,
    /// plans valid, SLR ids in range.
    pub fn validate(&self, k: &Kernel, fg: &FusedGraph, slrs: usize) -> Result<(), String> {
        if self.tasks.len() != fg.tasks.len() {
            return Err(format!(
                "{} task configs for {} fused tasks",
                self.tasks.len(),
                fg.tasks.len()
            ));
        }
        for tc in &self.tasks {
            let rep = fg.tasks[tc.task].representative(k);
            let nest = &k.statements[rep].loops;
            if tc.perm.len() != nest.len() {
                return Err(format!("task {}: perm len mismatch", tc.task));
            }
            let mut sorted = tc.perm.clone();
            sorted.sort_unstable();
            if sorted != (0..nest.len()).collect::<Vec<_>>() {
                return Err(format!("task {}: perm {:?} is not a permutation", tc.task, tc.perm));
            }
            for (p, l) in nest.iter().enumerate() {
                if tc.padded_trip[p] < l.trip {
                    return Err(format!(
                        "task {}: padded trip {} < original {} at loop {}",
                        tc.task, tc.padded_trip[p], l.trip, p
                    ));
                }
                if tc.padded_trip[p] % tc.intra[p] != 0 {
                    return Err(format!(
                        "task {}: intra {} does not divide padded {} (Eq 1)",
                        tc.task, tc.intra[p], tc.padded_trip[p]
                    ));
                }
            }
            for (a, plan) in &tc.plans {
                plan.validate().map_err(|e| format!("task {} array {a}: {e}", tc.task))?;
            }
            if tc.slr >= slrs {
                return Err(format!("task {}: SLR {} out of range", tc.task, tc.slr));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_plan_validation() {
        let ok = TransferPlan { define_level: 0, transfer_level: 1, bitwidth: 512, buffers: 2 };
        assert!(ok.validate().is_ok());
        let bad_order =
            TransferPlan { define_level: 2, transfer_level: 1, bitwidth: 512, buffers: 2 };
        assert!(bad_order.validate().is_err());
        let bad_bw = TransferPlan { define_level: 0, transfer_level: 0, bitwidth: 48, buffers: 2 };
        assert!(bad_bw.validate().is_err());
        let bad_buf = TransferPlan { define_level: 0, transfer_level: 0, bitwidth: 64, buffers: 5 };
        assert!(bad_buf.validate().is_err());
    }

    #[test]
    fn task_config_arithmetic() {
        let tc = TaskConfig {
            task: 0,
            perm: vec![0, 1, 2],
            padded_trip: vec![180, 192, 204],
            intra: vec![10, 32, 4],
            ii: 3,
            plans: BTreeMap::new(),
            slr: 0,
        };
        assert_eq!(tc.unroll_factor(), 10 * 32 * 4);
        assert_eq!(tc.inter_trip(0), 18);
        assert_eq!(tc.inter_trip(1), 6);
        assert_eq!(tc.inter_trip(2), 51);
        let red = [false, false, true];
        assert_eq!(tc.nonred_order(&red), vec![0, 1]);
        assert_eq!(tc.red_order(&red), vec![2]);
    }
}
