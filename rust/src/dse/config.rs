//! The design configuration — the NLP's decision variables (Table 2),
//! bound to one kernel. A [`DesignConfig`] fully determines the generated
//! HLS design, the simulator input and the analytic latency.

use crate::analysis::fusion::{FusedGraph, FusionPlan};
use crate::ir::Kernel;
use std::collections::BTreeMap;

/// How tasks execute relative to each other — the axis that separates
/// Prometheus (dataflow, concurrent) from shared-buffer frameworks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutionModel {
    /// `#pragma HLS dataflow`: fused tasks run concurrently, FIFOs carry
    /// intermediates, computation/communication overlap via ping-pong
    /// buffers (Prometheus).
    Dataflow,
    /// Tasks run back-to-back sharing on-chip buffers; transfers may still
    /// overlap compute within a task if `overlap` is set on the plan
    /// (Sisyphus = no overlap, sequential).
    Sequential,
}

/// Where an array's on-chip buffer is defined and where data is moved
/// (paper Eqs 5–6): `define_level ≤ transfer_level`, level 0 = before any
/// inter-tile loop, level `i ≥ 1` = under the `i`-th non-reduction
/// inter-tile loop of the owning task (in permuted order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferPlan {
    pub define_level: usize,
    pub transfer_level: usize,
    /// Selected burst width in bits (Eq 3).
    pub bitwidth: u64,
    /// Number of buffers: 1 = no overlap, 2 = double (read xor write),
    /// 3 = triple (read and write).
    pub buffers: u64,
}

impl TransferPlan {
    pub fn validate(&self) -> Result<(), String> {
        if self.define_level > self.transfer_level {
            return Err(format!(
                "define level {} deeper than transfer level {} (Eq 6)",
                self.define_level, self.transfer_level
            ));
        }
        if !matches!(self.buffers, 1..=3) {
            return Err(format!("buffer count {} outside 1..=3", self.buffers));
        }
        if !self.bitwidth.is_power_of_two() || self.bitwidth < 32 || self.bitwidth > 512 {
            return Err(format!("bitwidth {} not a power of two in 32..=512", self.bitwidth));
        }
        Ok(())
    }
}

/// Per fused task decisions.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskConfig {
    /// Fused task id this config belongs to.
    pub task: usize,
    /// Loop order of the representative statement's nest: a permutation of
    /// loop positions with non-reduction loops first (inter-tile order)
    /// and reduction loops last (pipelined directly above the intra task,
    /// largest trip innermost — §3.4).
    pub perm: Vec<usize>,
    /// Padded trip count per loop position (≥ original; Eqs 1–2).
    pub padded_trip: Vec<u64>,
    /// Intra-tile trip count (= unroll factor contribution) per loop
    /// position; divides `padded_trip`.
    pub intra: Vec<u64>,
    /// Initiation interval of the pipelined reduction inter-tile loop
    /// (= fadd latency when a reduction exists, else 1).
    pub ii: u64,
    /// Transfer/definition plan per array touched by the task.
    pub plans: BTreeMap<String, TransferPlan>,
    /// SLR the task is mapped to (Eq 11).
    pub slr: usize,
}

impl TaskConfig {
    /// Unroll factor = product of intra trips (the fully unrolled
    /// intra-tile workload, §3.3).
    pub fn unroll_factor(&self) -> u64 {
        self.intra.iter().product()
    }

    /// Inter-tile trip of loop position `p`.
    pub fn inter_trip(&self, p: usize) -> u64 {
        self.padded_trip[p] / self.intra[p]
    }

    /// Positions of the non-reduction loops in permuted (outer→inner)
    /// order, given the representative statement's reduction mask.
    pub fn nonred_order(&self, red_mask: &[bool]) -> Vec<usize> {
        self.perm.iter().copied().filter(|&p| !red_mask[p]).collect()
    }

    /// Positions of reduction loops (pipelined, innermost).
    pub fn red_order(&self, red_mask: &[bool]) -> Vec<usize> {
        self.perm.iter().copied().filter(|&p| red_mask[p]).collect()
    }
}

/// A complete design for one kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignConfig {
    pub kernel: String,
    pub model: ExecutionModel,
    /// Whether load/compute/store overlap (ping-pong) is enabled.
    pub overlap: bool,
    /// The fusion variant this design was solved for — the canonical
    /// statement partition plus per-part fusion ranges
    /// ([`FusionPlan`]). Task ids in `tasks` index the [`FusedGraph`]
    /// this plan materializes (a ranged part contributes its peeled
    /// prologue/epilogue tasks too, each with its own `TaskConfig`), so
    /// a design is only meaningful together with its own fusion:
    /// `validate` rejects a graph realizing a different partition,
    /// which is also the gate that keeps QoR-DB warm starts from
    /// crossing incompatible variants.
    pub fusion: FusionPlan,
    pub tasks: Vec<TaskConfig>,
}

impl DesignConfig {
    pub fn task(&self, id: usize) -> &TaskConfig {
        &self.tasks[id]
    }

    /// Structural validation against the kernel/fused graph: the fusion
    /// plan is legal for `k` and is exactly the partition `fg`
    /// realizes, permutation is a permutation, intra divides padded
    /// trip, padded ≥ the task's *effective* trip (a ranged/peeled
    /// task's outermost loop spans only its `[lo, hi)` slice), plans
    /// valid, SLR ids in range.
    pub fn validate(&self, k: &Kernel, fg: &FusedGraph, slrs: usize) -> Result<(), String> {
        self.fusion.validate(k)?;
        if self.fusion != fg.plan() {
            return Err(format!(
                "design was solved for fusion {:?} but is evaluated against {:?} \
                 (fusion variants are incompatible)",
                self.fusion.parts(),
                fg.plan().parts()
            ));
        }
        if self.tasks.len() != fg.tasks.len() {
            return Err(format!(
                "{} task configs for {} fused tasks",
                self.tasks.len(),
                fg.tasks.len()
            ));
        }
        // id coverage before any indexing: persisted designs (QoR DB
        // records survive hand edits and version skew) must fail this
        // gate with an Err, never an index panic
        let mut seen_ids = vec![false; fg.tasks.len()];
        for tc in &self.tasks {
            if tc.task >= fg.tasks.len() {
                return Err(format!(
                    "task id {} out of range ({} fused tasks)",
                    tc.task,
                    fg.tasks.len()
                ));
            }
            if seen_ids[tc.task] {
                return Err(format!("duplicate config for task {}", tc.task));
            }
            seen_ids[tc.task] = true;
        }
        for tc in &self.tasks {
            let rep = fg.tasks[tc.task].representative(k);
            let nest = &k.statements[rep].loops;
            if tc.perm.len() != nest.len() {
                return Err(format!("task {}: perm len mismatch", tc.task));
            }
            if tc.padded_trip.len() != nest.len() || tc.intra.len() != nest.len() {
                return Err(format!(
                    "task {}: padded_trip/intra lengths ({}, {}) do not match the {}-loop nest",
                    tc.task,
                    tc.padded_trip.len(),
                    tc.intra.len(),
                    nest.len()
                ));
            }
            let mut sorted = tc.perm.clone();
            sorted.sort_unstable();
            if sorted != (0..nest.len()).collect::<Vec<_>>() {
                return Err(format!("task {}: perm {:?} is not a permutation", tc.task, tc.perm));
            }
            for (p, l) in nest.iter().enumerate() {
                // a ranged/peeled task covers only its outer-range span
                let eff_trip = if p == 0 {
                    fg.tasks[tc.task].outer_span().unwrap_or(l.trip)
                } else {
                    l.trip
                };
                if tc.padded_trip[p] < eff_trip {
                    return Err(format!(
                        "task {}: padded trip {} < effective {} at loop {}",
                        tc.task, tc.padded_trip[p], eff_trip, p
                    ));
                }
                if tc.intra[p] == 0 || tc.padded_trip[p] % tc.intra[p] != 0 {
                    return Err(format!(
                        "task {}: intra {} does not divide padded {} (Eq 1)",
                        tc.task, tc.intra[p], tc.padded_trip[p]
                    ));
                }
            }
            for (a, plan) in &tc.plans {
                plan.validate().map_err(|e| format!("task {} array {a}: {e}", tc.task))?;
            }
            if tc.slr >= slrs {
                return Err(format!("task {}: SLR {} out of range", tc.task, tc.slr));
            }
        }
        Ok(())
    }
}

// ---- serde: persistence for the QoR knowledge base ---------------------
//
// Manual `serde::{Serialize, Deserialize}` implementations: the vendored
// serde (see `vendor/serde`) has no derive proc-macro, so the impls a
// `#[derive(Serialize, Deserialize)]` would generate are written out by
// hand. The on-disk JSON shape is versioned by the QoR-DB envelope
// (`service::qor_db::FORMAT_VERSION`), not per type.
mod serde_impls {
    use super::*;
    use serde::{Deserialize, Error, Serialize, Value};

    impl Serialize for ExecutionModel {
        fn serialize(&self) -> Value {
            Value::Str(
                match self {
                    ExecutionModel::Dataflow => "dataflow",
                    ExecutionModel::Sequential => "sequential",
                }
                .to_string(),
            )
        }
    }

    impl Deserialize for ExecutionModel {
        fn deserialize(v: &Value) -> Result<ExecutionModel, Error> {
            match v.as_str() {
                Some("dataflow") => Ok(ExecutionModel::Dataflow),
                Some("sequential") => Ok(ExecutionModel::Sequential),
                other => Err(Error::new(format!("invalid execution model {other:?}"))),
            }
        }
    }

    impl Serialize for TransferPlan {
        fn serialize(&self) -> Value {
            Value::Obj(vec![
                ("define_level".to_string(), self.define_level.serialize()),
                ("transfer_level".to_string(), self.transfer_level.serialize()),
                ("bitwidth".to_string(), self.bitwidth.serialize()),
                ("buffers".to_string(), self.buffers.serialize()),
            ])
        }
    }

    impl Deserialize for TransferPlan {
        fn deserialize(v: &Value) -> Result<TransferPlan, Error> {
            Ok(TransferPlan {
                define_level: usize::deserialize(v.field("define_level")?)?,
                transfer_level: usize::deserialize(v.field("transfer_level")?)?,
                bitwidth: u64::deserialize(v.field("bitwidth")?)?,
                buffers: u64::deserialize(v.field("buffers")?)?,
            })
        }
    }

    impl Serialize for TaskConfig {
        fn serialize(&self) -> Value {
            Value::Obj(vec![
                ("task".to_string(), self.task.serialize()),
                ("perm".to_string(), self.perm.serialize()),
                ("padded_trip".to_string(), self.padded_trip.serialize()),
                ("intra".to_string(), self.intra.serialize()),
                ("ii".to_string(), self.ii.serialize()),
                ("plans".to_string(), self.plans.serialize()),
                ("slr".to_string(), self.slr.serialize()),
            ])
        }
    }

    impl Deserialize for TaskConfig {
        fn deserialize(v: &Value) -> Result<TaskConfig, Error> {
            Ok(TaskConfig {
                task: usize::deserialize(v.field("task")?)?,
                perm: Vec::deserialize(v.field("perm")?)?,
                padded_trip: Vec::deserialize(v.field("padded_trip")?)?,
                intra: Vec::deserialize(v.field("intra")?)?,
                ii: u64::deserialize(v.field("ii")?)?,
                plans: BTreeMap::deserialize(v.field("plans")?)?,
                slr: usize::deserialize(v.field("slr")?)?,
            })
        }
    }

    impl Serialize for DesignConfig {
        fn serialize(&self) -> Value {
            Value::Obj(vec![
                ("kernel".to_string(), self.kernel.serialize()),
                ("model".to_string(), self.model.serialize()),
                ("overlap".to_string(), self.overlap.serialize()),
                ("fusion".to_string(), self.fusion.serialize()),
                ("tasks".to_string(), self.tasks.serialize()),
            ])
        }
    }

    impl Deserialize for DesignConfig {
        fn deserialize(v: &Value) -> Result<DesignConfig, Error> {
            Ok(DesignConfig {
                kernel: String::deserialize(v.field("kernel")?)?,
                model: ExecutionModel::deserialize(v.field("model")?)?,
                overlap: bool::deserialize(v.field("overlap")?)?,
                fusion: FusionPlan::deserialize(v.field("fusion")?)?,
                tasks: Vec::deserialize(v.field("tasks")?)?,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_plan_validation() {
        let ok = TransferPlan { define_level: 0, transfer_level: 1, bitwidth: 512, buffers: 2 };
        assert!(ok.validate().is_ok());
        let bad_order =
            TransferPlan { define_level: 2, transfer_level: 1, bitwidth: 512, buffers: 2 };
        assert!(bad_order.validate().is_err());
        let bad_bw = TransferPlan { define_level: 0, transfer_level: 0, bitwidth: 48, buffers: 2 };
        assert!(bad_bw.validate().is_err());
        let bad_buf = TransferPlan { define_level: 0, transfer_level: 0, bitwidth: 64, buffers: 5 };
        assert!(bad_buf.validate().is_err());
    }

    #[test]
    fn task_config_arithmetic() {
        let tc = TaskConfig {
            task: 0,
            perm: vec![0, 1, 2],
            padded_trip: vec![180, 192, 204],
            intra: vec![10, 32, 4],
            ii: 3,
            plans: BTreeMap::new(),
            slr: 0,
        };
        assert_eq!(tc.unroll_factor(), 10 * 32 * 4);
        assert_eq!(tc.inter_trip(0), 18);
        assert_eq!(tc.inter_trip(1), 6);
        assert_eq!(tc.inter_trip(2), 51);
        let red = [false, false, true];
        assert_eq!(tc.nonred_order(&red), vec![0, 1]);
        assert_eq!(tc.red_order(&red), vec![2]);
    }

    #[test]
    fn design_config_serde_round_trip() {
        use serde::{Deserialize, Serialize};
        let mut plans = BTreeMap::new();
        plans.insert(
            "A".to_string(),
            TransferPlan { define_level: 0, transfer_level: 1, bitwidth: 512, buffers: 2 },
        );
        let design = DesignConfig {
            kernel: "gemm".into(),
            model: ExecutionModel::Dataflow,
            overlap: true,
            fusion: FusionPlan::new(vec![vec![0, 1]]),
            tasks: vec![TaskConfig {
                task: 0,
                perm: vec![2, 0, 1],
                padded_trip: vec![200, 220, 240],
                intra: vec![10, 4, 8],
                ii: 3,
                plans,
                slr: 1,
            }],
        };
        let text = serde::json::to_string_pretty(&design.serialize());
        let back = DesignConfig::deserialize(&serde::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, design);
    }
}
