//! Loop-order enumeration (paper §3.4, Eq 4).
//!
//! After tiling, the intra-tile is fully unrolled so only the *inter-tile*
//! order matters. Reduction loops sit innermost (pipelined), ranked by
//! trip count with the largest innermost; the non-reduction inter-tile
//! loops are freely permutable — the NLP picks among those orders.
//! Statements fused into one task share the same permutation (Eq 4),
//! which is guaranteed by permuting the representative nest only.
//!
//! Under dataflow, FIFO edges constrain orders further: producer and
//! consumer must traverse the communicated array in a compatible order
//! (§6.4) — enforced by [`fifo_compatible`].

use crate::ir::{Kernel, Statement};

/// All permutations of `items` (n ≤ 4 in practice — nests are depth ≤ 3).
pub fn permutations(items: &[usize]) -> Vec<Vec<usize>> {
    if items.is_empty() {
        return vec![vec![]];
    }
    let mut out = Vec::new();
    for (i, &x) in items.iter().enumerate() {
        let mut rest = items.to_vec();
        rest.remove(i);
        for mut p in permutations(&rest) {
            p.insert(0, x);
            out.push(p);
        }
    }
    out
}

/// Legal inter-tile orders for a statement: every permutation of its
/// non-reduction loops, each followed by its reduction loops ranked with
/// the largest trip count innermost (§3.4).
pub fn legal_orders(s: &Statement) -> Vec<Vec<usize>> {
    let nonred = s.parallel_loops();
    let mut red = s.reduction_loops();
    // largest trip innermost = ascending trip order then reversed ranks:
    // sort ascending so the largest ends up last (innermost).
    red.sort_by_key(|&p| s.loops[p].trip);
    permutations(&nonred)
        .into_iter()
        .map(|mut p| {
            p.extend(red.iter().copied());
            p
        })
        .collect()
}

/// Whether producer order `p_ord` and consumer order `c_ord` traverse the
/// shared array compatibly for FIFO streaming: the sequence of the
/// array's *indexing loops* (by name) must match in relative order —
/// data leaves the producer in exactly the order the consumer ingests it.
pub fn fifo_compatible(
    k: &Kernel,
    producer: usize,
    p_ord: &[usize],
    consumer: usize,
    c_ord: &[usize],
    array: &str,
) -> bool {
    let sp = &k.statements[producer];
    let sc = &k.statements[consumer];
    // names of loops indexing `array` in traversal order, producer side
    let order_of = |s: &Statement, ord: &[usize]| -> Vec<String> {
        let acc = if s.write.array == array {
            Some(&s.write)
        } else {
            s.reads.iter().find(|r| r.array == array)
        };
        let Some(acc) = acc else { return vec![] };
        // dims in array-dimension order -> loop names; traversal order =
        // positions sorted by their place in `ord`
        let mut dims: Vec<(usize, usize)> = acc
            .loop_positions()
            .into_iter()
            .enumerate()
            .map(|(d, p)| (d, ord.iter().position(|&q| q == p).unwrap_or(usize::MAX)))
            .collect();
        dims.sort_by_key(|&(_, place)| place);
        dims.into_iter().map(|(d, _)| format!("dim{d}")).collect()
    };
    let po = order_of(sp, p_ord);
    let co = order_of(sc, c_ord);
    po.is_empty() || co.is_empty() || po == co
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    #[test]
    fn permutation_counts() {
        assert_eq!(permutations(&[0]).len(), 1);
        assert_eq!(permutations(&[0, 1]).len(), 2);
        assert_eq!(permutations(&[0, 1, 2]).len(), 6);
        assert_eq!(permutations(&[]).len(), 1);
    }

    #[test]
    fn gemm_orders() {
        // gemm S1: i,j parallel, k reduction -> 2 orders, k always last.
        let k = polybench::gemm();
        let orders = legal_orders(&k.statements[1]);
        assert_eq!(orders.len(), 2);
        for o in &orders {
            assert_eq!(*o.last().unwrap(), 2, "reduction loop innermost");
        }
        assert!(orders.contains(&vec![0, 1, 2]));
        assert!(orders.contains(&vec![1, 0, 2]));
    }

    #[test]
    fn reduction_ranking_largest_innermost() {
        // For a hypothetical 2-reduction nest the larger trip goes last.
        use crate::ir::{Access, Loop, OpCounts, StmtKind};
        let s = Statement {
            id: 0,
            kind: StmtKind::Compute,
            loops: vec![
                Loop::new("i", 10, false),
                Loop::new("k1", 50, true),
                Loop::new("k2", 200, true),
            ],
            write: Access::new("o", &[0]),
            reads: vec![Access::new("o", &[0])],
            ops: OpCounts::new(1, 1),
        };
        let orders = legal_orders(&s);
        assert_eq!(orders.len(), 1);
        assert_eq!(orders[0], vec![0, 1, 2]); // k2 (trip 200) innermost
    }

    #[test]
    fn fifo_order_constraint_3mm() {
        // E produced by S1 (write E[i][j]) and consumed by S5 (reads
        // E[i][k]): producer traverses dims (i outer, j inner) with order
        // i,j,k; consumer reads E dims via loops (i, k): with order
        // i,j,k the consumer traverses dim0 outer, dim1 inner — compatible.
        let k = polybench::three_mm();
        assert!(fifo_compatible(&k, 1, &[0, 1, 2], 5, &[0, 1, 2], "E"));
        // j0-outer in the consumer leaves the E dim traversal unchanged
        // (j does not index E) — still compatible, matching Listing 6's
        // FT2 which runs j0 outermost.
        assert!(fifo_compatible(&k, 1, &[0, 1, 2], 5, &[1, 0, 2], "E"));
    }

    #[test]
    fn fifo_transposed_consumer_incompatible() {
        // Synthetic: producer writes T[i][j] row-major; a consumer reading
        // T[j][i] with the same loop order traverses the array transposed
        // — FIFO streaming order breaks.
        use crate::ir::{Access, ArrayDecl, Loop, OpCounts, StmtKind};
        let mk_stmt = |id: usize, write: Access, reads: Vec<Access>| Statement {
            id,
            kind: StmtKind::Compute,
            loops: vec![Loop::new("i", 8, false), Loop::new("j", 8, false)],
            write,
            reads,
            ops: OpCounts::new(1, 0),
        };
        let k = Kernel {
            name: "synth".into(),
            description: String::new(),
            arrays: vec![
                ArrayDecl::new("T", &[8, 8], false, false),
                ArrayDecl::new("A", &[8, 8], true, false),
                ArrayDecl::new("O", &[8, 8], false, true),
            ],
            statements: vec![
                mk_stmt(0, Access::new("T", &[0, 1]), vec![Access::new("A", &[0, 1])]),
                mk_stmt(1, Access::new("O", &[0, 1]), vec![Access::new("T", &[1, 0])]),
            ],
        };
        assert!(!fifo_compatible(&k, 0, &[0, 1], 1, &[0, 1], "T"));
        // flipping the consumer's loop order restores compatibility
        assert!(fifo_compatible(&k, 0, &[0, 1], 1, &[1, 0], "T"));
    }
}
