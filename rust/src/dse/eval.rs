//! The unified evaluation core: **one** plan-resolution layer shared by
//! the analytic cost model ([`super::cost`]), the resource constraints
//! ([`super::constraints`]), the executing simulator
//! ([`crate::sim::engine`]), the board model ([`crate::sim::board`]) and
//! the HLS code generator ([`crate::codegen::hls`]).
#![deny(missing_docs)]
//!
//! Before this module existed, each of those consumers independently
//! re-resolved transfer plans (`default_plan`, `define_level` /
//! `transfer_level` clamping, tile geometry) from a `TaskGeometry` it
//! rebuilt per evaluation — four copies of the same logic that could
//! silently diverge. Now a candidate design is resolved **once** into a
//! [`ResolvedDesign`] and every consumer reads the same precomputed
//! numbers, so they agree on what the design *means* by construction.
//!
//! Two layers, split by what can be memoized when:
//!
//! * [`GeometryCache`] / [`TaskStatics`] — everything that depends only
//!   on the kernel and its fusion, built **once at fusion time**:
//!   per-array declarations and translated accesses, representative
//!   nests, *effective trip counts* (a ranged/peeled task's outermost
//!   loop is narrowed to its `[lo, hi)` span, so peeled sub-tasks get
//!   their own geometry), legal loop orders, statement→representative
//!   position maps, FIFO topology. The solver's inner loop (10^5+
//!   evaluations per solve) shares one cache; `service::batch` shares
//!   it further across parallel jobs for the same kernel.
//! * [`ResolvedTask`] / [`ResolvedPlan`] — everything a concrete
//!   [`TaskConfig`] adds: clamped+defaulted transfer plans, tile
//!   dimensions and byte counts at the define level, transfer counts,
//!   partition factors. Rebuilt per candidate; invalidated by any change
//!   to tile factors, permutation or plans (see DESIGN.md §Evaluation
//!   core for the invalidation rules).

use super::config::{DesignConfig, TaskConfig, TransferPlan};
use super::permutation::legal_orders;
use super::space::TaskGeometry;
use crate::analysis::fusion::{enumerate_fusions, fuse_with_plan, FusedGraph, FusedTask, FusionPlan};
use crate::ir::{Kernel, StmtKind};

/// Configuration-independent facts about one array of a fused task:
/// the fused-time access memo joined with the array's declaration and
/// its FIFO topology, so per-candidate resolution never does string
/// lookups into the kernel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayStatics {
    /// Array name as declared in the kernel.
    pub name: String,
    /// Access translated to representative-nest loop positions, one
    /// entry per array dimension (`None` = dimension not indexed by a
    /// loop iterator).
    pub access: Vec<Option<usize>>,
    /// Declared extent of each dimension.
    pub dims: Vec<u64>,
    /// Bytes per element of the declared dtype.
    pub elem_bytes: u64,
    /// Bits per element of the declared dtype.
    pub elem_bits: u64,
    /// Declared total element count.
    pub total_elems: u64,
    /// Whether any statement of the task reads this array.
    pub reads: bool,
    /// Whether any statement of the task writes this array.
    pub writes: bool,
    /// Whether the array is a kernel input (lives off-chip).
    pub is_input: bool,
    /// Whether the array is a kernel output (stored off-chip).
    pub is_output: bool,
    /// Whether the array is an intermediate (neither input nor output).
    pub is_intermediate: bool,
    /// Producing fused task when this array arrives over a FIFO: the
    /// lowest-id producer (the only one, except when a ranged producer
    /// part was peeled).
    pub fifo_producer: Option<usize>,
    /// Every producing task of this FIFO-borne array, ascending — a
    /// ranged producer part contributes each of its peels. The
    /// simulator token-gates the consumer on all of them, so a
    /// consumer can never start ahead of an unfinished peel. Empty for
    /// non-FIFO arrays.
    pub fifo_producers: Vec<usize>,
}

impl ArrayStatics {
    /// Whether the task ingests this array (off-chip input, or a
    /// read-only intermediate arriving over a FIFO).
    pub fn inbound(&self) -> bool {
        self.is_input || (self.reads && !self.writes)
    }
}

/// Configuration-independent facts about one fused task, memoized at
/// fusion time so the solver's per-candidate evaluation starts from
/// here instead of re-deriving them.
#[derive(Debug, Clone)]
pub struct TaskStatics {
    /// Fused task id.
    pub task: usize,
    /// Representative statement id (deepest compute nest).
    pub rep: usize,
    /// Reduction mask of the representative nest, by loop position.
    pub red_mask: Vec<bool>,
    /// Statement ids of the fused task, program order.
    pub stmts: Vec<usize>,
    /// The task's primary output (the single output for classic tasks).
    pub output: String,
    /// Every array this task writes, first-touch order (≥ 2 entries
    /// after a cross-array merge).
    pub outputs: Vec<String>,
    /// Effective trip count per representative loop position: the
    /// declared trips, with position 0 narrowed to the task's
    /// fused/peeled `outer_range` span when one is set. The solver
    /// enumerates tile factors against these, so peeled sub-tasks get
    /// their own geometry.
    pub trips: Vec<u64>,
    /// Sub-range `[lo, hi)` of the outermost loop this task covers
    /// (`None` = full iteration space) — see
    /// [`crate::analysis::fusion::FusedTask::outer_range`].
    pub outer_range: Option<(u64, u64)>,
    /// Whether the task contains an init statement.
    pub has_init: bool,
    /// Legal inter-tile loop orders (reduction loops pinned innermost).
    pub orders: Vec<Vec<usize>>,
    /// Per-array statics, first-touch order.
    pub arrays: Vec<ArrayStatics>,
    /// Per statement (parallel to `stmts`): each of its loop positions
    /// mapped onto the representative nest by iterator name.
    pub stmt_rep_pos: Vec<Vec<Option<usize>>>,
    /// Per outgoing FIFO edge `(array, elements)`: what this task
    /// actually emits of that array — a peel's entry is scaled to its
    /// outer-range share of the array's writer iterations. The
    /// simulator derives each consumer's per-array token rate from
    /// this, so a cross-array merged engine is not credited with
    /// emitting every array at its combined rate.
    pub fifo_out_elems_by_array: Vec<(String, u64)>,
    // Name → `arrays` index, sorted by name: by-name lookups
    // (`array`/`array_pos`, `ResolvedTask::plan_for`) binary-search
    // this instead of linear string-scanning `arrays` per call.
    array_index: Vec<(String, usize)>,
}

impl TaskStatics {
    fn new(k: &Kernel, fg: &FusedGraph, fused: &FusedTask) -> TaskStatics {
        let rep = fused.representative(k);
        let rep_stmt = &k.statements[rep];
        let red_mask: Vec<bool> = rep_stmt.loops.iter().map(|l| l.reduction).collect();
        let orders = legal_orders(rep_stmt);
        let stmt_rep_pos: Vec<Vec<Option<usize>>> = fused
            .stmts
            .iter()
            .map(|&sid| {
                k.statements[sid]
                    .loops
                    .iter()
                    .map(|l| rep_stmt.loops.iter().position(|rl| rl.name == l.name))
                    .collect()
            })
            .collect();
        let arrays: Vec<ArrayStatics> = fused
            .array_info
            .iter()
            .map(|info| {
                let decl = k.array(&info.name).expect("declared array");
                let mut fifo_producers: Vec<usize> = fg
                    .edges
                    .iter()
                    .filter(|(_, dst, arr)| *dst == fused.id && arr == &info.name)
                    .map(|(src, _, _)| *src)
                    .collect();
                fifo_producers.sort_unstable();
                fifo_producers.dedup();
                let fifo_producer = fifo_producers.first().copied();
                ArrayStatics {
                    name: info.name.clone(),
                    access: info.access.clone(),
                    dims: decl.dims.clone(),
                    elem_bytes: decl.dtype.bytes(),
                    elem_bits: decl.dtype.bits(),
                    total_elems: decl.elems(),
                    reads: info.reads,
                    writes: info.writes,
                    is_input: decl.is_input,
                    is_output: decl.is_output,
                    is_intermediate: decl.is_intermediate(),
                    fifo_producer,
                    fifo_producers,
                }
            })
            .collect();
        // Per outgoing edge, the elements this task actually emits of
        // that array: a peel covers only its outer-range share of the
        // array's *writer* iterations (scaled per array — the writers
        // of different arrays in a ranged cross-array merge may have
        // different outer trips), so its stream carries that fraction
        // of the declared footprint.
        let fifo_out_elems_by_array: Vec<(String, u64)> = fg
            .edges
            .iter()
            .filter(|(src, _, _)| *src == fused.id)
            .map(|(_, _, a)| {
                let total = k.array(a).map(|x| x.elems()).unwrap_or(0);
                let emitted = match fused.outer_range {
                    Some((lo, hi)) => {
                        let wtrip = fused
                            .stmts
                            .iter()
                            .find(|&&s| &k.statements[s].write.array == a)
                            .and_then(|&s| k.statements[s].loops.first().map(|l| l.trip))
                            .unwrap_or(0);
                        if wtrip > 0 {
                            total * (hi - lo).min(wtrip) / wtrip
                        } else {
                            total
                        }
                    }
                    None => total,
                };
                (a.clone(), emitted)
            })
            .collect();
        let has_init = fused
            .stmts
            .iter()
            .any(|&s| k.statements[s].kind == StmtKind::Init);
        let mut array_index: Vec<(String, usize)> =
            arrays.iter().enumerate().map(|(i, a)| (a.name.clone(), i)).collect();
        array_index.sort();
        let trips: Vec<u64> = rep_stmt
            .loops
            .iter()
            .enumerate()
            .map(|(p, l)| {
                if p == 0 {
                    fused.outer_span().unwrap_or(l.trip)
                } else {
                    l.trip
                }
            })
            .collect();
        TaskStatics {
            task: fused.id,
            rep,
            red_mask,
            stmts: fused.stmts.clone(),
            output: fused.output.clone(),
            outputs: fused.outputs.clone(),
            trips,
            outer_range: fused.outer_range,
            has_init,
            orders,
            arrays,
            stmt_rep_pos,
            fifo_out_elems_by_array,
            array_index,
        }
    }

    /// Index of array `name` in [`TaskStatics::arrays`], resolved
    /// through the fusion-time sorted name index (no per-call linear
    /// string scan).
    pub fn array_pos(&self, name: &str) -> Option<usize> {
        self.array_index
            .binary_search_by(|(n, _)| n.as_str().cmp(name))
            .ok()
            .map(|i| self.array_index[i].1)
    }

    /// The statics of array `name`, if this task touches it.
    pub fn array(&self, name: &str) -> Option<&ArrayStatics> {
        self.array_pos(name).map(|i| &self.arrays[i])
    }

    /// Total elements this task emits over outgoing FIFO edges (the
    /// sum of [`TaskStatics::fifo_out_elems_by_array`]).
    pub fn fifo_out_total_elems(&self) -> u64 {
        self.fifo_out_elems_by_array.iter().map(|(_, e)| *e).sum()
    }

    /// Elements this task emits of array `name` over its outgoing FIFO
    /// edges (0 when it does not stream that array). The simulator's
    /// step-spec builder reads producer emissions through this, both
    /// when walking a full design and when the solver precomputes
    /// per-candidate specs for its leaf fast path.
    pub fn fifo_emitted(&self, name: &str) -> u64 {
        self.fifo_out_elems_by_array
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, e)| *e)
            .unwrap_or(0)
    }
}

/// Fusion-time memo for every task of a kernel. Owns all its data
/// (no borrows), so one cache can be shared across solver stages and
/// across `service::batch` worker threads for the same kernel.
#[derive(Debug, Clone)]
pub struct GeometryCache {
    /// Per-task statics, indexed by fused task id.
    pub tasks: Vec<TaskStatics>,
}

impl GeometryCache {
    /// Build the fusion-time memo for every task of `fg`.
    pub fn new(k: &Kernel, fg: &FusedGraph) -> GeometryCache {
        GeometryCache {
            tasks: fg.tasks.iter().map(|t| TaskStatics::new(k, fg, t)).collect(),
        }
    }
}

/// One fusion variant, fully materialized: the canonical plan, its
/// fused-task graph, and the fusion-time geometry memo. Built once per
/// kernel and shared read-only across solver workers and batch jobs.
#[derive(Debug, Clone)]
pub struct FusionVariant {
    /// The canonical statement partition this variant realizes.
    pub plan: FusionPlan,
    /// The materialized fused-task graph (peels included).
    pub fg: FusedGraph,
    /// The fusion-time geometry memo for `fg`.
    pub cache: GeometryCache,
}

impl FusionVariant {
    fn materialize(k: &Kernel, plan: FusionPlan) -> FusionVariant {
        let fg = fuse_with_plan(k, &plan).expect("enumerated fusion plans are legal");
        let cache = GeometryCache::new(k, &fg);
        FusionVariant { plan, fg, cache }
    }
}

/// The kernel's explorable fusion space: every legal variant between
/// full fission and max output-stationary fusion — including partial
/// (loop-range) fusions with their peeled sub-tasks and cross-array
/// merges of unifying sibling nests — variant 0 always the max-fusion
/// plan. The solver's outer loop iterates these; the service layer
/// builds one space per kernel and shares it across requests.
#[derive(Debug, Clone)]
pub struct FusionSpace {
    /// The legal variants, variant 0 always the max-fusion plan.
    pub variants: Vec<FusionVariant>,
}

impl FusionSpace {
    /// The full legal fusion space of `k` (variant 0 = max fusion).
    pub fn enumerate(k: &Kernel) -> FusionSpace {
        FusionSpace {
            variants: enumerate_fusions(k)
                .into_iter()
                .map(|p| FusionVariant::materialize(k, p))
                .collect(),
        }
    }

    /// The single-variant (fixed max-fusion) space — pre-fusion-DSE
    /// behaviour, used by the baselines and `explore_fusion = false`.
    pub fn fixed(k: &Kernel) -> FusionSpace {
        FusionSpace {
            variants: vec![FusionVariant::materialize(k, FusionPlan::max_fusion(k))],
        }
    }

    /// Build the space a solver run will explore under `explore_fusion`.
    pub fn for_solver(k: &Kernel, explore_fusion: bool) -> FusionSpace {
        if explore_fusion {
            FusionSpace::enumerate(k)
        } else {
            FusionSpace::fixed(k)
        }
    }

    /// Index of the variant realizing `plan`, if it is in this space.
    pub fn variant_of(&self, plan: &FusionPlan) -> Option<usize> {
        self.variants.iter().position(|v| &v.plan == plan)
    }

    /// Remove and return variant `i` (drops the rest of the space) —
    /// the flow uses this to hand the winning variant's graph and cache
    /// onward without cloning them.
    pub fn take_variant(&mut self, i: usize) -> FusionVariant {
        self.variants.swap_remove(i)
    }
}

/// One array's transfer plan after resolution: levels clamped into the
/// task's level range, defaults filled in, and the plan-dependent
/// geometry precomputed. This is the *only* place in the codebase where
/// plans are defaulted and clamped — every consumer reads these fields.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResolvedPlan {
    /// Define level, clamped to `0..levels`.
    pub define_level: usize,
    /// Transfer level, clamped to `0..levels`.
    pub transfer_level: usize,
    /// Selected burst width in bits (Eq 3).
    pub bitwidth: u64,
    /// Number of ping-pong buffers (1 = no overlap, 2/3 = double/triple).
    pub buffers: u64,
    /// Data-tile extents at the define level (paper `f_{a,l}`).
    pub tile_dims: Vec<u64>,
    /// Product of `tile_dims` (1 for zero-rank tiles).
    pub tile_elems: u64,
    /// Bytes of one define-level tile (0 for zero-rank tiles).
    pub tile_bytes: u64,
    /// How many times the define-level transfer executes.
    pub transfer_count: u64,
    /// Array partitioning factor (Eq 8): product of the intra factors
    /// of the loops indexing the array.
    pub partitions: u64,
}

impl ResolvedPlan {
    /// The plan as the (clamped) decision-variable tuple.
    pub fn as_plan(&self) -> TransferPlan {
        TransferPlan {
            define_level: self.define_level,
            transfer_level: self.transfer_level,
            bitwidth: self.bitwidth,
            buffers: self.buffers,
        }
    }
}

/// One fused task under a concrete [`TaskConfig`], fully resolved:
/// permuted loop orders, per-level transfer counts and one
/// [`ResolvedPlan`] per array. Constructed once per candidate and read
/// by every consumer.
pub struct ResolvedTask<'a> {
    /// The underlying tile geometry (permuted orders, tile math).
    pub geo: TaskGeometry<'a>,
    /// Per-array resolved plans, parallel to `statics().arrays`.
    pub plans: Vec<ResolvedPlan>,
    /// Output tile steps = product of all non-reduction inter trips.
    pub steps: u64,
    /// `transfer_counts[l]` = executions of a level-`l` transfer.
    pub transfer_counts: Vec<u64>,
}

impl<'a> ResolvedTask<'a> {
    /// The fusion-time statics this resolution reads from.
    pub fn statics(&self) -> &'a TaskStatics {
        self.geo.st
    }

    /// The task configuration this resolution was built for.
    pub fn cfg(&self) -> &'a TaskConfig {
        self.geo.cfg
    }

    /// Number of transfer levels: 0 (before loops) ..= nonred.len().
    pub fn levels(&self) -> usize {
        self.geo.levels()
    }

    /// Iterate (array statics, resolved plan) pairs, first-touch order.
    pub fn arrays(&self) -> impl Iterator<Item = (&ArrayStatics, &ResolvedPlan)> + '_ {
        self.geo.st.arrays.iter().zip(self.plans.iter())
    }

    /// The (statics, resolved plan) pair of array `name`.
    pub fn plan_for(&self, name: &str) -> Option<(&ArrayStatics, &ResolvedPlan)> {
        self.geo.st.array_pos(name).map(|i| (&self.geo.st.arrays[i], &self.plans[i]))
    }
}

/// Build the default transfer plan for `a` at `level`: define and
/// transfer at `level`, buffers = 2 (read xor write) or 3 (both),
/// natural bit width (Eq 3). Consumers never call this directly —
/// [`resolve_task`] applies it to every array without an explicit plan.
pub fn default_plan(geo: &TaskGeometry, a: &ArrayStatics, level: usize) -> TransferPlan {
    let rw = a.writes && a.reads;
    TransferPlan {
        define_level: level,
        transfer_level: level,
        bitwidth: geo.natural_bitwidth_at(a, level),
        buffers: if rw { 3 } else { 2 },
    }
}

/// The transfer-plan candidates the solver's coordinate descent scores
/// for one array: the diagonal plans (define = transfer at each level)
/// plus, per non-deepest level, the reuse plan that buffers at the
/// level but streams at the deepest level.
pub fn plan_options(geo: &TaskGeometry, a: &ArrayStatics) -> Vec<TransferPlan> {
    let levels = geo.levels();
    let mut options = Vec::with_capacity(2 * levels);
    for l in 0..levels {
        options.push(default_plan(geo, a, l));
        if l + 1 < levels {
            let mut p = default_plan(geo, a, l);
            p.transfer_level = levels - 1;
            options.push(p);
        }
    }
    options
}

/// Resolve one task configuration against its fusion-time statics: the
/// single construction every consumer's numbers derive from.
pub fn resolve_task<'a>(
    k: &'a Kernel,
    st: &'a TaskStatics,
    cfg: &'a TaskConfig,
) -> ResolvedTask<'a> {
    let geo = TaskGeometry::new(k, st, cfg);
    let levels = geo.levels();
    let transfer_counts: Vec<u64> = (0..levels).map(|l| geo.transfer_count(l)).collect();
    let steps = transfer_counts[levels - 1].max(1);
    let plans: Vec<ResolvedPlan> = st
        .arrays
        .iter()
        .map(|a| {
            let plan = cfg
                .plans
                .get(a.name.as_str())
                .copied()
                .unwrap_or_else(|| default_plan(&geo, a, levels - 1));
            let d = plan.define_level.min(levels - 1);
            let t = plan.transfer_level.min(levels - 1);
            let tile_dims = geo.tile_dims_at(a, d);
            let tile_elems: u64 = tile_dims.iter().product();
            let tile_bytes =
                if tile_dims.is_empty() { 0 } else { tile_elems * a.elem_bytes };
            let partitions: u64 = a
                .access
                .iter()
                .map(|p| p.map(|p| cfg.intra[p]).unwrap_or(1))
                .product();
            ResolvedPlan {
                define_level: d,
                transfer_level: t,
                bitwidth: plan.bitwidth,
                buffers: plan.buffers,
                tile_dims,
                tile_elems,
                tile_bytes,
                transfer_count: transfer_counts[d],
                partitions,
            }
        })
        .collect();
    ResolvedTask { geo, plans, steps, transfer_counts }
}

/// Reusable resolution buffers for one (fusion variant, task) of the
/// solver's stage-1/2 enumeration: everything [`resolve_task`] would
/// allocate per candidate — the permuted order vectors, the per-level
/// transfer counts, and one [`ResolvedPlan`] (tile-dims buffer
/// included) per array — allocated once and rewritten in place, with
/// **incremental** recomputation keyed on what actually changed since
/// the previously resolved point.
///
/// Protocol (enforced by the borrow checker where possible):
///
/// 1. [`ResolveArena::resolve`] lends the buffers to the returned
///    [`ResolvedTask`] (no copy); while it is alive the config cannot
///    be mutated.
/// 2. [`ResolveArena::reclaim`] takes the buffers back and marks them
///    as reflecting the config as of that resolve. Skipping `reclaim`
///    is safe — the next `resolve` falls back to a full rebuild.
/// 3. `changed_from` is the first representative-nest position whose
///    `(intra, padded_trip)` pair differs from the previously resolved
///    config (the nest length when no factor changed): positions before
///    it MUST be unchanged, positions at or after it may have changed
///    arbitrarily. The solver's Cartesian scan varies the deepest
///    position fastest, so consecutive points share a long unchanged
///    prefix and only downstream geometry is recomputed. Transfer-plan
///    changes need no signalling — they are detected by comparing the
///    stored resolution against the config's current plans.
/// 4. Any **permutation** change (or pointing the arena at a different
///    task) must call [`ResolveArena::invalidate`] first: `nonred`/
///    `red` and every per-array depth decision are retained across the
///    points of one permutation.
///
/// Resolution through the arena is byte-identical to [`resolve_task`]:
/// `tests/solver_stage12.rs` pins incremental-vs-fresh equality over a
/// sampled config grid for every (kernel, variant, task) of the zoo.
#[derive(Debug, Default)]
pub struct ResolveArena {
    ready: bool,
    nonred: Vec<usize>,
    red: Vec<usize>,
    transfer_counts: Vec<u64>,
    plans: Vec<ResolvedPlan>,
    // Whether each array's stored resolution came from an explicit
    // config plan (vs the defaulting path): an explicit→default flip
    // with an unchanged define level would otherwise retain the
    // explicit bit width where the default path derives the natural
    // one. Stays in the arena (not lent out with the ResolvedTask).
    was_explicit: Vec<bool>,
}

impl ResolveArena {
    /// Empty arena; buffers grow on first use.
    pub fn new() -> ResolveArena {
        ResolveArena::default()
    }

    /// Forget the retained geometry: the next [`ResolveArena::resolve`]
    /// rebuilds everything (required after a permutation change or a
    /// task switch).
    pub fn invalidate(&mut self) {
        self.ready = false;
    }

    /// Resolve `cfg` against `st`, reusing the retained buffers and
    /// recomputing only geometry downstream of `changed_from` (plus any
    /// array whose transfer plan differs from the stored resolution).
    pub fn resolve<'a>(
        &mut self,
        k: &'a Kernel,
        st: &'a TaskStatics,
        cfg: &'a TaskConfig,
        changed_from: usize,
    ) -> ResolvedTask<'a> {
        let full = !self.ready || self.plans.len() != st.arrays.len();
        self.ready = false;
        let mut nonred = std::mem::take(&mut self.nonred);
        let mut red = std::mem::take(&mut self.red);
        if full {
            nonred.clear();
            red.clear();
            for &p in &cfg.perm {
                if st.red_mask[p] {
                    red.push(p);
                } else {
                    nonred.push(p);
                }
            }
        }
        let geo = TaskGeometry { k, st, cfg, nonred, red };
        let levels = geo.levels();
        // Transfer counts are a running product over ≤ nest-depth
        // levels: always recomputed (cheap scalars), never reallocated.
        let mut transfer_counts = std::mem::take(&mut self.transfer_counts);
        transfer_counts.clear();
        let mut running = 1u64;
        transfer_counts.push(1);
        for &p in &geo.nonred {
            running *= cfg.inter_trip(p);
            transfer_counts.push(running);
        }
        debug_assert_eq!(transfer_counts.len(), levels);
        let steps = transfer_counts[levels - 1].max(1);
        let mut plans = std::mem::take(&mut self.plans);
        if full {
            // Keep existing per-array entries (their tile-dims buffers
            // are reusable); add stale placeholders as needed.
            plans.truncate(st.arrays.len());
            while plans.len() < st.arrays.len() {
                plans.push(ResolvedPlan {
                    define_level: usize::MAX,
                    transfer_level: 0,
                    bitwidth: 0,
                    buffers: 0,
                    tile_dims: Vec::new(),
                    tile_elems: 0,
                    tile_bytes: 0,
                    transfer_count: 0,
                    partitions: 0,
                });
            }
        }
        self.was_explicit.resize(st.arrays.len(), true);
        for (ai, (a, rp)) in st.arrays.iter().zip(plans.iter_mut()).enumerate() {
            let explicit = cfg.plans.get(a.name.as_str()).copied();
            let (d, t) = match &explicit {
                Some(p) => (p.define_level.min(levels - 1), p.transfer_level.min(levels - 1)),
                None => (levels - 1, levels - 1),
            };
            // The expensive part — tile extents and the partition
            // product — is stale iff the define level moved, any
            // accessed position sits at/after the first changed one, or
            // the plan source flipped between explicit and defaulted.
            let stale = full
                || rp.define_level != d
                || self.was_explicit[ai] != explicit.is_some()
                || a.access.iter().flatten().any(|&p| p >= changed_from);
            if stale {
                geo.tile_dims_into(a, d, &mut rp.tile_dims);
                rp.tile_elems = rp.tile_dims.iter().product();
                rp.tile_bytes =
                    if rp.tile_dims.is_empty() { 0 } else { rp.tile_elems * a.elem_bytes };
                rp.partitions = a
                    .access
                    .iter()
                    .map(|p| p.map(|p| cfg.intra[p]).unwrap_or(1))
                    .product();
            }
            match explicit {
                Some(p) => {
                    rp.bitwidth = p.bitwidth;
                    rp.buffers = p.buffers;
                }
                None => {
                    // Defaulted plan (Eq 3 natural width): its input is
                    // the deepest tile's last extent, which only moves
                    // when the tile itself did.
                    if stale {
                        rp.bitwidth = geo.natural_bitwidth_at(a, d);
                    }
                    rp.buffers = if a.writes && a.reads { 3 } else { 2 };
                }
            }
            rp.define_level = d;
            rp.transfer_level = t;
            rp.transfer_count = transfer_counts[d];
            self.was_explicit[ai] = explicit.is_some();
        }
        ResolvedTask { geo, plans, steps, transfer_counts }
    }

    /// Take the buffers back from a finished [`ResolvedTask`] and mark
    /// them as reflecting the config it was resolved for.
    pub fn reclaim(&mut self, rt: ResolvedTask<'_>) {
        let TaskGeometry { nonred, red, .. } = rt.geo;
        self.nonred = nonred;
        self.red = red;
        self.transfer_counts = rt.transfer_counts;
        self.plans = rt.plans;
        self.ready = true;
    }
}

/// A complete design resolved against one kernel: one [`ResolvedTask`]
/// per task config, plus the graph context every DAG-level consumer
/// needs. Constructed once per candidate design, consumed by
/// `graph_latency`, `feasible`/`slr_usage`, `simulate`, `board_eval`
/// and `generate_hls`.
pub struct ResolvedDesign<'a> {
    /// The kernel the design optimizes.
    pub k: &'a Kernel,
    /// The fused-task graph of the design's own fusion variant.
    pub fg: &'a FusedGraph,
    /// The design being resolved.
    pub design: &'a DesignConfig,
    /// Indexed by **task id** (`tasks[i].cfg().task == i`), regardless
    /// of the order `design.tasks` was stored in — graph-level
    /// consumers index by id, and persisted designs (QoR DB) are not
    /// guaranteed to list their tasks in id order.
    pub tasks: Vec<ResolvedTask<'a>>,
}

impl<'a> ResolvedDesign<'a> {
    /// Resolve `design` against its fusion variant's graph and cache.
    pub fn new(
        k: &'a Kernel,
        fg: &'a FusedGraph,
        cache: &'a GeometryCache,
        design: &'a DesignConfig,
    ) -> ResolvedDesign<'a> {
        let mut tasks: Vec<ResolvedTask<'a>> = design
            .tasks
            .iter()
            .map(|tc| resolve_task(k, &cache.tasks[tc.task], tc))
            .collect();
        tasks.sort_by_key(|rt| rt.geo.cfg.task);
        ResolvedDesign { k, fg, design, tasks }
    }

    /// The resolved task with id `t`.
    pub fn task(&self, t: usize) -> &ResolvedTask<'a> {
        &self.tasks[t]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::fusion::fuse;
    use crate::dse::config::ExecutionModel;
    use crate::ir::polybench;
    use std::collections::BTreeMap;

    /// The paper's Listing-6 FT0 config for 3mm (see space.rs tests).
    fn ft0_cfg() -> TaskConfig {
        let mut plans = BTreeMap::new();
        plans.insert(
            "B".into(),
            TransferPlan { define_level: 0, transfer_level: 0, bitwidth: 512, buffers: 2 },
        );
        plans.insert(
            "A".into(),
            TransferPlan { define_level: 1, transfer_level: 1, bitwidth: 512, buffers: 2 },
        );
        plans.insert(
            "E".into(),
            TransferPlan { define_level: 2, transfer_level: 2, bitwidth: 512, buffers: 3 },
        );
        TaskConfig {
            task: 0,
            perm: vec![0, 1, 2],
            padded_trip: vec![180, 192, 204],
            intra: vec![10, 32, 4],
            ii: 3,
            plans,
            slr: 0,
        }
    }

    #[test]
    fn statics_memoize_fusion_facts() {
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        assert_eq!(cache.tasks.len(), 3);
        let ft0 = &cache.tasks[0];
        assert_eq!(ft0.rep, 1);
        assert_eq!(ft0.red_mask, [false, false, true]);
        assert_eq!(ft0.stmts, [0, 1]);
        assert_eq!(ft0.output, "E");
        assert!(ft0.has_init);
        // 2 non-reduction loops -> 2 legal orders, k pinned innermost
        assert_eq!(ft0.orders.len(), 2);
        for o in &ft0.orders {
            assert_eq!(*o.last().unwrap(), 2);
        }
        // E is written by S0 (init, loops i,j) and S1; the access memo
        // resolves through the representative nest.
        let e = ft0.array("E").unwrap();
        assert_eq!(e.access, [Some(0), Some(1)]);
        assert!(e.writes && e.reads);
        let a = ft0.array("A").unwrap();
        assert!(a.reads && !a.writes);
        assert!(a.is_input);
        // FT2 ingests E over a FIFO from FT0
        let e_in_ft2 = cache.tasks[2].array("E").unwrap();
        assert_eq!(e_in_ft2.fifo_producer, Some(0));
        assert_eq!(ft0.array("E").unwrap().fifo_producer, None);
        // FT0 emits E (180x190 elements) downstream
        assert_eq!(ft0.fifo_out_total_elems(), 180 * 190);
        assert_eq!(ft0.fifo_out_elems_by_array, vec![("E".to_string(), 180 * 190)]);
    }

    #[test]
    fn resolution_precomputes_listing6_tiles() {
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let cfg = ft0_cfg();
        let rt = resolve_task(&k, &cache.tasks[0], &cfg);
        assert_eq!(rt.levels(), 3);
        assert_eq!(rt.transfer_counts, [1, 18, 108]);
        assert_eq!(rt.steps, 108);
        let (b, bp) = rt.plan_for("B").unwrap();
        assert!(b.is_input);
        assert_eq!(bp.tile_dims, [204, 192]);
        assert_eq!(bp.tile_bytes, 204 * 192 * 4);
        assert_eq!(bp.transfer_count, 1);
        let (_, ap) = rt.plan_for("A").unwrap();
        assert_eq!(ap.tile_dims, [10, 204]);
        assert_eq!(ap.transfer_count, 18);
        let (_, ep) = rt.plan_for("E").unwrap();
        assert_eq!(ep.tile_dims, [10, 32]);
        assert_eq!(ep.transfer_count, 108);
        assert_eq!(ep.buffers, 3);
        // Eq 8: partitions = product of intra factors on indexed dims
        assert_eq!(ap.partitions, 10 * 4);
        assert_eq!(ep.partitions, 10 * 32);
    }

    #[test]
    fn missing_plans_default_to_deepest_level() {
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let mut cfg = ft0_cfg();
        cfg.plans.clear();
        let rt = resolve_task(&k, &cache.tasks[0], &cfg);
        for (a, rp) in rt.arrays() {
            assert_eq!(rp.define_level, rt.levels() - 1, "{}", a.name);
            assert_eq!(rp.transfer_level, rt.levels() - 1, "{}", a.name);
            // read xor write -> 2 buffers, read and write -> 3
            let expect = if a.reads && a.writes { 3 } else { 2 };
            assert_eq!(rp.buffers, expect, "{}", a.name);
        }
    }

    #[test]
    fn out_of_range_levels_are_clamped() {
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let mut cfg = ft0_cfg();
        cfg.plans.insert(
            "A".into(),
            TransferPlan { define_level: 9, transfer_level: 9, bitwidth: 128, buffers: 2 },
        );
        let rt = resolve_task(&k, &cache.tasks[0], &cfg);
        let (_, ap) = rt.plan_for("A").unwrap();
        assert_eq!(ap.define_level, rt.levels() - 1);
        assert_eq!(ap.transfer_level, rt.levels() - 1);
        assert_eq!(ap.bitwidth, 128, "explicit bit width survives clamping");
    }

    #[test]
    fn plan_options_cover_diagonal_and_reuse() {
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let cfg = ft0_cfg();
        let geo = TaskGeometry::new(&k, &cache.tasks[0], &cfg);
        let a = cache.tasks[0].array("A").unwrap();
        let opts = plan_options(&geo, a);
        // levels = 3: diagonal plans at 0,1,2 + reuse plans at 0,1
        assert_eq!(opts.len(), 5);
        for p in &opts {
            assert!(p.define_level <= p.transfer_level, "{p:?}");
            assert!(p.validate().is_ok(), "{p:?}");
        }
        assert!(opts.iter().any(|p| p.define_level == 0 && p.transfer_level == 2));
    }

    #[test]
    fn resolved_design_parallels_config() {
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let design = DesignConfig {
            kernel: k.name.clone(),
            model: ExecutionModel::Dataflow,
            overlap: true,
            fusion: fg.plan(),
            tasks: (0..3)
                .map(|t| {
                    let rep = fg.tasks[t].representative(&k);
                    let nest = &k.statements[rep].loops;
                    TaskConfig {
                        task: t,
                        perm: (0..nest.len()).collect(),
                        padded_trip: nest.iter().map(|l| l.trip).collect(),
                        intra: vec![1; nest.len()],
                        ii: 3,
                        plans: BTreeMap::new(),
                        slr: 0,
                    }
                })
                .collect(),
        };
        let rd = ResolvedDesign::new(&k, &fg, &cache, &design);
        assert_eq!(rd.tasks.len(), 3);
        for (rt, tc) in rd.tasks.iter().zip(&design.tasks) {
            assert_eq!(rt.cfg().task, tc.task);
            assert_eq!(rt.plans.len(), rt.statics().arrays.len());
        }
        // a persisted design may store its tasks out of id order; the
        // resolved view is id-indexed regardless
        let mut shuffled = design.clone();
        shuffled.tasks.reverse();
        let rd2 = ResolvedDesign::new(&k, &fg, &cache, &shuffled);
        for (i, rt) in rd2.tasks.iter().enumerate() {
            assert_eq!(rt.cfg().task, i);
        }
    }

    #[test]
    fn fusion_space_shapes() {
        // single-variant kernel: enumerate == fixed
        let gemm = polybench::gemm();
        let space = FusionSpace::enumerate(&gemm);
        assert_eq!(space.variants.len(), 1);
        assert_eq!(space.variants[0].plan, FusionPlan::max_fusion(&gemm));
        assert_eq!(FusionSpace::fixed(&gemm).variants.len(), 1);
        // multi-variant kernel: max fusion leads, lookups resolve, and
        // take_variant hands out the matching graph + cache
        let gemver = polybench::gemver();
        let mut space = FusionSpace::enumerate(&gemver);
        assert_eq!(space.variants.len(), 2);
        assert_eq!(space.variants[0].plan, FusionPlan::max_fusion(&gemver));
        let split = space.variants[1].plan.clone();
        assert_eq!(space.variant_of(&split), Some(1));
        assert_eq!(space.variant_of(&FusionPlan::new(vec![vec![0]])), None);
        let v = space.take_variant(1);
        assert_eq!(v.plan, split);
        assert_eq!(v.fg.plan(), split);
        assert_eq!(v.cache.tasks.len(), v.fg.tasks.len());
        assert_eq!(FusionSpace::for_solver(&gemver, false).variants.len(), 1);
        assert_eq!(FusionSpace::for_solver(&gemver, true).variants.len(), 2);
    }

    /// One resolved view compared field-wise (ResolvedTask itself is
    /// borrow-laden and deliberately not PartialEq).
    fn assert_same(inc: &ResolvedTask, fresh: &ResolvedTask) {
        assert_eq!(inc.plans, fresh.plans);
        assert_eq!(inc.transfer_counts, fresh.transfer_counts);
        assert_eq!(inc.steps, fresh.steps);
        assert_eq!(inc.geo.nonred, fresh.geo.nonred);
        assert_eq!(inc.geo.red, fresh.geo.red);
    }

    #[test]
    fn arena_matches_fresh_resolution_incrementally() {
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let st = &cache.tasks[0];
        let mut arena = ResolveArena::new();
        let mut cfg = ft0_cfg();
        // Walk a small factor grid under one permutation, deepest
        // position varying fastest like the solver's enum_factors, with
        // changed_from computed by comparison like the solver does.
        let mut prev: Option<Vec<u64>> = None;
        for i in [1u64, 2, 10] {
            for j in [1u64, 32] {
                for kk in [2u64, 4] {
                    cfg.intra = vec![i, j, kk];
                    let changed = match &prev {
                        Some(pi) => {
                            (0..3).find(|&x| cfg.intra[x] != pi[x]).unwrap_or(3)
                        }
                        None => 0,
                    };
                    let fresh = resolve_task(&k, st, &cfg);
                    let inc = arena.resolve(&k, st, &cfg, changed);
                    assert_same(&inc, &fresh);
                    arena.reclaim(inc);
                    prev = Some(cfg.intra.clone());
                }
            }
        }
        // A permutation change requires invalidation.
        cfg.perm = vec![1, 0, 2];
        arena.invalidate();
        let fresh = resolve_task(&k, st, &cfg);
        let inc = arena.resolve(&k, st, &cfg, 0);
        assert_same(&inc, &fresh);
        arena.reclaim(inc);
        // Stage-2-style plan switch with no factor change: detected by
        // comparing stored resolutions, no changed_from signal needed.
        cfg.plans.insert(
            "A".into(),
            TransferPlan { define_level: 0, transfer_level: 2, bitwidth: 128, buffers: 2 },
        );
        let fresh = resolve_task(&k, st, &cfg);
        let inc = arena.resolve(&k, st, &cfg, 3);
        assert_same(&inc, &fresh);
        arena.reclaim(inc);
        // Explicit → defaulted flip with an unchanged define level must
        // re-derive the natural bit width (the was_explicit guard).
        cfg.plans.clear();
        let fresh = resolve_task(&k, st, &cfg);
        let inc = arena.resolve(&k, st, &cfg, 3);
        assert_same(&inc, &fresh);
        arena.reclaim(inc);
    }
}
