//! Tile geometry: the pure per-task tile math (paper §3.3–3.5) that the
//! evaluation core ([`super::eval`]) builds [`ResolvedTask`]s from.
//!
//! For a fused task, the generated loop structure is:
//!
//! ```text
//! [level-0 transfers]                       // t_{a,0}: before any loop
//! for nonred[0] (inter)                     // level 1 transfers inside
//!   for nonred[1] (inter)                   // level 2 transfers inside
//!     ...
//!     init-task (intra, fully unrolled)
//!     for red (inter, pipelined II)
//!       compute-task (intra, fully unrolled)
//!     store/send of the output tile
//! ```
//!
//! An array transferred at level `l` moves one *data tile* per iteration
//! of the enclosing loops; its tile covers everything accessed deeper
//! than `l`.
//!
//! [`TaskGeometry`] answers only configuration-geometry questions (tile
//! dims, transfer counts, natural bit widths) against the fusion-time
//! [`TaskStatics`] memo. It does **not** resolve transfer plans: plan
//! defaulting and level clamping live in exactly one place,
//! [`super::eval`], and downstream consumers (cost model, constraints,
//! simulator, codegen) read the precomputed [`ResolvedTask`] instead of
//! re-deriving geometry per evaluation.
//!
//! [`ResolvedTask`]: super::eval::ResolvedTask
//! [`TaskStatics`]: super::eval::TaskStatics

use super::config::TaskConfig;
use super::eval::{ArrayStatics, TaskStatics};
use super::padding::best_bitwidth;
use crate::ir::{Kernel, Statement};

/// Tile geometry of one fused task under a given configuration, built
/// from the fusion-time statics (no per-evaluation string lookups).
pub struct TaskGeometry<'a> {
    pub k: &'a Kernel,
    pub st: &'a TaskStatics,
    pub cfg: &'a TaskConfig,
    /// Non-reduction inter-tile loop positions, permuted (outer→inner).
    pub nonred: Vec<usize>,
    /// Reduction loop positions, permuted order (outer→inner).
    pub red: Vec<usize>,
}

impl<'a> TaskGeometry<'a> {
    pub fn new(k: &'a Kernel, st: &'a TaskStatics, cfg: &'a TaskConfig) -> Self {
        let nonred = cfg.nonred_order(&st.red_mask);
        let red = cfg.red_order(&st.red_mask);
        TaskGeometry { k, st, cfg, nonred, red }
    }

    /// Representative statement.
    pub fn rep_stmt(&self) -> &'a Statement {
        &self.k.statements[self.st.rep]
    }

    /// Number of transfer levels: 0 (before loops) ..= nonred.len().
    pub fn levels(&self) -> usize {
        self.nonred.len() + 1
    }

    /// Depth of loop position `p` in the generated structure: place in
    /// the permuted non-reduction order (1-based level), or
    /// `nonred.len() + 1 + rank` for reduction loops (they sit inside all
    /// non-reduction levels). Public so the evaluation core's arena can
    /// answer single-dimension geometry questions without materializing
    /// a tile vector.
    pub fn depth_of(&self, p: usize) -> usize {
        if let Some(place) = self.nonred.iter().position(|&q| q == p) {
            place + 1
        } else {
            let rank = self.red.iter().position(|&q| q == p).unwrap_or(0);
            self.nonred.len() + 1 + rank
        }
    }

    /// Extent of each dimension of `a`'s data tile when transferred at
    /// `level` (paper `f_{a,l}`): dimensions indexed by loops strictly
    /// deeper than the transfer point span the full padded extent;
    /// dimensions whose loop is at or outside the transfer point span
    /// only the intra-tile factor. Unindexed dims span fully.
    pub fn tile_dims_at(&self, a: &ArrayStatics, level: usize) -> Vec<u64> {
        let mut dims = Vec::with_capacity(a.access.len());
        self.tile_dims_into(a, level, &mut dims);
        dims
    }

    /// In-place variant of [`Self::tile_dims_at`]: clears `out` and
    /// fills it with the tile extents, so the evaluation core's arena
    /// can rewrite a retained buffer instead of allocating per point.
    pub fn tile_dims_into(&self, a: &ArrayStatics, level: usize, out: &mut Vec<u64>) {
        out.clear();
        out.extend(a.access.iter().enumerate().map(|(d, rep_pos)| match rep_pos {
            Some(p) => {
                if self.depth_of(*p) > level {
                    // loop iterates inside the transfer point: tile
                    // spans the whole (padded) extent of this dim
                    self.cfg.padded_trip[*p]
                } else {
                    self.cfg.intra[*p]
                }
            }
            None => a.dims[d],
        }));
    }

    /// The last entry of [`Self::tile_dims_at`] without materializing
    /// the vector — the only tile fact the natural-bit-width selection
    /// (Eq 3) needs, and the scalar the arena's incremental default-plan
    /// path recomputes per point.
    pub fn last_tile_dim(&self, a: &ArrayStatics, level: usize) -> Option<u64> {
        let d = a.access.len().checked_sub(1)?;
        Some(match a.access[d] {
            Some(p) => {
                if self.depth_of(p) > level {
                    self.cfg.padded_trip[p]
                } else {
                    self.cfg.intra[p]
                }
            }
            None => a.dims[d],
        })
    }

    /// Bytes of one data tile of `a` at `level`.
    pub fn tile_bytes_at(&self, a: &ArrayStatics, level: usize) -> u64 {
        if a.access.is_empty() {
            return 0;
        }
        let elems: u64 = self.tile_dims_at(a, level).iter().product();
        elems * a.elem_bytes
    }

    /// How many times a transfer at `level` executes = product of inter
    /// trips of the enclosing non-reduction loops (levels 1..=level).
    pub fn transfer_count(&self, level: usize) -> u64 {
        self.nonred
            .iter()
            .take(level)
            .map(|&p| self.cfg.inter_trip(p))
            .product()
    }

    /// Natural bit width for `a` transferred at `level` (Eq 3): widest
    /// power-of-two burst whose element count divides the tile's last
    /// dimension.
    pub fn natural_bitwidth_at(&self, a: &ArrayStatics, level: usize) -> u64 {
        let Some(last) = self.last_tile_dim(a, level) else { return 32 };
        best_bitwidth(last, a.elem_bits, 512)
    }

    /// By-name variant of [`Self::tile_dims_at`] (tests, reports).
    pub fn tile_dims(&self, name: &str, level: usize) -> Vec<u64> {
        self.st
            .array(name)
            .map(|a| self.tile_dims_at(a, level))
            .unwrap_or_default()
    }

    /// By-name variant of [`Self::natural_bitwidth_at`].
    pub fn natural_bitwidth(&self, name: &str, level: usize) -> u64 {
        self.st
            .array(name)
            .map(|a| self.natural_bitwidth_at(a, level))
            .unwrap_or(32)
    }

    /// Intra-tile instances of the representative statement = unroll
    /// factor; instances including padding waste.
    pub fn padded_instances(&self) -> u64 {
        self.cfg.padded_trip.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::super::config::TransferPlan;
    use super::super::eval::GeometryCache;
    use super::*;
    use crate::analysis::fusion::fuse;
    use crate::ir::polybench;
    use std::collections::BTreeMap;

    /// Build the paper's Listing-6 FT0 config for 3mm: loops (i,j,k),
    /// padded (180,192,204), intra (10,32,4), B at level 0, A at level 1,
    /// E defined+stored at level 2.
    fn ft0_cfg() -> TaskConfig {
        let mut plans = BTreeMap::new();
        plans.insert(
            "B".into(),
            TransferPlan { define_level: 0, transfer_level: 0, bitwidth: 512, buffers: 2 },
        );
        plans.insert(
            "A".into(),
            TransferPlan { define_level: 1, transfer_level: 1, bitwidth: 512, buffers: 2 },
        );
        plans.insert(
            "E".into(),
            TransferPlan { define_level: 2, transfer_level: 2, bitwidth: 512, buffers: 3 },
        );
        TaskConfig {
            task: 0,
            perm: vec![0, 1, 2],
            padded_trip: vec![180, 192, 204],
            intra: vec![10, 32, 4],
            ii: 3,
            plans,
            slr: 0,
        }
    }

    #[test]
    fn listing6_ft0_tiles() {
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let cfg = ft0_cfg();
        let geo = TaskGeometry::new(&k, &cache.tasks[0], &cfg);
        assert_eq!(geo.st.rep, 1);
        assert_eq!(geo.nonred, vec![0, 1]);
        assert_eq!(geo.red, vec![2]);
        // B[k][j] at level 0: full padded extents = 204 x 192 (Listing 6 l.2)
        assert_eq!(geo.tile_dims("B", 0), vec![204, 192]);
        // A[i][k] at level 1 (under i0): intra_i x padded_k = 10 x 204 (l.4)
        assert_eq!(geo.tile_dims("A", 1), vec![10, 204]);
        // E[i][j] at level 2 (under j0): 10 x 32 (l.6)
        assert_eq!(geo.tile_dims("E", 2), vec![10, 32]);
        // transfer counts: level 0 once; level 1 per i0 (18); level 2 per
        // i0*j0 (18*6)
        assert_eq!(geo.transfer_count(0), 1);
        assert_eq!(geo.transfer_count(1), 18);
        assert_eq!(geo.transfer_count(2), 108);
    }

    #[test]
    fn natural_bitwidths() {
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let cfg = ft0_cfg();
        let geo = TaskGeometry::new(&k, &cache.tasks[0], &cfg);
        // B tile last dim 192 = 16*12 -> full 512-bit
        assert_eq!(geo.natural_bitwidth("B", 0), 512);
        // A tile last dim 204 = 4*51 -> 4 floats = 128 bits
        assert_eq!(geo.natural_bitwidth("A", 1), 128);
        // E tile last dim 32 -> 512
        assert_eq!(geo.natural_bitwidth("E", 2), 512);
    }

    #[test]
    fn permuted_depths() {
        // With perm (j,i,k) the level-1 loop is j: a tile of A[i][k] at
        // level 1 spans full i and k (i is deeper).
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let mut cfg = ft0_cfg();
        cfg.perm = vec![1, 0, 2];
        let geo = TaskGeometry::new(&k, &cache.tasks[0], &cfg);
        assert_eq!(geo.nonred, vec![1, 0]);
        assert_eq!(geo.tile_dims("A", 1), vec![180, 204]);
        // E under level 2 (now i0 inner): intra_i x intra_j
        assert_eq!(geo.tile_dims("E", 2), vec![10, 32]);
    }
}
