//! Tile geometry: the bridge between a [`TaskConfig`] and everything that
//! consumes it (cost model, resource constraints, simulator, codegen).
//!
//! For a fused task, the generated loop structure is (§3.3–3.5):
//!
//! ```text
//! [level-0 transfers]                       // t_{a,0}: before any loop
//! for nonred[0] (inter)                     // level 1 transfers inside
//!   for nonred[1] (inter)                   // level 2 transfers inside
//!     ...
//!     init-task (intra, fully unrolled)
//!     for red (inter, pipelined II)
//!       compute-task (intra, fully unrolled)
//!     store/send of the output tile
//! ```
//!
//! An array transferred at level `l` moves one *data tile* per iteration
//! of the enclosing loops; its tile covers everything accessed deeper
//! than `l`.

use super::config::{TaskConfig, TransferPlan};
use super::padding::best_bitwidth;
use crate::analysis::fusion::{ArrayInfo, FusedGraph, FusedTask};
use crate::ir::{Kernel, Statement};
use std::collections::BTreeMap;

/// Resolved geometry of one fused task under a given configuration.
///
/// Construction memoizes everything that is configuration-independent
/// but repeatedly needed by the cost model and constraints (array list,
/// translated accesses, read/write sets) — this is the solver's inner
/// loop, see EXPERIMENTS.md §Perf.
pub struct TaskGeometry<'a> {
    pub kernel: &'a Kernel,
    pub fused: &'a FusedTask,
    pub cfg: &'a TaskConfig,
    /// Representative statement id and its reduction mask.
    pub rep: usize,
    pub red_mask: Vec<bool>,
    /// Non-reduction inter-tile loop positions, permuted (outer→inner).
    pub nonred: Vec<usize>,
    /// Reduction loop positions, permuted order (outer→inner).
    pub red: Vec<usize>,
    /// Memoized per-array info, borrowed from the fused task (built once
    /// at fusion time — the solver constructs a geometry per evaluation).
    cache: &'a [ArrayInfo],
}

impl<'a> TaskGeometry<'a> {
    pub fn new(kernel: &'a Kernel, fg: &'a FusedGraph, cfg: &'a TaskConfig) -> Self {
        let fused = &fg.tasks[cfg.task];
        let rep = fused.representative(kernel);
        let nest = &kernel.statements[rep].loops;
        let red_mask: Vec<bool> = nest.iter().map(|l| l.reduction).collect();
        let nonred = cfg.nonred_order(&red_mask);
        let red = cfg.red_order(&red_mask);
        TaskGeometry {
            kernel,
            fused,
            cfg,
            rep,
            red_mask,
            nonred,
            red,
            cache: &fused.array_info,
        }
    }

    /// Representative statement.
    pub fn rep_stmt(&self) -> &Statement {
        &self.kernel.statements[self.rep]
    }

    /// Number of transfer levels: 0 (before loops) ..= nonred.len().
    pub fn levels(&self) -> usize {
        self.nonred.len() + 1
    }

    /// Map a loop position of statement `sid` onto the representative
    /// nest by iterator name (fused statements share iterators, Eq 4).
    pub fn rep_pos_of(&self, sid: usize, pos: usize) -> Option<usize> {
        let name = &self.kernel.statements[sid].loops[pos].name;
        self.rep_stmt().loops.iter().position(|l| &l.name == name)
    }

    /// The access of array `a` from any statement in this fused task,
    /// with loop positions translated to representative positions
    /// (memoized at construction).
    pub fn access_of(&self, a: &str) -> Option<Vec<Option<usize>>> {
        self.access_ref(a).map(|acc| acc.to_vec())
    }

    /// Borrowing variant of [`Self::access_of`] — no allocation.
    pub fn access_ref(&self, a: &str) -> Option<&[Option<usize>]> {
        self.cache
            .iter()
            .find(|i| i.name == a)
            .map(|i| i.access.as_slice())
    }

    /// The full per-array memo (name, translated access, writes, reads).
    pub fn infos(&self) -> &[ArrayInfo] {
        self.cache
    }

    /// All arrays this fused task touches (reads ∪ writes), deduplicated
    /// in first-touch order (memoized).
    pub fn arrays(&self) -> Vec<String> {
        self.cache.iter().map(|i| i.name.clone()).collect()
    }

    /// Iterate array names without allocating (perf-sensitive callers).
    pub fn array_names(&self) -> impl Iterator<Item = &str> {
        self.cache.iter().map(|i| i.name.as_str())
    }

    /// Whether the task writes `a` (memoized).
    pub fn writes(&self, a: &str) -> bool {
        self.cache.iter().any(|i| i.name == a && i.writes)
    }

    /// Whether the task reads `a` (memoized).
    pub fn reads(&self, a: &str) -> bool {
        self.cache.iter().any(|i| i.name == a && i.reads)
    }

    /// Depth of loop position `p` in the generated structure: place in
    /// the permuted non-reduction order (1-based level), or
    /// `nonred.len() + 1 + rank` for reduction loops (they sit inside all
    /// non-reduction levels).
    fn depth_of(&self, p: usize) -> usize {
        if let Some(place) = self.nonred.iter().position(|&q| q == p) {
            place + 1
        } else {
            let rank = self.red.iter().position(|&q| q == p).unwrap_or(0);
            self.nonred.len() + 1 + rank
        }
    }

    /// Extent of each dimension of array `a`'s data tile when transferred
    /// at `level` (paper `f_{a,l}`): dimensions indexed by loops strictly
    /// deeper than the transfer point span the full padded extent;
    /// dimensions whose loop is at or outside the transfer point span
    /// only the intra-tile factor. Unindexed dims span fully.
    pub fn tile_dims(&self, a: &str, level: usize) -> Vec<u64> {
        let Some(acc) = self.access_ref(a) else {
            return vec![];
        };
        let decl = self.kernel.array(a).expect("declared array");
        acc.iter()
            .enumerate()
            .map(|(d, rep_pos)| match rep_pos {
                Some(p) => {
                    if self.depth_of(*p) > level {
                        // loop iterates inside the transfer point: tile
                        // spans the whole (padded) extent of this dim
                        self.cfg.padded_trip[*p]
                    } else {
                        self.cfg.intra[*p]
                    }
                }
                None => decl.dims[d],
            })
            .collect()
    }

    /// Bytes of one data tile of `a` at `level`.
    pub fn tile_bytes(&self, a: &str, level: usize) -> u64 {
        let dims = self.tile_dims(a, level);
        if dims.is_empty() {
            return 0;
        }
        let elems: u64 = dims.iter().product();
        elems * self.kernel.array(a).map(|d| d.dtype.bytes()).unwrap_or(4)
    }

    /// Tile dims computed from a memoized [`ArrayInfo`] — the
    /// allocation-free fast path used by the cost model and constraints.
    pub fn tile_dims_for(&self, info: &ArrayInfo, level: usize) -> Vec<u64> {
        let decl = self.kernel.array(&info.name).expect("declared array");
        info.access
            .iter()
            .enumerate()
            .map(|(d, rep_pos)| match rep_pos {
                Some(p) => {
                    if self.depth_of(*p) > level {
                        self.cfg.padded_trip[*p]
                    } else {
                        self.cfg.intra[*p]
                    }
                }
                None => decl.dims[d],
            })
            .collect()
    }

    /// Tile bytes from a memoized [`ArrayInfo`] (no name lookups).
    pub fn tile_bytes_for(&self, info: &ArrayInfo, level: usize) -> u64 {
        if info.access.is_empty() {
            return 0;
        }
        let decl = self.kernel.array(&info.name).expect("declared array");
        let elems: u64 = info
            .access
            .iter()
            .enumerate()
            .map(|(d, rep_pos)| match rep_pos {
                Some(p) => {
                    if self.depth_of(*p) > level {
                        self.cfg.padded_trip[*p]
                    } else {
                        self.cfg.intra[*p]
                    }
                }
                None => decl.dims[d],
            })
            .product();
        elems * decl.dtype.bytes()
    }

    /// How many times a transfer at `level` executes = product of inter
    /// trips of the enclosing non-reduction loops (levels 1..=level).
    pub fn transfer_count(&self, level: usize) -> u64 {
        self.nonred
            .iter()
            .take(level)
            .map(|&p| self.cfg.inter_trip(p))
            .product()
    }

    /// Natural bit width for `a` transferred at `level` (Eq 3): widest
    /// power-of-two burst whose element count divides the tile's last
    /// dimension.
    pub fn natural_bitwidth(&self, a: &str, level: usize) -> u64 {
        let dims = self.tile_dims(a, level);
        let Some(&last) = dims.last() else { return 32 };
        let elem_bits = self.kernel.array(a).map(|d| d.dtype.bits()).unwrap_or(32);
        best_bitwidth(last, elem_bits, 512)
    }

    /// Build the default transfer plan for `a`: define and transfer at
    /// `level`, buffers = 2 (read xor write) or 3 (both), natural width.
    pub fn default_plan(&self, a: &str, level: usize) -> TransferPlan {
        let rw = self.writes(a) && self.reads(a);
        TransferPlan {
            define_level: level,
            transfer_level: level,
            bitwidth: self.natural_bitwidth(a, level),
            buffers: if rw { 3 } else { 2 },
        }
    }

    /// Intra-tile instances of the representative statement = unroll
    /// factor; instances including padding waste.
    pub fn padded_instances(&self) -> u64 {
        self.cfg.padded_trip.iter().product()
    }
}

/// Map of array → (tile_bytes, per-level transfer cycles) used by both
/// the cost model and the solver's transfer-plan selection.
pub fn plan_footprints(
    geo: &TaskGeometry,
) -> BTreeMap<String, Vec<u64>> {
    let mut out = BTreeMap::new();
    for a in geo.arrays() {
        let per_level: Vec<u64> =
            (0..geo.levels()).map(|l| geo.tile_bytes(&a, l)).collect();
        out.insert(a, per_level);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::fusion::fuse;
    use crate::ir::polybench;
    use std::collections::BTreeMap;

    /// Build the paper's Listing-6 FT0 config for 3mm: loops (i,j,k),
    /// padded (180,192,204), intra (10,32,4), B at level 0, A at level 1,
    /// E defined+stored at level 2.
    fn ft0_cfg() -> TaskConfig {
        let mut plans = BTreeMap::new();
        plans.insert(
            "B".into(),
            TransferPlan { define_level: 0, transfer_level: 0, bitwidth: 512, buffers: 2 },
        );
        plans.insert(
            "A".into(),
            TransferPlan { define_level: 1, transfer_level: 1, bitwidth: 512, buffers: 2 },
        );
        plans.insert(
            "E".into(),
            TransferPlan { define_level: 2, transfer_level: 2, bitwidth: 512, buffers: 3 },
        );
        TaskConfig {
            task: 0,
            perm: vec![0, 1, 2],
            padded_trip: vec![180, 192, 204],
            intra: vec![10, 32, 4],
            ii: 3,
            plans,
            slr: 0,
        }
    }

    #[test]
    fn listing6_ft0_tiles() {
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let cfg = ft0_cfg();
        let geo = TaskGeometry::new(&k, &fg, &cfg);
        assert_eq!(geo.rep, 1);
        assert_eq!(geo.nonred, vec![0, 1]);
        assert_eq!(geo.red, vec![2]);
        // B[k][j] at level 0: full padded extents = 204 x 192 (Listing 6 l.2)
        assert_eq!(geo.tile_dims("B", 0), vec![204, 192]);
        // A[i][k] at level 1 (under i0): intra_i x padded_k = 10 x 204 (l.4)
        assert_eq!(geo.tile_dims("A", 1), vec![10, 204]);
        // E[i][j] at level 2 (under j0): 10 x 32 (l.6)
        assert_eq!(geo.tile_dims("E", 2), vec![10, 32]);
        // transfer counts: level 0 once; level 1 per i0 (18); level 2 per
        // i0*j0 (18*6)
        assert_eq!(geo.transfer_count(0), 1);
        assert_eq!(geo.transfer_count(1), 18);
        assert_eq!(geo.transfer_count(2), 108);
    }

    #[test]
    fn natural_bitwidths() {
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let cfg = ft0_cfg();
        let geo = TaskGeometry::new(&k, &fg, &cfg);
        // B tile last dim 192 = 16*12 -> full 512-bit
        assert_eq!(geo.natural_bitwidth("B", 0), 512);
        // A tile last dim 204 = 4*51 -> 4 floats = 128 bits
        assert_eq!(geo.natural_bitwidth("A", 1), 128);
        // E tile last dim 32 -> 512
        assert_eq!(geo.natural_bitwidth("E", 2), 512);
    }

    #[test]
    fn init_stmt_access_translates() {
        // E is written by S0 (init, loops i,j) and S1; access must resolve
        // through the representative nest.
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let cfg = ft0_cfg();
        let geo = TaskGeometry::new(&k, &fg, &cfg);
        let acc = geo.access_of("E").unwrap();
        assert_eq!(acc, vec![Some(0), Some(1)]);
        assert!(geo.writes("E"));
        assert!(geo.reads("A"));
        assert!(!geo.writes("A"));
    }

    #[test]
    fn permuted_depths() {
        // With perm (j,i,k) the level-1 loop is j: a tile of A[i][k] at
        // level 1 spans full i and k (i is deeper).
        let k = polybench::three_mm();
        let fg = fuse(&k);
        let mut cfg = ft0_cfg();
        cfg.perm = vec![1, 0, 2];
        let geo = TaskGeometry::new(&k, &fg, &cfg);
        assert_eq!(geo.nonred, vec![1, 0]);
        assert_eq!(geo.tile_dims("A", 1), vec![180, 204]);
        // E under level 2 (now i0 inner): intra_i x intra_j
        assert_eq!(geo.tile_dims("E", 2), vec![10, 32]);
    }
}
