//! The analytic latency model (paper §4.2, Eqs 12–16), evaluated over
//! the shared evaluation core.
//!
//! `task_latency` implements the per-task recursion: intra-tile latency
//! (Eq 15), pipelined reduction tiles (Eq 16), then the level recursion
//! with communication overlap (Eq 14, with the level's trip count made
//! explicit). `graph_latency` implements the DAG recursion (Eqs 12–13)
//! with FIFO `shift`s for dataflow designs and full serialization for
//! shared-buffer (Sequential) designs.
//!
//! All inputs come precomputed from a [`ResolvedTask`] /
//! [`ResolvedDesign`] ([`super::eval`]): clamped transfer plans, tile
//! bytes at the define level, transfer counts. This module performs no
//! plan resolution of its own — the simulator, constraints and codegen
//! read the same resolved numbers, so the consumers cannot drift.

use super::config::ExecutionModel;
use super::eval::{ResolvedDesign, ResolvedTask};
use crate::hw::Device;
use crate::ir::Kernel;

/// Latency of one fused task in cycles, including its share of off-chip
/// and FIFO communication.
pub fn task_latency(rt: &ResolvedTask, dev: &Device, overlap: bool) -> u64 {
    let compute = pipelined_compute_latency(rt, dev);

    // Per-array total inbound cycles, amortized over the iterations of the
    // loop level where the movement happens (define level granularity —
    // data is brought on-chip once per define-tile).
    let levels = rt.levels();
    // per level, the set of inbound stream totals: distinct arrays ride
    // distinct HBM channels concurrently (§3.7 duplicates read-only
    // arrays), so a level's inbound cost is its slowest stream.
    let mut in_streams: Vec<Vec<u64>> = vec![Vec::new(); levels + 1];
    let mut out_total = vec![0u64; levels + 1];
    for (a, rp) in rt.arrays() {
        // inbound: inputs from off-chip, intermediates from FIFOs — both
        // modelled at the selected bit width. Pure-write outputs are not
        // preloaded (§2.4: E/F/G initialized on chip).
        let per_tile = dev.transfer_cycles(rp.tile_bytes, rp.bitwidth);
        if a.inbound() {
            in_streams[rp.transfer_level].push(rp.transfer_count * per_tile);
        }
        if a.writes && (a.is_output || a.is_intermediate) {
            out_total[rp.define_level] += rp.transfer_count * per_tile;
        }
    }
    let in_total: Vec<u64> = in_streams
        .iter()
        .map(|streams| {
            if streams.len() <= dev.mem_channels {
                streams.iter().copied().max().unwrap_or(0)
            } else {
                // oversubscribed channels serialize; ceiling division —
                // truncating here under-counted the transfer cycles
                streams.iter().sum::<u64>().div_ceil(dev.mem_channels as u64)
            }
        })
        .collect();

    // Level recursion, innermost non-reduction level outward (Eq 14 with
    // the trip count T_l explicit):
    //   overlap:  lat_l = in_l + T_l * max(body, in_l/T_l, out_l/T_l) + out_l/T_l
    //   serial:   lat_l = T_l * (in+body+out per iteration)
    let nlev = rt.geo.nonred.len();
    let mut body = compute;
    for l in (1..=nlev).rev() {
        let t_l = rt.cfg().inter_trip(rt.geo.nonred[l - 1]).max(1);
        // in_total[l]/out_total[l] are TOTAL cycles over the whole kernel
        // run; the body at level l executes transfer_counts[l] times, so
        // the per-iteration share divides by that (not by t_l alone —
        // otherwise reuse plans with define < transfer get re-multiplied
        // by the outer trip counts).
        let execs = rt.transfer_counts[l].max(1);
        let per_in = in_total[l] / execs;
        let per_out = out_total[l] / execs;
        body = if overlap {
            // ping-pong: prologue load, t_l-1 steady-state steps, final
            // compute, drain store. Degenerates to the serial form at
            // t_l = 1 (nothing to overlap).
            let steady = body.max(per_in).max(per_out);
            per_in + (t_l - 1) * steady + body + per_out
        } else {
            t_l * (per_in + body + per_out)
        };
    }
    // level 0: loads before any loop + final stores, never overlapped.
    in_total[0] + body + out_total[0]
}

/// Eq 15 + Eq 16: intra-tile latency and the pipelined reduction loop.
pub fn pipelined_compute_latency(rt: &ResolvedTask, dev: &Device) -> u64 {
    let il_par = dev.fmul_latency + dev.fadd_latency; // dependent MAC chain
    let il_red = dev.fadd_latency;

    // Eq 15: reduction tree depth over the intra-tile reduction extent.
    let cfg = rt.cfg();
    let red_intra: u64 = rt.geo.red.iter().map(|&p| cfg.intra[p]).product();
    let lat_intra = il_par
        + if red_intra > 1 {
            (il_red as f64 * (red_intra as f64).log2()).ceil() as u64
        } else {
            0
        };

    // Eq 16: II-pipelined inter-tile reduction iterations.
    let red_inter: u64 = rt.geo.red.iter().map(|&p| cfg.inter_trip(p)).product();
    let ii = if rt.geo.red.is_empty() { 1 } else { cfg.ii };
    let mut lat = lat_intra + ii * red_inter.saturating_sub(1);

    // Init statements in the fused task execute as their own intra task
    // once per output tile — one unrolled assignment, a couple of cycles.
    if rt.statics().has_init {
        lat += 2;
    }
    lat
}

/// Result of the DAG latency computation.
#[derive(Debug, Clone)]
pub struct GraphLatency {
    /// Finish time of each fused task (cycles).
    pub finish: Vec<u64>,
    /// Standalone duration of each task.
    pub duration: Vec<u64>,
    /// Eq 13: latest sink finish.
    pub total: u64,
}

/// Eqs 12–13 over the fused-task graph. Convenience wrapper that
/// resolves `design` cold; hot paths resolve once and call
/// [`graph_latency_resolved`].
pub fn graph_latency(
    k: &Kernel,
    fg: &crate::analysis::fusion::FusedGraph,
    design: &super::config::DesignConfig,
    dev: &Device,
) -> GraphLatency {
    let cache = super::eval::GeometryCache::new(k, fg);
    let rd = ResolvedDesign::new(k, fg, &cache, design);
    graph_latency_resolved(&rd, dev)
}

/// Total latency of a Sequential (shared-buffer) schedule over
/// standalone task durations indexed by task id: tasks run back-to-back
/// in program order, so the total is the latest sink's prefix sum.
///
/// This *is* the Sequential execution semantics — `graph_latency_resolved`
/// and the executing simulator both reduce to it, and the solver's leaf
/// fast path scores Sequential leaves with it directly (no design
/// resolution, no simulation), which keeps all three equal by
/// construction.
pub fn sequential_total(durations: &[u64], sinks: &[usize]) -> u64 {
    let mut clock = 0u64;
    let mut total = 0u64;
    for (i, &d) in durations.iter().enumerate() {
        clock += d;
        if clock > total && sinks.contains(&i) {
            total = clock;
        }
    }
    total
}

/// Eqs 12–13 over a resolved design.
pub fn graph_latency_resolved(rd: &ResolvedDesign, dev: &Device) -> GraphLatency {
    let n = rd.fg.tasks.len();
    let mut duration = vec![0u64; n];
    for rt in &rd.tasks {
        duration[rt.cfg().task] = task_latency(rt, dev, rd.design.overlap);
    }

    let mut finish = vec![0u64; n];
    match rd.design.model {
        ExecutionModel::Sequential => {
            // shared-buffer frameworks: tasks in program order, no overlap.
            let mut t = 0;
            for i in 0..n {
                t += duration[i];
                finish[i] = t;
            }
        }
        ExecutionModel::Dataflow => {
            for i in 0..n {
                let mut start = 0u64;
                for p in rd.fg.predecessors(i) {
                    let sh = shift(rd, p, i, duration[p]);
                    // producer began at finish[p] - duration[p]
                    let p_start = finish[p] - duration[p];
                    start = start.max(p_start + sh);
                }
                // inter-SLR FIFO crossing penalty
                let slr_pen: u64 = rd
                    .fg
                    .predecessors(i)
                    .iter()
                    .filter(|&&p| rd.task(p).cfg().slr != rd.task(i).cfg().slr)
                    .count() as u64
                    * dev.inter_slr_latency;
                finish[i] = start + slr_pen + duration[i];
            }
        }
    }
    let total = rd
        .fg
        .sinks()
        .into_iter()
        .map(|s| finish[s])
        .max()
        .unwrap_or(0);
    GraphLatency { finish, duration, total }
}

/// `shift_{T_p, T_c}` (Eq 12): cycles after the producer's start at which
/// the consumer can begin — the time for the producer to emit the first
/// data tile the consumer waits for. If the consumer ingests array `a`
/// with its transfer at level 0 (whole-array buffering), it must wait for
/// all of `a`; otherwise for the fraction its first tile covers.
fn shift(rd: &ResolvedDesign, producer: usize, consumer: usize, producer_duration: u64) -> u64 {
    let mut sh = 0u64;
    for (s, d, a) in &rd.fg.edges {
        if *s != producer || *d != consumer {
            continue;
        }
        let total = rd.k.array(a).map(|x| x.elems()).unwrap_or(1).max(1);
        let first_tile = rd
            .task(consumer)
            .plan_for(a)
            .map(|(_, rp)| rp.tile_elems)
            .unwrap_or(1)
            .max(1);
        let frac = (first_tile as f64 / total as f64).min(1.0);
        sh = sh.max((producer_duration as f64 * frac).ceil() as u64);
    }
    sh.max(1)
}

/// Throughput in GFLOP/s for a total latency (uses *unpadded* FLOPs).
pub fn gflops(k: &Kernel, total_cycles: u64, dev: &Device) -> f64 {
    if total_cycles == 0 {
        return 0.0;
    }
    let secs = total_cycles as f64 * dev.cycle_time_s();
    k.total_flops() as f64 / secs / 1e9
}

#[cfg(test)]
mod tests {
    use super::super::config::{DesignConfig, TaskConfig, TransferPlan};
    use super::super::eval::{resolve_task, GeometryCache};
    use super::*;
    use crate::analysis::fusion::fuse;
    use crate::ir::polybench;
    use std::collections::BTreeMap;

    fn simple_cfg(task: usize, perm: Vec<usize>, padded: Vec<u64>, intra: Vec<u64>) -> TaskConfig {
        TaskConfig {
            task,
            perm,
            padded_trip: padded,
            intra,
            ii: 3,
            plans: BTreeMap::new(),
            slr: 0,
        }
    }

    #[test]
    fn intra_latency_grows_with_reduction_log() {
        let k = polybench::gemm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let dev = Device::u55c();
        let c1 = simple_cfg(0, vec![0, 1, 2], vec![200, 220, 240], vec![10, 10, 1]);
        let c2 = simple_cfg(0, vec![0, 1, 2], vec![200, 220, 240], vec![10, 10, 8]);
        let l1 = pipelined_compute_latency(&resolve_task(&k, &cache.tasks[0], &c1), &dev);
        let l2 = pipelined_compute_latency(&resolve_task(&k, &cache.tasks[0], &c2), &dev);
        // wider reduction tile: fewer pipelined iterations, so lower total
        assert!(l2 < l1, "{l2} !< {l1}");
    }

    #[test]
    fn unrolling_reduces_task_latency() {
        let k = polybench::gemm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let dev = Device::u55c();
        let small = simple_cfg(0, vec![0, 1, 2], vec![200, 220, 240], vec![2, 2, 1]);
        let big = simple_cfg(0, vec![0, 1, 2], vec![200, 220, 240], vec![10, 22, 4]);
        let ls = task_latency(&resolve_task(&k, &cache.tasks[0], &small), &dev, true);
        let lb = task_latency(&resolve_task(&k, &cache.tasks[0], &big), &dev, true);
        assert!(lb < ls / 4, "expected big unroll much faster: {lb} vs {ls}");
    }

    #[test]
    fn overlap_beats_serial() {
        let k = polybench::gemm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let dev = Device::u55c();
        let cfg = simple_cfg(0, vec![0, 1, 2], vec![200, 220, 240], vec![10, 22, 4]);
        let rt = resolve_task(&k, &cache.tasks[0], &cfg);
        let with = task_latency(&rt, &dev, true);
        let without = task_latency(&rt, &dev, false);
        assert!(with <= without);
    }

    #[test]
    fn dataflow_overlaps_independent_tasks() {
        // 3-madd: two independent adds + a dependent one. Dataflow total
        // must be well below the sequential sum.
        let k = polybench::three_madd();
        let fg = fuse(&k);
        let dev = Device::u55c();
        let mk = |task| {
            let mut c = simple_cfg(task, vec![0, 1], vec![400, 400], vec![4, 16]);
            c.ii = 1;
            c.plans.insert(
                fg.tasks[task].output.clone(),
                TransferPlan { define_level: 2, transfer_level: 2, bitwidth: 512, buffers: 3 },
            );
            c
        };
        let df = DesignConfig {
            kernel: k.name.clone(),
            model: ExecutionModel::Dataflow,
            overlap: true,
            fusion: fg.plan(),
            tasks: (0..3).map(mk).collect(),
        };
        let seq = DesignConfig { model: ExecutionModel::Sequential, ..df.clone() };
        let l_df = graph_latency(&k, &fg, &df, &dev);
        let l_seq = graph_latency(&k, &fg, &seq, &dev);
        assert!(l_df.total < l_seq.total, "{} !< {}", l_df.total, l_seq.total);
        // sequential total is exactly the sum of durations
        assert_eq!(l_seq.total, l_seq.duration.iter().sum::<u64>());
    }

    #[test]
    fn gflops_accounting() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        // at 220MHz, 1e6 cycles = 4.545ms; gemm ~21.2 MFLOP
        let g = gflops(&k, 1_000_000, &dev);
        let expect = k.total_flops() as f64 / (1e6 / 220e6) / 1e9;
        assert!((g - expect).abs() < 1e-9);
        assert_eq!(gflops(&k, 0, &dev), 0.0);
    }
}
