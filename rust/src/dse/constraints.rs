//! Resource constraints (paper Eqs 7–11) and design resource estimation.

use super::config::DesignConfig;
use super::space::TaskGeometry;
use crate::analysis::fusion::FusedGraph;
use crate::hw::resources::{bram18_for, cost, ResourceVec};
use crate::hw::{Device, SlrBudget};
use crate::ir::{Kernel, StmtKind};

/// Eq 8–9: array partitioning per array = product of the intra-tile trip
/// counts of the loops indexing it; must not exceed `max_part`.
pub fn partition_of(geo: &TaskGeometry, array: &str) -> u64 {
    match geo.access_ref(array) {
        Some(acc) => acc
            .iter()
            .map(|p| p.map(|p| geo.cfg.intra[p]).unwrap_or(1))
            .product(),
        None => 1,
    }
}

/// Check Eq 8 for every array of every task.
pub fn partition_ok(k: &Kernel, fg: &FusedGraph, design: &DesignConfig, dev: &Device) -> bool {
    design.tasks.iter().all(|tc| {
        let geo = TaskGeometry::new(k, fg, tc);
        geo.arrays()
            .iter()
            .all(|a| partition_of(&geo, a) <= dev.max_partition)
    })
}

/// Resource usage of one fused task (DSP via Eq 10 with the II division,
/// LUT/FF via per-op costs, BRAM via buffered tiles × N_a in 18 Kb
/// blocks plus stream engines).
pub fn task_resources(geo: &TaskGeometry, _dev: &Device) -> ResourceVec {
    let mut r = cost::KERNEL_BASE;

    // compute: every statement in the fused task contributes its unrolled
    // op tree. II-pipelined loops let Vitis fold DSPs by ~II (Eq 10).
    for &sid in &geo.fused.stmts {
        let s = &geo.kernel.statements[sid];
        // unroll factor of this statement = product of intra factors of
        // its own loops (mapped onto the representative nest)
        let uf: u64 = (0..s.loops.len())
            .map(|p| geo.rep_pos_of(sid, p).map(|rp| geo.cfg.intra[rp]).unwrap_or(1))
            .product();
        let ii = if s.loops.iter().any(|l| l.reduction) && s.kind == StmtKind::Compute {
            geo.cfg.ii.max(1)
        } else {
            1
        };
        let per_instance = cost::FMUL.scale(s.ops.mul as f64)
            + cost::FADD.scale(s.ops.add as f64)
            + cost::FDIV.scale(s.ops.div as f64)
            + cost::PER_INSTANCE_CTRL;
        r += per_instance.scale(uf as f64 / ii as f64);
    }

    // memory: buffers at their define level × N_a, partitioned (Eq 7)
    for info in geo.infos() {
        let plan = geo
            .cfg
            .plans
            .get(info.name.as_str())
            .copied()
            .unwrap_or_else(|| geo.default_plan(&info.name, geo.levels() - 1));
        let d = plan.define_level.min(geo.levels() - 1);
        let bytes = geo.tile_bytes_for(info, d);
        let parts: u64 = info
            .access
            .iter()
            .map(|p| p.map(|p| geo.cfg.intra[p]).unwrap_or(1))
            .product();
        r.bram18 += bram18_for(bytes, parts) * plan.buffers as f64;
        // one stream engine per off-chip or FIFO connection
        r += cost::STREAM_ENGINE;
    }
    r
}

/// Per-SLR resource usage of the whole design.
pub fn slr_usage(
    k: &Kernel,
    fg: &FusedGraph,
    design: &DesignConfig,
    dev: &Device,
) -> Vec<ResourceVec> {
    let mut per = vec![ResourceVec::ZERO; dev.slrs];
    for tc in &design.tasks {
        let geo = TaskGeometry::new(k, fg, tc);
        per[tc.slr.min(dev.slrs - 1)] += task_resources(&geo, dev);
    }
    per
}

/// Total design resources.
pub fn total_usage(k: &Kernel, fg: &FusedGraph, design: &DesignConfig, dev: &Device) -> ResourceVec {
    slr_usage(k, fg, design, dev)
        .into_iter()
        .fold(ResourceVec::ZERO, |a, b| a + b)
}

/// Eq 7 + Eq 10 + Eq 11 applied per SLR with budget `budget` (already
/// scaled to the scenario's utilization cap).
pub fn feasible(
    k: &Kernel,
    fg: &FusedGraph,
    design: &DesignConfig,
    dev: &Device,
    budget: &SlrBudget,
) -> bool {
    if !partition_ok(k, fg, design, dev) {
        return false;
    }
    if design.tasks.iter().any(|t| t.slr >= dev.slrs) {
        return false;
    }
    slr_usage(k, fg, design, dev)
        .iter()
        .all(|u| u.fits(budget))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::fusion::fuse;
    use crate::dse::config::{ExecutionModel, TaskConfig};
    use std::collections::BTreeMap;

    fn cfg(task: usize, intra: Vec<u64>, padded: Vec<u64>) -> TaskConfig {
        TaskConfig {
            task,
            perm: (0..intra.len()).collect(),
            padded_trip: padded,
            intra,
            ii: 3,
            plans: BTreeMap::new(),
            slr: 0,
        }
    }

    #[test]
    fn listing7_partitioning() {
        // Paper §4.1.6: array D traversed by unrolled k1 (3) and j1 (32)
        // -> 96 partitions.
        let k = crate::ir::polybench::three_mm();
        let fg = fuse(&k);
        let c = cfg(1, vec![19, 32, 3], vec![190, 224, 220]);
        let geo = TaskGeometry::new(&k, &fg, &c);
        assert_eq!(partition_of(&geo, "D"), 3 * 32);
        assert_eq!(partition_of(&geo, "F"), 19 * 32);
        assert_eq!(partition_of(&geo, "C"), 19 * 3);
    }

    #[test]
    fn dsp_scales_with_unroll_over_ii() {
        let k = crate::ir::polybench::gemm();
        let fg = fuse(&k);
        let dev = Device::u55c();
        let small = cfg(0, vec![2, 2, 1], vec![200, 220, 240]);
        let big = cfg(0, vec![8, 8, 1], vec![200, 220, 240]);
        let rs = task_resources(&TaskGeometry::new(&k, &fg, &small), &dev);
        let rb = task_resources(&TaskGeometry::new(&k, &fg, &big), &dev);
        assert!(rb.dsp > rs.dsp * 8.0, "dsp {} vs {}", rb.dsp, rs.dsp);
        // Eq 10 spot check: gemm S1 = 1 add + 1 mul, II=3, UF=64 ->
        // (2+3)/3*64 ≈ 106 DSP for S1 plus S0's mul (UF 64, II 1 -> 192).
        assert!(rb.dsp > 100.0);
    }

    #[test]
    fn feasibility_cuts_oversized_designs() {
        let k = crate::ir::polybench::gemm();
        let fg = fuse(&k);
        let dev = Device::u55c();
        let budget = dev.slr.scaled(0.6);
        let modest = DesignConfig {
            kernel: k.name.clone(),
            model: ExecutionModel::Dataflow,
            overlap: true,
            tasks: vec![cfg(0, vec![4, 4, 1], vec![200, 220, 240])],
        };
        assert!(feasible(&k, &fg, &modest, &dev, &budget));
        let monster = DesignConfig {
            tasks: vec![cfg(0, vec![200, 220, 1], vec![200, 220, 240])],
            ..modest.clone()
        };
        assert!(!feasible(&k, &fg, &monster, &dev, &budget));
    }

    #[test]
    fn partition_limit_enforced() {
        let k = crate::ir::polybench::gemm();
        let fg = fuse(&k);
        let dev = Device::u55c(); // max_partition = 1024
        let d = DesignConfig {
            kernel: k.name.clone(),
            model: ExecutionModel::Dataflow,
            overlap: true,
            // C partitions = 50*44 = 2200 > 1024
            tasks: vec![cfg(0, vec![50, 44, 1], vec![200, 220, 240])],
        };
        assert!(!partition_ok(&k, &fg, &d, &dev));
    }
}
