//! Resource constraints (paper Eqs 7–11) and design resource estimation,
//! evaluated over the shared evaluation core ([`super::eval`]): tile
//! bytes, buffer counts and partition factors come precomputed from a
//! [`ResolvedTask`], so the constraints see exactly the plans the cost
//! model, simulator and codegen see.

use super::config::DesignConfig;
use super::eval::{GeometryCache, ResolvedDesign, ResolvedTask};
use crate::analysis::fusion::FusedGraph;
use crate::hw::resources::{bram18_for, cost, ResourceVec};
use crate::hw::{Device, SlrBudget};
use crate::ir::{Kernel, StmtKind};

/// Eq 8–9: array partitioning per array = product of the intra-tile trip
/// counts of the loops indexing it; must not exceed `max_part`.
pub fn partition_of(rt: &ResolvedTask, array: &str) -> u64 {
    rt.plan_for(array).map(|(_, rp)| rp.partitions).unwrap_or(1)
}

/// Check Eq 8 for every array of every task.
pub fn partition_ok(rd: &ResolvedDesign, dev: &Device) -> bool {
    rd.tasks
        .iter()
        .all(|rt| rt.plans.iter().all(|rp| rp.partitions <= dev.max_partition))
}

/// Resource usage of one fused task (DSP via Eq 10 with the II division,
/// LUT/FF via per-op costs, BRAM via buffered tiles × N_a in 18 Kb
/// blocks plus stream engines).
pub fn task_resources(rt: &ResolvedTask, _dev: &Device) -> ResourceVec {
    let mut r = cost::KERNEL_BASE;
    let st = rt.statics();
    let cfg = rt.cfg();

    // compute: every statement in the fused task contributes its unrolled
    // op tree. II-pipelined loops let Vitis fold DSPs by ~II (Eq 10).
    for (si, &sid) in st.stmts.iter().enumerate() {
        let s = &rt.geo.k.statements[sid];
        // unroll factor of this statement = product of intra factors of
        // its own loops (mapped onto the representative nest, memoized
        // at fusion time)
        let uf: u64 = st.stmt_rep_pos[si]
            .iter()
            .map(|rp| rp.map(|rp| cfg.intra[rp]).unwrap_or(1))
            .product();
        let ii = if s.loops.iter().any(|l| l.reduction) && s.kind == StmtKind::Compute {
            cfg.ii.max(1)
        } else {
            1
        };
        let per_instance = cost::FMUL.scale(s.ops.mul as f64)
            + cost::FADD.scale(s.ops.add as f64)
            + cost::FDIV.scale(s.ops.div as f64)
            + cost::PER_INSTANCE_CTRL;
        r += per_instance.scale(uf as f64 / ii as f64);
    }

    // memory: buffers at their define level × N_a, partitioned (Eq 7)
    for (_, rp) in rt.arrays() {
        r.bram18 += bram18_for(rp.tile_bytes, rp.partitions) * rp.buffers as f64;
        // one stream engine per off-chip or FIFO connection
        r += cost::STREAM_ENGINE;
    }
    r
}

/// Per-SLR resource usage of a resolved design.
pub fn slr_usage_resolved(rd: &ResolvedDesign, dev: &Device) -> Vec<ResourceVec> {
    let mut per = vec![ResourceVec::ZERO; dev.slrs];
    for rt in &rd.tasks {
        per[rt.cfg().slr.min(dev.slrs - 1)] += task_resources(rt, dev);
    }
    per
}

/// Per-SLR resource usage of the whole design (cold-resolving wrapper).
pub fn slr_usage(
    k: &Kernel,
    fg: &FusedGraph,
    design: &DesignConfig,
    dev: &Device,
) -> Vec<ResourceVec> {
    let cache = GeometryCache::new(k, fg);
    let rd = ResolvedDesign::new(k, fg, &cache, design);
    slr_usage_resolved(&rd, dev)
}

/// Total design resources.
pub fn total_usage(k: &Kernel, fg: &FusedGraph, design: &DesignConfig, dev: &Device) -> ResourceVec {
    slr_usage(k, fg, design, dev)
        .into_iter()
        .fold(ResourceVec::ZERO, |a, b| a + b)
}

/// Eq 7 + Eq 10 + Eq 11 applied per SLR with budget `budget` (already
/// scaled to the scenario's utilization cap), over a resolved design.
pub fn feasible_resolved(rd: &ResolvedDesign, dev: &Device, budget: &SlrBudget) -> bool {
    if !partition_ok(rd, dev) {
        return false;
    }
    if rd.design.tasks.iter().any(|t| t.slr >= dev.slrs) {
        return false;
    }
    slr_usage_resolved(rd, dev).iter().all(|u| u.fits(budget))
}

/// [`feasible_resolved`] with cold resolution — callers that already
/// hold a [`ResolvedDesign`] should use the resolved variant.
pub fn feasible(
    k: &Kernel,
    fg: &FusedGraph,
    design: &DesignConfig,
    dev: &Device,
    budget: &SlrBudget,
) -> bool {
    let cache = GeometryCache::new(k, fg);
    let rd = ResolvedDesign::new(k, fg, &cache, design);
    feasible_resolved(&rd, dev, budget)
}

#[cfg(test)]
mod tests {
    use super::super::eval::resolve_task;
    use super::*;
    use crate::analysis::fusion::fuse;
    use crate::dse::config::{ExecutionModel, TaskConfig};
    use std::collections::BTreeMap;

    fn cfg(task: usize, intra: Vec<u64>, padded: Vec<u64>) -> TaskConfig {
        TaskConfig {
            task,
            perm: (0..intra.len()).collect(),
            padded_trip: padded,
            intra,
            ii: 3,
            plans: BTreeMap::new(),
            slr: 0,
        }
    }

    #[test]
    fn listing7_partitioning() {
        // Paper §4.1.6: array D traversed by unrolled k1 (3) and j1 (32)
        // -> 96 partitions.
        let k = crate::ir::polybench::three_mm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let c = cfg(1, vec![19, 32, 3], vec![190, 224, 220]);
        let rt = resolve_task(&k, &cache.tasks[1], &c);
        assert_eq!(partition_of(&rt, "D"), 3 * 32);
        assert_eq!(partition_of(&rt, "F"), 19 * 32);
        assert_eq!(partition_of(&rt, "C"), 19 * 3);
    }

    #[test]
    fn dsp_scales_with_unroll_over_ii() {
        let k = crate::ir::polybench::gemm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let dev = Device::u55c();
        let small = cfg(0, vec![2, 2, 1], vec![200, 220, 240]);
        let big = cfg(0, vec![8, 8, 1], vec![200, 220, 240]);
        let rs = task_resources(&resolve_task(&k, &cache.tasks[0], &small), &dev);
        let rb = task_resources(&resolve_task(&k, &cache.tasks[0], &big), &dev);
        assert!(rb.dsp > rs.dsp * 8.0, "dsp {} vs {}", rb.dsp, rs.dsp);
        // Eq 10 spot check: gemm S1 = 1 add + 1 mul, II=3, UF=64 ->
        // (2+3)/3*64 ≈ 106 DSP for S1 plus S0's mul (UF 64, II 1 -> 192).
        assert!(rb.dsp > 100.0);
    }

    #[test]
    fn feasibility_cuts_oversized_designs() {
        let k = crate::ir::polybench::gemm();
        let fg = fuse(&k);
        let dev = Device::u55c();
        let budget = dev.slr.scaled(0.6);
        let modest = DesignConfig {
            kernel: k.name.clone(),
            model: ExecutionModel::Dataflow,
            overlap: true,
            fusion: fg.plan(),
            tasks: vec![cfg(0, vec![4, 4, 1], vec![200, 220, 240])],
        };
        assert!(feasible(&k, &fg, &modest, &dev, &budget));
        let monster = DesignConfig {
            tasks: vec![cfg(0, vec![200, 220, 1], vec![200, 220, 240])],
            ..modest.clone()
        };
        assert!(!feasible(&k, &fg, &monster, &dev, &budget));
    }

    #[test]
    fn partition_limit_enforced() {
        let k = crate::ir::polybench::gemm();
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let dev = Device::u55c(); // max_partition = 1024
        let d = DesignConfig {
            kernel: k.name.clone(),
            model: ExecutionModel::Dataflow,
            overlap: true,
            fusion: fg.plan(),
            // C partitions = 50*44 = 2200 > 1024
            tasks: vec![cfg(0, vec![50, 44, 1], vec![200, 220, 240])],
        };
        let rd = ResolvedDesign::new(&k, &fg, &cache, &d);
        assert!(!partition_ok(&rd, &dev));
    }
}
