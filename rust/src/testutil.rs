//! In-tree property-testing support (the environment has no network
//! access and `proptest` is not vendored): a deterministic xorshift PRNG
//! plus a tiny `for_random` driver used by property tests across modules.

/// xorshift64* — deterministic, seedable, good enough for test-case
/// generation.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        XorShift { state: seed.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Uniform choice from a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[(self.next_u64() % items.len() as u64) as usize]
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// f32 in [-0.5, 0.5).
    pub fn f32_unit(&mut self) -> f32 {
        (self.next_u64() % 1000) as f32 / 1000.0 - 0.5
    }
}

/// Run `body` against `n` generated cases; panics include the case index
/// and seed so failures reproduce exactly.
pub fn for_random(seed: u64, n: usize, mut body: impl FnMut(&mut XorShift, usize)) {
    for i in 0..n {
        let mut rng = XorShift::new(seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        body(&mut rng, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut rng = XorShift::new(7);
        for _ in 0..1000 {
            let v = rng.range(3, 9);
            assert!((3..=9).contains(&v));
        }
    }

    #[test]
    fn zero_seed_does_not_stick() {
        let mut rng = XorShift::new(0);
        assert_ne!(rng.next_u64(), 0);
    }

    #[test]
    fn for_random_covers_n() {
        let mut count = 0;
        for_random(1, 25, |_, _| count += 1);
        assert_eq!(count, 25);
    }
}
