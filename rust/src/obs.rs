//! `prometheus::obs` — solve observability: spans, counters, incumbent
//! timelines and a Chrome-trace exporter.
//!
//! Prometheus's value proposition is *explaining* where QoR comes from:
//! which fusion variant won, where the solver spent its budget, and why
//! candidates died. This module is the vendored, zero-dependency
//! telemetry layer that makes those questions answerable end to end:
//!
//! * **Spans** — RAII [`Span`] guards record wall-clock phases
//!   (`flow.fusion_space`, `flow.solve`, `flow.sim`, …) as Chrome
//!   trace-event *complete* events (`ph: "X"`).
//! * **Counters** — [`SolveCounters`] is the shared mutable counter
//!   block one solve threads through its stages: candidates enumerated,
//!   Pareto-truncated, bound-/resource-/symmetry-pruned,
//!   deadline-killed, a DFS depth histogram, and the *incumbent
//!   timeline* (every improvement of the shared branch-and-bound bound
//!   as `(elapsed, latency, variant)`). It freezes into the plain-data
//!   [`SolveTelemetry`] carried on `SolverResult`.
//! * **Export** — [`chrome_trace_json`] renders collected events in the
//!   Chrome trace-event JSON format (`{"traceEvents": [...]}`),
//!   viewable in `chrome://tracing` or Perfetto; the CLI's `--trace
//!   out.json` flag wires it up.
//!
//! Two independent switches control cost:
//!
//! * **Tracing** ([`trace_enabled`]) gates the global event sink. It is
//!   on when `PROMETHEUS_TRACE=1` is set in the environment or after
//!   [`start_trace`] (the CLI `--trace` path). When off, every span or
//!   instant helper is a single relaxed atomic load.
//! * **Telemetry** (`SolverOptions::telemetry`) gates the per-solve
//!   counter block. When off, every [`SolveCounters`] method is one
//!   predictable branch on a plain `bool` — `benches/solver_eval.rs`
//!   asserts the projected overhead stays under 2% of a solve.
//!
//! Both switches are observational only: the solver's search order,
//! pruning decisions and returned design are bit-identical with
//! telemetry/tracing on or off (property-tested across the kernel zoo
//! in `tests/telemetry.rs`).

#![deny(missing_docs)]

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

// ---- global tracing switch and event sink ------------------------------

/// Flipped by [`start_trace`] / [`stop_trace`] (the CLI `--trace` path).
static TRACE_STARTED: AtomicBool = AtomicBool::new(false);

/// `PROMETHEUS_TRACE` environment check, evaluated once per process.
fn env_trace() -> bool {
    static ENV: OnceLock<bool> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("PROMETHEUS_TRACE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
    })
}

/// Whether trace events are being collected right now.
///
/// True when `PROMETHEUS_TRACE` is set (and not `0`/empty) or between
/// [`start_trace`] and [`stop_trace`]. The disabled cost of every
/// tracing helper bottoms out in this single relaxed load.
pub fn trace_enabled() -> bool {
    TRACE_STARTED.load(Ordering::Relaxed) || env_trace()
}

/// Process-wide trace epoch: all event timestamps are µs since the
/// first call to any timestamping helper.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process trace epoch.
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Small dense per-thread ids (Chrome traces want integer `tid`s; the
/// OS thread id is not exposed as an integer on stable).
fn tid() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

/// A trace-event argument value (shown in the viewer's detail pane).
#[derive(Debug, Clone, PartialEq)]
pub enum ArgVal {
    /// Exact integer argument.
    Int(i128),
    /// Floating-point argument.
    Float(f64),
    /// String argument.
    Str(String),
}

impl ArgVal {
    fn to_value(&self) -> serde::Value {
        match self {
            ArgVal::Int(i) => serde::Value::Int(*i),
            ArgVal::Float(f) => serde::Value::Float(*f),
            ArgVal::Str(s) => serde::Value::Str(s.clone()),
        }
    }
}

/// One collected event in the Chrome trace-event model.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Event name (e.g. `flow.solve`, `incumbent`, `solve.variant0`).
    pub name: String,
    /// Category shown as a filterable group in the viewer.
    pub cat: &'static str,
    /// Phase: `X` complete (has `dur_us`), `i` instant, `C` counter.
    pub ph: char,
    /// Start timestamp, µs since the process trace epoch.
    pub ts_us: u64,
    /// Duration in µs — `Some` only for complete (`X`) events.
    pub dur_us: Option<u64>,
    /// Dense per-thread id (see the module docs; not an OS tid).
    pub tid: u64,
    /// Event arguments, rendered under `"args"`.
    pub args: Vec<(String, ArgVal)>,
}

/// Hard cap on buffered events so a pathological run cannot exhaust
/// memory; overflow is *counted*, never silent (see [`stop_trace`]).
const MAX_TRACE_EVENTS: usize = 262_144;

struct Sink {
    events: Vec<TraceEvent>,
    dropped: u64,
}

static SINK: Mutex<Sink> = Mutex::new(Sink { events: Vec::new(), dropped: 0 });

/// Append one event to the global sink (no-op when tracing is off).
pub fn record(ev: TraceEvent) {
    if !trace_enabled() {
        return;
    }
    let mut sink = SINK.lock().unwrap();
    if sink.events.len() >= MAX_TRACE_EVENTS {
        sink.dropped += 1;
    } else {
        sink.events.push(ev);
    }
}

/// Start collecting trace events (clears anything previously buffered).
pub fn start_trace() {
    let mut sink = SINK.lock().unwrap();
    sink.events.clear();
    sink.dropped = 0;
    TRACE_STARTED.store(true, Ordering::Relaxed);
}

/// Stop collecting and drain the sink: returns the buffered events and
/// how many were dropped at the [`MAX_TRACE_EVENTS`] cap.
///
/// With `PROMETHEUS_TRACE` set in the environment, collection resumes
/// immediately (the env switch cannot be un-set at runtime).
pub fn stop_trace() -> (Vec<TraceEvent>, u64) {
    TRACE_STARTED.store(false, Ordering::Relaxed);
    let mut sink = SINK.lock().unwrap();
    (std::mem::take(&mut sink.events), std::mem::replace(&mut sink.dropped, 0))
}

// ---- spans and event helpers -------------------------------------------

/// RAII span: records a complete (`X`) event from creation to drop.
///
/// Construct through [`span`], which returns `None` when tracing is
/// off so the disabled path never allocates.
pub struct Span {
    name: String,
    cat: &'static str,
    start_us: u64,
    args: Vec<(String, ArgVal)>,
}

impl Span {
    /// Attach an argument (builder-style, for use under `Option::map`).
    pub fn arg(mut self, key: &str, val: ArgVal) -> Span {
        self.args.push((key.to_string(), val));
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let start = self.start_us;
        record(TraceEvent {
            name: std::mem::take(&mut self.name),
            cat: self.cat,
            ph: 'X',
            ts_us: start,
            dur_us: Some(now_us().saturating_sub(start)),
            tid: tid(),
            args: std::mem::take(&mut self.args),
        });
    }
}

/// Open a span covering the enclosing scope.
///
/// ```ignore
/// let _s = obs::span("flow", "flow.solve");
/// ```
///
/// Returns `None` when tracing is off — the disabled cost is one
/// relaxed atomic load and no allocation.
pub fn span(cat: &'static str, name: &str) -> Option<Span> {
    if !trace_enabled() {
        return None;
    }
    Some(Span { name: name.to_string(), cat, start_us: now_us(), args: Vec::new() })
}

/// Record an instant (`i`) event at the current time (process scope).
pub fn instant(cat: &'static str, name: &str, args: Vec<(String, ArgVal)>) {
    if !trace_enabled() {
        return;
    }
    record(TraceEvent {
        name: name.to_string(),
        cat,
        ph: 'i',
        ts_us: now_us(),
        dur_us: None,
        tid: tid(),
        args,
    });
}

/// Record a counter (`C`) event; args should be numeric to plot.
pub fn counter(cat: &'static str, name: &str, args: Vec<(String, ArgVal)>) {
    if !trace_enabled() {
        return;
    }
    record(TraceEvent {
        name: name.to_string(),
        cat,
        ph: 'C',
        ts_us: now_us(),
        dur_us: None,
        tid: tid(),
        args,
    });
}

// ---- Chrome trace-event export -----------------------------------------

/// Render events as Chrome trace-event JSON: the `{"traceEvents":
/// [...]}` object-envelope flavor understood by `chrome://tracing` and
/// Perfetto. Dropped-event counts surface under `"otherData"` so a
/// truncated trace is never mistaken for a complete one.
pub fn chrome_trace_json(events: &[TraceEvent], dropped: u64) -> String {
    use serde::Value;
    let rendered: Vec<Value> = events
        .iter()
        .map(|e| {
            let mut fields = vec![
                ("name".to_string(), Value::Str(e.name.clone())),
                ("cat".to_string(), Value::Str(e.cat.to_string())),
                ("ph".to_string(), Value::Str(e.ph.to_string())),
                ("ts".to_string(), Value::Int(e.ts_us as i128)),
                ("pid".to_string(), Value::Int(1)),
                ("tid".to_string(), Value::Int(e.tid as i128)),
            ];
            if let Some(dur) = e.dur_us {
                fields.push(("dur".to_string(), Value::Int(dur as i128)));
            }
            if e.ph == 'i' {
                // instant scope: "p" = process-wide line in the viewer
                fields.push(("s".to_string(), Value::Str("p".to_string())));
            }
            if !e.args.is_empty() {
                fields.push((
                    "args".to_string(),
                    Value::Obj(e.args.iter().map(|(k, v)| (k.clone(), v.to_value())).collect()),
                ));
            }
            Value::Obj(fields)
        })
        .collect();
    serde::to_string(&Value::Obj(vec![
        ("traceEvents".to_string(), Value::Arr(rendered)),
        ("displayTimeUnit".to_string(), Value::Str("ms".to_string())),
        (
            "otherData".to_string(),
            Value::Obj(vec![("dropped_events".to_string(), Value::Int(dropped as i128))]),
        ),
    ]))
}

/// Write [`chrome_trace_json`] to `path`.
pub fn write_chrome_trace(
    path: &std::path::Path,
    events: &[TraceEvent],
    dropped: u64,
) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace_json(events, dropped))
}

// ---- latency summaries -------------------------------------------------

/// Nearest-rank percentile over an **ascending-sorted** sample slice
/// (microseconds in the serve metrics, but unit-agnostic). `pct` in
/// `[0, 100]`; an empty slice yields 0. Nearest-rank (ceil(p/100·N)-th
/// order statistic) rather than interpolation: every reported value is
/// a latency that actually occurred.
pub fn percentile(sorted: &[u64], pct: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((pct / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

// ---- structured solve telemetry ----------------------------------------

/// Counter block for one fusion variant of one solve.
///
/// "Pruned" counters tally *candidates never expanded*: a
/// `bound_pruned` of 1000 means 1000 `(candidate, region)` children
/// were cut at their parent because the candidate's standalone latency
/// already exceeded the shared incumbent bound.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct VariantCounters {
    /// Stage-1/2 design points scored during per-task enumeration
    /// (tile factors × permutations × transfer-plan refinements).
    pub enumerated: u64,
    /// The stage-1 subset of [`VariantCounters::enumerated`]: tile-factor ×
    /// permutation points actually resolved and scored, excluding the
    /// stage-2 transfer-plan refinements (whose count is *not* invariant
    /// under starvation — the survivor set shifts). This is the counter
    /// the `enum_pruned` accounting invariant is stated against.
    pub stage1_points: u64,
    /// Candidates surviving the per-task Pareto reduction.
    pub pareto_kept: u64,
    /// Candidates dropped by Pareto dominance or front truncation.
    pub pareto_dropped: u64,
    /// Stage-3 DFS nodes entered.
    pub dfs_nodes: u64,
    /// Complete assignments scored by the executing simulator.
    pub leaves_simulated: u64,
    /// Children cut because the candidate's standalone latency exceeded
    /// the shared incumbent bound.
    pub bound_pruned: u64,
    /// Children cut by per-region resource overflow.
    pub resource_pruned: u64,
    /// Region-renamed duplicate children never generated (SLR symmetry
    /// breaking: new regions open in index order).
    pub symmetry_pruned: u64,
    /// Complete assignments discarded by the leaf pre-filter: the
    /// analytic lower bound already exceeded the shared incumbent, so
    /// the executing simulation (and the design assembly feeding it)
    /// was skipped entirely.
    pub model_pruned: u64,
    /// Pareto candidates removed from this variant's DFS lists by the
    /// shared fusion-aware beam: their standalone latency exceeded the
    /// cross-variant incumbent established before the DFS started.
    pub beam_starved: u64,
    /// Stage-1 enumeration points never resolved: the analytic
    /// per-subtree latency floor (best achievable `UF/II` given the
    /// remaining unroll budget) already exceeded the pre-enumeration
    /// incumbent bound, so whole factor subtrees / permutations were
    /// skipped. Counted in *points* — `enum_pruned + stage1_points`
    /// equals the reference enumeration's `stage1_points`.
    pub enum_pruned: u64,
    /// Subtrees abandoned after the anytime deadline expired with an
    /// incumbent already in hand.
    pub deadline_killed: u64,
}

impl VariantCounters {
    /// Element-wise accumulate `other` into `self`.
    pub fn add(&mut self, other: &VariantCounters) {
        self.enumerated += other.enumerated;
        self.stage1_points += other.stage1_points;
        self.pareto_kept += other.pareto_kept;
        self.pareto_dropped += other.pareto_dropped;
        self.dfs_nodes += other.dfs_nodes;
        self.leaves_simulated += other.leaves_simulated;
        self.bound_pruned += other.bound_pruned;
        self.resource_pruned += other.resource_pruned;
        self.symmetry_pruned += other.symmetry_pruned;
        self.model_pruned += other.model_pruned;
        self.beam_starved += other.beam_starved;
        self.enum_pruned += other.enum_pruned;
        self.deadline_killed += other.deadline_killed;
    }

    /// Prune-partition rates: what fraction of all pruned work each
    /// bucket accounts for, as percentages `(bound, symmetry, resource,
    /// model)`. Zero across the board when nothing was pruned.
    pub fn prune_rates(&self) -> (f64, f64, f64, f64) {
        let total =
            self.bound_pruned + self.symmetry_pruned + self.resource_pruned + self.model_pruned;
        if total == 0 {
            return (0.0, 0.0, 0.0, 0.0);
        }
        let pct = |n: u64| n as f64 * 100.0 / total as f64;
        (
            pct(self.bound_pruned),
            pct(self.symmetry_pruned),
            pct(self.resource_pruned),
            pct(self.model_pruned),
        )
    }

    /// Stage-1 prune rate: the percentage of all stage-1 enumeration
    /// points that bound-driven starvation skipped before resolution,
    /// `enum_pruned / (stage1_points + enum_pruned)`. Zero when nothing
    /// was enumerated (or nothing skipped).
    pub fn stage1_prune_rate(&self) -> f64 {
        let total = self.stage1_points + self.enum_pruned;
        if total == 0 {
            return 0.0;
        }
        self.enum_pruned as f64 * 100.0 / total as f64
    }
}

/// One improvement of the shared branch-and-bound incumbent.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IncumbentEvent {
    /// Wall time since the solve started, µs. Timestamps are
    /// wall-clock: deterministic runs repeat the `(latency, variant)`
    /// sequence exactly but not these.
    pub elapsed_us: u64,
    /// The new best end-to-end simulated latency, cycles.
    pub latency: u64,
    /// Index of the fusion variant the improving design realizes.
    pub variant: usize,
}

/// Structured telemetry of one solve, carried on `SolverResult`.
///
/// All-empty (`enabled: false`) when `SolverOptions::telemetry` was
/// off or the result came straight from the QoR cache.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SolveTelemetry {
    /// Whether collection was on for this solve.
    pub enabled: bool,
    /// Per-fusion-variant counters, indexed like the solve's variant
    /// list (`SolverResult::fusion_variants` entries).
    pub variants: Vec<VariantCounters>,
    /// DFS nodes entered per depth; index = number of tasks already
    /// assigned when the node was entered.
    pub depth_hist: Vec<u64>,
    /// Incumbent timeline: every improvement of the shared bound, in
    /// discovery order.
    pub incumbents: Vec<IncumbentEvent>,
}

impl SolveTelemetry {
    /// Counters summed across all fusion variants.
    pub fn totals(&self) -> VariantCounters {
        let mut total = VariantCounters::default();
        for v in &self.variants {
            total.add(v);
        }
        total
    }

    /// Human-readable multi-line summary (the CLI `--telemetry` view).
    /// Empty string when collection was off.
    pub fn render(&self) -> String {
        if !self.enabled {
            return String::new();
        }
        let t = self.totals();
        let mut out = String::new();
        out.push_str(&format!(
            "solve telemetry: {} variant(s), {} points enumerated, {} DFS nodes, {} leaves simulated\n",
            self.variants.len(),
            t.enumerated,
            t.dfs_nodes,
            t.leaves_simulated
        ));
        out.push_str(&format!(
            "  pareto kept/dropped: {}/{}; pruned: {} bound, {} symmetry, {} resource, {} model, {} deadline-killed\n",
            t.pareto_kept,
            t.pareto_dropped,
            t.bound_pruned,
            t.symmetry_pruned,
            t.resource_pruned,
            t.model_pruned,
            t.deadline_killed
        ));
        let (b, s, r, m) = t.prune_rates();
        out.push_str(&format!(
            "  prune rates: {b:.1}% bound / {s:.1}% symmetry / {r:.1}% resource / {m:.1}% model; {} beam-starved\n",
            t.beam_starved
        ));
        out.push_str(&format!(
            "  stage-1: {} of {} points starved before resolution ({:.1}% of the stage-1 space)\n",
            t.enum_pruned,
            t.stage1_points + t.enum_pruned,
            t.stage1_prune_rate()
        ));
        match (self.incumbents.first(), self.incumbents.last()) {
            (Some(first), Some(last)) => out.push_str(&format!(
                "  incumbents: {} improvement(s); first {} cyc (variant {}) @ {:.1} ms, best {} cyc (variant {}) @ {:.1} ms\n",
                self.incumbents.len(),
                first.latency,
                first.variant,
                first.elapsed_us as f64 / 1000.0,
                last.latency,
                last.variant,
                last.elapsed_us as f64 / 1000.0
            )),
            _ => out.push_str("  incumbents: none recorded\n"),
        }
        let hist: Vec<String> = self.depth_hist.iter().map(|n| n.to_string()).collect();
        out.push_str(&format!("  DFS depth histogram: [{}]\n", hist.join(", ")));
        for (vi, v) in self.variants.iter().enumerate() {
            out.push_str(&format!(
                "  variant {vi}: {} points (+{} enum-pruned), {} nodes, {} leaves, pruned {}b/{}s/{}r/{}m, {} starved\n",
                v.enumerated,
                v.enum_pruned,
                v.dfs_nodes,
                v.leaves_simulated,
                v.bound_pruned,
                v.symmetry_pruned,
                v.resource_pruned,
                v.model_pruned,
                v.beam_starved
            ));
        }
        out
    }
}

// ---- live counter block (atomics) --------------------------------------

#[derive(Default)]
struct VariantAtomics {
    enumerated: AtomicU64,
    stage1_points: AtomicU64,
    pareto_kept: AtomicU64,
    pareto_dropped: AtomicU64,
    dfs_nodes: AtomicU64,
    leaves_simulated: AtomicU64,
    bound_pruned: AtomicU64,
    resource_pruned: AtomicU64,
    symmetry_pruned: AtomicU64,
    model_pruned: AtomicU64,
    beam_starved: AtomicU64,
    enum_pruned: AtomicU64,
    deadline_killed: AtomicU64,
}

impl VariantAtomics {
    fn freeze(self) -> VariantCounters {
        VariantCounters {
            enumerated: self.enumerated.into_inner(),
            stage1_points: self.stage1_points.into_inner(),
            pareto_kept: self.pareto_kept.into_inner(),
            pareto_dropped: self.pareto_dropped.into_inner(),
            dfs_nodes: self.dfs_nodes.into_inner(),
            leaves_simulated: self.leaves_simulated.into_inner(),
            bound_pruned: self.bound_pruned.into_inner(),
            resource_pruned: self.resource_pruned.into_inner(),
            symmetry_pruned: self.symmetry_pruned.into_inner(),
            model_pruned: self.model_pruned.into_inner(),
            beam_starved: self.beam_starved.into_inner(),
            enum_pruned: self.enum_pruned.into_inner(),
            deadline_killed: self.deadline_killed.into_inner(),
        }
    }
}

/// Shared mutable counter state for one in-flight solve, threaded by
/// reference through the solver's stages and worker threads.
///
/// Every recording method starts with `if !self.enabled { return; }` —
/// a predictable branch on a plain `bool` — so a telemetry-off solve
/// pays (and allocates) nearly nothing. The disabled per-call cost is
/// bench-bounded in `benches/solver_eval.rs`.
pub struct SolveCounters {
    enabled: bool,
    variants: Vec<VariantAtomics>,
    depth: Vec<AtomicU64>,
    incumbents: Mutex<Vec<IncumbentEvent>>,
}

impl SolveCounters {
    /// Create a counter block for `n_variants` fusion variants and DFS
    /// depths `0..depth_slots`. With `enabled: false` nothing is
    /// allocated and every method is an early return.
    pub fn new(enabled: bool, n_variants: usize, depth_slots: usize) -> SolveCounters {
        SolveCounters {
            enabled,
            variants: if enabled {
                (0..n_variants).map(|_| VariantAtomics::default()).collect()
            } else {
                Vec::new()
            },
            depth: if enabled {
                (0..depth_slots.max(1)).map(|_| AtomicU64::new(0)).collect()
            } else {
                Vec::new()
            },
            incumbents: Mutex::new(Vec::new()),
        }
    }

    /// Whether collection is on (pre-check before computing expensive
    /// counter arguments).
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Stage-1/2: `n` design points were scored for variant `vi`.
    #[inline]
    pub fn enumerated(&self, vi: usize, n: u64) {
        if !self.enabled {
            return;
        }
        self.variants[vi].enumerated.fetch_add(n, Ordering::Relaxed);
    }

    /// Stage-1 only: `n` tile-factor × permutation points were resolved
    /// and scored for variant `vi` (a subset of [`SolveCounters::enumerated`]).
    #[inline]
    pub fn stage1_points(&self, vi: usize, n: u64) {
        if !self.enabled {
            return;
        }
        self.variants[vi].stage1_points.fetch_add(n, Ordering::Relaxed);
    }

    /// Pareto reduction for one task of variant `vi`: `kept` survived,
    /// `dropped` were dominated or truncated away.
    #[inline]
    pub fn pareto(&self, vi: usize, kept: u64, dropped: u64) {
        if !self.enabled {
            return;
        }
        self.variants[vi].pareto_kept.fetch_add(kept, Ordering::Relaxed);
        self.variants[vi].pareto_dropped.fetch_add(dropped, Ordering::Relaxed);
    }

    /// A DFS node was entered at `depth` (tasks already assigned).
    #[inline]
    pub fn dfs_node(&self, vi: usize, depth: usize) {
        if !self.enabled {
            return;
        }
        self.variants[vi].dfs_nodes.fetch_add(1, Ordering::Relaxed);
        let slot = depth.min(self.depth.len() - 1);
        self.depth[slot].fetch_add(1, Ordering::Relaxed);
    }

    /// A complete assignment was scored by the executing simulator.
    #[inline]
    pub fn leaf(&self, vi: usize) {
        if !self.enabled {
            return;
        }
        self.variants[vi].leaves_simulated.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` children were cut by the incumbent bound.
    #[inline]
    pub fn bound_pruned(&self, vi: usize, n: u64) {
        if !self.enabled {
            return;
        }
        self.variants[vi].bound_pruned.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` children were cut by per-region resource overflow.
    #[inline]
    pub fn resource_pruned(&self, vi: usize, n: u64) {
        if !self.enabled {
            return;
        }
        self.variants[vi].resource_pruned.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` region-renamed duplicate children were never generated.
    #[inline]
    pub fn symmetry_pruned(&self, vi: usize, n: u64) {
        if !self.enabled {
            return;
        }
        self.variants[vi].symmetry_pruned.fetch_add(n, Ordering::Relaxed);
    }

    /// A complete assignment was discarded by the analytic leaf
    /// pre-filter — no design assembled, no simulation run.
    #[inline]
    pub fn model_pruned(&self, vi: usize) {
        if !self.enabled {
            return;
        }
        self.variants[vi].model_pruned.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` Pareto candidates were dropped from variant `vi`'s DFS lists
    /// by the shared fusion-aware beam.
    #[inline]
    pub fn beam_starved(&self, vi: usize, n: u64) {
        if !self.enabled {
            return;
        }
        self.variants[vi].beam_starved.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` stage-1 enumeration points were skipped before resolution
    /// because their subtree's analytic latency floor already exceeded
    /// the pre-enumeration incumbent bound.
    #[inline]
    pub fn enum_pruned(&self, vi: usize, n: u64) {
        if !self.enabled {
            return;
        }
        self.variants[vi].enum_pruned.fetch_add(n, Ordering::Relaxed);
    }

    /// A subtree was abandoned because the deadline expired with an
    /// incumbent in hand.
    #[inline]
    pub fn deadline_killed(&self, vi: usize) {
        if !self.enabled {
            return;
        }
        self.variants[vi].deadline_killed.fetch_add(1, Ordering::Relaxed);
    }

    /// The shared incumbent improved: record the timeline event (and an
    /// instant trace event when tracing is on). Called under the
    /// incumbent lock, so the timeline is totally ordered.
    pub fn incumbent(&self, elapsed_us: u64, latency: u64, variant: usize) {
        if self.enabled {
            self.incumbents
                .lock()
                .unwrap()
                .push(IncumbentEvent { elapsed_us, latency, variant });
        }
        if trace_enabled() {
            instant(
                "solver",
                "incumbent",
                vec![
                    ("latency".to_string(), ArgVal::Int(latency as i128)),
                    ("variant".to_string(), ArgVal::Int(variant as i128)),
                ],
            );
        }
    }

    /// Freeze the live counters into plain-data [`SolveTelemetry`].
    pub fn finish(self) -> SolveTelemetry {
        if !self.enabled {
            return SolveTelemetry::default();
        }
        SolveTelemetry {
            enabled: true,
            variants: self.variants.into_iter().map(VariantAtomics::freeze).collect(),
            depth_hist: self.depth.into_iter().map(AtomicU64::into_inner).collect(),
            incumbents: self.incumbents.into_inner().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile(&[], 50.0), 0);
        assert_eq!(percentile(&[7], 50.0), 7);
        assert_eq!(percentile(&[7], 99.0), 7);
        let s: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&s, 50.0), 50);
        assert_eq!(percentile(&s, 99.0), 99);
        assert_eq!(percentile(&s, 100.0), 100);
        assert_eq!(percentile(&s, 0.0), 1);
        assert_eq!(percentile(&[10, 20, 30], 50.0), 20);
        assert_eq!(percentile(&[10, 20, 30], 99.0), 30);
    }

    #[test]
    fn disabled_counters_freeze_to_default() {
        let c = SolveCounters::new(false, 3, 8);
        // indices that would be out of bounds if the early return failed
        c.enumerated(2, 100);
        c.dfs_node(1, 99);
        c.leaf(0);
        c.bound_pruned(0, 5);
        c.enum_pruned(1, 17);
        c.stage1_points(2, 8);
        c.incumbent(1, 2, 0);
        assert_eq!(c.finish(), SolveTelemetry::default());
    }

    #[test]
    fn enabled_counters_accumulate_and_freeze() {
        let c = SolveCounters::new(true, 2, 4);
        c.enumerated(0, 10);
        c.enumerated(1, 5);
        c.pareto(0, 3, 7);
        c.dfs_node(0, 0);
        c.dfs_node(0, 9); // clamps into the last depth slot
        c.leaf(0);
        c.bound_pruned(1, 2);
        c.symmetry_pruned(1, 4);
        c.enum_pruned(0, 30);
        c.stage1_points(0, 8);
        c.stage1_points(1, 2);
        c.incumbent(123, 456, 1);
        let t = c.finish();
        assert!(t.enabled);
        assert_eq!(t.variants.len(), 2);
        assert_eq!(t.variants[0].enumerated, 10);
        assert_eq!(t.variants[0].pareto_kept, 3);
        assert_eq!(t.variants[0].pareto_dropped, 7);
        assert_eq!(t.variants[0].dfs_nodes, 2);
        assert_eq!(t.variants[0].leaves_simulated, 1);
        assert_eq!(t.variants[1].bound_pruned, 2);
        assert_eq!(t.variants[1].symmetry_pruned, 4);
        assert_eq!(t.variants[0].enum_pruned, 30);
        assert_eq!(t.variants[0].stage1_points, 8);
        // stage-1 rate: 30 pruned of (8 + 2) resolved + 30 = 40 total points
        assert!((t.totals().stage1_prune_rate() - 30.0 * 100.0 / 40.0).abs() < 1e-9);
        assert_eq!(t.depth_hist, vec![1, 0, 0, 1]);
        assert_eq!(
            t.incumbents,
            vec![IncumbentEvent { elapsed_us: 123, latency: 456, variant: 1 }]
        );
        assert_eq!(t.totals().enumerated, 15);
        let summary = t.render();
        assert!(summary.contains("15 points enumerated"), "{summary}");
        assert!(summary.contains("1 improvement(s)"), "{summary}");
    }

    #[test]
    fn chrome_trace_json_is_valid_and_complete() {
        let events = vec![
            TraceEvent {
                name: "flow.solve".to_string(),
                cat: "flow",
                ph: 'X',
                ts_us: 10,
                dur_us: Some(250),
                tid: 1,
                args: vec![("kernel".to_string(), ArgVal::Str("3mm".to_string()))],
            },
            TraceEvent {
                name: "incumbent".to_string(),
                cat: "solver",
                ph: 'i',
                ts_us: 42,
                dur_us: None,
                tid: 2,
                args: vec![("latency".to_string(), ArgVal::Int(1234))],
            },
        ];
        let json = chrome_trace_json(&events, 7);
        let doc = serde::parse(&json).expect("exporter must emit valid JSON");
        let evs = doc.field("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].field("name").unwrap().as_str(), Some("flow.solve"));
        assert_eq!(evs[0].field("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[0].field("dur").unwrap().as_int(), Some(250));
        assert_eq!(evs[0].field("args").unwrap().field("kernel").unwrap().as_str(), Some("3mm"));
        assert_eq!(evs[1].field("ph").unwrap().as_str(), Some("i"));
        assert_eq!(evs[1].field("s").unwrap().as_str(), Some("p"));
        for e in evs {
            for req in ["name", "cat", "ph", "ts", "pid", "tid"] {
                assert!(e.get(req).is_some(), "event missing `{req}`: {json}");
            }
        }
        assert_eq!(
            doc.field("otherData").unwrap().field("dropped_events").unwrap().as_int(),
            Some(7)
        );
    }

    #[test]
    fn sink_collects_only_between_start_and_stop() {
        // NB: the sink is process-global; concurrent tests may add their
        // own events while tracing is on, so assertions are "contains",
        // never exact counts.
        record(TraceEvent {
            name: "before".to_string(),
            cat: "test",
            ph: 'i',
            ts_us: 0,
            dur_us: None,
            tid: 0,
            args: Vec::new(),
        });
        start_trace();
        instant("test", "obs.sink.marker", Vec::new());
        {
            let _s = span("test", "obs.sink.span").map(|s| s.arg("k", ArgVal::Int(1)));
        }
        let (events, _dropped) = stop_trace();
        if !env_trace() {
            assert!(!events.iter().any(|e| e.name == "before"));
        }
        assert!(events.iter().any(|e| e.name == "obs.sink.marker" && e.ph == 'i'));
        let sp = events.iter().find(|e| e.name == "obs.sink.span").unwrap();
        assert_eq!(sp.ph, 'X');
        assert!(sp.dur_us.is_some());
        assert_eq!(sp.args, vec![("k".to_string(), ArgVal::Int(1))]);
    }
}
