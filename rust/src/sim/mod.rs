//! Cycle-approximate FPGA dataflow simulation — the reproduction's
//! substitute for Vitis RTL simulation and on-board Alveo U55C execution.
//!
//! [`engine`] *executes* a [`crate::dse::DesignConfig`] at data-tile
//! granularity: ping-pong-buffered loads, pipelined compute, FIFO tokens
//! between fused tasks, DDR burst latency — the same structure the HLS
//! code generator emits. It is the authority the analytic model (Eqs
//! 12–16) is validated against.
//!
//! [`board`] layers the physical-design effects the paper measures on
//! hardware: per-SLR utilization, congestion-driven frequency
//! degradation, and bitstream feasibility.

pub mod board;
pub mod engine;

pub use board::{board_eval, board_eval_resolved, BoardReport};
pub use engine::{simulate, simulate_resolved, SimReport};
