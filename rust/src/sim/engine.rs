//! The dataflow execution engine.
//!
//! Because the fused-task graph is acyclic and FIFO traversal orders are
//! compatible (checked by the DSE), the simulation reduces to an *exact*
//! topological timing analysis over tile steps: for each fused task we
//! materialize its inter-tile iteration space, chain load/compute/store
//! through the ping-pong recurrences, and resolve FIFO waits against the
//! producer's emission timestamps. This executes the same pipeline an
//! event-heap simulator would, in O(total tile steps).
//!
//! All per-task numbers (tile bytes, transfer counts, FIFO topology)
//! come precomputed from the shared evaluation core
//! ([`crate::dse::eval`]) — the engine performs no plan resolution, so
//! it cannot drift from the analytic model or the code generator.
//!
//! For **Sequential** (shared-buffer) designs there is no cross-task
//! concurrency to execute: each task's duration is the closed form of
//! the shared per-task recursion (Eq 14), evaluated on the very same
//! [`crate::dse::eval::ResolvedTask`] the analytic model reads. This makes `simulate` and
//! `graph_latency` equal by construction for Sequential designs — the
//! guard pinned by `tests/consistency_model_sim.rs`.

use crate::analysis::fusion::FusedGraph;
use crate::dse::config::{DesignConfig, ExecutionModel};
use crate::dse::cost::{pipelined_compute_latency, task_latency};
use crate::dse::eval::{GeometryCache, ResolvedDesign};
use crate::hw::Device;
use crate::ir::Kernel;

/// One FIFO edge's stall attribution: cycles the consumer spent gated
/// on tokens from this producer. Telemetry only — collected when
/// tracing is on ([`crate::obs::trace_enabled`]); empty otherwise.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FifoStall {
    /// Producing task id (a range-peeled part counts separately).
    pub producer: usize,
    /// Consuming (stalled) task id.
    pub consumer: usize,
    /// Name of the array streamed over this FIFO.
    pub array: String,
    /// Stall cycles charged to this edge: for each stalled step, the
    /// full stall goes to the *binding* producer — the one whose token
    /// availability set the step's ready time (first-wins on ties).
    pub cycles: u64,
}

/// Simulation output for one design.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Total latency in cycles (last store of any sink task).
    pub cycles: u64,
    /// Per-task busy cycles (compute only) — utilization diagnostics.
    pub compute_cycles: Vec<u64>,
    /// Per-task stall cycles spent waiting on FIFO tokens.
    pub fifo_stall_cycles: Vec<u64>,
    /// Per-task cycles blocked on DDR transfers (not overlapped).
    pub ddr_blocked_cycles: Vec<u64>,
    /// Total tile steps executed (simulator work measure).
    pub steps: u64,
    /// Per-FIFO stall attribution (telemetry): which producer edge the
    /// `fifo_stall_cycles` of each consumer are waiting on. Collected
    /// only while tracing is enabled — the attribution bookkeeping
    /// (array-name clones, per-edge tallies) is off the leaf-simulation
    /// hot path otherwise — and always empty for Sequential designs,
    /// which have no FIFOs. Sums to at most `fifo_stall_cycles[t]` per
    /// consumer `t` (preload-bound steps stay unattributed).
    pub fifo_stalls: Vec<FifoStall>,
}

impl SimReport {
    pub fn gflops(&self, k: &Kernel, dev: &Device) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        k.total_flops() as f64 / (self.cycles as f64 * dev.cycle_time_s()) / 1e9
    }
}

/// Per-task tile-step cost description derived from the resolved design.
struct TaskSteps {
    /// Number of output tile steps (product of non-reduction inter trips).
    steps: u64,
    /// Compute cycles per step (pipelined reduction + intra).
    compute: u64,
    /// DDR-in cycles per step, amortized per the transfer plans.
    ddr_in: u64,
    /// DDR-out cycles per step (off-chip outputs only).
    ddr_out: u64,
    /// Cycles of level-0 preloading before the first step.
    preload: u64,
    /// FIFO inputs: (producer task, elems needed per step, producer's
    /// per-step emission rate of *this* array). One entry per
    /// producing task — a range-peeled producer part contributes one
    /// per peel, so the consumer waits on all of them.
    fifo_in: Vec<(usize, u64, u64)>,
    /// Array name per `fifo_in` entry — filled only when stall
    /// attribution is on (`attr`), empty (and never read) otherwise.
    fifo_arrays: Vec<String>,
    /// Whether ping-pong overlap is active.
    overlap: bool,
}

fn build_steps(rd: &ResolvedDesign, t: usize, dev: &Device, attr: bool) -> TaskSteps {
    let rt = rd.task(t);
    let steps = rt.steps;
    let compute = pipelined_compute_latency(rt, dev);

    let mut preload = 0u64;
    let mut ddr_in_streams: Vec<u64> = Vec::new(); // per-array totals
    let mut ddr_out_total = 0u64;
    let mut fifo_in = Vec::new();
    let mut fifo_arrays: Vec<String> = Vec::new();

    for (a, rp) in rt.arrays() {
        // FIFO input: array produced by another fused task. When the
        // producer part was range-peeled, every peel is a producer
        // (`fifo_producers`, precomputed at fusion time) — token-gate
        // on each of them, so the consumer cannot be simulated
        // starting ahead of an unfinished peel. The token rate is the
        // producer's per-step emission of *this* array: a cross-array
        // merged engine splits its bandwidth across its outputs, and a
        // producer broadcasting one array to several consumers
        // produces each element once (the pre-PR 5 model summed the
        // footprint per edge, crediting broadcast consumers with a
        // doubled rate). A peeled *consumer* likewise demands only its
        // outer-range share of an array the ranged loop indexes.
        if a.fifo_producer.is_some() {
            // demand: the whole array, narrowed to this task's
            // outer-range share when the ranged loop indexes it
            let outer_indexed = a.access.iter().any(|p| *p == Some(0));
            let demand = match rt.statics().outer_range {
                Some((lo, hi)) if outer_indexed => {
                    let full = rd.k.statements[rt.statics().rep]
                        .loops
                        .first()
                        .map(|l| l.trip)
                        .unwrap_or(0);
                    if full > 0 {
                        a.total_elems * (hi - lo).min(full) / full
                    } else {
                        a.total_elems
                    }
                }
                _ => a.total_elems,
            };
            let per_step = demand.div_ceil(steps);
            for &p in &a.fifo_producers {
                let prt = rd.task(p);
                let emitted = prt
                    .statics()
                    .fifo_out_elems_by_array
                    .iter()
                    .find(|(n, _)| n == &a.name)
                    .map(|(_, e)| *e)
                    .unwrap_or(0);
                let rate = emitted.div_ceil(prt.steps.max(1));
                fifo_in.push((p, per_step, rate));
                if attr {
                    fifo_arrays.push(a.name.clone());
                }
            }
            continue; // FIFO tiles don't hit DDR
        }
        let per_tile = dev.transfer_cycles(rp.tile_bytes, rp.bitwidth);
        let times = rp.transfer_count;

        if a.inbound() {
            if rp.define_level == 0 {
                // preloads of distinct arrays stream over distinct HBM
                // channels concurrently (U55C: 32 channels, one per
                // array after the read-only duplication of §3.7)
                preload = preload.max(per_tile);
            } else {
                ddr_in_streams.push(times * per_tile);
            }
        }
        if a.writes && a.is_output {
            ddr_out_total += times * per_tile;
        }
    }
    // concurrent channels: per-step inbound cost is the slowest stream,
    // as long as channels remain (beyond that, streams serialize —
    // ceiling division, matching the cost model)
    let ddr_in_total = if ddr_in_streams.len() <= dev.mem_channels {
        ddr_in_streams.iter().copied().max().unwrap_or(0)
    } else {
        ddr_in_streams.iter().sum::<u64>().div_ceil(dev.mem_channels as u64)
    };

    TaskSteps {
        steps,
        compute,
        ddr_in: ddr_in_total / steps,
        ddr_out: ddr_out_total / steps,
        preload,
        fifo_in,
        fifo_arrays,
        overlap: rd.design.overlap,
    }
}

/// Execute the design (cold-resolving wrapper over
/// [`simulate_resolved`]).
pub fn simulate(k: &Kernel, fg: &FusedGraph, design: &DesignConfig, dev: &Device) -> SimReport {
    let cache = GeometryCache::new(k, fg);
    let rd = ResolvedDesign::new(k, fg, &cache, design);
    simulate_resolved(&rd, dev)
}

/// Execute a resolved design. Returns the simulated report.
pub fn simulate_resolved(rd: &ResolvedDesign, dev: &Device) -> SimReport {
    match rd.design.model {
        ExecutionModel::Sequential => simulate_sequential(rd, dev),
        ExecutionModel::Dataflow => simulate_dataflow(rd, dev),
    }
}

/// Shared-buffer execution: tasks run back-to-back, so the tile
/// pipeline degenerates to the closed-form per-task recursion evaluated
/// on the shared [`crate::dse::eval::ResolvedTask`] — equal to the
/// analytic model by construction.
fn simulate_sequential(rd: &ResolvedDesign, dev: &Device) -> SimReport {
    let n = rd.fg.tasks.len();
    let mut duration = vec![0u64; n];
    let mut finish = vec![0u64; n];
    let mut compute_cycles = vec![0u64; n];
    let mut ddr_blocked = vec![0u64; n];
    let mut total_steps = 0u64;
    // Index by task id, exactly like `graph_latency_resolved` — a
    // persisted design whose `tasks` vector is not ordered by id must
    // still serialize in task-id (program) order.
    for rt in &rd.tasks {
        let t = rt.cfg().task;
        let dur = task_latency(rt, dev, rd.design.overlap);
        let compute = pipelined_compute_latency(rt, dev) * rt.steps;
        duration[t] = dur;
        compute_cycles[t] = compute;
        ddr_blocked[t] = dur.saturating_sub(compute);
        total_steps += rt.steps;
    }
    let mut clock = 0u64;
    for t in 0..n {
        clock += duration[t];
        finish[t] = clock;
    }
    let cycles = rd
        .fg
        .sinks()
        .into_iter()
        .map(|s| finish[s])
        .max()
        .unwrap_or(0);
    SimReport {
        cycles,
        compute_cycles,
        fifo_stall_cycles: vec![0; n],
        ddr_blocked_cycles: ddr_blocked,
        steps: total_steps,
        fifo_stalls: Vec::new(),
    }
}

/// Dataflow execution: the tile-step pipeline with FIFO token waits.
fn simulate_dataflow(rd: &ResolvedDesign, dev: &Device) -> SimReport {
    let n = rd.fg.tasks.len();
    // Per-FIFO stall attribution rides on the tracing switch: leaf
    // simulations inside a telemetry-off solve never pay for the
    // array-name clones or per-edge tallies.
    let attr_on = crate::obs::trace_enabled();
    let specs: Vec<TaskSteps> = (0..n).map(|t| build_steps(rd, t, dev, attr_on)).collect();
    let mut fifo_stalls: Vec<FifoStall> = Vec::new();

    // producer emission timestamps: per task, the time at which the i-th
    // step's outputs are emitted (filled in topological order).
    let mut emit_times: Vec<Vec<u64>> = vec![Vec::new(); n];
    let mut finish = vec![0u64; n];
    let mut compute_cycles = vec![0u64; n];
    let mut fifo_stall = vec![0u64; n];
    let mut ddr_blocked = vec![0u64; n];
    let mut total_steps = 0u64;

    for t in 0..n {
        let spec = &specs[t];
        let slr_pen: u64 = rd
            .fg
            .predecessors(t)
            .iter()
            .filter(|&&p| rd.task(p).cfg().slr != rd.task(t).cfg().slr)
            .count() as u64
            * dev.inter_slr_latency;

        let start_base = slr_pen;

        // cumulative FIFO availability: time when `e` elements of the
        // producer's output of the consumed array have been emitted
        // (`rate` = that producer's per-step emission of the array; a
        // demand beyond what the producer emits clamps to its final
        // emission, so a peel gates its consumer until it finishes).
        let avail = |p: usize, elems_needed: u64, rate: u64| -> u64 {
            let per = rate.max(1);
            let idx = elems_needed.div_ceil(per).max(1) as usize - 1;
            let times = &emit_times[p];
            if times.is_empty() {
                0
            } else {
                times[idx.min(times.len() - 1)]
            }
        };

        let mut load_done_prev = 0u64;
        let mut compute_done_prev = 0u64;
        let mut store_done_prev = 0u64;
        let mut emits = Vec::with_capacity(spec.steps as usize);
        let mut edge_stall: Vec<u64> =
            if attr_on { vec![0; spec.fifo_in.len()] } else { Vec::new() };
        let preload_done = start_base + spec.preload;
        if spec.preload > 0 {
            ddr_blocked[t] += spec.preload;
        }

        for i in 0..spec.steps {
            total_steps += 1;
            // FIFO wait: cumulative elements needed through step i+1.
            // `binding` tracks which edge set the ready time (strict
            // improvement + in-order scan = first-wins on ties, so the
            // attribution is deterministic); None = preload-bound.
            let mut in_ready = preload_done;
            let mut binding: Option<usize> = None;
            for (ei, &(p, per_step, rate)) in spec.fifo_in.iter().enumerate() {
                let need = per_step * (i + 1);
                let ready = avail(p, need, rate);
                if ready > in_ready {
                    in_ready = ready;
                    binding = Some(ei);
                }
            }
            // load of tile i may begin once the previous tile's buffer is
            // free (ping-pong: after compute of i-1) and data is ready
            let load_start = if spec.overlap {
                load_done_prev.max(compute_done_prev.saturating_sub(spec.compute)).max(in_ready)
            } else {
                store_done_prev.max(in_ready)
            };
            let load_done = load_start + spec.ddr_in;
            let stall = in_ready.saturating_sub(load_done_prev.max(compute_done_prev));
            fifo_stall[t] += stall;
            if attr_on && stall > 0 {
                if let Some(ei) = binding {
                    edge_stall[ei] += stall;
                }
            }

            let compute_start = load_done.max(compute_done_prev);
            let compute_done = compute_start + spec.compute;
            compute_cycles[t] += spec.compute;

            let store_start = compute_done.max(store_done_prev);
            let store_done = store_start + spec.ddr_out;
            if !spec.overlap {
                ddr_blocked[t] += spec.ddr_in + spec.ddr_out;
            }

            emits.push(store_done);
            load_done_prev = load_done;
            compute_done_prev = compute_done;
            store_done_prev = store_done;
        }
        finish[t] = store_done_prev.max(preload_done);
        emit_times[t] = emits;
        if attr_on {
            for (ei, &(p, _, _)) in spec.fifo_in.iter().enumerate() {
                if edge_stall[ei] > 0 {
                    fifo_stalls.push(FifoStall {
                        producer: p,
                        consumer: t,
                        array: spec.fifo_arrays[ei].clone(),
                        cycles: edge_stall[ei],
                    });
                }
            }
        }
    }

    let cycles = rd
        .fg
        .sinks()
        .into_iter()
        .map(|s| finish[s])
        .max()
        .unwrap_or(0);
    SimReport {
        cycles,
        compute_cycles,
        fifo_stall_cycles: fifo_stall,
        ddr_blocked_cycles: ddr_blocked,
        steps: total_steps,
        fifo_stalls,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::cost::graph_latency;
    use crate::dse::eval::resolve_task;
    use crate::dse::solver::{solve, SolverOptions};
    use crate::ir::polybench;
    use std::time::Duration;

    fn opts() -> SolverOptions {
        SolverOptions {
            beam: 12,
            max_factor_per_loop: 32,
            max_unroll: 1024,
            timeout: Duration::from_secs(30),
            ..SolverOptions::default()
        }
    }

    #[test]
    fn sim_and_model_agree_on_gemm() {
        // The analytic model (Eqs 12–16) and the executing simulator must
        // agree within a modest factor on a non-congested design — this is
        // the model-fidelity check DESIGN.md §6 promises.
        let k = polybench::gemm();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &opts()).unwrap();
        let fg = &r.fused;
        let sim = simulate(&k, fg, &r.design, &dev);
        let model = graph_latency(&k, fg, &r.design, &dev).total;
        let ratio = sim.cycles as f64 / model as f64;
        assert!(
            (0.4..2.5).contains(&ratio),
            "sim {} vs model {} (ratio {ratio})",
            sim.cycles,
            model
        );
    }

    #[test]
    fn dataflow_beats_sequential_in_sim() {
        let k = polybench::three_madd();
        let dev = Device::u55c();
        let df = solve(&k, &dev, &opts()).unwrap();
        let fg = &df.fused;
        let mut seq_design = df.design.clone();
        seq_design.model = ExecutionModel::Sequential;
        let s_df = simulate(&k, fg, &df.design, &dev);
        let s_seq = simulate(&k, fg, &seq_design, &dev);
        assert!(s_df.cycles < s_seq.cycles);
    }

    #[test]
    fn consumer_stalls_on_producer() {
        // 2-madd: the second add cannot finish before the first emits.
        let k = polybench::two_madd();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &opts()).unwrap();
        let sim = simulate(&k, &r.fused, &r.design, &dev);
        assert!(sim.cycles > 0);
        assert_eq!(sim.compute_cycles.len(), 2);
    }

    #[test]
    fn sim_counts_steps() {
        let k = polybench::madd();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &opts()).unwrap();
        let sim = simulate(&k, &r.fused, &r.design, &dev);
        let cache = GeometryCache::new(&k, &r.fused);
        let rt = resolve_task(&k, &cache.tasks[0], &r.design.tasks[0]);
        assert_eq!(sim.steps, rt.steps);
    }
}
