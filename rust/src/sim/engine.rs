//! The dataflow execution engine.
//!
//! Because the fused-task graph is acyclic and FIFO traversal orders are
//! compatible (checked by the DSE), the simulation reduces to an *exact*
//! topological timing analysis over tile steps: for each fused task we
//! materialize its inter-tile iteration space, chain load/compute/store
//! through the ping-pong recurrences, and resolve FIFO waits against the
//! producer's emission timestamps. This executes the same pipeline an
//! event-heap simulator would, in O(total tile steps).
//!
//! All per-task numbers (tile bytes, transfer counts, FIFO topology)
//! come precomputed from the shared evaluation core
//! ([`crate::dse::eval`]) — the engine performs no plan resolution, so
//! it cannot drift from the analytic model or the code generator.
//!
//! For **Sequential** (shared-buffer) designs there is no cross-task
//! concurrency to execute: each task's duration is the closed form of
//! the shared per-task recursion (Eq 14), evaluated on the very same
//! [`crate::dse::eval::ResolvedTask`] the analytic model reads. This makes `simulate` and
//! `graph_latency` equal by construction for Sequential designs — the
//! guard pinned by `tests/consistency_model_sim.rs`.

use crate::analysis::fusion::FusedGraph;
use crate::dse::config::{DesignConfig, ExecutionModel};
use crate::dse::cost::{pipelined_compute_latency, task_latency};
use crate::dse::eval::{GeometryCache, ResolvedDesign, ResolvedTask, TaskStatics};
use crate::hw::Device;
use crate::ir::Kernel;

/// One FIFO edge's stall attribution: cycles the consumer spent gated
/// on tokens from this producer. Telemetry only — collected when
/// tracing is on ([`crate::obs::trace_enabled`]); empty otherwise.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FifoStall {
    /// Producing task id (a range-peeled part counts separately).
    pub producer: usize,
    /// Consuming (stalled) task id.
    pub consumer: usize,
    /// Name of the array streamed over this FIFO.
    pub array: String,
    /// Stall cycles charged to this edge: for each stalled step, the
    /// full stall goes to the *binding* producer — the one whose token
    /// availability set the step's ready time (first-wins on ties).
    pub cycles: u64,
}

/// Simulation output for one design.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Total latency in cycles (last store of any sink task).
    pub cycles: u64,
    /// Per-task busy cycles (compute only) — utilization diagnostics.
    pub compute_cycles: Vec<u64>,
    /// Per-task stall cycles spent waiting on FIFO tokens.
    pub fifo_stall_cycles: Vec<u64>,
    /// Per-task cycles blocked on DDR transfers (not overlapped).
    pub ddr_blocked_cycles: Vec<u64>,
    /// Total tile steps executed (simulator work measure).
    pub steps: u64,
    /// Per-FIFO stall attribution (telemetry): which producer edge the
    /// `fifo_stall_cycles` of each consumer are waiting on. Collected
    /// only while tracing is enabled — the attribution bookkeeping
    /// (array-name clones, per-edge tallies) is off the leaf-simulation
    /// hot path otherwise — and always empty for Sequential designs,
    /// which have no FIFOs. Sums to at most `fifo_stall_cycles[t]` per
    /// consumer `t` (preload-bound steps stay unattributed).
    pub fifo_stalls: Vec<FifoStall>,
}

impl SimReport {
    pub fn gflops(&self, k: &Kernel, dev: &Device) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        k.total_flops() as f64 / (self.cycles as f64 * dev.cycle_time_s()) / 1e9
    }
}

/// Per-task tile-step cost description derived from a resolved task.
///
/// Built once per design task by [`simulate_dataflow`], or once per
/// *Pareto candidate* by the solver's leaf fast path (via
/// [`candidate_steps`]) and reused across every DFS leaf that assigns
/// the candidate. Everything in here is assignment-independent: SLR
/// placement enters the simulation only through the `slr_pen` argument
/// of [`run_dataflow`], and the FIFO entries carry the producer's
/// *total* emission (a fusion-variant static) rather than a per-step
/// rate, so a consumer candidate's spec does not depend on which
/// candidate the producer task ends up assigned.
pub(crate) struct TaskSteps {
    /// Number of output tile steps (product of non-reduction inter trips).
    steps: u64,
    /// Compute cycles per step (pipelined reduction + intra).
    compute: u64,
    /// DDR-in cycles per step, amortized per the transfer plans.
    ddr_in: u64,
    /// DDR-out cycles per step (off-chip outputs only).
    ddr_out: u64,
    /// Cycles of level-0 preloading before the first step.
    preload: u64,
    /// FIFO inputs: (producer task, elems needed per step, producer's
    /// *total* emission of this array). One entry per producing task —
    /// a range-peeled producer part contributes one per peel, so the
    /// consumer waits on all of them. The per-step token rate is
    /// derived inside [`run_dataflow`] as
    /// `emitted.div_ceil(specs[producer].steps)` — bit-identical to
    /// computing it here, since the producer spec's `steps` is the
    /// same resolved trip product either way.
    fifo_in: Vec<(usize, u64, u64)>,
    /// Array name per `fifo_in` entry — filled only when stall
    /// attribution is on (`attr`), empty (and never read) otherwise.
    fifo_arrays: Vec<String>,
    /// Whether ping-pong overlap is active.
    overlap: bool,
}

/// Build the step spec for one resolved task. Producer statics are
/// looked up through `statics_of` so the same code serves both callers:
/// a full [`ResolvedDesign`] (statics via the design's own tasks) and
/// the solver's per-candidate path (statics via the `GeometryCache`) —
/// the two lookups return the same fusion-time object.
fn build_steps_from<'s>(
    k: &Kernel,
    rt: &ResolvedTask<'_>,
    overlap: bool,
    dev: &Device,
    attr: bool,
    statics_of: impl Fn(usize) -> &'s TaskStatics,
) -> TaskSteps {
    let steps = rt.steps;
    let compute = pipelined_compute_latency(rt, dev);

    let mut preload = 0u64;
    let mut ddr_in_streams: Vec<u64> = Vec::new(); // per-array totals
    let mut ddr_out_total = 0u64;
    let mut fifo_in = Vec::new();
    let mut fifo_arrays: Vec<String> = Vec::new();

    for (a, rp) in rt.arrays() {
        // FIFO input: array produced by another fused task. When the
        // producer part was range-peeled, every peel is a producer
        // (`fifo_producers`, precomputed at fusion time) — token-gate
        // on each of them, so the consumer cannot be simulated
        // starting ahead of an unfinished peel. The token rate is the
        // producer's per-step emission of *this* array: a cross-array
        // merged engine splits its bandwidth across its outputs, and a
        // producer broadcasting one array to several consumers
        // produces each element once (the pre-PR 5 model summed the
        // footprint per edge, crediting broadcast consumers with a
        // doubled rate). A peeled *consumer* likewise demands only its
        // outer-range share of an array the ranged loop indexes.
        if a.fifo_producer.is_some() {
            // demand: the whole array, narrowed to this task's
            // outer-range share when the ranged loop indexes it
            let outer_indexed = a.access.iter().any(|p| *p == Some(0));
            let demand = match rt.statics().outer_range {
                Some((lo, hi)) if outer_indexed => {
                    let full = k.statements[rt.statics().rep]
                        .loops
                        .first()
                        .map(|l| l.trip)
                        .unwrap_or(0);
                    if full > 0 {
                        a.total_elems * (hi - lo).min(full) / full
                    } else {
                        a.total_elems
                    }
                }
                _ => a.total_elems,
            };
            let per_step = demand.div_ceil(steps);
            for &p in &a.fifo_producers {
                let emitted = statics_of(p).fifo_emitted(&a.name);
                fifo_in.push((p, per_step, emitted));
                if attr {
                    fifo_arrays.push(a.name.clone());
                }
            }
            continue; // FIFO tiles don't hit DDR
        }
        let per_tile = dev.transfer_cycles(rp.tile_bytes, rp.bitwidth);
        let times = rp.transfer_count;

        if a.inbound() {
            if rp.define_level == 0 {
                // preloads of distinct arrays stream over distinct HBM
                // channels concurrently (U55C: 32 channels, one per
                // array after the read-only duplication of §3.7)
                preload = preload.max(per_tile);
            } else {
                ddr_in_streams.push(times * per_tile);
            }
        }
        if a.writes && a.is_output {
            ddr_out_total += times * per_tile;
        }
    }
    // concurrent channels: per-step inbound cost is the slowest stream,
    // as long as channels remain (beyond that, streams serialize —
    // ceiling division, matching the cost model)
    let ddr_in_total = if ddr_in_streams.len() <= dev.mem_channels {
        ddr_in_streams.iter().copied().max().unwrap_or(0)
    } else {
        ddr_in_streams.iter().sum::<u64>().div_ceil(dev.mem_channels as u64)
    };

    TaskSteps {
        steps,
        compute,
        ddr_in: ddr_in_total / steps,
        ddr_out: ddr_out_total / steps,
        preload,
        fifo_in,
        fifo_arrays,
        overlap,
    }
}

fn build_steps(rd: &ResolvedDesign, t: usize, dev: &Device, attr: bool) -> TaskSteps {
    build_steps_from(rd.k, rd.task(t), rd.design.overlap, dev, attr, |p| rd.task(p).statics())
}

/// Build the step spec for one *candidate* resolution, without a
/// [`ResolvedDesign`]: producer statics come straight from the
/// fusion-variant `GeometryCache`. This is the solver's leaf-fast-path
/// entry point — one call per (task, Pareto candidate) pair, amortized
/// over every DFS leaf that assigns the candidate. Stall attribution is
/// never collected here (the solver discards everything but cycles).
pub(crate) fn candidate_steps(
    k: &Kernel,
    cache: &GeometryCache,
    rt: &ResolvedTask<'_>,
    overlap: bool,
    dev: &Device,
) -> TaskSteps {
    build_steps_from(k, rt, overlap, dev, false, |p| &cache.tasks[p])
}

/// Reusable buffers for [`run_dataflow`]: one instance per DFS worker
/// amortizes every per-leaf allocation of the dataflow simulation
/// (emission timestamp vectors, per-task stats) across the whole
/// search.
pub(crate) struct DataflowScratch {
    /// Per task: emission timestamp of each tile step's outputs.
    emit_times: Vec<Vec<u64>>,
    finish: Vec<u64>,
    compute_cycles: Vec<u64>,
    fifo_stall: Vec<u64>,
    ddr_blocked: Vec<u64>,
    /// Per-FIFO-edge token rate for the task being simulated.
    rates: Vec<u64>,
    /// Per-FIFO-edge stall tally (attribution only).
    edge_stall: Vec<u64>,
    fifo_stalls: Vec<FifoStall>,
    total_steps: u64,
}

impl DataflowScratch {
    pub(crate) fn new() -> Self {
        DataflowScratch {
            emit_times: Vec::new(),
            finish: Vec::new(),
            compute_cycles: Vec::new(),
            fifo_stall: Vec::new(),
            ddr_blocked: Vec::new(),
            rates: Vec::new(),
            edge_stall: Vec::new(),
            fifo_stalls: Vec::new(),
            total_steps: 0,
        }
    }

    /// Reset for an `n`-task run, keeping every buffer's capacity.
    fn reset(&mut self, n: usize) {
        self.emit_times.truncate(n);
        for v in &mut self.emit_times {
            v.clear();
        }
        while self.emit_times.len() < n {
            self.emit_times.push(Vec::new());
        }
        for v in [
            &mut self.finish,
            &mut self.compute_cycles,
            &mut self.fifo_stall,
            &mut self.ddr_blocked,
        ] {
            v.clear();
            v.resize(n, 0);
        }
        self.fifo_stalls.clear();
        self.total_steps = 0;
    }
}

/// The dataflow step loop, shared verbatim between [`simulate_dataflow`]
/// and the solver's DFS leaf scoring — there is exactly one copy of the
/// timing recurrence, so the fast path cannot drift from the simulator.
///
/// `specs[t]` is task `t`'s step spec, `slr_pen[t]` its inter-SLR input
/// penalty (the only assignment-dependent input), `sinks` the graph's
/// output tasks. Returns total cycles; per-task stats stay in `scratch`
/// for callers that want them.
pub(crate) fn run_dataflow(
    specs: &[&TaskSteps],
    slr_pen: &[u64],
    sinks: &[usize],
    attr: bool,
    scratch: &mut DataflowScratch,
) -> u64 {
    let n = specs.len();
    scratch.reset(n);

    for t in 0..n {
        let spec = specs[t];
        let start_base = slr_pen[t];

        // token rates, derived once per edge from the producer's spec:
        // a demand beyond what the producer emits clamps to its final
        // emission, so a peel gates its consumer until it finishes
        scratch.rates.clear();
        for &(p, _, emitted) in &spec.fifo_in {
            scratch.rates.push(emitted.div_ceil(specs[p].steps.max(1)));
        }

        // producers precede consumers in task-id order, so every
        // emission vector this task reads is already filled
        let (done, rest) = scratch.emit_times.split_at_mut(t);
        let emits = &mut rest[0];

        // cumulative FIFO availability: time when `e` elements of the
        // producer's output of the consumed array have been emitted
        let avail = |p: usize, elems_needed: u64, rate: u64| -> u64 {
            let per = rate.max(1);
            let idx = elems_needed.div_ceil(per).max(1) as usize - 1;
            let times = &done[p];
            if times.is_empty() {
                0
            } else {
                times[idx.min(times.len() - 1)]
            }
        };

        let mut load_done_prev = 0u64;
        let mut compute_done_prev = 0u64;
        let mut store_done_prev = 0u64;
        emits.reserve(spec.steps as usize);
        if attr {
            scratch.edge_stall.clear();
            scratch.edge_stall.resize(spec.fifo_in.len(), 0);
        }
        let preload_done = start_base + spec.preload;
        if spec.preload > 0 {
            scratch.ddr_blocked[t] += spec.preload;
        }

        for i in 0..spec.steps {
            scratch.total_steps += 1;
            // FIFO wait: cumulative elements needed through step i+1.
            // `binding` tracks which edge set the ready time (strict
            // improvement + in-order scan = first-wins on ties, so the
            // attribution is deterministic); None = preload-bound.
            let mut in_ready = preload_done;
            let mut binding: Option<usize> = None;
            for (ei, &(p, per_step, _)) in spec.fifo_in.iter().enumerate() {
                let need = per_step * (i + 1);
                let ready = avail(p, need, scratch.rates[ei]);
                if ready > in_ready {
                    in_ready = ready;
                    binding = Some(ei);
                }
            }
            // load of tile i may begin once the previous tile's buffer is
            // free (ping-pong: after compute of i-1) and data is ready
            let load_start = if spec.overlap {
                load_done_prev.max(compute_done_prev.saturating_sub(spec.compute)).max(in_ready)
            } else {
                store_done_prev.max(in_ready)
            };
            let load_done = load_start + spec.ddr_in;
            let stall = in_ready.saturating_sub(load_done_prev.max(compute_done_prev));
            scratch.fifo_stall[t] += stall;
            if attr && stall > 0 {
                if let Some(ei) = binding {
                    scratch.edge_stall[ei] += stall;
                }
            }

            let compute_start = load_done.max(compute_done_prev);
            let compute_done = compute_start + spec.compute;
            scratch.compute_cycles[t] += spec.compute;

            let store_start = compute_done.max(store_done_prev);
            let store_done = store_start + spec.ddr_out;
            if !spec.overlap {
                scratch.ddr_blocked[t] += spec.ddr_in + spec.ddr_out;
            }

            emits.push(store_done);
            load_done_prev = load_done;
            compute_done_prev = compute_done;
            store_done_prev = store_done;
        }
        scratch.finish[t] = store_done_prev.max(preload_done);
        if attr {
            for (ei, &(p, _, _)) in spec.fifo_in.iter().enumerate() {
                if scratch.edge_stall[ei] > 0 {
                    scratch.fifo_stalls.push(FifoStall {
                        producer: p,
                        consumer: t,
                        array: spec.fifo_arrays[ei].clone(),
                        cycles: scratch.edge_stall[ei],
                    });
                }
            }
        }
    }

    sinks.iter().map(|&s| scratch.finish[s]).max().unwrap_or(0)
}

/// Execute the design (cold-resolving wrapper over
/// [`simulate_resolved`]).
pub fn simulate(k: &Kernel, fg: &FusedGraph, design: &DesignConfig, dev: &Device) -> SimReport {
    let cache = GeometryCache::new(k, fg);
    let rd = ResolvedDesign::new(k, fg, &cache, design);
    simulate_resolved(&rd, dev)
}

/// Execute a resolved design. Returns the simulated report.
pub fn simulate_resolved(rd: &ResolvedDesign, dev: &Device) -> SimReport {
    match rd.design.model {
        ExecutionModel::Sequential => simulate_sequential(rd, dev),
        ExecutionModel::Dataflow => simulate_dataflow(rd, dev),
    }
}

/// Shared-buffer execution: tasks run back-to-back, so the tile
/// pipeline degenerates to the closed-form per-task recursion evaluated
/// on the shared [`crate::dse::eval::ResolvedTask`] — equal to the
/// analytic model by construction.
fn simulate_sequential(rd: &ResolvedDesign, dev: &Device) -> SimReport {
    let n = rd.fg.tasks.len();
    let mut duration = vec![0u64; n];
    let mut compute_cycles = vec![0u64; n];
    let mut ddr_blocked = vec![0u64; n];
    let mut total_steps = 0u64;
    // Index by task id, exactly like `graph_latency_resolved` — a
    // persisted design whose `tasks` vector is not ordered by id must
    // still serialize in task-id (program) order.
    for rt in &rd.tasks {
        let t = rt.cfg().task;
        let dur = task_latency(rt, dev, rd.design.overlap);
        let compute = pipelined_compute_latency(rt, dev) * rt.steps;
        duration[t] = dur;
        compute_cycles[t] = compute;
        ddr_blocked[t] = dur.saturating_sub(compute);
        total_steps += rt.steps;
    }
    // the same closed form the analytic model and the solver's leaf
    // fast path evaluate — equal by construction
    let cycles = crate::dse::cost::sequential_total(&duration, &rd.fg.sinks());
    SimReport {
        cycles,
        compute_cycles,
        fifo_stall_cycles: vec![0; n],
        ddr_blocked_cycles: ddr_blocked,
        steps: total_steps,
        fifo_stalls: Vec::new(),
    }
}

/// Dataflow execution: the tile-step pipeline with FIFO token waits,
/// one [`run_dataflow`] pass over per-task specs.
fn simulate_dataflow(rd: &ResolvedDesign, dev: &Device) -> SimReport {
    let n = rd.fg.tasks.len();
    // Per-FIFO stall attribution rides on the tracing switch: leaf
    // simulations inside a telemetry-off solve never pay for the
    // array-name clones or per-edge tallies.
    let attr_on = crate::obs::trace_enabled();
    let specs: Vec<TaskSteps> = (0..n).map(|t| build_steps(rd, t, dev, attr_on)).collect();
    let spec_refs: Vec<&TaskSteps> = specs.iter().collect();
    let slr_pen: Vec<u64> = (0..n)
        .map(|t| {
            rd.fg
                .predecessors(t)
                .iter()
                .filter(|&&p| rd.task(p).cfg().slr != rd.task(t).cfg().slr)
                .count() as u64
                * dev.inter_slr_latency
        })
        .collect();
    let sinks = rd.fg.sinks();

    let mut scratch = DataflowScratch::new();
    let cycles = run_dataflow(&spec_refs, &slr_pen, &sinks, attr_on, &mut scratch);
    SimReport {
        cycles,
        compute_cycles: std::mem::take(&mut scratch.compute_cycles),
        fifo_stall_cycles: std::mem::take(&mut scratch.fifo_stall),
        ddr_blocked_cycles: std::mem::take(&mut scratch.ddr_blocked),
        steps: scratch.total_steps,
        fifo_stalls: std::mem::take(&mut scratch.fifo_stalls),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::cost::graph_latency;
    use crate::dse::eval::resolve_task;
    use crate::dse::solver::{solve, SolverOptions};
    use crate::ir::polybench;
    use std::time::Duration;

    fn opts() -> SolverOptions {
        SolverOptions {
            beam: 12,
            max_factor_per_loop: 32,
            max_unroll: 1024,
            timeout: Duration::from_secs(30),
            ..SolverOptions::default()
        }
    }

    #[test]
    fn sim_and_model_agree_on_gemm() {
        // The analytic model (Eqs 12–16) and the executing simulator must
        // agree within a modest factor on a non-congested design — this is
        // the model-fidelity check DESIGN.md §6 promises.
        let k = polybench::gemm();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &opts()).unwrap();
        let fg = &r.fused;
        let sim = simulate(&k, fg, &r.design, &dev);
        let model = graph_latency(&k, fg, &r.design, &dev).total;
        let ratio = sim.cycles as f64 / model as f64;
        assert!(
            (0.4..2.5).contains(&ratio),
            "sim {} vs model {} (ratio {ratio})",
            sim.cycles,
            model
        );
    }

    #[test]
    fn dataflow_beats_sequential_in_sim() {
        let k = polybench::three_madd();
        let dev = Device::u55c();
        let df = solve(&k, &dev, &opts()).unwrap();
        let fg = &df.fused;
        let mut seq_design = df.design.clone();
        seq_design.model = ExecutionModel::Sequential;
        let s_df = simulate(&k, fg, &df.design, &dev);
        let s_seq = simulate(&k, fg, &seq_design, &dev);
        assert!(s_df.cycles < s_seq.cycles);
    }

    #[test]
    fn consumer_stalls_on_producer() {
        // 2-madd: the second add cannot finish before the first emits.
        let k = polybench::two_madd();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &opts()).unwrap();
        let sim = simulate(&k, &r.fused, &r.design, &dev);
        assert!(sim.cycles > 0);
        assert_eq!(sim.compute_cycles.len(), 2);
    }

    #[test]
    fn sim_counts_steps() {
        let k = polybench::madd();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &opts()).unwrap();
        let sim = simulate(&k, &r.fused, &r.design, &dev);
        let cache = GeometryCache::new(&k, &r.fused);
        let rt = resolve_task(&k, &cache.tasks[0], &r.design.tasks[0]);
        assert_eq!(sim.steps, rt.steps);
    }
}
