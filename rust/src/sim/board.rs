//! On-board evaluation model: the physical-design effects that separate
//! RTL simulation from hardware (paper §2.2, §6.3, Table 8).
//!
//! Three first-order effects are modelled, all deterministic:
//!
//! 1. **bitstream feasibility** — a region whose LUT/FF/DSP/BRAM demand
//!    exceeds its budget fails placement; demand within the budget but
//!    above a congestion knee risks failure, which the coordinator's
//!    regeneration loop (paper §5.7) resolves by tightening constraints;
//! 2. **frequency degradation** — routing pressure (high LUT utilization,
//!    very wide partitioning, inter-SLR crossings) lowers achieved fmax
//!    below the 220 MHz target, exactly the effect visible in Table 8
//!    (e.g. atax 3-SLR at 137 MHz);
//! 3. **inter-SLR latency** — already charged per crossing by the engine.

use crate::analysis::fusion::FusedGraph;
use crate::dse::config::DesignConfig;
use crate::dse::constraints::slr_usage_resolved;
use crate::dse::eval::{GeometryCache, ResolvedDesign};
use crate::hw::{Device, SlrBudget};
use crate::ir::Kernel;

use super::engine::{simulate_resolved, SimReport};

/// Result of a modelled on-board run.
#[derive(Debug, Clone)]
pub struct BoardReport {
    /// Whether place-and-route succeeded under the given budget.
    pub bitstream_ok: bool,
    /// Max utilization fraction over regions (vs the scenario budget).
    pub peak_utilization: f64,
    /// Achieved clock after congestion derating (MHz).
    pub fmhz: f64,
    /// Cycle-level result from the engine.
    pub sim: SimReport,
    /// Execution time at the achieved clock (ms).
    pub time_ms: f64,
    /// Throughput at the achieved clock (GF/s).
    pub gflops: f64,
    /// Number of FIFO edges crossing SLR boundaries.
    pub slr_crossings: usize,
}

/// Congestion knee: above this fraction of the budget, frequency starts
/// degrading steeply and feasibility becomes marginal.
const CONGESTION_KNEE: f64 = 0.80;

/// Evaluate `design` as an on-board run with per-region budget `budget`
/// (cold-resolving wrapper over [`board_eval_resolved`]).
pub fn board_eval(
    k: &Kernel,
    fg: &FusedGraph,
    design: &DesignConfig,
    dev: &Device,
    budget: &SlrBudget,
) -> BoardReport {
    let cache = GeometryCache::new(k, fg);
    let rd = ResolvedDesign::new(k, fg, &cache, design);
    board_eval_resolved(&rd, dev, budget)
}

/// Evaluate a resolved design as an on-board run.
pub fn board_eval_resolved(rd: &ResolvedDesign, dev: &Device, budget: &SlrBudget) -> BoardReport {
    let usage = slr_usage_resolved(rd, dev);
    let peak_utilization = usage
        .iter()
        .map(|u| u.utilization(budget))
        .fold(0.0, f64::max);

    let slr_crossings = rd
        .fg
        .edges
        .iter()
        .filter(|(s, d, _)| rd.task(*s).cfg().slr != rd.task(*d).cfg().slr)
        .count();

    // widest partitioning in the design (routing fan-out pressure),
    // read straight off the resolved plans
    let max_part = rd
        .tasks
        .iter()
        .map(|rt| rt.plans.iter().map(|rp| rp.partitions).max().unwrap_or(1))
        .max()
        .unwrap_or(1);

    // Feasibility: hard fail over budget; soft region between the knee
    // and 1.0 passes (the paper regenerates only on hard congestion).
    let bitstream_ok = peak_utilization <= 1.0;

    // Frequency derating: smooth penalty above the knee plus routing
    // pressure terms. Calibrated against Table 8's observed clocks
    // (220 → 137 MHz range).
    let over = (peak_utilization - CONGESTION_KNEE).max(0.0) / (1.0 - CONGESTION_KNEE);
    let util_pen = 50.0 * over;
    let part_pen = if max_part > 256 {
        18.0 * ((max_part as f64) / 256.0).log2()
    } else {
        0.0
    };
    let slr_pen = 9.0 * slr_crossings as f64;
    let fmhz = (dev.fmax_mhz - util_pen - part_pen - slr_pen).max(100.0);

    let sim = simulate_resolved(rd, dev);
    let time_ms = sim.cycles as f64 / (fmhz * 1e6) * 1e3;
    let gflops = if sim.cycles > 0 {
        rd.k.total_flops() as f64 / (time_ms / 1e3) / 1e9
    } else {
        0.0
    };

    BoardReport {
        bitstream_ok,
        peak_utilization,
        fmhz,
        sim,
        time_ms,
        gflops,
        slr_crossings,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::solver::{solve, Scenario, SolverOptions};
    use crate::ir::polybench;
    use std::time::Duration;

    fn board_opts(slrs: usize, frac: f64) -> SolverOptions {
        SolverOptions {
            scenario: Scenario::OnBoard { slrs, frac },
            beam: 12,
            max_factor_per_loop: 32,
            max_unroll: 1024,
            timeout: Duration::from_secs(30),
            ..SolverOptions::default()
        }
    }

    #[test]
    fn feasible_design_generates_bitstream() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &board_opts(1, 0.6)).unwrap();
        let budget = dev.slr.scaled(0.6);
        let b = board_eval(&k, &r.fused, &r.design, &dev, &budget);
        assert!(b.bitstream_ok, "utilization {}", b.peak_utilization);
        assert!(b.fmhz > 100.0 && b.fmhz <= dev.fmax_mhz);
        assert!(b.gflops > 0.0);
    }

    #[test]
    fn overcommitted_design_fails_bitstream() {
        // Solve for the full device, then evaluate under a 15% budget —
        // the AutoDSE-3mm situation of Table 8.
        let k = polybench::gemm();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &board_opts(1, 1.0)).unwrap();
        let tiny = dev.slr.scaled(0.15);
        let b = board_eval(&k, &r.fused, &r.design, &dev, &tiny);
        assert!(!b.bitstream_ok);
    }

    #[test]
    fn multi_slr_derates_frequency() {
        let k = polybench::three_mm();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &board_opts(3, 0.6)).unwrap();
        let budget = dev.slr.scaled(0.6);
        let b = board_eval(&k, &r.fused, &r.design, &dev, &budget);
        if b.slr_crossings > 0 {
            assert!(b.fmhz < dev.fmax_mhz);
        }
        assert!(b.time_ms > 0.0);
    }
}
