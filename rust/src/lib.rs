//! # Prometheus — Holistic Optimization Framework for FPGA Accelerators
//!
//! Reproduction of Pouget, Lo, Pouchet & Cong, *Holistic Optimization
//! Framework for FPGA Accelerators*, ACM TODAES 2025 (DOI
//! 10.1145/3769307), built as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the Prometheus framework itself: affine
//!   kernel IR, dependency analysis and task-graph construction, task
//!   fusion, the holistic design space (tiling, permutation, padding,
//!   bit-width packing, array partitioning, buffering, SLR assignment),
//!   the NLP-style cost model and solver, HLS-C++/host code generation,
//!   and a cycle-approximate dataflow *FPGA simulator* standing in for
//!   Vitis RTL simulation and on-board Alveo U55C runs.
//! * **Layer 2 (python/compile/model.py)** — PolyBench kernels written in
//!   JAX, AOT-lowered to HLO text artifacts consumed by
//!   [`runtime`] for functional (numerical) validation of optimized
//!   designs.
//! * **Layer 1 (python/compile/kernels/)** — Pallas tile kernels
//!   (output-stationary matmul tile, vector ops) mirroring the fully
//!   unrolled intra-tile tasks Prometheus generates, validated against a
//!   pure-jnp oracle.
//!
//! See `ARCHITECTURE.md` for the request lifecycle (CLI → coordinator
//! → fusion space → solver → simulator/board → codegen, with a worked
//! example per stage), `DESIGN.md` for the full system inventory and
//! the paper-experiment index, and `EXPERIMENTS.md` for
//! measured-vs-paper results.

pub mod analysis;
pub mod baselines;
pub mod codegen;
pub mod coordinator;
pub mod dse;
pub mod hw;
pub mod ir;
pub mod obs;
mod par;
pub mod report;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod testutil;

pub use coordinator::flow::{
    optimize_kernel, optimize_kernel_cached, optimize_kernel_stored, OptimizeOptions,
};
pub use dse::config::DesignConfig;
pub use ir::kernel::Kernel;
pub use service::{
    run_batch, serve_lines, BatchOptions, BatchRequest, Daemon, DesignKey, QorDb, QorStore,
    ServeOptions,
};
