//! Resource usage estimation and accounting.
//!
//! Coefficients follow Vitis HLS's first-order cost of f32 arithmetic on
//! UltraScale+: a pipelined fmul = 3 DSP + ~85 LUT + ~150 FF, fadd = 2 DSP
//! + ~200 LUT + ~300 FF; FIFOs and partitioned buffers consume BRAM18 in
//! 18 Kb blocks. These feed Table 7 / Table 8's utilization columns.

use super::device::SlrBudget;
use std::ops::{Add, AddAssign};

/// Continuous resource vector (fractions accumulate before rounding).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResourceVec {
    pub dsp: f64,
    pub bram18: f64,
    pub lut: f64,
    pub ff: f64,
}

impl ResourceVec {
    pub const ZERO: ResourceVec = ResourceVec { dsp: 0.0, bram18: 0.0, lut: 0.0, ff: 0.0 };

    pub fn fits(&self, budget: &SlrBudget) -> bool {
        self.dsp <= budget.dsp as f64
            && self.bram18 <= budget.bram18 as f64
            && self.lut <= budget.lut as f64
            && self.ff <= budget.ff as f64
    }

    /// Max utilization fraction across resource classes w.r.t. `budget`.
    pub fn utilization(&self, budget: &SlrBudget) -> f64 {
        let fracs = [
            self.dsp / budget.dsp as f64,
            self.bram18 / budget.bram18 as f64,
            self.lut / budget.lut as f64,
            self.ff / budget.ff as f64,
        ];
        fracs.into_iter().fold(0.0, f64::max)
    }

    pub fn scale(&self, s: f64) -> ResourceVec {
        ResourceVec {
            dsp: self.dsp * s,
            bram18: self.bram18 * s,
            lut: self.lut * s,
            ff: self.ff * s,
        }
    }
}

impl Add for ResourceVec {
    type Output = ResourceVec;
    fn add(self, o: ResourceVec) -> ResourceVec {
        ResourceVec {
            dsp: self.dsp + o.dsp,
            bram18: self.bram18 + o.bram18,
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
        }
    }
}

impl AddAssign for ResourceVec {
    fn add_assign(&mut self, o: ResourceVec) {
        *self = *self + o;
    }
}

/// Integer summary used in reports (Table 8 shape: DSP, BRAM, LUT-K, FF-K).
#[derive(Debug, Clone, Copy, Default)]
pub struct ResourceUsage {
    pub dsp: u64,
    pub bram18: u64,
    pub lut: u64,
    pub ff: u64,
}

impl From<ResourceVec> for ResourceUsage {
    fn from(v: ResourceVec) -> Self {
        ResourceUsage {
            dsp: v.dsp.ceil() as u64,
            bram18: v.bram18.ceil() as u64,
            lut: v.lut.ceil() as u64,
            ff: v.ff.ceil() as u64,
        }
    }
}

/// Per-operation implementation cost (f32, UltraScale+, pipelined).
pub mod cost {
    use super::ResourceVec;

    pub const FMUL: ResourceVec = ResourceVec { dsp: 3.0, bram18: 0.0, lut: 85.0, ff: 150.0 };
    pub const FADD: ResourceVec = ResourceVec { dsp: 2.0, bram18: 0.0, lut: 200.0, ff: 300.0 };
    pub const FDIV: ResourceVec = ResourceVec { dsp: 0.0, bram18: 0.0, lut: 800.0, ff: 1200.0 };

    /// Control/interconnect overhead per unrolled statement instance.
    pub const PER_INSTANCE_CTRL: ResourceVec =
        ResourceVec { dsp: 0.0, bram18: 0.0, lut: 25.0, ff: 40.0 };

    /// Fixed cost of a load/store FIFO engine at 512-bit width.
    pub const STREAM_ENGINE: ResourceVec =
        ResourceVec { dsp: 0.0, bram18: 8.0, lut: 1800.0, ff: 2600.0 };

    /// Base kernel infrastructure (AXI adapters, control).
    pub const KERNEL_BASE: ResourceVec =
        ResourceVec { dsp: 4.0, bram18: 16.0, lut: 12_000.0, ff: 18_000.0 };
}

/// BRAM18 blocks needed for `bytes` of buffer split over `partitions`
/// banks: each bank rounds up to at least one 18 Kb block (2.25 KiB).
pub fn bram18_for(bytes: u64, partitions: u64) -> f64 {
    if bytes == 0 {
        return 0.0;
    }
    let parts = partitions.max(1);
    let per_bank = (bytes as f64 / parts as f64) / (18.0 * 1024.0 / 8.0);
    parts as f64 * per_bank.max(1.0).ceil()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::device::Device;

    #[test]
    fn vec_arithmetic() {
        let v = cost::FMUL + cost::FADD;
        assert_eq!(v.dsp, 5.0);
        let s = v.scale(10.0);
        assert_eq!(s.dsp, 50.0);
    }

    #[test]
    fn fits_and_utilization() {
        let d = Device::u55c();
        let v = ResourceVec { dsp: 1504.0, bram18: 0.0, lut: 0.0, ff: 0.0 };
        assert!(v.fits(&d.slr));
        assert!((v.utilization(&d.slr) - 0.5).abs() < 1e-9);
        let big = ResourceVec { dsp: 4000.0, ..ResourceVec::ZERO };
        assert!(!big.fits(&d.slr));
    }

    #[test]
    fn bram_rounding() {
        // A 1-byte buffer still takes one BRAM18 per bank.
        assert_eq!(bram18_for(1, 1), 1.0);
        assert_eq!(bram18_for(1, 8), 8.0);
        // 36 KiB over 2 banks = 8 blocks per bank... (18KiB/bank / 2.25KiB)
        assert_eq!(bram18_for(36 * 1024, 2), 16.0);
        assert_eq!(bram18_for(0, 4), 0.0);
    }
}
