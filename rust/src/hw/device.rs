//! Device descriptions. Numbers from the Alveo U55C datasheet (XCU55C,
//! Virtex UltraScale+ VU47P) and the paper's evaluation settings.

use super::resources::ResourceVec;

/// Per-SLR resource budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlrBudget {
    pub dsp: u64,
    /// BRAM18 blocks.
    pub bram18: u64,
    pub lut: u64,
    pub ff: u64,
    pub uram: u64,
}

impl SlrBudget {
    pub fn as_vec(&self) -> ResourceVec {
        ResourceVec {
            dsp: self.dsp as f64,
            bram18: self.bram18 as f64,
            lut: self.lut as f64,
            ff: self.ff as f64,
        }
    }

    /// Scale the budget by a utilization cap (the paper uses 60%, 55% and
    /// 15% scenarios on board).
    pub fn scaled(&self, frac: f64) -> SlrBudget {
        SlrBudget {
            dsp: (self.dsp as f64 * frac) as u64,
            bram18: (self.bram18 as f64 * frac) as u64,
            lut: (self.lut as f64 * frac) as u64,
            ff: (self.ff as f64 * frac) as u64,
            uram: (self.uram as f64 * frac) as u64,
        }
    }
}

/// An FPGA device model.
#[derive(Debug, Clone)]
pub struct Device {
    pub name: String,
    pub slrs: usize,
    pub slr: SlrBudget,
    /// Target clock in MHz (paper: 220 MHz).
    pub fmax_mhz: f64,
    /// Maximum off-chip burst width in bits (AMD: 512).
    pub max_bus_bits: u64,
    /// Off-chip latency in cycles for the first beat of a burst (Vitis
    /// flow default: 64).
    pub ddr_latency_cycles: u64,
    /// Number of independent off-chip memory channels (U55C HBM: 32).
    pub mem_channels: usize,
    /// Maximum array partitioning Vitis accepts (paper: 1024).
    pub max_partition: u64,
    /// Extra cycles for a FIFO crossing between SLRs.
    pub inter_slr_latency: u64,
    /// DSPs consumed by one f32 multiply / add (Vitis defaults used in the
    /// paper's Eq 10 example: DSP_* = 3, DSP_+ = 2).
    pub dsp_per_mul: u64,
    pub dsp_per_add: u64,
    /// f32 add latency in cycles (drives reduction II = 3 as in Listing 6).
    pub fadd_latency: u64,
    /// f32 mul latency in cycles.
    pub fmul_latency: u64,
}

impl Device {
    /// The Alveo U55C: 9024 DSP, 4032 BRAM18 (2016 BRAM36), 1304K LUT,
    /// 2607K FF, 960 URAM, split over 3 SLRs.
    pub fn u55c() -> Device {
        Device {
            name: "Alveo U55C".into(),
            slrs: 3,
            slr: SlrBudget {
                dsp: 9024 / 3,
                bram18: 4032 / 3,
                lut: 1_304_000 / 3,
                ff: 2_607_000 / 3,
                uram: 960 / 3,
            },
            fmax_mhz: 220.0,
            max_bus_bits: 512,
            ddr_latency_cycles: 64,
            mem_channels: 32,
            max_partition: 1024,
            inter_slr_latency: 4,
            dsp_per_mul: 3,
            dsp_per_add: 2,
            fadd_latency: 3,
            fmul_latency: 2,
        }
    }

    /// Whole-device budget (all SLRs).
    pub fn total(&self) -> SlrBudget {
        SlrBudget {
            dsp: self.slr.dsp * self.slrs as u64,
            bram18: self.slr.bram18 * self.slrs as u64,
            lut: self.slr.lut * self.slrs as u64,
            ff: self.slr.ff * self.slrs as u64,
            uram: self.slr.uram * self.slrs as u64,
        }
    }

    /// On-chip bytes available per SLR from BRAM18 (2.25 KiB each, usable
    /// 2 KiB data width aligned).
    pub fn slr_bram_bytes(&self) -> u64 {
        self.slr.bram18 * 18 * 1024 / 8
    }

    /// Bytes per cycle for a stream of width `bits`.
    pub fn bytes_per_cycle(&self, bits: u64) -> f64 {
        bits.min(self.max_bus_bits) as f64 / 8.0
    }

    /// Cycles to move `bytes` at bus width `bits`, burst latency included.
    pub fn transfer_cycles(&self, bytes: u64, bits: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.ddr_latency_cycles + (bytes as f64 / self.bytes_per_cycle(bits)).ceil() as u64
    }

    /// Seconds per cycle at the target clock.
    pub fn cycle_time_s(&self) -> f64 {
        1.0 / (self.fmax_mhz * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u55c_budgets() {
        let d = Device::u55c();
        assert_eq!(d.slrs, 3);
        assert_eq!(d.total().dsp, 9024);
        assert_eq!(d.slr.dsp, 3008);
        assert!(d.slr_bram_bytes() > 3_000_000); // ~3 MiB per SLR
    }

    #[test]
    fn transfer_cycle_math() {
        let d = Device::u55c();
        // 216 floats at 256 bits = 8 floats/cycle = 27 beats (paper §2.1.6)
        assert_eq!(d.transfer_cycles(216 * 4, 256), 64 + 27);
        // without packing (32-bit) = 216 beats
        assert_eq!(d.transfer_cycles(216 * 4, 32), 64 + 216);
        assert_eq!(d.transfer_cycles(0, 512), 0);
    }

    #[test]
    fn scaled_budget() {
        let d = Device::u55c();
        let s = d.slr.scaled(0.60);
        assert_eq!(s.dsp, (3008f64 * 0.6) as u64);
        assert!(s.lut < d.slr.lut);
    }

    #[test]
    fn bus_width_clamped() {
        let d = Device::u55c();
        assert_eq!(d.bytes_per_cycle(1024), 64.0); // clamped to 512
        assert_eq!(d.bytes_per_cycle(64), 8.0);
    }
}
