//! FPGA device models and resource accounting.
//!
//! The evaluation platform is the AMD/Xilinx **Alveo U55C** (paper §6.1):
//! 3 Super Logic Regions, HBM2, Vitis flow with a 220 MHz target clock and
//! a default 64-cycle off-chip access latency.

pub mod device;
pub mod resources;

pub use device::{Device, SlrBudget};
pub use resources::{ResourceUsage, ResourceVec};
