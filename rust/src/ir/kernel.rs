//! Kernels, statements and loops — the unit the whole framework operates on.

use super::access::{Access, ArrayDecl};
use std::collections::BTreeMap;

/// One loop of a statement's nest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Loop {
    /// Iterator name as it appears in the PolyBench source (`i`, `j`, `k`).
    pub name: String,
    /// Exact trip count (medium dataset sizes; triangular nests use the
    /// average trip count, which is exact for total-work accounting).
    pub trip: u64,
    /// Whether the statement carries a reduction along this loop (the
    /// written element does not depend on it ⇒ loop-carried accumulate).
    pub reduction: bool,
}

impl Loop {
    pub fn new(name: &str, trip: u64, reduction: bool) -> Self {
        Loop { name: name.to_string(), trip, reduction }
    }
}

/// Statement kind: zero-initialisation vs. compute/update. Init statements
/// fuse with the update that follows them (output-stationary fusion).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StmtKind {
    /// `X[i][j] = 0` or `X[i][j] = beta * X[i][j]` style prologue.
    Init,
    /// The main compute statement.
    Compute,
}

/// Floating-point operation counts of one dynamic instance of a statement.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub add: u64,
    pub mul: u64,
    pub div: u64,
}

impl OpCounts {
    pub fn new(add: u64, mul: u64) -> Self {
        OpCounts { add, mul, div: 0 }
    }

    pub fn total(&self) -> u64 {
        self.add + self.mul + self.div
    }
}

/// One statement after maximal distribution: a perfect loop nest around a
/// single assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Statement {
    /// `S0`, `S1`, ... following the paper's naming.
    pub id: usize,
    pub kind: StmtKind,
    /// Loop nest, outermost first, in the *original* program order.
    pub loops: Vec<Loop>,
    /// The array (and affine function) written by this statement.
    pub write: Access,
    /// Arrays read. For updates (`C[i][j] += ...`) the written array is
    /// also listed here.
    pub reads: Vec<Access>,
    /// FLOPs per dynamic instance.
    pub ops: OpCounts,
}

impl Statement {
    /// Total dynamic instances of the statement.
    pub fn instances(&self) -> u64 {
        self.loops.iter().map(|l| l.trip).product()
    }

    /// Total FLOPs contributed by the statement.
    pub fn flops(&self) -> u64 {
        self.instances() * self.ops.total()
    }

    /// Positions of reduction loops.
    pub fn reduction_loops(&self) -> Vec<usize> {
        self.loops
            .iter()
            .enumerate()
            .filter(|(_, l)| l.reduction)
            .map(|(p, _)| p)
            .collect()
    }

    /// Positions of non-reduction (parallel) loops.
    pub fn parallel_loops(&self) -> Vec<usize> {
        self.loops
            .iter()
            .enumerate()
            .filter(|(_, l)| !l.reduction)
            .map(|(p, _)| p)
            .collect()
    }
}

/// A whole kernel: arrays + maximally distributed statements.
///
/// The constructors in [`super::polybench`] build the 15 evaluation kernels
/// of the paper (Table 5).
#[derive(Debug, Clone)]
pub struct Kernel {
    pub name: String,
    pub arrays: Vec<ArrayDecl>,
    pub statements: Vec<Statement>,
    /// Human description, mirrored into Table 5 output.
    pub description: String,
}

impl Kernel {
    pub fn array(&self, name: &str) -> Option<&ArrayDecl> {
        self.arrays.iter().find(|a| a.name == name)
    }

    /// Total FLOPs of the kernel — the numerator of every GF/s figure.
    pub fn total_flops(&self) -> u64 {
        self.statements.iter().map(|s| s.flops()).sum()
    }

    /// Total off-chip footprint (inputs + outputs) in bytes.
    pub fn io_bytes(&self) -> u64 {
        self.arrays
            .iter()
            .filter(|a| a.is_input || a.is_output)
            .map(|a| a.bytes())
            .sum()
    }

    /// Arithmetic intensity in FLOP/byte over the off-chip footprint:
    /// `O(N)` reuse kernels (gemm-family) score ≫ 1, `O(1)` kernels
    /// (madd, mvt, bicg) score ≈ constant. Used for Table 5's reuse
    /// classification.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.total_flops() as f64 / self.io_bytes() as f64
    }

    /// The statement that writes each array, by array name.
    pub fn writers(&self) -> BTreeMap<&str, Vec<usize>> {
        let mut m: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for s in &self.statements {
            m.entry(s.write.array.as_str()).or_default().push(s.id);
        }
        m
    }

    /// Trip count of the loop at `pos` for statement `sid`.
    pub fn trip(&self, sid: usize, pos: usize) -> u64 {
        self.statements[sid].loops[pos].trip
    }

    /// Validate internal consistency (every access resolves to a declared
    /// array with matching rank, loop positions in range). Used by tests
    /// and by the property harness over the kernel zoo.
    pub fn validate(&self) -> Result<(), String> {
        for s in &self.statements {
            let mut accs: Vec<&Access> = vec![&s.write];
            accs.extend(s.reads.iter());
            for acc in accs {
                let arr = self
                    .array(&acc.array)
                    .ok_or_else(|| format!("{}: S{} references undeclared {}", self.name, s.id, acc.array))?;
                if arr.dims.len() != acc.idx.len() {
                    return Err(format!(
                        "{}: S{} access {} rank {} vs decl rank {}",
                        self.name,
                        s.id,
                        acc.array,
                        acc.idx.len(),
                        arr.dims.len()
                    ));
                }
                for p in acc.loop_positions() {
                    if p >= s.loops.len() {
                        return Err(format!(
                            "{}: S{} access {} names loop {} of {}",
                            self.name,
                            s.id,
                            acc.array,
                            p,
                            s.loops.len()
                        ));
                    }
                }
            }
            if s.kind == StmtKind::Compute && s.ops.total() == 0 {
                return Err(format!("{}: compute S{} has zero ops", self.name, s.id));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::polybench;
    use super::*;

    #[test]
    fn statement_accounting() {
        let k = polybench::gemm();
        let s_update = k
            .statements
            .iter()
            .find(|s| s.kind == StmtKind::Compute && s.ops.mul > 0 && s.loops.len() == 3)
            .unwrap();
        assert_eq!(s_update.instances(), 200 * 220 * 240);
        assert_eq!(s_update.reduction_loops(), vec![2]);
        assert_eq!(s_update.parallel_loops(), vec![0, 1]);
    }

    #[test]
    fn gemm_flops_match_closed_form() {
        let k = polybench::gemm();
        // 2*NI*NJ*NK for the MACs + NI*NJ for the beta scale.
        let expect = 2 * 200 * 220 * 240 + 200 * 220;
        assert_eq!(k.total_flops(), expect as u64);
    }

    #[test]
    fn all_kernels_validate() {
        for k in polybench::all_kernels() {
            k.validate().unwrap_or_else(|e| panic!("{e}"));
        }
    }

    #[test]
    fn intensity_classifies_bound() {
        let gemm = polybench::gemm();
        let madd = polybench::madd();
        assert!(gemm.arithmetic_intensity() > 10.0, "gemm compute-bound");
        assert!(madd.arithmetic_intensity() < 1.0, "madd memory-bound");
    }
}
