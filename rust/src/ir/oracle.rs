//! Rust-native reference execution of the kernel zoo.
//!
//! The oracle serves two roles:
//! 1. functional ground truth for the PJRT runtime path — after the JAX/
//!    Pallas artifact for a kernel executes, [`crate::coordinator`]
//!    compares its outputs against these implementations;
//! 2. FLOP-count cross-check — the IR's symbolic counts must agree with
//!    what the naive implementation actually performs.
//!
//! Inputs are generated deterministically (same scheme as
//! `python/compile/model.py::inputs_for`): element `n` of array number `a`
//! is `((n * 16807 + a * 2671 + 13) % 1000) / 1000 - 0.5`, so rust and
//! python agree bit-for-bit on the f32 inputs without exchanging files.

/// Deterministic pseudo-input, identical formula to the python side.
pub fn input_element(array_ordinal: u64, flat_index: u64) -> f32 {
    let v = (flat_index.wrapping_mul(16807) + array_ordinal * 2671 + 13) % 1000;
    v as f32 / 1000.0 - 0.5
}

/// Fill a buffer for the `ordinal`-th input array of a kernel.
pub fn input_array(ordinal: u64, len: usize) -> Vec<f32> {
    (0..len as u64).map(|i| input_element(ordinal, i)).collect()
}

fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            for j in 0..n {
                c[i * n + j] += av * b[kk * n + j];
            }
        }
    }
    c
}

/// Outputs of one kernel as named flat buffers.
pub struct OracleOut {
    pub names: Vec<String>,
    pub bufs: Vec<Vec<f32>>,
}

impl OracleOut {
    fn one(name: &str, buf: Vec<f32>) -> Self {
        OracleOut { names: vec![name.into()], bufs: vec![buf] }
    }
}

/// Execute the reference implementation of `kernel` on the deterministic
/// inputs. Returns the kernel's output arrays. Supported: the kernels the
/// AOT layer lowers (gemm, 2mm, 3mm, atax, bicg, mvt, gesummv, madd,
/// 2-madd, 3-madd).
pub fn run(kernel: &str) -> Option<OracleOut> {
    match kernel {
        "gemm" => {
            let (ni, nj, nk) = (200, 220, 240);
            let c0 = input_array(0, ni * nj);
            let a = input_array(1, ni * nk);
            let b = input_array(2, nk * nj);
            let ab = matmul(&a, &b, ni, nk, nj);
            let out: Vec<f32> = c0
                .iter()
                .zip(ab.iter())
                .map(|(c, p)| 1.2 * c + 1.5 * p)
                .collect();
            Some(OracleOut::one("C", out))
        }
        "2mm" => {
            let (ni, nj, nk, nl) = (180, 190, 210, 220);
            let a = input_array(0, ni * nk);
            let b = input_array(1, nk * nj);
            let c = input_array(2, nj * nl);
            let d0 = input_array(3, ni * nl);
            let tmp: Vec<f32> = matmul(&a, &b, ni, nk, nj).iter().map(|v| 1.5 * v).collect();
            let tc = matmul(&tmp, &c, ni, nj, nl);
            let out: Vec<f32> = d0.iter().zip(tc.iter()).map(|(d, p)| 1.2 * d + p).collect();
            Some(OracleOut::one("D", out))
        }
        "3mm" => {
            let (ni, nj, nk, nl, nm) = (180, 190, 200, 210, 220);
            let a = input_array(0, ni * nk);
            let b = input_array(1, nk * nj);
            let c = input_array(2, nj * nm);
            let d = input_array(3, nm * nl);
            let e = matmul(&a, &b, ni, nk, nj);
            let f = matmul(&c, &d, nj, nm, nl);
            let g = matmul(&e, &f, ni, nj, nl);
            Some(OracleOut::one("G", g))
        }
        "atax" => {
            let (m, n) = (390, 410);
            let a = input_array(0, m * n);
            let x = input_array(1, n);
            let mut tmp = vec![0f32; m];
            for i in 0..m {
                for j in 0..n {
                    tmp[i] += a[i * n + j] * x[j];
                }
            }
            let mut y = vec![0f32; n];
            for i in 0..m {
                for j in 0..n {
                    y[j] += a[i * n + j] * tmp[i];
                }
            }
            Some(OracleOut::one("y", y))
        }
        "bicg" => {
            let (m, n) = (390, 410);
            let a = input_array(0, m * n);
            let r = input_array(1, m);
            let p = input_array(2, n);
            let mut s = vec![0f32; n];
            let mut q = vec![0f32; m];
            for i in 0..m {
                for j in 0..n {
                    s[j] += r[i] * a[i * n + j];
                    q[i] += a[i * n + j] * p[j];
                }
            }
            Some(OracleOut { names: vec!["s".into(), "q".into()], bufs: vec![s, q] })
        }
        "mvt" => {
            let n = 400;
            let a = input_array(0, n * n);
            let x1_0 = input_array(1, n);
            let x2_0 = input_array(2, n);
            let y1 = input_array(3, n);
            let y2 = input_array(4, n);
            let mut x1 = x1_0.clone();
            let mut x2 = x2_0.clone();
            for i in 0..n {
                for j in 0..n {
                    x1[i] += a[i * n + j] * y1[j];
                    x2[i] += a[j * n + i] * y2[j];
                }
            }
            Some(OracleOut { names: vec!["x1".into(), "x2".into()], bufs: vec![x1, x2] })
        }
        "gesummv" => {
            let n = 250;
            let a = input_array(0, n * n);
            let b = input_array(1, n * n);
            let x = input_array(2, n);
            let mut y = vec![0f32; n];
            for i in 0..n {
                let mut t = 0f32;
                let mut yy = 0f32;
                for j in 0..n {
                    t += a[i * n + j] * x[j];
                    yy += b[i * n + j] * x[j];
                }
                y[i] = 1.5 * t + 1.2 * yy;
            }
            Some(OracleOut::one("y", y))
        }
        "madd" => {
            let n = 400usize;
            let a = input_array(0, n * n);
            let b = input_array(1, n * n);
            let c: Vec<f32> = a.iter().zip(b.iter()).map(|(x, y)| x + y).collect();
            Some(OracleOut::one("C", c))
        }
        "2-madd" => {
            let n = 400usize;
            let a = input_array(0, n * n);
            let b = input_array(1, n * n);
            let c = input_array(2, n * n);
            let d: Vec<f32> = (0..n * n).map(|i| (a[i] + b[i]) + c[i]).collect();
            Some(OracleOut::one("D", d))
        }
        "3-madd" => {
            let n = 400usize;
            let a = input_array(0, n * n);
            let b = input_array(1, n * n);
            let c = input_array(2, n * n);
            let d = input_array(3, n * n);
            let f: Vec<f32> = (0..n * n).map(|i| (a[i] + b[i]) + (c[i] + d[i])).collect();
            Some(OracleOut::one("F", f))
        }
        _ => None,
    }
}

/// The set of kernels the functional-validation path covers.
pub fn validated_kernels() -> &'static [&'static str] {
    &["gemm", "2mm", "3mm", "atax", "bicg", "mvt", "gesummv", "madd", "2-madd", "3-madd"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_inputs() {
        assert_eq!(input_element(0, 0), input_element(0, 0));
        // formula spot-check: n=1,a=0 -> (16807+13)%1000 = 820 -> 0.32
        assert!((input_element(0, 1) - 0.32).abs() < 1e-6);
        // different arrays differ
        assert_ne!(input_element(0, 5), input_element(1, 5));
    }

    #[test]
    fn matmul_identity() {
        // 2x2 identity times arbitrary matrix
        let i2 = vec![1., 0., 0., 1.];
        let m = vec![3., 4., 5., 6.];
        assert_eq!(matmul(&i2, &m, 2, 2, 2), m);
    }

    #[test]
    fn all_validated_kernels_run() {
        for k in validated_kernels() {
            let out = run(k).unwrap_or_else(|| panic!("{k} missing"));
            assert!(!out.bufs.is_empty());
            for b in &out.bufs {
                assert!(b.iter().all(|v| v.is_finite()), "{k} produced non-finite values");
            }
        }
    }

    #[test]
    fn three_madd_is_sum_of_four() {
        let out = run("3-madd").unwrap();
        let n = 400usize;
        let a = input_array(0, n * n);
        let b = input_array(1, n * n);
        let c = input_array(2, n * n);
        let d = input_array(3, n * n);
        let f = &out.bufs[0];
        for idx in [0usize, 17, 999, n * n - 1] {
            let expect = a[idx] + b[idx] + c[idx] + d[idx];
            assert!((f[idx] - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn unknown_kernel_is_none() {
        assert!(run("jacobi-2d").is_none());
    }
}
