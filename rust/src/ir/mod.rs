//! Affine kernel intermediate representation.
//!
//! This is the PoCC/ISCC substitute for the reproduction: PolyBench kernels
//! are static-control affine programs, so we encode them directly as loop
//! nests with exact trip counts and affine (single-iterator) array access
//! functions. Dependence analysis, task-graph construction and the design
//! space all operate on this IR.

pub mod access;
pub mod kernel;
pub mod oracle;
pub mod polybench;

pub use access::{Access, ArrayDecl, DataType};
pub use kernel::{Kernel, Loop, OpCounts, Statement, StmtKind};
