//! The PolyBench/C 4.2.1 kernel zoo (medium dataset), plus the paper's
//! n-madd kernels — every benchmark of Table 5, already maximally
//! distributed (one statement per loop body) as §3.1 requires.
//!
//! Conventions:
//! * loop lists are outermost-first and named as in PolyBench sources;
//! * `reads` include the written array for `+=` updates;
//! * init statements carry `StmtKind::Init` and zero ops when they only
//!   zero a buffer, or real ops when they scale (`beta*C`).
//! * trip counts for triangular nests (symm/syr2k/syrk/trmm) use the exact
//!   average so total-FLOP accounting matches the real kernel.

use super::access::{Access, ArrayDecl};
use super::kernel::{Kernel, Loop, OpCounts, Statement, StmtKind};

fn stmt(
    id: usize,
    kind: StmtKind,
    loops: Vec<Loop>,
    write: Access,
    reads: Vec<Access>,
    ops: OpCounts,
) -> Statement {
    Statement { id, kind, loops, write, reads, ops }
}

/// `gemm`: C = alpha*A*B + beta*C.  NI=200, NJ=220, NK=240.
pub fn gemm() -> Kernel {
    let (ni, nj, nk) = (200, 220, 240);
    Kernel {
        name: "gemm".into(),
        description: "Matrix-multiply (C = alpha*A*B + beta*C)".into(),
        arrays: vec![
            ArrayDecl::new("C", &[ni, nj], true, true),
            ArrayDecl::new("A", &[ni, nk], true, false),
            ArrayDecl::new("B", &[nk, nj], true, false),
        ],
        statements: vec![
            // S0: C[i][j] *= beta
            stmt(
                0,
                StmtKind::Init,
                vec![Loop::new("i", ni, false), Loop::new("j", nj, false)],
                Access::new("C", &[0, 1]),
                vec![Access::new("C", &[0, 1])],
                OpCounts::new(0, 1),
            ),
            // S1: C[i][j] += alpha * A[i][k] * B[k][j]
            stmt(
                1,
                StmtKind::Compute,
                vec![
                    Loop::new("i", ni, false),
                    Loop::new("j", nj, false),
                    Loop::new("k", nk, true),
                ],
                Access::new("C", &[0, 1]),
                vec![
                    Access::new("C", &[0, 1]),
                    Access::new("A", &[0, 2]),
                    Access::new("B", &[2, 1]),
                ],
                // one mul + one add per MAC (alpha folded into A load, as
                // the HLS codegen does)
                OpCounts::new(1, 1),
            ),
        ],
    }
}

/// `2mm`: D = alpha*A*B*C + beta*D.  NI=180, NJ=190, NK=210, NL=220.
pub fn two_mm() -> Kernel {
    let (ni, nj, nk, nl) = (180, 190, 210, 220);
    Kernel {
        name: "2mm".into(),
        description: "2 Matrix Mult. (alpha*A*B*C + beta*D)".into(),
        arrays: vec![
            ArrayDecl::new("tmp", &[ni, nj], false, false),
            ArrayDecl::new("A", &[ni, nk], true, false),
            ArrayDecl::new("B", &[nk, nj], true, false),
            ArrayDecl::new("C", &[nj, nl], true, false),
            ArrayDecl::new("D", &[ni, nl], true, true),
        ],
        statements: vec![
            // S0: tmp[i][j] = 0
            stmt(
                0,
                StmtKind::Init,
                vec![Loop::new("i", ni, false), Loop::new("j", nj, false)],
                Access::new("tmp", &[0, 1]),
                vec![],
                OpCounts::default(),
            ),
            // S1: tmp[i][j] += alpha * A[i][k] * B[k][j]
            stmt(
                1,
                StmtKind::Compute,
                vec![
                    Loop::new("i", ni, false),
                    Loop::new("j", nj, false),
                    Loop::new("k", nk, true),
                ],
                Access::new("tmp", &[0, 1]),
                vec![
                    Access::new("tmp", &[0, 1]),
                    Access::new("A", &[0, 2]),
                    Access::new("B", &[2, 1]),
                ],
                OpCounts::new(1, 1),
            ),
            // S2: D[i][j] *= beta
            stmt(
                2,
                StmtKind::Init,
                vec![Loop::new("i", ni, false), Loop::new("j", nl, false)],
                Access::new("D", &[0, 1]),
                vec![Access::new("D", &[0, 1])],
                OpCounts::new(0, 1),
            ),
            // S3: D[i][j] += tmp[i][k] * C[k][j]
            stmt(
                3,
                StmtKind::Compute,
                vec![
                    Loop::new("i", ni, false),
                    Loop::new("j", nl, false),
                    Loop::new("k", nj, true),
                ],
                Access::new("D", &[0, 1]),
                vec![
                    Access::new("D", &[0, 1]),
                    Access::new("tmp", &[0, 2]),
                    Access::new("C", &[2, 1]),
                ],
                OpCounts::new(1, 1),
            ),
        ],
    }
}

/// `3mm`: G = (A*B)*(C*D).  NI=180, NJ=190, NK=200, NL=210, NM=220.
/// Listing 4 of the paper.
pub fn three_mm() -> Kernel {
    let (ni, nj, nk, nl, nm) = (180, 190, 200, 210, 220);
    Kernel {
        name: "3mm".into(),
        description: "3 Matrix Mult. ((A*B)*(C*D))".into(),
        arrays: vec![
            ArrayDecl::new("E", &[ni, nj], false, false),
            ArrayDecl::new("A", &[ni, nk], true, false),
            ArrayDecl::new("B", &[nk, nj], true, false),
            ArrayDecl::new("F", &[nj, nl], false, false),
            ArrayDecl::new("C", &[nj, nm], true, false),
            ArrayDecl::new("D", &[nm, nl], true, false),
            ArrayDecl::new("G", &[ni, nl], true, true),
        ],
        statements: vec![
            // S0: E[i][j] = 0
            stmt(
                0,
                StmtKind::Init,
                vec![Loop::new("i", ni, false), Loop::new("j", nj, false)],
                Access::new("E", &[0, 1]),
                vec![],
                OpCounts::default(),
            ),
            // S1: E[i][j] += A[i][k] * B[k][j]
            stmt(
                1,
                StmtKind::Compute,
                vec![
                    Loop::new("i", ni, false),
                    Loop::new("j", nj, false),
                    Loop::new("k", nk, true),
                ],
                Access::new("E", &[0, 1]),
                vec![
                    Access::new("E", &[0, 1]),
                    Access::new("A", &[0, 2]),
                    Access::new("B", &[2, 1]),
                ],
                OpCounts::new(1, 1),
            ),
            // S2: F[i][j] = 0
            stmt(
                2,
                StmtKind::Init,
                vec![Loop::new("i", nj, false), Loop::new("j", nl, false)],
                Access::new("F", &[0, 1]),
                vec![],
                OpCounts::default(),
            ),
            // S3: F[i][j] += C[i][k] * D[k][j]
            stmt(
                3,
                StmtKind::Compute,
                vec![
                    Loop::new("i", nj, false),
                    Loop::new("j", nl, false),
                    Loop::new("k", nm, true),
                ],
                Access::new("F", &[0, 1]),
                vec![
                    Access::new("F", &[0, 1]),
                    Access::new("C", &[0, 2]),
                    Access::new("D", &[2, 1]),
                ],
                OpCounts::new(1, 1),
            ),
            // S4: G[i][j] = 0
            stmt(
                4,
                StmtKind::Init,
                vec![Loop::new("i", ni, false), Loop::new("j", nl, false)],
                Access::new("G", &[0, 1]),
                vec![],
                OpCounts::default(),
            ),
            // S5: G[i][j] += E[i][k] * F[k][j]
            stmt(
                5,
                StmtKind::Compute,
                vec![
                    Loop::new("i", ni, false),
                    Loop::new("j", nl, false),
                    Loop::new("k", nj, true),
                ],
                Access::new("G", &[0, 1]),
                vec![
                    Access::new("G", &[0, 1]),
                    Access::new("E", &[0, 2]),
                    Access::new("F", &[2, 1]),
                ],
                OpCounts::new(1, 1),
            ),
        ],
    }
}

/// `atax`: y = A^T (A x).  M=390, N=410.
pub fn atax() -> Kernel {
    let (m, n) = (390, 410);
    Kernel {
        name: "atax".into(),
        description: "Matrix transpose and vector mult.".into(),
        arrays: vec![
            ArrayDecl::new("A", &[m, n], true, false),
            ArrayDecl::new("x", &[n], true, false),
            ArrayDecl::new("y", &[n], false, true),
            ArrayDecl::new("tmp", &[m], false, false),
        ],
        statements: vec![
            // S0: y[i] = 0   (over N)
            stmt(
                0,
                StmtKind::Init,
                vec![Loop::new("i", n, false)],
                Access::new("y", &[0]),
                vec![],
                OpCounts::default(),
            ),
            // S1: tmp[i] = 0  (over M)
            stmt(
                1,
                StmtKind::Init,
                vec![Loop::new("i", m, false)],
                Access::new("tmp", &[0]),
                vec![],
                OpCounts::default(),
            ),
            // S2: tmp[i] += A[i][j] * x[j]
            stmt(
                2,
                StmtKind::Compute,
                vec![Loop::new("i", m, false), Loop::new("j", n, true)],
                Access::new("tmp", &[0]),
                vec![
                    Access::new("tmp", &[0]),
                    Access::new("A", &[0, 1]),
                    Access::new("x", &[1]),
                ],
                OpCounts::new(1, 1),
            ),
            // S3: y[j] += A[i][j] * tmp[i]  — reduction over i (loop 0)
            stmt(
                3,
                StmtKind::Compute,
                vec![Loop::new("i", m, true), Loop::new("j", n, false)],
                Access::new("y", &[1]),
                vec![
                    Access::new("y", &[1]),
                    Access::new("A", &[0, 1]),
                    Access::new("tmp", &[0]),
                ],
                OpCounts::new(1, 1),
            ),
        ],
    }
}

/// `bicg`: s = A^T r, q = A p.  M=390 (rows, i), N=410 (cols, j).
pub fn bicg() -> Kernel {
    let (m, n) = (390, 410);
    Kernel {
        name: "bicg".into(),
        description: "BiCG sub-kernel of BiCGStab solver".into(),
        arrays: vec![
            ArrayDecl::new("A", &[m, n], true, false),
            ArrayDecl::new("r", &[m], true, false),
            ArrayDecl::new("p", &[n], true, false),
            ArrayDecl::new("s", &[n], false, true),
            ArrayDecl::new("q", &[m], false, true),
        ],
        statements: vec![
            // S0: s[i] = 0 over N
            stmt(
                0,
                StmtKind::Init,
                vec![Loop::new("i", n, false)],
                Access::new("s", &[0]),
                vec![],
                OpCounts::default(),
            ),
            // S1: q[i] = 0 over M
            stmt(
                1,
                StmtKind::Init,
                vec![Loop::new("i", m, false)],
                Access::new("q", &[0]),
                vec![],
                OpCounts::default(),
            ),
            // S2: s[j] += r[i] * A[i][j] — reduction over i
            stmt(
                2,
                StmtKind::Compute,
                vec![Loop::new("i", m, true), Loop::new("j", n, false)],
                Access::new("s", &[1]),
                vec![
                    Access::new("s", &[1]),
                    Access::new("A", &[0, 1]),
                    Access::new("r", &[0]),
                ],
                OpCounts::new(1, 1),
            ),
            // S3: q[i] += A[i][j] * p[j] — reduction over j
            stmt(
                3,
                StmtKind::Compute,
                vec![Loop::new("i", m, false), Loop::new("j", n, true)],
                Access::new("q", &[0]),
                vec![
                    Access::new("q", &[0]),
                    Access::new("A", &[0, 1]),
                    Access::new("p", &[1]),
                ],
                OpCounts::new(1, 1),
            ),
        ],
    }
}

/// `mvt`: x1 += A y1; x2 += A^T y2.  N=400.
pub fn mvt() -> Kernel {
    let n = 400;
    Kernel {
        name: "mvt".into(),
        description: "Matrix Vector product and Transpose".into(),
        arrays: vec![
            ArrayDecl::new("A", &[n, n], true, false),
            ArrayDecl::new("x1", &[n], true, true),
            ArrayDecl::new("x2", &[n], true, true),
            ArrayDecl::new("y1", &[n], true, false),
            ArrayDecl::new("y2", &[n], true, false),
        ],
        statements: vec![
            // S0: x1[i] += A[i][j] * y1[j]
            stmt(
                0,
                StmtKind::Compute,
                vec![Loop::new("i", n, false), Loop::new("j", n, true)],
                Access::new("x1", &[0]),
                vec![
                    Access::new("x1", &[0]),
                    Access::new("A", &[0, 1]),
                    Access::new("y1", &[1]),
                ],
                OpCounts::new(1, 1),
            ),
            // S1: x2[i] += A[j][i] * y2[j]
            stmt(
                1,
                StmtKind::Compute,
                vec![Loop::new("i", n, false), Loop::new("j", n, true)],
                Access::new("x2", &[0]),
                vec![
                    Access::new("x2", &[0]),
                    Access::new("A", &[1, 0]),
                    Access::new("y2", &[1]),
                ],
                OpCounts::new(1, 1),
            ),
        ],
    }
}

/// `gesummv`: y = alpha*A*x + beta*B*x.  N=250.
pub fn gesummv() -> Kernel {
    let n = 250;
    Kernel {
        name: "gesummv".into(),
        description: "Scalar, vector and matrix mult.".into(),
        arrays: vec![
            ArrayDecl::new("A", &[n, n], true, false),
            ArrayDecl::new("B", &[n, n], true, false),
            ArrayDecl::new("x", &[n], true, false),
            ArrayDecl::new("tmp", &[n], false, false),
            // `y` is the B*x partial (intermediate); `y_out` the kernel
            // output — distributing the final combine into its own task
            // matches the paper's dataflow (2N inter-task traffic).
            ArrayDecl::new("y", &[n], false, false),
            ArrayDecl::new("y_out", &[n], false, true),
        ],
        statements: vec![
            // S0: tmp[i] = 0
            stmt(
                0,
                StmtKind::Init,
                vec![Loop::new("i", n, false)],
                Access::new("tmp", &[0]),
                vec![],
                OpCounts::default(),
            ),
            // S1: y[i] = 0
            stmt(
                1,
                StmtKind::Init,
                vec![Loop::new("i", n, false)],
                Access::new("y", &[0]),
                vec![],
                OpCounts::default(),
            ),
            // S2: tmp[i] += A[i][j] * x[j]
            stmt(
                2,
                StmtKind::Compute,
                vec![Loop::new("i", n, false), Loop::new("j", n, true)],
                Access::new("tmp", &[0]),
                vec![
                    Access::new("tmp", &[0]),
                    Access::new("A", &[0, 1]),
                    Access::new("x", &[1]),
                ],
                OpCounts::new(1, 1),
            ),
            // S3: y[i] += B[i][j] * x[j]
            stmt(
                3,
                StmtKind::Compute,
                vec![Loop::new("i", n, false), Loop::new("j", n, true)],
                Access::new("y", &[0]),
                vec![
                    Access::new("y", &[0]),
                    Access::new("B", &[0, 1]),
                    Access::new("x", &[1]),
                ],
                OpCounts::new(1, 1),
            ),
            // S4: y_out[i] = alpha*tmp[i] + beta*y[i]
            stmt(
                4,
                StmtKind::Compute,
                vec![Loop::new("i", n, false)],
                Access::new("y_out", &[0]),
                vec![Access::new("y", &[0]), Access::new("tmp", &[0])],
                OpCounts::new(1, 2),
            ),
        ],
    }
}

/// `gemver`: A_hat = A + u1 v1^T + u2 v2^T; x = ...; w = A_hat x.  N=400.
pub fn gemver() -> Kernel {
    let n = 400;
    Kernel {
        name: "gemver".into(),
        description: "Vector mult. and matrix add.".into(),
        arrays: vec![
            ArrayDecl::new("A", &[n, n], true, false),
            ArrayDecl::new("Ah", &[n, n], false, false),
            ArrayDecl::new("u1", &[n], true, false),
            ArrayDecl::new("v1", &[n], true, false),
            ArrayDecl::new("u2", &[n], true, false),
            ArrayDecl::new("v2", &[n], true, false),
            ArrayDecl::new("x", &[n], true, true),
            ArrayDecl::new("y", &[n], true, false),
            ArrayDecl::new("z", &[n], true, false),
            ArrayDecl::new("w", &[n], true, true),
        ],
        statements: vec![
            // S0: Ah[i][j] = A[i][j] + u1[i]*v1[j] + u2[i]*v2[j]
            stmt(
                0,
                StmtKind::Compute,
                vec![Loop::new("i", n, false), Loop::new("j", n, false)],
                Access::new("Ah", &[0, 1]),
                vec![
                    Access::new("A", &[0, 1]),
                    Access::new("u1", &[0]),
                    Access::new("v1", &[1]),
                    Access::new("u2", &[0]),
                    Access::new("v2", &[1]),
                ],
                OpCounts::new(2, 2),
            ),
            // S1: x[i] += beta * Ah[j][i] * y[j]  (reduction over j)
            stmt(
                1,
                StmtKind::Compute,
                vec![Loop::new("i", n, false), Loop::new("j", n, true)],
                Access::new("x", &[0]),
                vec![
                    Access::new("x", &[0]),
                    Access::new("Ah", &[1, 0]),
                    Access::new("y", &[1]),
                ],
                OpCounts::new(1, 1),
            ),
            // S2: x[i] += z[i]
            stmt(
                2,
                StmtKind::Compute,
                vec![Loop::new("i", n, false)],
                Access::new("x", &[0]),
                vec![Access::new("x", &[0]), Access::new("z", &[0])],
                OpCounts::new(1, 0),
            ),
            // S3: w[i] += alpha * Ah[i][j] * x[j]
            stmt(
                3,
                StmtKind::Compute,
                vec![Loop::new("i", n, false), Loop::new("j", n, true)],
                Access::new("w", &[0]),
                vec![
                    Access::new("w", &[0]),
                    Access::new("Ah", &[0, 1]),
                    Access::new("x", &[1]),
                ],
                OpCounts::new(1, 1),
            ),
        ],
    }
}

/// `syrk`: C = alpha*A*A^T + beta*C (lower triangular).  M=240? PolyBench
/// medium: M=200 (cols of A), N=240 (C is N×N). Triangular j<=i halves the
/// work; trips use exact averages.
pub fn syrk() -> Kernel {
    let (n, m) = (240, 200);
    let tri = (n + 1) / 2; // average trip of j in 0..=i
    Kernel {
        name: "syrk".into(),
        description: "Symmetric rank-k update".into(),
        arrays: vec![
            ArrayDecl::new("C", &[n, n], true, true),
            ArrayDecl::new("A", &[n, m], true, false),
        ],
        statements: vec![
            // S0: C[i][j] *= beta (j <= i)
            stmt(
                0,
                StmtKind::Init,
                vec![Loop::new("i", n, false), Loop::new("j", tri, false)],
                Access::new("C", &[0, 1]),
                vec![Access::new("C", &[0, 1])],
                OpCounts::new(0, 1),
            ),
            // S1: C[i][j] += alpha * A[i][k] * A[j][k] (j <= i)
            stmt(
                1,
                StmtKind::Compute,
                vec![
                    Loop::new("i", n, false),
                    Loop::new("j", tri, false),
                    Loop::new("k", m, true),
                ],
                Access::new("C", &[0, 1]),
                vec![
                    Access::new("C", &[0, 1]),
                    Access::new("A", &[0, 2]),
                    Access::new("A", &[1, 2]),
                ],
                OpCounts::new(1, 1),
            ),
        ],
    }
}

/// `syr2k`: C = alpha*(A*B^T + B*A^T) + beta*C.  N=240, M=200.
pub fn syr2k() -> Kernel {
    let (n, m) = (240, 200);
    let tri = (n + 1) / 2;
    Kernel {
        name: "syr2k".into(),
        description: "Symmetric rank-2k update".into(),
        arrays: vec![
            ArrayDecl::new("C", &[n, n], true, true),
            ArrayDecl::new("A", &[n, m], true, false),
            ArrayDecl::new("B", &[n, m], true, false),
        ],
        statements: vec![
            stmt(
                0,
                StmtKind::Init,
                vec![Loop::new("i", n, false), Loop::new("j", tri, false)],
                Access::new("C", &[0, 1]),
                vec![Access::new("C", &[0, 1])],
                OpCounts::new(0, 1),
            ),
            // S1: C[i][j] += A[j][k]*alpha*B[i][k] + B[j][k]*alpha*A[i][k]
            stmt(
                1,
                StmtKind::Compute,
                vec![
                    Loop::new("i", n, false),
                    Loop::new("j", tri, false),
                    Loop::new("k", m, true),
                ],
                Access::new("C", &[0, 1]),
                vec![
                    Access::new("C", &[0, 1]),
                    Access::new("A", &[1, 2]),
                    Access::new("B", &[0, 2]),
                    Access::new("B", &[1, 2]),
                    Access::new("A", &[0, 2]),
                ],
                OpCounts::new(2, 2),
            ),
        ],
    }
}

/// `trmm`: B = alpha * A^T * B, A unit lower triangular.  M=200, N=240.
pub fn trmm() -> Kernel {
    let (m, n) = (200, 240);
    let tri = (m + 1) / 2; // average trip of k in i+1..M
    Kernel {
        name: "trmm".into(),
        description: "Triangular matrix-mult.".into(),
        arrays: vec![
            ArrayDecl::new("B", &[m, n], true, true),
            ArrayDecl::new("A", &[m, m], true, false),
        ],
        statements: vec![
            // S0: B[i][j] += A[k][i] * B[k][j]  (k > i, averaged)
            stmt(
                0,
                StmtKind::Compute,
                vec![
                    Loop::new("i", m, false),
                    Loop::new("j", n, false),
                    Loop::new("k", tri, true),
                ],
                Access::new("B", &[0, 1]),
                vec![
                    Access::new("B", &[0, 1]),
                    Access::new("A", &[2, 0]),
                    Access::new("B", &[2, 1]),
                ],
                OpCounts::new(1, 1),
            ),
            // S1: B[i][j] *= alpha
            stmt(
                1,
                StmtKind::Compute,
                vec![Loop::new("i", m, false), Loop::new("j", n, false)],
                Access::new("B", &[0, 1]),
                vec![Access::new("B", &[0, 1])],
                OpCounts::new(0, 1),
            ),
        ],
    }
}

/// `symm`: C = alpha*A*B + beta*C with A symmetric.  M=200, N=240.
pub fn symm() -> Kernel {
    let (m, n) = (200, 240);
    let tri = (m + 1) / 2; // average trip of k in 0..i
    Kernel {
        name: "symm".into(),
        description: "Symmetric matrix-mult.".into(),
        arrays: vec![
            ArrayDecl::new("C", &[m, n], true, true),
            ArrayDecl::new("A", &[m, m], true, false),
            ArrayDecl::new("B", &[m, n], true, false),
            ArrayDecl::new("temp2", &[m, n], false, false),
        ],
        statements: vec![
            // S0: temp2[i][j] = sum_k B[k][j]*A[i][k]   (k < i)
            stmt(
                0,
                StmtKind::Compute,
                vec![
                    Loop::new("i", m, false),
                    Loop::new("j", n, false),
                    Loop::new("k", tri, true),
                ],
                Access::new("temp2", &[0, 1]),
                vec![
                    Access::new("temp2", &[0, 1]),
                    Access::new("B", &[2, 1]),
                    Access::new("A", &[0, 2]),
                ],
                OpCounts::new(1, 1),
            ),
            // S1: C[k][j] += alpha*B[i][j]*A[i][k] scatter half (modeled as
            // second triangular MAC stream writing C)
            stmt(
                1,
                StmtKind::Compute,
                vec![
                    Loop::new("i", m, false),
                    Loop::new("j", n, false),
                    Loop::new("k", tri, true),
                ],
                Access::new("C", &[0, 1]),
                vec![
                    Access::new("C", &[0, 1]),
                    Access::new("B", &[0, 1]),
                    Access::new("A", &[0, 2]),
                ],
                OpCounts::new(1, 1),
            ),
            // S2: C[i][j] = beta*C[i][j] + alpha*B[i][j]*A[i][i] + alpha*temp2[i][j]
            stmt(
                2,
                StmtKind::Compute,
                vec![Loop::new("i", m, false), Loop::new("j", n, false)],
                Access::new("C", &[0, 1]),
                vec![
                    Access::new("C", &[0, 1]),
                    Access::new("B", &[0, 1]),
                    Access::new("temp2", &[0, 1]),
                ],
                OpCounts::new(2, 3),
            ),
        ],
    }
}

/// `madd`: C = A + B, N=400 (paper's own kernel).
pub fn madd() -> Kernel {
    let n = 400;
    Kernel {
        name: "madd".into(),
        description: "Matrix add. (C = A + B)".into(),
        arrays: vec![
            ArrayDecl::new("A", &[n, n], true, false),
            ArrayDecl::new("B", &[n, n], true, false),
            ArrayDecl::new("C", &[n, n], false, true),
        ],
        statements: vec![stmt(
            0,
            StmtKind::Compute,
            vec![Loop::new("i", n, false), Loop::new("j", n, false)],
            Access::new("C", &[0, 1]),
            vec![Access::new("A", &[0, 1]), Access::new("B", &[0, 1])],
            OpCounts::new(1, 0),
        )],
    }
}

/// `2-madd`: D = (A + B) + C — the first sum feeds the second (paper §6.1).
pub fn two_madd() -> Kernel {
    let n = 400;
    Kernel {
        name: "2-madd".into(),
        description: "2 Matrix add. (D = (A + B) + C)".into(),
        arrays: vec![
            ArrayDecl::new("A", &[n, n], true, false),
            ArrayDecl::new("B", &[n, n], true, false),
            ArrayDecl::new("C", &[n, n], true, false),
            ArrayDecl::new("T", &[n, n], false, false),
            ArrayDecl::new("D", &[n, n], false, true),
        ],
        statements: vec![
            stmt(
                0,
                StmtKind::Compute,
                vec![Loop::new("i", n, false), Loop::new("j", n, false)],
                Access::new("T", &[0, 1]),
                vec![Access::new("A", &[0, 1]), Access::new("B", &[0, 1])],
                OpCounts::new(1, 0),
            ),
            stmt(
                1,
                StmtKind::Compute,
                vec![Loop::new("i", n, false), Loop::new("j", n, false)],
                Access::new("D", &[0, 1]),
                vec![Access::new("T", &[0, 1]), Access::new("C", &[0, 1])],
                OpCounts::new(1, 0),
            ),
        ],
    }
}

/// `3-madd`: F = (A + B) + (C + D) — two independent sums feed the final
/// one (the kernel that shows off concurrent tasks, paper Table 7).
pub fn three_madd() -> Kernel {
    let n = 400;
    Kernel {
        name: "3-madd".into(),
        description: "3 Matrix add. (F = (A + B) + (C + D))".into(),
        arrays: vec![
            ArrayDecl::new("A", &[n, n], true, false),
            ArrayDecl::new("B", &[n, n], true, false),
            ArrayDecl::new("C", &[n, n], true, false),
            ArrayDecl::new("D", &[n, n], true, false),
            ArrayDecl::new("T1", &[n, n], false, false),
            ArrayDecl::new("T2", &[n, n], false, false),
            ArrayDecl::new("F", &[n, n], false, true),
        ],
        statements: vec![
            stmt(
                0,
                StmtKind::Compute,
                vec![Loop::new("i", n, false), Loop::new("j", n, false)],
                Access::new("T1", &[0, 1]),
                vec![Access::new("A", &[0, 1]), Access::new("B", &[0, 1])],
                OpCounts::new(1, 0),
            ),
            stmt(
                1,
                StmtKind::Compute,
                vec![Loop::new("i", n, false), Loop::new("j", n, false)],
                Access::new("T2", &[0, 1]),
                vec![Access::new("C", &[0, 1]), Access::new("D", &[0, 1])],
                OpCounts::new(1, 0),
            ),
            stmt(
                2,
                StmtKind::Compute,
                vec![Loop::new("i", n, false), Loop::new("j", n, false)],
                Access::new("F", &[0, 1]),
                vec![Access::new("T1", &[0, 1]), Access::new("T2", &[0, 1])],
                OpCounts::new(1, 0),
            ),
        ],
    }
}

/// All 15 kernels of Table 5 in the paper's row order.
pub fn all_kernels() -> Vec<Kernel> {
    vec![
        bicg(),
        madd(),
        mvt(),
        atax(),
        gesummv(),
        two_madd(),
        three_madd(),
        gemver(),
        two_mm(),
        gemm(),
        syr2k(),
        syrk(),
        trmm(),
        three_mm(),
        symm(),
    ]
}

/// Kernel lookup by paper name.
pub fn by_name(name: &str) -> Option<Kernel> {
    all_kernels().into_iter().find(|k| k.name == name)
}

/// The 11-kernel subset of Table 6 (RTL comparison).
pub fn table6_kernels() -> Vec<Kernel> {
    ["2mm", "3mm", "atax", "bicg", "gemm", "gesummv", "mvt", "symm", "syr2k", "syrk", "trmm"]
        .iter()
        .map(|n| by_name(n).unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_complete() {
        assert_eq!(all_kernels().len(), 15);
        assert_eq!(table6_kernels().len(), 11);
        assert!(by_name("3mm").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn three_mm_matches_listing4() {
        let k = three_mm();
        assert_eq!(k.statements.len(), 6);
        // E = A×B: 180×190×200 MACs
        assert_eq!(k.statements[1].instances(), 180 * 190 * 200);
        // F = C×D: 190×210×220 MACs
        assert_eq!(k.statements[3].instances(), 190 * 210 * 220);
        // G = E×F: 180×210×190 MACs
        assert_eq!(k.statements[5].instances(), 180 * 210 * 190);
        // E and F are intermediates, G is the only output
        assert!(k.array("E").unwrap().is_intermediate());
        assert!(k.array("F").unwrap().is_intermediate());
        assert!(k.array("G").unwrap().is_output);
    }

    #[test]
    fn mvt_transposed_access() {
        let k = mvt();
        // S1 reads A[j][i]: dim0 indexed by loop 1 (j), dim1 by loop 0 (i).
        let a = &k.statements[1].reads[1];
        assert_eq!(a.loop_positions(), vec![1, 0]);
    }

    #[test]
    fn no_duplicate_array_names() {
        for k in all_kernels() {
            let mut names: Vec<_> = k.arrays.iter().map(|a| &a.name).collect();
            names.sort();
            names.dedup();
            assert_eq!(names.len(), k.arrays.len(), "{}", k.name);
        }
    }

    #[test]
    fn every_kernel_has_an_output() {
        for k in all_kernels() {
            assert!(k.arrays.iter().any(|a| a.is_output), "{}", k.name);
        }
    }
}
