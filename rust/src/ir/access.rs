//! Array declarations and affine access functions.

use std::fmt;

/// Element type of an array. The paper evaluates single-precision floats
/// exclusively; the enum exists so the packing model (bits per element,
/// burst divisibility) is explicit rather than hard-coded.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataType {
    F32,
    F64,
    I32,
}

impl DataType {
    /// Width of one element in bits.
    pub fn bits(self) -> u64 {
        match self {
            DataType::F32 | DataType::I32 => 32,
            DataType::F64 => 64,
        }
    }

    /// Width of one element in bytes.
    pub fn bytes(self) -> u64 {
        self.bits() / 8
    }

    /// C type spelling, used by the HLS code generator.
    pub fn c_name(self) -> &'static str {
        match self {
            DataType::F32 => "float",
            DataType::F64 => "double",
            DataType::I32 => "int",
        }
    }
}

impl fmt::Display for DataType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.c_name())
    }
}

/// A (possibly multi-dimensional) array in the kernel signature or an
/// intermediate produced by one statement and consumed by another.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayDecl {
    pub name: String,
    /// Extent of each dimension, innermost last.
    pub dims: Vec<u64>,
    pub dtype: DataType,
    /// Lives in off-chip memory at kernel start (kernel input).
    pub is_input: bool,
    /// Must be written back to off-chip memory at kernel end.
    pub is_output: bool,
}

impl ArrayDecl {
    pub fn new(name: &str, dims: &[u64], is_input: bool, is_output: bool) -> Self {
        ArrayDecl {
            name: name.to_string(),
            dims: dims.to_vec(),
            dtype: DataType::F32,
            is_input,
            is_output,
        }
    }

    /// Total number of elements.
    pub fn elems(&self) -> u64 {
        self.dims.iter().product()
    }

    /// Total footprint in bytes.
    pub fn bytes(&self) -> u64 {
        self.elems() * self.dtype.bytes()
    }

    /// Purely intermediate: neither loaded from nor stored to off-chip
    /// memory; such arrays travel between fused tasks through FIFOs.
    pub fn is_intermediate(&self) -> bool {
        !self.is_input && !self.is_output
    }
}

/// One affine index expression. PolyBench accesses are single-iterator per
/// dimension (`A[i][k]`, `B[k][j]`, transposed forms `A[j][i]`), which this
/// captures exactly; `Zero` covers broadcast dims of rank-reduced views.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Index {
    /// The iterator of the loop with this name (by position in the
    /// statement's loop nest).
    Iter(usize),
    /// Constant zero index (unused dimension).
    Zero,
}

/// An affine array access `array[ idx_0 ][ idx_1 ]...` appearing in a
/// statement, tagged read or write by its position in [`super::Statement`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Access {
    pub array: String,
    /// One entry per array dimension; `Index::Iter(p)` refers to position
    /// `p` in the statement's loop list (0 = outermost).
    pub idx: Vec<Index>,
}

impl Access {
    /// `Access::new("A", &[0, 2])` = `A[l0][l2]`.
    pub fn new(array: &str, loop_positions: &[usize]) -> Self {
        Access {
            array: array.to_string(),
            idx: loop_positions.iter().map(|&p| Index::Iter(p)).collect(),
        }
    }

    /// Loop positions (into the owning statement's loop list) that index
    /// this access, in dimension order.
    pub fn loop_positions(&self) -> Vec<usize> {
        self.idx
            .iter()
            .filter_map(|i| match i {
                Index::Iter(p) => Some(*p),
                Index::Zero => None,
            })
            .collect()
    }

    /// Whether loop position `p` indexes any dimension of this access.
    pub fn uses_loop(&self, p: usize) -> bool {
        self.idx.contains(&Index::Iter(p))
    }

    /// The loop position indexing the **last** (fastest-varying) dimension,
    /// if it is iterator-indexed. Drives the bit-width rule (paper Eq 3).
    pub fn last_dim_loop(&self) -> Option<usize> {
        match self.idx.last() {
            Some(Index::Iter(p)) => Some(*p),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_widths() {
        assert_eq!(DataType::F32.bits(), 32);
        assert_eq!(DataType::F64.bytes(), 8);
        assert_eq!(DataType::F32.c_name(), "float");
    }

    #[test]
    fn array_footprint() {
        let a = ArrayDecl::new("A", &[180, 200], true, false);
        assert_eq!(a.elems(), 36_000);
        assert_eq!(a.bytes(), 144_000);
        assert!(!a.is_intermediate());
        let e = ArrayDecl::new("E", &[180, 190], false, false);
        assert!(e.is_intermediate());
    }

    #[test]
    fn access_positions() {
        // B[k][j] in a (i,j,k) nest -> dims indexed by loops 2 and 1.
        let b = Access::new("B", &[2, 1]);
        assert_eq!(b.loop_positions(), vec![2, 1]);
        assert!(b.uses_loop(1));
        assert!(!b.uses_loop(0));
        assert_eq!(b.last_dim_loop(), Some(1));
    }
}
