//! Task fusion (paper §3.1) — as an *explored* dimension of the design
//! space, not a fixed pre-pass.
#![deny(missing_docs)]
//!
//! A [`FusionPlan`] is a canonical partition of the kernel's statements
//! into fused tasks. [`enumerate_fusions`] produces every
//! dependence-legal plan between the two extremes the paper's unified
//! space spans:
//!
//! * **fully fissioned** — one task per statement;
//! * **max output-stationary fusion** — statements writing the same
//!   array merge into one task (today's [`fuse`] output, variant 0), so
//!   every output tile is produced — loaded, computed, stored or sent —
//!   exactly once.
//!
//! Beyond the contiguous output-stationary partitions of the original
//! space, a plan part now carries the paper's §3.1 full generality:
//!
//! * **cross-array fusion** — one part may contain statements writing
//!   *different* arrays when their loop nests unify (same iterator
//!   names with equal trip counts and reduction flags) and no flow or
//!   anti dependence runs between the merged statement groups. mvt's
//!   two concurrent MAC nests merge into one engine this way.
//! * **partial (loop-range) fusion** — a part may carry an optional
//!   *fusion range* `[lo, hi)` over the statements' shared outermost
//!   (non-reduction) loop: the statements are fused only over that
//!   sub-range of their iteration spaces, and the remaining iterations
//!   are *peeled* into prologue (`[0, lo)`) and epilogue
//!   (`[hi, trip)`) sub-tasks, materialized as separate tasks of the
//!   [`FusedGraph`] with their own geometry. Peels are cut per output
//!   subgroup, so an init/update pair is never split by a range.
//!
//! Legality is checked by [`FusionPlan::validate`]:
//!
//! * an init/update pair (a [`StmtKind::Init`] statement and the
//!   updates of the same array) may never split across a FIFO — the
//!   zero-init writes the very tile the update accumulates into, and a
//!   loop-carried accumulator cannot re-read its running value from a
//!   stream;
//! * within one part, every statement group writing the same array is a
//!   *contiguous* program-order run of that array's writers —
//!   concurrent tasks overwriting the same array in an unordered way
//!   are rejected;
//! * a part mixing output arrays (or carrying a range) must *unify*:
//!   every loop of every member maps by iterator name onto the
//!   representative nest with an equal reduction flag and — except for
//!   the ranged outermost loop — an equal trip count, and no flow/anti
//!   dependence may run between member statements writing different
//!   arrays;
//! * flow dependences between the materialized tasks (peels included)
//!   must not create a cycle (checked by Kahn's algorithm, not assumed
//!   from statement numbering).
//!
//! FIFO edges use **last-writer** flow semantics: a statement reading
//! array `a` depends on the *latest* preceding writer of `a`, so a
//! split update chain (`x += A·y` then `x += z`) pipelines through one
//! FIFO instead of fanning every historical writer into every reader.
//! Peels of one part never exchange FIFO data with each other (their
//! outer-loop ranges are disjoint, so each peel produces and consumes
//! its own slice locally); a downstream reader depends on *every* peel
//! of its producer part. For max fusion all of this is edge-for-edge
//! identical to the classic array-level flow graph (all writers of an
//! array share a single whole-range task), which the property suite
//! pins bit-exactly.

use crate::ir::access::Index;
use crate::ir::{Kernel, StmtKind};
use std::collections::BTreeSet;

/// Configuration-independent, per-array info of a fused task, computed
/// once at fusion time (the DSE constructs a geometry per design-point
/// evaluation — 10^5+ per solve — so this must not be rebuilt there; see
/// EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayInfo {
    /// Array name as declared in the kernel.
    pub name: String,
    /// Access function translated to representative-nest loop positions
    /// (None = dimension not indexed by a loop iterator).
    pub access: Vec<Option<usize>>,
    /// Whether any statement of the task writes this array.
    pub writes: bool,
    /// Whether any statement of the task reads this array.
    pub reads: bool,
}

/// Role of a materialized task within its [`FusionPlan`] part: ranged
/// parts peel their leftover iterations into separate tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeelRole {
    /// The single task of an unranged part (the whole iteration space).
    Whole,
    /// The fused task of a ranged part, covering the `[lo, hi)` range.
    Main,
    /// A peeled prologue (`[0, lo)`) of one output subgroup.
    Prologue,
    /// A peeled epilogue (`[hi, trip)`) of one output subgroup.
    Epilogue,
}

/// A fused task: an ordered group of statement ids (e.g. `FT0 = {S0,
/// S1}` zero-init + MAC in 3mm). Classic tasks write a single array;
/// cross-array merged tasks write several (`outputs`); ranged tasks
/// cover only a sub-range of the shared outermost loop (`outer_range`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusedTask {
    /// Topological task id within its [`FusedGraph`].
    pub id: usize,
    /// Statement ids, program order. The *representative* statement (the
    /// one whose loop nest shapes the tiling space) is the compute
    /// statement with the deepest nest.
    pub stmts: Vec<usize>,
    /// The task's primary output: the array written by its first
    /// statement (the single output for classic tasks).
    pub output: String,
    /// Every array this task writes, first-touch order (length 1 for
    /// classic output-stationary tasks, ≥ 2 after a cross-array merge).
    pub outputs: Vec<String>,
    /// Memoized per-array info (first-touch order).
    pub array_info: Vec<ArrayInfo>,
    /// Sub-range `[lo, hi)` of the representative's outermost loop this
    /// task covers (`None` = the full iteration space). Set for the
    /// main task and the peels of a ranged part.
    pub outer_range: Option<(u64, u64)>,
    /// Index of the [`FusionPlan`] part this task realizes (peels share
    /// their part index with the main task they were cut from).
    pub part: usize,
    /// Whether this task is the whole part, the fused range, or a peel.
    pub role: PeelRole,
}

impl FusedTask {
    /// The statement whose loop nest drives tiling/permutation choices:
    /// deepest compute statement of the group.
    pub fn representative(&self, k: &Kernel) -> usize {
        representative_of(k, &self.stmts)
    }

    /// Trip count of the covered outer-loop range (`hi - lo`), `None`
    /// when the task spans the full iteration space.
    pub fn outer_span(&self) -> Option<u64> {
        self.outer_range.map(|(lo, hi)| hi - lo)
    }
}

/// The statement of `stmts` whose loop nest drives tiling choices:
/// deepest compute statement, most ops on ties.
fn representative_of(k: &Kernel, stmts: &[usize]) -> usize {
    *stmts
        .iter()
        .max_by_key(|&&sid| {
            let s = &k.statements[sid];
            (s.loops.len(), s.kind == StmtKind::Compute, s.ops.total())
        })
        .expect("fused task is non-empty")
}

// ---- FusionPlan: the canonical partition encoding ----------------------

/// A fusion choice, encoded as a canonical partition of statement ids
/// into tasks plus an optional fusion *range* per part: each part
/// ascending (= program order), parts ordered by their first statement,
/// ranges riding along. This is the form persisted in
/// [`crate::dse::config::DesignConfig`] and compared by the QoR
/// knowledge base, so two solves of the same variant always agree on
/// the encoding regardless of task renumbering. A part's range is the
/// `[lo, hi)` slice of the shared outermost loop over which its
/// statements fuse (`None` = full fusion over the whole space).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct FusionPlan {
    parts: Vec<Vec<usize>>,
    ranges: Vec<Option<(u64, u64)>>,
}

impl FusionPlan {
    /// Build an unranged plan from raw parts, canonicalizing the
    /// encoding (parts sorted internally and by first element).
    /// Legality against a kernel is a separate question — see
    /// [`FusionPlan::validate`].
    pub fn new(parts: Vec<Vec<usize>>) -> FusionPlan {
        FusionPlan::new_with_ranges(parts, Vec::new())
    }

    /// Build a plan from raw parts and per-part fusion ranges
    /// (`ranges[i]` belongs to `parts[i]`; missing tail entries default
    /// to `None`), canonicalizing the encoding. The range travels with
    /// its part through the canonical sort.
    pub fn new_with_ranges(
        parts: Vec<Vec<usize>>,
        mut ranges: Vec<Option<(u64, u64)>>,
    ) -> FusionPlan {
        debug_assert!(
            ranges.len() <= parts.len(),
            "{} ranges for {} parts — surplus ranges would be dropped silently",
            ranges.len(),
            parts.len()
        );
        ranges.resize(parts.len(), None);
        let mut paired: Vec<(Vec<usize>, Option<(u64, u64)>)> =
            parts.into_iter().zip(ranges).collect();
        for (p, _) in &mut paired {
            p.sort_unstable();
        }
        paired.sort_by_key(|(p, _)| p.first().copied().unwrap_or(usize::MAX));
        let (parts, ranges) = paired.into_iter().unzip();
        FusionPlan { parts, ranges }
    }

    /// The canonical parts, each ascending, ordered by first statement.
    pub fn parts(&self) -> &[Vec<usize>] {
        &self.parts
    }

    /// The per-part fusion ranges, parallel to [`FusionPlan::parts`]
    /// (`None` = the part fuses over its whole iteration space).
    pub fn ranges(&self) -> &[Option<(u64, u64)>] {
        &self.ranges
    }

    /// The fusion range of part `i`, if one is set.
    pub fn range(&self, i: usize) -> Option<(u64, u64)> {
        self.ranges.get(i).copied().flatten()
    }

    /// Whether any part carries a fusion range.
    pub fn has_ranges(&self) -> bool {
        self.ranges.iter().any(Option::is_some)
    }

    /// Number of plan parts. The materialized [`FusedGraph`] has at
    /// least this many tasks (ranged parts add their peels).
    pub fn n_tasks(&self) -> usize {
        self.parts.len()
    }

    /// Human-readable form of each part, in the paper's Table 9 shape
    /// with the range suffix for ranged parts: `{S0, S1}` or
    /// `{S1[100:300], S2[100:300]}`.
    pub fn part_strings(&self) -> Vec<String> {
        self.parts
            .iter()
            .enumerate()
            .map(|(pi, p)| {
                let ss: Vec<String> = p
                    .iter()
                    .map(|s| match self.range(pi) {
                        Some((lo, hi)) => format!("S{s}[{lo}:{hi}]"),
                        None => format!("S{s}"),
                    })
                    .collect();
                format!("{{{}}}", ss.join(", "))
            })
            .collect()
    }

    /// Today's coarsest plan: statements grouped by written array.
    pub fn max_fusion(k: &Kernel) -> FusionPlan {
        FusionPlan::new(output_groups(k))
    }

    /// The finest nominal plan: one task per statement. Not necessarily
    /// *legal* (init/update pairs must stay fused) — it bounds the
    /// space, the enumeration filters legality.
    pub fn fissioned(k: &Kernel) -> FusionPlan {
        FusionPlan::new(k.statements.iter().map(|s| vec![s.id]).collect())
    }

    /// Full legality check against `k` (the rules in the module doc):
    /// exact statement coverage, contiguous same-array runs within each
    /// output group, init/update pairs unsplit, unification (loop-nest
    /// compatibility + no internal cross-array dependences) for
    /// cross-array and ranged parts, well-formed ranges, and an acyclic
    /// materialized task graph.
    ///
    /// ```
    /// use prometheus::analysis::fusion::FusionPlan;
    /// use prometheus::ir::polybench;
    ///
    /// let k = polybench::gemm();
    /// // the max output-stationary fusion is always legal
    /// assert!(FusionPlan::max_fusion(&k).validate(&k).is_ok());
    /// // splitting gemm's init/update pair across a FIFO is not
    /// let split = FusionPlan::new(vec![vec![0], vec![1]]);
    /// assert!(split.validate(&k).unwrap_err().contains("init/update"));
    /// ```
    pub fn validate(&self, k: &Kernel) -> Result<(), String> {
        self.checked_layout(k).map(|_| ())
    }

    /// The full legality check, returning the validated raw layout, its
    /// flow edges and their topological order — so
    /// [`fuse_with_plan`] materializes exactly what was checked
    /// instead of re-deriving all three.
    fn checked_layout(
        &self,
        k: &Kernel,
    ) -> Result<(Vec<RawTask>, Vec<(usize, usize, String)>, Vec<usize>), String> {
        let n = k.statements.len();
        let mut owner = vec![usize::MAX; n];
        for (pi, part) in self.parts.iter().enumerate() {
            if part.is_empty() {
                return Err(format!("fusion plan for {}: empty task", k.name));
            }
            for w in part.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!(
                        "fusion plan for {}: part {:?} is not strictly ascending",
                        k.name, part
                    ));
                }
            }
            for &sid in part {
                if sid >= n {
                    return Err(format!(
                        "fusion plan for {}: statement S{sid} out of range (kernel has {n})",
                        k.name
                    ));
                }
                if owner[sid] != usize::MAX {
                    return Err(format!(
                        "fusion plan for {}: statement S{sid} appears in two tasks",
                        k.name
                    ));
                }
                owner[sid] = pi;
            }
            self.validate_part(k, pi, part)?;
        }
        if owner.iter().any(|&o| o == usize::MAX) {
            return Err(format!(
                "fusion plan for {}: not every statement is assigned a task",
                k.name
            ));
        }

        // Per output group: init/update glue and contiguous runs.
        for group in output_groups(k) {
            let has_init = group.iter().any(|&s| k.statements[s].kind == StmtKind::Init);
            let first_owner = owner[group[0]];
            if has_init && group.iter().any(|&s| owner[s] != first_owner) {
                return Err(format!(
                    "fusion plan for {}: init/update pair of `{}` split across a FIFO",
                    k.name, k.statements[group[0]].write.array
                ));
            }
            // each part's members must be consecutive in the group: once
            // the owning part changes it may never come back
            let mut seen: Vec<usize> = Vec::new();
            for &s in &group {
                let o = owner[s];
                match seen.last() {
                    Some(&last) if last == o => {}
                    _ => {
                        if seen.contains(&o) {
                            return Err(format!(
                                "fusion plan for {}: non-contiguous split of `{}` writers",
                                k.name, k.statements[group[0]].write.array
                            ));
                        }
                        seen.push(o);
                    }
                }
            }
        }

        // Acyclicity of the materialized task graph (peels included)
        // under last-writer flow.
        let layout = materialize_layout(k, self);
        let edges = layout_flow_edges(k, &layout);
        let Some(order) = kahn_order(layout.len(), &edges) else {
            return Err(format!(
                "fusion plan for {}: flow dependences create a task cycle",
                k.name
            ));
        };
        Ok((layout, edges, order))
    }

    /// Part-local rules: unification and internal-dependence checks for
    /// cross-array and ranged parts, and range well-formedness.
    fn validate_part(&self, k: &Kernel, pi: usize, part: &[usize]) -> Result<(), String> {
        let range = self.range(pi);
        let cross = part
            .iter()
            .any(|&sid| k.statements[sid].write.array != k.statements[part[0]].write.array);
        if !cross && range.is_none() {
            return Ok(()); // classic output-stationary part
        }

        // Unification: every loop of every member maps by name onto the
        // representative nest with an equal reduction flag; trips must
        // be equal everywhere except the ranged outermost loop.
        let rep = representative_of(k, part);
        let rep_loops = &k.statements[rep].loops;
        for &sid in part {
            let s = &k.statements[sid];
            for (li, l) in s.loops.iter().enumerate() {
                let Some(rp) = rep_loops.iter().position(|rl| rl.name == l.name) else {
                    return Err(format!(
                        "fusion plan for {}: loop `{}` of S{sid} does not unify with the \
                         representative nest of part {part:?}",
                        k.name, l.name
                    ));
                };
                if rep_loops[rp].reduction != l.reduction {
                    return Err(format!(
                        "fusion plan for {}: loop `{}` of S{sid} disagrees with S{rep} on \
                         reduction, so part {part:?} does not unify",
                        k.name, l.name
                    ));
                }
                let outer_exempt = range.is_some() && li == 0 && rp == 0;
                if !outer_exempt && rep_loops[rp].trip != l.trip {
                    return Err(format!(
                        "fusion plan for {}: loop `{}` of S{sid} has trip {} vs {} in S{rep}, \
                         so part {part:?} does not unify",
                        k.name, l.name, l.trip, rep_loops[rp].trip
                    ));
                }
            }
        }

        // No flow or anti dependence between member statements writing
        // different arrays: a cross-array producer/consumer pair cannot
        // share one engine (the consumer would read a tile the same
        // iteration is still producing).
        for (ai, &a) in part.iter().enumerate() {
            for &b in &part[ai + 1..] {
                let (sa, sb) = (&k.statements[a], &k.statements[b]);
                if sa.write.array == sb.write.array {
                    continue;
                }
                if sb.reads.iter().any(|r| r.array == sa.write.array) {
                    return Err(format!(
                        "fusion plan for {}: flow dependence S{a} -> S{b} (array `{}`) inside \
                         one fused task",
                        k.name, sa.write.array
                    ));
                }
                if sa.reads.iter().any(|r| r.array == sb.write.array) {
                    return Err(format!(
                        "fusion plan for {}: anti dependence S{a} -> S{b} (array `{}`) inside \
                         one fused task",
                        k.name, sb.write.array
                    ));
                }
            }
        }

        // Range well-formedness.
        if let Some((lo, hi)) = range {
            if part.len() < 2 {
                return Err(format!(
                    "fusion plan for {}: fusion range on single-statement part {part:?}",
                    k.name
                ));
            }
            if lo >= hi {
                return Err(format!(
                    "fusion plan for {}: empty fusion range [{lo}:{hi}) on part {part:?}",
                    k.name
                ));
            }
            if rep_loops.first().map(|l| l.reduction).unwrap_or(true) {
                return Err(format!(
                    "fusion plan for {}: fusion range over a reduction (or missing) outermost \
                     loop of part {part:?}",
                    k.name
                ));
            }
            let outer = &rep_loops[0].name;
            for &sid in part {
                match k.statements[sid].loops.first() {
                    Some(l) if &l.name == outer => {}
                    _ => {
                        return Err(format!(
                            "fusion plan for {}: S{sid} does not share the outermost iterator \
                             `{outer}` required by the fusion range of part {part:?}",
                            k.name
                        ))
                    }
                }
            }
            let outer_trips: Vec<u64> =
                part.iter().map(|&sid| k.statements[sid].loops[0].trip).collect();
            let min_trip = *outer_trips.iter().min().expect("part is non-empty");
            if hi > min_trip {
                return Err(format!(
                    "fusion plan for {}: fusion range [{lo}:{hi}) exceeds the smallest outer \
                     trip {min_trip} of part {part:?}",
                    k.name
                ));
            }
            if lo == 0 && hi == min_trip && outer_trips.iter().all(|&t| t == min_trip) {
                return Err(format!(
                    "fusion plan for {}: degenerate fusion range [{lo}:{hi}) covers the whole \
                     iteration space of part {part:?} — encode it without a range",
                    k.name
                ));
            }
            // peels are cut per output subgroup; a subgroup whose
            // members disagree on the outer trip has no single peel
            for sg in output_subgroups(k, part) {
                let t0 = k.statements[sg[0]].loops[0].trip;
                if sg.iter().any(|&s| k.statements[s].loops[0].trip != t0) {
                    return Err(format!(
                        "fusion plan for {}: writers of `{}` disagree on the outer trip, so \
                         the ranged part {part:?} cannot peel them together",
                        k.name, k.statements[sg[0]].write.array
                    ));
                }
            }
        }
        Ok(())
    }
}

// Manual serde impls (the vendored serde has no derive proc-macro): an
// unranged part is a JSON array of statement ids; a ranged part is an
// object `{"stmts": [..], "range": [lo, hi]}`. Deserialization
// re-canonicalizes, so hand-edited databases cannot smuggle in a
// non-canonical encoding. The QoR DB's FORMAT_VERSION gates old files:
// v2 databases (whose plans predate ranges) are evicted wholesale.
impl serde::Serialize for FusionPlan {
    fn serialize(&self) -> serde::Value {
        serde::Value::Arr(
            self.parts
                .iter()
                .zip(&self.ranges)
                .map(|(p, r)| {
                    let stmts = serde::Value::Arr(
                        p.iter().map(|s| serde::Serialize::serialize(s)).collect(),
                    );
                    match r {
                        None => stmts,
                        Some((lo, hi)) => serde::Value::Obj(vec![
                            ("stmts".to_string(), stmts),
                            (
                                "range".to_string(),
                                serde::Value::Arr(vec![
                                    serde::Serialize::serialize(lo),
                                    serde::Serialize::serialize(hi),
                                ]),
                            ),
                        ]),
                    }
                })
                .collect(),
        )
    }
}

impl serde::Deserialize for FusionPlan {
    fn deserialize(v: &serde::Value) -> Result<FusionPlan, serde::Error> {
        let items = v
            .as_arr()
            .ok_or_else(|| serde::Error::new("fusion plan must be an array of parts"))?;
        let mut parts: Vec<Vec<usize>> = Vec::with_capacity(items.len());
        let mut ranges: Vec<Option<(u64, u64)>> = Vec::with_capacity(items.len());
        for item in items {
            match item {
                serde::Value::Arr(_) => {
                    parts.push(serde::Deserialize::deserialize(item)?);
                    ranges.push(None);
                }
                serde::Value::Obj(_) => {
                    parts.push(serde::Deserialize::deserialize(item.field("stmts")?)?);
                    let r: Vec<u64> = serde::Deserialize::deserialize(item.field("range")?)?;
                    if r.len() != 2 {
                        return Err(serde::Error::new(format!(
                            "fusion range must be [lo, hi], got {} entries",
                            r.len()
                        )));
                    }
                    ranges.push(Some((r[0], r[1])));
                }
                other => {
                    return Err(serde::Error::new(format!(
                        "invalid fusion part: expected array or object, got {}",
                        other.kind()
                    )))
                }
            }
        }
        Ok(FusionPlan::new_with_ranges(parts, ranges))
    }
}

/// Max statement-partition variants [`enumerate_fusions`] returns; the
/// zoo needs at most a handful, the cap bounds pathological inputs.
/// Variant 0 (max fusion) is always retained.
pub const MAX_FUSION_VARIANTS: usize = 64;

/// Max split/merge combinations the enumeration *examines* (validation
/// included) — bounds the walk itself for kernels whose per-group
/// composition product explodes, independent of how many combos turn
/// out legal. Combo 0 (max fusion) is always examined first.
pub const MAX_FUSION_COMBOS: usize = 4096;

/// Enumerate every dependence-legal fusion plan of `k` between full
/// fission and max output-stationary fusion, deterministically ordered
/// with **max fusion first** (variant 0). Each output group either
/// stays whole or splits into contiguous runs; groups holding an init
/// statement never split; and on top of every base partition, each
/// *pair* of parts writing different arrays is offered as a cross-array
/// merge — whole-range when the nests unify exactly, or fused over the
/// common outer prefix `[0, min_trip)` (with the longer statements'
/// tails peeled) when only the outer trips differ. Plans whose
/// materialized task graph is cyclic are dropped.
///
/// ```
/// use prometheus::analysis::fusion::{enumerate_fusions, FusionPlan};
/// use prometheus::ir::polybench;
///
/// let k = polybench::mvt();
/// let variants = enumerate_fusions(&k);
/// // variant 0 is always the max output-stationary fusion ...
/// assert_eq!(variants[0], FusionPlan::max_fusion(&k));
/// // ... and mvt's two independent MAC nests also merge into one
/// // engine (a cross-array variant)
/// assert!(variants.iter().any(|p| p.parts() == [vec![0, 1]]));
/// ```
pub fn enumerate_fusions(k: &Kernel) -> Vec<FusionPlan> {
    let groups = output_groups(k);
    let choices: Vec<Vec<Vec<Vec<usize>>>> =
        groups.iter().map(|g| group_partitions(k, g)).collect();
    let mut out: Vec<FusionPlan> = Vec::new();
    let mut seen: BTreeSet<FusionPlan> = BTreeSet::new();
    let mut idx = vec![0usize; choices.len()];
    // the caps bound the *work*, not just the list: stop walking (and
    // validating) the cartesian product once the list is full, and stop
    // examining combos altogether past a fixed budget even when most of
    // them are invalid (cyclic) — enumeration must stay cheap relative
    // to one solve. Both cuts are deterministic (odometer order, then
    // lexicographic part pairs).
    let mut examined = 0usize;
    'odometer: loop {
        if out.len() >= MAX_FUSION_VARIANTS || examined >= MAX_FUSION_COMBOS {
            break;
        }
        examined += 1;
        let mut parts: Vec<Vec<usize>> = Vec::new();
        for (gi, &ci) in choices.iter().zip(idx.iter()) {
            parts.extend(gi[ci].iter().cloned());
        }
        let base = FusionPlan::new(parts);
        let base_ok = base.validate(k).is_ok();
        if base_ok && seen.insert(base.clone()) {
            out.push(base.clone());
        }
        if base_ok {
            merge_variants(k, &base, &mut out, &mut seen, &mut examined);
        }
        // advance the odometer, last group fastest (combo 0 = all-whole
        // = max fusion, so it leads the list)
        let mut d = choices.len();
        loop {
            if d == 0 {
                break 'odometer;
            }
            d -= 1;
            idx[d] += 1;
            if idx[d] < choices[d].len() {
                break;
            }
            idx[d] = 0;
        }
    }
    debug_assert!(!out.is_empty(), "max fusion is always legal");
    out
}

/// Offer every pairwise cross-array merge of `base`'s parts: the
/// whole-range merge when the nests unify exactly, else the common
/// outer-prefix range merge `[0, min_trip)`. Merges are pairwise only —
/// a merged plan is not re-merged — which keeps the walk linear in
/// parts² while covering every sibling-nest pair the zoo exhibits.
fn merge_variants(
    k: &Kernel,
    base: &FusionPlan,
    out: &mut Vec<FusionPlan>,
    seen: &mut BTreeSet<FusionPlan>,
    examined: &mut usize,
) {
    let nparts = base.parts().len();
    for i in 0..nparts {
        for j in (i + 1)..nparts {
            if out.len() >= MAX_FUSION_VARIANTS || *examined >= MAX_FUSION_COMBOS {
                return;
            }
            // only genuinely cross-array pairs: merging two runs of the
            // same array's writers just reconstructs another base combo
            let pa = &base.parts()[i];
            let pb = &base.parts()[j];
            if k.statements[pa[0]].write.array == k.statements[pb[0]].write.array {
                continue;
            }
            // base parts carrying a range are not re-merged (base plans
            // are unranged today; this guards future callers)
            if base.range(i).is_some() || base.range(j).is_some() {
                continue;
            }
            let mut merged_parts: Vec<Vec<usize>> = Vec::with_capacity(nparts - 1);
            for (pi, p) in base.parts().iter().enumerate() {
                if pi == j {
                    continue;
                }
                if pi == i {
                    let mut m = p.clone();
                    m.extend(pb.iter().copied());
                    m.sort_unstable();
                    merged_parts.push(m);
                } else {
                    merged_parts.push(p.clone());
                }
            }
            *examined += 1;
            let whole = FusionPlan::new(merged_parts.clone());
            if whole.validate(k).is_ok() {
                if seen.insert(whole.clone()) {
                    out.push(whole);
                }
                continue;
            }
            // exact unification failed — when only the outer trips
            // disagree, fuse the shared prefix [0, min) and peel the
            // longer tails (validate re-checks everything)
            let min_outer = pa
                .iter()
                .chain(pb.iter())
                .map(|&s| k.statements[s].loops.first().map(|l| l.trip).unwrap_or(0))
                .min()
                .unwrap_or(0);
            if min_outer == 0 {
                continue;
            }
            *examined += 1;
            // the merged part keeps position i in canonical order (its
            // first statement is unchanged and parts are disjoint)
            let mut ranges: Vec<Option<(u64, u64)>> = vec![None; merged_parts.len()];
            ranges[i] = Some((0, min_outer));
            let ranged = FusionPlan::new_with_ranges(merged_parts, ranges);
            if ranged.validate(k).is_ok() && seen.insert(ranged.clone()) {
                out.push(ranged);
            }
        }
    }
}

/// Statements grouped by written array, in first-writer program order —
/// the atoms of the fusion space. (The whole-kernel case of
/// [`output_subgroups`]: one grouping implementation, so enumeration
/// and peel-cutting can never disagree.)
fn output_groups(k: &Kernel) -> Vec<Vec<usize>> {
    let all: Vec<usize> = (0..k.statements.len()).collect();
    output_subgroups(k, &all)
}

/// The statements of one plan part grouped by written array,
/// first-touch order — the units a ranged part peels.
fn output_subgroups(k: &Kernel, part: &[usize]) -> Vec<Vec<usize>> {
    let mut groups: Vec<(&str, Vec<usize>)> = Vec::new();
    for &sid in part {
        let a = k.statements[sid].write.array.as_str();
        if let Some(g) = groups.iter_mut().find(|(n, _)| *n == a) {
            g.1.push(sid);
        } else {
            groups.push((a, vec![sid]));
        }
    }
    groups.into_iter().map(|(_, g)| g).collect()
}

/// Legal sub-partitions of one output group: the whole group first,
/// then (when no init statement glues the group together) every
/// contiguous composition, in split-mask order.
fn group_partitions(k: &Kernel, group: &[usize]) -> Vec<Vec<Vec<usize>>> {
    let m = group.len();
    let has_init = group.iter().any(|&s| k.statements[s].kind == StmtKind::Init);
    if m == 1 || has_init || m > 16 {
        return vec![vec![group.to_vec()]];
    }
    let mut res = Vec::with_capacity(1usize << (m - 1));
    for mask in 0u32..(1u32 << (m - 1)) {
        let mut parts: Vec<Vec<usize>> = vec![vec![group[0]]];
        for (i, &s) in group.iter().enumerate().skip(1) {
            if mask & (1 << (i - 1)) != 0 {
                parts.push(vec![s]);
            } else {
                parts.last_mut().expect("non-empty").push(s);
            }
        }
        res.push(parts);
    }
    res
}

/// The latest statement before `before` (program order) that writes
/// `array` — the producer a read of `array` actually consumes.
fn last_writer(k: &Kernel, before: usize, array: &str) -> Option<usize> {
    k.statements[..before]
        .iter()
        .rev()
        .find(|s| s.write.array == array)
        .map(|s| s.id)
}

/// One not-yet-renumbered task of a plan's materialization: the plan
/// part it realizes, its peel role, its statements and its outer-loop
/// range. Unranged parts materialize as a single `Whole` task; ranged
/// parts as per-subgroup prologues, the `Main` fused range, then
/// per-subgroup epilogues.
struct RawTask {
    part: usize,
    role: PeelRole,
    stmts: Vec<usize>,
    range: Option<(u64, u64)>,
}

/// Deterministically expand a plan into its raw task layout, cutting
/// the peels of every ranged part. Assumes a validated plan (indexing
/// `loops[0]` of ranged statements is then safe).
fn materialize_layout(k: &Kernel, plan: &FusionPlan) -> Vec<RawTask> {
    let mut out = Vec::new();
    for (pi, part) in plan.parts().iter().enumerate() {
        match plan.range(pi) {
            None => out.push(RawTask {
                part: pi,
                role: PeelRole::Whole,
                stmts: part.clone(),
                range: None,
            }),
            Some((lo, hi)) => {
                let subgroups = output_subgroups(k, part);
                if lo > 0 {
                    for sg in &subgroups {
                        out.push(RawTask {
                            part: pi,
                            role: PeelRole::Prologue,
                            stmts: sg.clone(),
                            range: Some((0, lo)),
                        });
                    }
                }
                out.push(RawTask {
                    part: pi,
                    role: PeelRole::Main,
                    stmts: part.clone(),
                    range: Some((lo, hi)),
                });
                for sg in &subgroups {
                    let trip = k.statements[sg[0]]
                        .loops
                        .first()
                        .map(|l| l.trip)
                        .unwrap_or(0);
                    if trip > hi {
                        out.push(RawTask {
                            part: pi,
                            role: PeelRole::Epilogue,
                            stmts: sg.clone(),
                            range: Some((hi, trip)),
                        });
                    }
                }
            }
        }
    }
    out
}

/// Cross-task FIFO edges `(src_task, dst_task, array)` over a raw
/// layout, under last-writer flow semantics. Peels of one part never
/// exchange data (disjoint outer ranges produce and consume locally);
/// a reader in another part depends on *every* task containing the
/// last writer.
fn layout_flow_edges(k: &Kernel, layout: &[RawTask]) -> Vec<(usize, usize, String)> {
    let mut edges = BTreeSet::new();
    for (ti, t) in layout.iter().enumerate() {
        for &sid in &t.stmts {
            for r in &k.statements[sid].reads {
                if let Some(lw) = last_writer(k, sid, &r.array) {
                    for (tj, u) in layout.iter().enumerate() {
                        if u.part != t.part && u.stmts.contains(&lw) {
                            edges.insert((tj, ti, r.array.clone()));
                        }
                    }
                }
            }
        }
    }
    edges.into_iter().collect()
}

/// Kahn's algorithm with the smallest-id-first tie-break (a `BTreeSet`
/// worklist — the old `Vec` + `remove(0)` was O(n²)). Returns the
/// topological order, or `None` when the edges contain a cycle.
fn kahn_order(n: usize, edges: &[(usize, usize, String)]) -> Option<Vec<usize>> {
    let mut indeg = vec![0usize; n];
    let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (s, d, _) in edges {
        if s != d {
            indeg[*d] += 1;
            succ[*s].push(*d);
        }
    }
    let mut ready: BTreeSet<usize> = (0..n).filter(|&t| indeg[t] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(t) = ready.pop_first() {
        order.push(t);
        for &d in &succ[t] {
            indeg[d] -= 1;
            if indeg[d] == 0 {
                ready.insert(d);
            }
        }
    }
    if order.len() == n {
        Some(order)
    } else {
        None
    }
}

// ---- FusedGraph --------------------------------------------------------

/// The fused task graph: nodes are [`FusedTask`]s (peels included),
/// edges carry the array communicated over a FIFO between fused tasks.
/// Task ids are topological (producers precede consumers); `stmt_task`
/// memoizes the statement→task map so lookups are O(1).
#[derive(Debug, Clone)]
pub struct FusedGraph {
    /// The materialized tasks, topological order.
    pub tasks: Vec<FusedTask>,
    /// `(src_task, dst_task, array)` FIFO edges.
    pub edges: Vec<(usize, usize, String)>,
    /// Statement id → the task realizing its plan part (the `Whole` or
    /// `Main` task; a statement in a ranged part additionally appears
    /// in that part's peels). Precomputed at fusion time; the old
    /// per-call linear scan over every task was O(tasks × stmts).
    stmt_task: Vec<usize>,
}

impl FusedGraph {
    /// The task realizing statement `sid`'s plan part — O(1) via the
    /// fusion-time index. For ranged parts this is the `Main` fused
    /// task; the statement's peels are additional tasks of the same
    /// [`FusedTask::part`].
    pub fn task_of_stmt(&self, sid: usize) -> usize {
        self.stmt_task[sid]
    }

    /// Task ids with an edge into `t`, ascending and deduplicated.
    pub fn predecessors(&self, t: usize) -> Vec<usize> {
        let mut p: Vec<usize> = self
            .edges
            .iter()
            .filter(|(_, d, _)| *d == t)
            .map(|(s, _, _)| *s)
            .collect();
        p.sort_unstable();
        p.dedup();
        p
    }

    /// Task ids with no outgoing FIFO edge (the graph's outputs).
    pub fn sinks(&self) -> Vec<usize> {
        (0..self.tasks.len())
            .filter(|t| !self.edges.iter().any(|(s, _, _)| s == t))
            .collect()
    }

    /// Total elements communicated between fused tasks (Table 5, last
    /// column): for each FIFO edge, the footprint of the carried array.
    pub fn inter_task_elems(&self, k: &Kernel) -> u64 {
        let mut seen = BTreeSet::new();
        let mut total = 0;
        for (s, d, a) in &self.edges {
            if seen.insert((*s, *d, a.clone())) {
                total += k.array(a).map(|arr| arr.elems()).unwrap_or(0);
            }
        }
        total
    }

    /// Whether the graph is acyclic — a real topological check (Kahn)
    /// over the edges, not an assumption about id ordering: enumerated
    /// fusion variants are renumbered, but the check must hold on its
    /// own for any graph handed to a consumer.
    pub fn is_acyclic(&self) -> bool {
        kahn_order(self.tasks.len(), &self.edges).is_some()
    }

    /// The canonical [`FusionPlan`] this graph realizes — derived from
    /// the `Whole`/`Main` tasks (never stored separately), so it cannot
    /// drift. Peels are materialization detail, not plan parts.
    pub fn plan(&self) -> FusionPlan {
        let mut parts = Vec::new();
        let mut ranges = Vec::new();
        for t in &self.tasks {
            if matches!(t.role, PeelRole::Whole | PeelRole::Main) {
                parts.push(t.stmts.clone());
                ranges.push(match t.role {
                    PeelRole::Main => t.outer_range,
                    _ => None,
                });
            }
        }
        FusionPlan::new_with_ranges(parts, ranges)
    }

    /// The partition in the paper's Table 9 shape, with the range
    /// suffix for ranged/peeled tasks:
    /// `FT0 = {S1, S2}; FT1 = {S0[0:100], S3[0:100]}`.
    pub fn partition_string(&self) -> String {
        self.tasks
            .iter()
            .map(|t| {
                let stmts: Vec<String> = t
                    .stmts
                    .iter()
                    .map(|s| match t.outer_range {
                        Some((lo, hi)) => format!("S{s}[{lo}:{hi}]"),
                        None => format!("S{s}"),
                    })
                    .collect();
                format!("FT{} = {{{}}}", t.id, stmts.join(", "))
            })
            .collect::<Vec<_>>()
            .join("; ")
    }
}

/// Fuse statements of `k` into max output-stationary tasks — the fixed
/// coarsest plan, kept as the default entry point for consumers that do
/// not explore fusion.
pub fn fuse(k: &Kernel) -> FusedGraph {
    fuse_with_plan(k, &FusionPlan::max_fusion(k))
        .expect("max output-stationary fusion is always legal")
}

/// Materialize a fusion plan into a [`FusedGraph`]: validate legality,
/// expand ranged parts into main + peel tasks, build per-task array
/// memos, derive last-writer FIFO edges, and renumber tasks
/// topologically (Kahn with stable smallest-id tie-break) so producers
/// always precede consumers — atax groups y={S0,S3} before tmp={S1,S2}
/// in program order, but tmp feeds y; the paper's Table 9 likewise
/// lists atax as FT0:{S1,S2}, FT1:{S0,S3}.
pub fn fuse_with_plan(k: &Kernel, plan: &FusionPlan) -> Result<FusedGraph, String> {
    // one validation pass hands back the layout, edges and topological
    // order it already derived — nothing is recomputed here
    let (layout, edges, order) = plan.checked_layout(k)?;
    let n = layout.len();

    // order[new_id] = old_id; build the inverse map and renumber.
    let mut new_of_old = vec![0usize; n];
    for (new_id, &old_id) in order.iter().enumerate() {
        new_of_old[old_id] = new_id;
    }
    let mut tasks: Vec<FusedTask> = order
        .iter()
        .enumerate()
        .map(|(new_id, &old_id)| {
            let raw = &layout[old_id];
            let stmts = raw.stmts.clone();
            let output = k.statements[stmts[0]].write.array.clone();
            let mut outputs: Vec<String> = Vec::new();
            for &sid in &stmts {
                let a = &k.statements[sid].write.array;
                if !outputs.iter().any(|x| x == a) {
                    outputs.push(a.clone());
                }
            }
            FusedTask {
                id: new_id,
                stmts,
                output,
                outputs,
                array_info: Vec::new(),
                outer_range: raw.range,
                part: raw.part,
                role: raw.role,
            }
        })
        .collect();
    let edges: Vec<(usize, usize, String)> = {
        let mut e: Vec<(usize, usize, String)> = edges
            .into_iter()
            .map(|(s, d, a)| (new_of_old[s], new_of_old[d], a))
            .collect();
        e.sort();
        e
    };
    let mut stmt_task = vec![0usize; k.statements.len()];
    for t in &tasks {
        if matches!(t.role, PeelRole::Whole | PeelRole::Main) {
            for &sid in &t.stmts {
                stmt_task[sid] = t.id;
            }
        }
    }
    for t in &mut tasks {
        t.array_info = build_array_info(k, t);
    }
    let fg = FusedGraph { tasks, edges, stmt_task };
    debug_assert!(fg.is_acyclic());
    Ok(fg)
}

/// Build the per-array memo for one fused task: translate every access
/// onto the representative nest by iterator name (Eq 4 guarantees fused
/// statements share iterators) and record read/write membership.
fn build_array_info(k: &Kernel, task: &FusedTask) -> Vec<ArrayInfo> {
    let rep = task.representative(k);
    let rep_loops = &k.statements[rep].loops;
    let rep_pos_of = |sid: usize, pos: usize| -> Option<usize> {
        let name = &k.statements[sid].loops[pos].name;
        rep_loops.iter().position(|l| &l.name == name)
    };
    let translate = |sid: usize, acc: &crate::ir::Access| -> Vec<Option<usize>> {
        acc.idx
            .iter()
            .map(|ix| match ix {
                Index::Iter(p) => rep_pos_of(sid, *p),
                Index::Zero => None,
            })
            .collect()
    };
    let mut infos: Vec<ArrayInfo> = Vec::new();
    // rep statement first so its access translation wins
    let mut stmts: Vec<usize> = vec![rep];
    stmts.extend(task.stmts.iter().copied().filter(|&s| s != rep));
    // first-touch order must follow program order of the task's stmts
    for &sid in &task.stmts {
        let s = &k.statements[sid];
        for acc in std::iter::once(&s.write).chain(s.reads.iter()) {
            if !infos.iter().any(|i| i.name == acc.array) {
                // find the translation, preferring the rep statement
                let access = stmts
                    .iter()
                    .find_map(|&q| {
                        let qs = &k.statements[q];
                        if qs.write.array == acc.array {
                            return Some(translate(q, &qs.write));
                        }
                        qs.reads
                            .iter()
                            .find(|r| r.array == acc.array)
                            .map(|r| translate(q, r))
                    })
                    .unwrap_or_default();
                infos.push(ArrayInfo {
                    name: acc.array.clone(),
                    access,
                    writes: false,
                    reads: false,
                });
            }
        }
    }
    for &sid in &task.stmts {
        let s = &k.statements[sid];
        if let Some(i) = infos.iter_mut().find(|i| i.name == s.write.array) {
            i.writes = true;
        }
        for r in &s.reads {
            if let Some(i) = infos.iter_mut().find(|i| i.name == r.array) {
                i.reads = true;
            }
        }
    }
    infos
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    #[test]
    fn three_mm_fuses_to_three_tasks() {
        // Paper Listing 6: FT0={S0,S1}, FT1={S2,S3}, FT2={S4,S5}.
        let k = polybench::three_mm();
        let g = fuse(&k);
        assert_eq!(g.tasks.len(), 3);
        assert_eq!(g.tasks[0].stmts, vec![0, 1]);
        assert_eq!(g.tasks[1].stmts, vec![2, 3]);
        assert_eq!(g.tasks[2].stmts, vec![4, 5]);
        assert_eq!(g.tasks[0].output, "E");
        assert_eq!(g.tasks[2].output, "G");
        assert_eq!(g.tasks[0].outputs, vec!["E".to_string()]);
        assert_eq!(g.tasks[0].role, PeelRole::Whole);
        assert_eq!(g.tasks[0].outer_range, None);
        // FIFO edges: FT0 --E--> FT2, FT1 --F--> FT2.
        assert!(g.edges.iter().any(|(s, d, a)| (*s, *d, a.as_str()) == (0, 2, "E")));
        assert!(g.edges.iter().any(|(s, d, a)| (*s, *d, a.as_str()) == (1, 2, "F")));
        assert!(g.is_acyclic());
        assert_eq!(g.sinks(), vec![2]);
    }

    #[test]
    fn representative_is_deepest_compute() {
        let k = polybench::three_mm();
        let g = fuse(&k);
        assert_eq!(g.tasks[0].representative(&k), 1);
        assert_eq!(g.tasks[1].representative(&k), 3);
        assert_eq!(g.tasks[2].representative(&k), 5);
    }

    #[test]
    fn table5_comm_column() {
        // Paper Table 5: inter-task comm — 3mm: 2N² (E and F), atax: N
        // (tmp), bicg: 0, gesummv: 2N (tmp, y), 2-madd: N², 3-madd: 2N².
        let elems = |name: &str| {
            let k = polybench::by_name(name).unwrap();
            fuse(&k).inter_task_elems(&k)
        };
        assert_eq!(elems("bicg"), 0);
        assert_eq!(elems("madd"), 0);
        assert_eq!(elems("mvt"), 0);
        assert_eq!(elems("atax"), 390); // tmp[M]
        assert_eq!(elems("gesummv"), 2 * 250); // tmp + y
        assert_eq!(elems("2-madd"), 400 * 400);
        assert_eq!(elems("3-madd"), 2 * 400 * 400);
        assert_eq!(elems("3mm"), 180 * 190 + 190 * 210); // E + F
        assert_eq!(elems("2mm"), 180 * 190); // tmp
    }

    #[test]
    fn atax_tasks_renumbered_topologically() {
        // Paper Table 9: atax FT0 = {S1, S2} (tmp), FT1 = {S0, S3} (y).
        let k = polybench::atax();
        let g = fuse(&k);
        assert_eq!(g.tasks.len(), 2);
        assert_eq!(g.tasks[0].output, "tmp");
        assert_eq!(g.tasks[0].stmts, vec![1, 2]);
        assert_eq!(g.tasks[1].output, "y");
        assert_eq!(g.tasks[1].stmts, vec![0, 3]);
        assert!(g.is_acyclic());
        assert_eq!(g.partition_string(), "FT0 = {S1, S2}; FT1 = {S0, S3}");
    }

    #[test]
    fn mvt_tasks_stay_separate_under_max_fusion() {
        // mvt's two statements write different arrays -> 2 concurrent
        // tasks under the (output-stationary) max fusion.
        let k = polybench::mvt();
        let g = fuse(&k);
        assert_eq!(g.tasks.len(), 2);
        assert!(g.edges.is_empty());
    }

    #[test]
    fn mvt_cross_array_merge_is_one_engine() {
        // The cross-array variant merges both MAC nests into one task
        // writing x1 and x2, with no FIFO edges.
        let k = polybench::mvt();
        let merged = FusionPlan::new(vec![vec![0, 1]]);
        merged.validate(&k).unwrap_or_else(|e| panic!("{e}"));
        let g = fuse_with_plan(&k, &merged).unwrap();
        assert_eq!(g.tasks.len(), 1);
        assert_eq!(g.tasks[0].stmts, vec![0, 1]);
        assert_eq!(g.tasks[0].outputs, vec!["x1".to_string(), "x2".to_string()]);
        assert_eq!(g.tasks[0].role, PeelRole::Whole);
        assert!(g.edges.is_empty());
        assert_eq!(g.plan(), merged);
        // and the enumeration offers it as a variant
        let variants = enumerate_fusions(&k);
        assert!(variants.contains(&merged), "{variants:?}");
    }

    #[test]
    fn cross_array_merge_rejects_internal_dependences() {
        // 2-madd: S1 reads T written by S0 — one engine cannot both
        // produce and consume the tile in the same iteration.
        let k = polybench::two_madd();
        let err = FusionPlan::new(vec![vec![0, 1]]).validate(&k).unwrap_err();
        assert!(err.contains("dependence"), "{err}");
        // 3mm: E and F nests unify by name but disagree on every trip.
        let k3 = polybench::three_mm();
        let err3 = FusionPlan::new(vec![vec![0, 1, 2, 3], vec![4, 5]])
            .validate(&k3)
            .unwrap_err();
        assert!(err3.contains("unify"), "{err3}");
    }

    #[test]
    fn range_fusion_peels_prologue_and_epilogue() {
        // gemver's x-update chain {S1, S2} fused over i in [100, 300):
        // the peels keep the chain together and the graph stays acyclic.
        let k = polybench::gemver();
        let plan = FusionPlan::new_with_ranges(
            vec![vec![0], vec![1, 2], vec![3]],
            vec![None, Some((100, 300)), None],
        );
        plan.validate(&k).unwrap_or_else(|e| panic!("{e}"));
        assert!(plan.has_ranges());
        let g = fuse_with_plan(&k, &plan).unwrap();
        // {S0}, prologue {S1,S2}[0:100], main {S1,S2}[100:300],
        // epilogue {S1,S2}[300:400], {S3}
        assert_eq!(g.tasks.len(), 5);
        let main = &g.tasks[g.task_of_stmt(1)];
        assert_eq!(main.role, PeelRole::Main);
        assert_eq!(main.outer_range, Some((100, 300)));
        assert_eq!(main.stmts, vec![1, 2]);
        let peels: Vec<&FusedTask> = g
            .tasks
            .iter()
            .filter(|t| matches!(t.role, PeelRole::Prologue | PeelRole::Epilogue))
            .collect();
        assert_eq!(peels.len(), 2);
        for p in &peels {
            assert_eq!(p.stmts, vec![1, 2], "peels keep the update chain together");
            assert_eq!(p.part, main.part);
        }
        assert!(g.is_acyclic());
        // the plan round-trips through the graph (peels fold back in)
        assert_eq!(g.plan(), plan);
        // w's task consumes x from every peel of the ranged part
        let tw = g.task_of_stmt(3);
        let x_producers: BTreeSet<usize> = g
            .edges
            .iter()
            .filter(|(_, d, a)| *d == tw && a == "x")
            .map(|(s, _, _)| *s)
            .collect();
        assert_eq!(x_producers.len(), 3, "{:?}", g.edges);
    }

    #[test]
    fn range_fusion_never_splits_init_update_pairs() {
        // gemm {S0 init, S1 update} over i in [0, 100): the epilogue
        // peel carries the whole pair, not just the update.
        let k = polybench::gemm();
        let plan = FusionPlan::new_with_ranges(vec![vec![0, 1]], vec![Some((0, 100))]);
        plan.validate(&k).unwrap_or_else(|e| panic!("{e}"));
        let g = fuse_with_plan(&k, &plan).unwrap();
        assert_eq!(g.tasks.len(), 2); // main [0:100) + epilogue [100:200)
        for t in &g.tasks {
            assert_eq!(t.stmts, vec![0, 1], "init/update pair split by a range");
        }
        assert_eq!(g.tasks[0].outer_range, Some((0, 100)));
        assert_eq!(g.tasks[1].outer_range, Some((100, 200)));
        assert_eq!(g.plan(), plan);
    }

    #[test]
    fn malformed_ranges_are_rejected() {
        let k = polybench::gemm();
        // empty range
        assert!(FusionPlan::new_with_ranges(vec![vec![0, 1]], vec![Some((100, 100))])
            .validate(&k)
            .is_err());
        // beyond the outer trip (gemm i = 200)
        assert!(FusionPlan::new_with_ranges(vec![vec![0, 1]], vec![Some((0, 500))])
            .validate(&k)
            .is_err());
        // degenerate full-span range must be encoded as None
        let err = FusionPlan::new_with_ranges(vec![vec![0, 1]], vec![Some((0, 200))])
            .validate(&k)
            .unwrap_err();
        assert!(err.contains("degenerate"), "{err}");
        // single-statement parts cannot carry a range
        let k2 = polybench::mvt();
        assert!(FusionPlan::new_with_ranges(vec![vec![0], vec![1]], vec![Some((0, 100)), None])
            .validate(&k2)
            .is_err());
    }

    #[test]
    fn every_stmt_in_exactly_one_task() {
        for k in polybench::all_kernels() {
            let g = fuse(&k);
            let mut seen = vec![0; k.statements.len()];
            for t in &g.tasks {
                for &s in &t.stmts {
                    seen[s] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "{}", k.name);
            // and the O(1) index agrees with membership
            for t in &g.tasks {
                for &s in &t.stmts {
                    assert_eq!(g.task_of_stmt(s), t.id, "{}", k.name);
                }
            }
        }
    }

    #[test]
    fn max_fusion_plan_round_trips() {
        for k in polybench::all_kernels() {
            let plan = FusionPlan::max_fusion(&k);
            plan.validate(&k).unwrap_or_else(|e| panic!("{e}"));
            let g = fuse_with_plan(&k, &plan).unwrap();
            assert_eq!(g.plan(), plan, "{}", k.name);
            // serde round-trip preserves the canonical encoding
            use serde::{Deserialize, Serialize};
            let back = FusionPlan::deserialize(&plan.serialize()).unwrap();
            assert_eq!(back, plan, "{}", k.name);
        }
    }

    #[test]
    fn ranged_plans_round_trip_through_serde() {
        use serde::{Deserialize, Serialize};
        let plan = FusionPlan::new_with_ranges(
            vec![vec![0], vec![1, 2], vec![3]],
            vec![None, Some((100, 300)), None],
        );
        let v = plan.serialize();
        let back = FusionPlan::deserialize(&v).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.range(1), Some((100, 300)));
        // the textual form really carries the range object
        let text = serde::json::to_string(&v);
        assert!(text.contains("\"range\""), "{text}");
        // malformed ranges fail to parse
        assert!(FusionPlan::deserialize(&serde::json::parse("[{\"stmts\":[0],\"range\":[1]}]")
            .unwrap())
        .is_err());
        assert!(FusionPlan::deserialize(&serde::Value::Int(3)).is_err());
    }

    #[test]
    fn enumerate_is_max_fusion_first_and_legal() {
        for k in polybench::all_kernels() {
            let variants = enumerate_fusions(&k);
            assert!(!variants.is_empty(), "{}", k.name);
            assert_eq!(variants[0], FusionPlan::max_fusion(&k), "{}", k.name);
            for plan in &variants {
                plan.validate(&k).unwrap_or_else(|e| panic!("{}: {e}", k.name));
            }
            // variants are distinct
            let set: BTreeSet<&FusionPlan> = variants.iter().collect();
            assert_eq!(set.len(), variants.len(), "{}", k.name);
        }
    }

    #[test]
    fn splittable_and_mergeable_groups_yield_extra_variants() {
        // gemver's x = {S1, S2}, trmm's B = {S0, S1} and symm's C =
        // {S1, S2} are compute/compute chains yielding a fission
        // variant each; mvt, gesummv and 3-madd carry independent
        // sibling nests that merge cross-array; symm's fissioned base
        // additionally lets the temp2/C[k-scatter] nests merge. Kernels
        // whose nests neither split nor unify stay single-variant.
        for (name, n) in [
            ("gemver", 2),
            ("trmm", 2),
            ("symm", 3),
            ("gemm", 1),
            ("3mm", 1),
            ("2mm", 1),
            ("atax", 1),
            ("bicg", 1),
            ("madd", 1),
            ("2-madd", 1),
            ("gesummv", 2),
            ("mvt", 2),
            ("3-madd", 2),
        ] {
            let k = polybench::by_name(name).unwrap();
            assert_eq!(enumerate_fusions(&k).len(), n, "{name}");
        }
    }

    #[test]
    fn split_variant_pipelines_over_a_fifo() {
        // gemver split: x's two updates become a producer/consumer pair
        // carrying x over a FIFO; the graph stays acyclic and
        // topologically numbered.
        let k = polybench::gemver();
        let variants = enumerate_fusions(&k);
        let split = variants
            .iter()
            .find(|p| p.n_tasks() == 4)
            .expect("gemver has a fission variant");
        let g = fuse_with_plan(&k, split).unwrap();
        assert!(g.is_acyclic());
        let t1 = g.task_of_stmt(1);
        let t2 = g.task_of_stmt(2);
        assert_ne!(t1, t2);
        assert!(t1 < t2, "producer must be renumbered before consumer");
        assert!(
            g.edges.iter().any(|(s, d, a)| (*s, *d, a.as_str()) == (t1, t2, "x")),
            "x FIFO edge missing: {:?}",
            g.edges
        );
        // last-writer semantics: S3 (reads x) consumes from S2's task,
        // not from both updates
        let t3 = g.task_of_stmt(3);
        assert!(g.edges.iter().any(|(s, d, a)| (*s, *d, a.as_str()) == (t2, t3, "x")));
        assert!(!g.edges.iter().any(|(s, d, a)| (*s, *d, a.as_str()) == (t1, t3, "x")));
    }

    #[test]
    fn illegal_plans_are_rejected() {
        let k = polybench::gemm(); // C = {S0 init, S1 update}
        // splitting the init/update pair
        let split = FusionPlan::new(vec![vec![0], vec![1]]);
        assert!(split.validate(&k).unwrap_err().contains("init/update"));
        assert!(fuse_with_plan(&k, &split).is_err());
        // missing / duplicated statements
        assert!(FusionPlan::new(vec![vec![0]]).validate(&k).is_err());
        assert!(FusionPlan::new(vec![vec![0, 1], vec![1]]).validate(&k).is_err());
        assert!(FusionPlan::new(vec![vec![0, 1, 2]]).validate(&k).is_err());
        // a cross-array merge whose nests cannot unify (bicg's s/q
        // engines disagree on which loop is the reduction)
        let kb = polybench::bicg();
        let err = FusionPlan::new(vec![vec![0, 1, 2, 3]]).validate(&kb).unwrap_err();
        assert!(err.contains("unify") || err.contains("reduction"), "{err}");
    }

    #[test]
    fn fissioned_bounds_the_space() {
        // For kernels with no same-array writers, fission == max fusion.
        let k = polybench::three_madd();
        assert_eq!(FusionPlan::fissioned(&k), FusionPlan::max_fusion(&k));
        let k2 = polybench::gemm();
        assert_ne!(FusionPlan::fissioned(&k2), FusionPlan::max_fusion(&k2));
    }

    #[test]
    fn part_strings_carry_ranges() {
        let plan = FusionPlan::new_with_ranges(
            vec![vec![0], vec![1, 2]],
            vec![None, Some((0, 64))],
        );
        assert_eq!(plan.part_strings(), vec!["{S0}", "{S1[0:64], S2[0:64]}"]);
    }
}
