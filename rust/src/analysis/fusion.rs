//! Task fusion (paper §3.1) — as an *explored* dimension of the design
//! space, not a fixed pre-pass.
//!
//! A [`FusionPlan`] is a canonical partition of the kernel's statements
//! into fused tasks. [`enumerate_fusions`] produces every
//! dependence-legal plan between the two extremes the paper's unified
//! space spans:
//!
//! * **fully fissioned** — one task per statement;
//! * **max output-stationary fusion** — statements writing the same
//!   array merge into one task (today's [`fuse`] output, variant 0), so
//!   every output tile is produced — loaded, computed, stored or sent —
//!   exactly once.
//!
//! Legality is checked against [`super::deps`]:
//!
//! * an init/update pair (a [`StmtKind::Init`] statement and the
//!   updates of the same array) may never split across a FIFO — the
//!   zero-init writes the very tile the update accumulates into, and a
//!   loop-carried accumulator cannot re-read its running value from a
//!   stream;
//! * each task's statements write a single array (the output-stationary
//!   invariant: a `FusedTask` has one `output`), and a split group is
//!   partitioned into *contiguous* program-order runs — concurrent
//!   tasks overwriting the same array in an unordered way are rejected;
//! * flow dependences between tasks must not create a cycle (checked by
//!   Kahn's algorithm, not assumed from statement numbering).
//!
//! FIFO edges use **last-writer** flow semantics: a statement reading
//! array `a` depends on the *latest* preceding writer of `a`, so a
//! split update chain (`x += A·y` then `x += z`) pipelines through one
//! FIFO instead of fanning every historical writer into every reader.
//! For max fusion this is edge-for-edge identical to the classic
//! array-level flow graph (all writers of an array share a task), which
//! the property suite pins bit-exactly.

use crate::ir::access::Index;
use crate::ir::{Kernel, StmtKind};
use std::collections::BTreeSet;

/// Configuration-independent, per-array info of a fused task, computed
/// once at fusion time (the DSE constructs a geometry per design-point
/// evaluation — 10^5+ per solve — so this must not be rebuilt there; see
/// EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayInfo {
    pub name: String,
    /// Access function translated to representative-nest loop positions
    /// (None = dimension not indexed by a loop iterator).
    pub access: Vec<Option<usize>>,
    pub writes: bool,
    pub reads: bool,
}

/// A fused task: an ordered group of statement ids sharing one output
/// array (e.g. `FT0 = {S0, S1}` zero-init + MAC in 3mm).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusedTask {
    pub id: usize,
    /// Statement ids, program order. The *representative* statement (the
    /// one whose loop nest shapes the tiling space) is the compute
    /// statement with the deepest nest.
    pub stmts: Vec<usize>,
    /// The array this task produces.
    pub output: String,
    /// Memoized per-array info (first-touch order).
    pub array_info: Vec<ArrayInfo>,
}

impl FusedTask {
    /// The statement whose loop nest drives tiling/permutation choices:
    /// deepest compute statement of the group.
    pub fn representative(&self, k: &Kernel) -> usize {
        *self
            .stmts
            .iter()
            .max_by_key(|&&sid| {
                let s = &k.statements[sid];
                (s.loops.len(), s.kind == StmtKind::Compute, s.ops.total())
            })
            .expect("fused task is non-empty")
    }
}

// ---- FusionPlan: the canonical partition encoding ----------------------

/// A fusion choice, encoded as a canonical partition of statement ids
/// into tasks: each part ascending (= program order), parts ordered by
/// their first statement. This is the form persisted in
/// [`crate::dse::config::DesignConfig`] and compared by the QoR
/// knowledge base, so two solves of the same variant always agree on
/// the encoding regardless of task renumbering.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct FusionPlan {
    parts: Vec<Vec<usize>>,
}

impl FusionPlan {
    /// Build a plan from raw parts, canonicalizing the encoding (parts
    /// sorted internally and by first element). Legality against a
    /// kernel is a separate question — see [`FusionPlan::validate`].
    pub fn new(mut parts: Vec<Vec<usize>>) -> FusionPlan {
        for p in &mut parts {
            p.sort_unstable();
        }
        parts.sort_by_key(|p| p.first().copied().unwrap_or(usize::MAX));
        FusionPlan { parts }
    }

    /// The canonical parts, each ascending, ordered by first statement.
    pub fn parts(&self) -> &[Vec<usize>] {
        &self.parts
    }

    /// Number of fused tasks this plan induces.
    pub fn n_tasks(&self) -> usize {
        self.parts.len()
    }

    /// Today's coarsest plan: statements grouped by written array.
    pub fn max_fusion(k: &Kernel) -> FusionPlan {
        FusionPlan::new(output_groups(k))
    }

    /// The finest nominal plan: one task per statement. Not necessarily
    /// *legal* (init/update pairs must stay fused) — it bounds the
    /// space, the enumeration filters legality.
    pub fn fissioned(k: &Kernel) -> FusionPlan {
        FusionPlan::new(k.statements.iter().map(|s| vec![s.id]).collect())
    }

    /// Full legality check against `k` (the rules in the module doc):
    /// exact statement coverage, one output array per part, contiguous
    /// runs within each output group, init/update pairs unsplit, and an
    /// acyclic induced task graph.
    pub fn validate(&self, k: &Kernel) -> Result<(), String> {
        let n = k.statements.len();
        let mut owner = vec![usize::MAX; n];
        for (pi, part) in self.parts.iter().enumerate() {
            if part.is_empty() {
                return Err(format!("fusion plan for {}: empty task", k.name));
            }
            for w in part.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!(
                        "fusion plan for {}: part {:?} is not strictly ascending",
                        k.name, part
                    ));
                }
            }
            for &sid in part {
                if sid >= n {
                    return Err(format!(
                        "fusion plan for {}: statement S{sid} out of range (kernel has {n})",
                        k.name
                    ));
                }
                if owner[sid] != usize::MAX {
                    return Err(format!(
                        "fusion plan for {}: statement S{sid} appears in two tasks",
                        k.name
                    ));
                }
                owner[sid] = pi;
            }
            let out = &k.statements[part[0]].write.array;
            if part.iter().any(|&sid| &k.statements[sid].write.array != out) {
                return Err(format!(
                    "fusion plan for {}: task {:?} mixes output arrays (not output-stationary)",
                    k.name, part
                ));
            }
        }
        if owner.iter().any(|&o| o == usize::MAX) {
            return Err(format!(
                "fusion plan for {}: not every statement is assigned a task",
                k.name
            ));
        }

        // Per output group: init/update glue and contiguous runs.
        for group in output_groups(k) {
            let has_init = group.iter().any(|&s| k.statements[s].kind == StmtKind::Init);
            let first_owner = owner[group[0]];
            if has_init && group.iter().any(|&s| owner[s] != first_owner) {
                return Err(format!(
                    "fusion plan for {}: init/update pair of `{}` split across a FIFO",
                    k.name, k.statements[group[0]].write.array
                ));
            }
            // each part's members must be consecutive in the group: once
            // the owning part changes it may never come back
            let mut seen: Vec<usize> = Vec::new();
            for &s in &group {
                let o = owner[s];
                match seen.last() {
                    Some(&last) if last == o => {}
                    _ => {
                        if seen.contains(&o) {
                            return Err(format!(
                                "fusion plan for {}: non-contiguous split of `{}` writers",
                                k.name, k.statements[group[0]].write.array
                            ));
                        }
                        seen.push(o);
                    }
                }
            }
        }

        // Acyclicity of the induced task graph under last-writer flow.
        let edges = task_flow_edges(k, &owner);
        if kahn_order(self.parts.len(), &edges).is_none() {
            return Err(format!(
                "fusion plan for {}: flow dependences create a task cycle",
                k.name
            ));
        }
        Ok(())
    }
}

// Manual serde impls (the vendored serde has no derive proc-macro): a
// plan is a JSON array of arrays of statement ids. Deserialization
// re-canonicalizes, so hand-edited databases cannot smuggle in a
// non-canonical encoding.
impl serde::Serialize for FusionPlan {
    fn serialize(&self) -> serde::Value {
        serde::Value::Arr(
            self.parts
                .iter()
                .map(|p| serde::Value::Arr(p.iter().map(|s| serde::Serialize::serialize(s)).collect()))
                .collect(),
        )
    }
}

impl serde::Deserialize for FusionPlan {
    fn deserialize(v: &serde::Value) -> Result<FusionPlan, serde::Error> {
        let parts: Vec<Vec<usize>> = serde::Deserialize::deserialize(v)?;
        Ok(FusionPlan::new(parts))
    }
}

/// Max statement-partition variants [`enumerate_fusions`] returns; the
/// zoo needs at most a handful, the cap bounds pathological inputs.
/// Variant 0 (max fusion) is always retained.
pub const MAX_FUSION_VARIANTS: usize = 64;

/// Max split combinations the enumeration *examines* (validation
/// included) — bounds the walk itself for kernels whose per-group
/// composition product explodes, independent of how many combos turn
/// out legal. Combo 0 (max fusion) is always examined first.
pub const MAX_FUSION_COMBOS: usize = 4096;

/// Enumerate every dependence-legal fusion plan of `k` between full
/// fission and max output-stationary fusion, deterministically ordered
/// with **max fusion first** (variant 0). Each output group either
/// stays whole or splits into contiguous runs; groups holding an init
/// statement never split; plans whose induced task graph is cyclic are
/// dropped.
pub fn enumerate_fusions(k: &Kernel) -> Vec<FusionPlan> {
    let groups = output_groups(k);
    let choices: Vec<Vec<Vec<Vec<usize>>>> =
        groups.iter().map(|g| group_partitions(k, g)).collect();
    let mut out = Vec::new();
    let mut idx = vec![0usize; choices.len()];
    // the caps bound the *work*, not just the list: stop walking (and
    // validating) the cartesian product once the list is full, and stop
    // examining combos altogether past a fixed budget even when most of
    // them are invalid (cyclic) — enumeration must stay cheap relative
    // to one solve. Both cuts are deterministic (odometer order).
    let mut examined = 0usize;
    'odometer: loop {
        if out.len() >= MAX_FUSION_VARIANTS || examined >= MAX_FUSION_COMBOS {
            break;
        }
        examined += 1;
        let mut parts: Vec<Vec<usize>> = Vec::new();
        for (gi, &ci) in choices.iter().zip(idx.iter()) {
            parts.extend(gi[ci].iter().cloned());
        }
        let plan = FusionPlan::new(parts);
        if plan.validate(k).is_ok() {
            out.push(plan);
        }
        // advance the odometer, last group fastest (combo 0 = all-whole
        // = max fusion, so it leads the list)
        let mut d = choices.len();
        loop {
            if d == 0 {
                break 'odometer;
            }
            d -= 1;
            idx[d] += 1;
            if idx[d] < choices[d].len() {
                break;
            }
            idx[d] = 0;
        }
    }
    debug_assert!(!out.is_empty(), "max fusion is always legal");
    out
}

/// Statements grouped by written array, in first-writer program order —
/// the atoms of the fusion space.
fn output_groups(k: &Kernel) -> Vec<Vec<usize>> {
    let mut groups: Vec<(&str, Vec<usize>)> = Vec::new();
    for s in &k.statements {
        if let Some(g) = groups.iter_mut().find(|(a, _)| *a == s.write.array) {
            g.1.push(s.id);
        } else {
            groups.push((s.write.array.as_str(), vec![s.id]));
        }
    }
    groups.into_iter().map(|(_, g)| g).collect()
}

/// Legal sub-partitions of one output group: the whole group first,
/// then (when no init statement glues the group together) every
/// contiguous composition, in split-mask order.
fn group_partitions(k: &Kernel, group: &[usize]) -> Vec<Vec<Vec<usize>>> {
    let m = group.len();
    let has_init = group.iter().any(|&s| k.statements[s].kind == StmtKind::Init);
    if m == 1 || has_init || m > 16 {
        return vec![vec![group.to_vec()]];
    }
    let mut res = Vec::with_capacity(1usize << (m - 1));
    for mask in 0u32..(1u32 << (m - 1)) {
        let mut parts: Vec<Vec<usize>> = vec![vec![group[0]]];
        for (i, &s) in group.iter().enumerate().skip(1) {
            if mask & (1 << (i - 1)) != 0 {
                parts.push(vec![s]);
            } else {
                parts.last_mut().expect("non-empty").push(s);
            }
        }
        res.push(parts);
    }
    res
}

/// The latest statement before `before` (program order) that writes
/// `array` — the producer a read of `array` actually consumes.
fn last_writer(k: &Kernel, before: usize, array: &str) -> Option<usize> {
    k.statements[..before]
        .iter()
        .rev()
        .find(|s| s.write.array == array)
        .map(|s| s.id)
}

/// Cross-task FIFO edges `(src_part, dst_part, array)` induced by a
/// statement→part assignment, under last-writer flow semantics.
fn task_flow_edges(k: &Kernel, owner: &[usize]) -> Vec<(usize, usize, String)> {
    let mut edges = BTreeSet::new();
    for d in &k.statements {
        for r in &d.reads {
            if let Some(lw) = last_writer(k, d.id, &r.array) {
                let (ts, td) = (owner[lw], owner[d.id]);
                if ts != td {
                    edges.insert((ts, td, r.array.clone()));
                }
            }
        }
    }
    edges.into_iter().collect()
}

/// Kahn's algorithm with the smallest-id-first tie-break (a `BTreeSet`
/// worklist — the old `Vec` + `remove(0)` was O(n²)). Returns the
/// topological order, or `None` when the edges contain a cycle.
fn kahn_order(n: usize, edges: &[(usize, usize, String)]) -> Option<Vec<usize>> {
    let mut indeg = vec![0usize; n];
    let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (s, d, _) in edges {
        if s != d {
            indeg[*d] += 1;
            succ[*s].push(*d);
        }
    }
    let mut ready: BTreeSet<usize> = (0..n).filter(|&t| indeg[t] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(t) = ready.pop_first() {
        order.push(t);
        for &d in &succ[t] {
            indeg[d] -= 1;
            if indeg[d] == 0 {
                ready.insert(d);
            }
        }
    }
    if order.len() == n {
        Some(order)
    } else {
        None
    }
}

// ---- FusedGraph --------------------------------------------------------

/// The fused task graph: nodes are [`FusedTask`]s, edges carry the array
/// communicated over a FIFO between fused tasks. Task ids are
/// topological (producers precede consumers); `stmt_task` memoizes the
/// statement→task map so lookups are O(1).
#[derive(Debug, Clone)]
pub struct FusedGraph {
    pub tasks: Vec<FusedTask>,
    /// `(src_task, dst_task, array)` FIFO edges.
    pub edges: Vec<(usize, usize, String)>,
    /// Statement id → owning task id (precomputed at fusion time; the
    /// old per-call linear scan over every task was O(tasks × stmts)).
    stmt_task: Vec<usize>,
}

impl FusedGraph {
    /// Owning task of statement `sid` — O(1) via the fusion-time index.
    pub fn task_of_stmt(&self, sid: usize) -> usize {
        self.stmt_task[sid]
    }

    pub fn predecessors(&self, t: usize) -> Vec<usize> {
        let mut p: Vec<usize> = self
            .edges
            .iter()
            .filter(|(_, d, _)| *d == t)
            .map(|(s, _, _)| *s)
            .collect();
        p.sort_unstable();
        p.dedup();
        p
    }

    pub fn sinks(&self) -> Vec<usize> {
        (0..self.tasks.len())
            .filter(|t| !self.edges.iter().any(|(s, _, _)| s == t))
            .collect()
    }

    /// Total elements communicated between fused tasks (Table 5, last
    /// column): for each FIFO edge, the footprint of the carried array.
    pub fn inter_task_elems(&self, k: &Kernel) -> u64 {
        let mut seen = BTreeSet::new();
        let mut total = 0;
        for (s, d, a) in &self.edges {
            if seen.insert((*s, *d, a.clone())) {
                total += k.array(a).map(|arr| arr.elems()).unwrap_or(0);
            }
        }
        total
    }

    /// Whether the graph is acyclic — a real topological check (Kahn)
    /// over the edges, not an assumption about id ordering: enumerated
    /// fusion variants are renumbered, but the check must hold on its
    /// own for any graph handed to a consumer.
    pub fn is_acyclic(&self) -> bool {
        kahn_order(self.tasks.len(), &self.edges).is_some()
    }

    /// The canonical [`FusionPlan`] this graph realizes — derived from
    /// the tasks (never stored separately), so it cannot drift.
    pub fn plan(&self) -> FusionPlan {
        FusionPlan::new(self.tasks.iter().map(|t| t.stmts.clone()).collect())
    }

    /// The partition in the paper's Table 9 shape:
    /// `FT0 = {S1, S2}; FT1 = {S0, S3}`.
    pub fn partition_string(&self) -> String {
        self.tasks
            .iter()
            .map(|t| {
                let stmts: Vec<String> = t.stmts.iter().map(|s| format!("S{s}")).collect();
                format!("FT{} = {{{}}}", t.id, stmts.join(", "))
            })
            .collect::<Vec<_>>()
            .join("; ")
    }
}

/// Fuse statements of `k` into max output-stationary tasks — the fixed
/// coarsest plan, kept as the default entry point for consumers that do
/// not explore fusion.
pub fn fuse(k: &Kernel) -> FusedGraph {
    fuse_with_plan(k, &FusionPlan::max_fusion(k))
        .expect("max output-stationary fusion is always legal")
}

/// Materialize a fusion plan into a [`FusedGraph`]: validate legality,
/// build per-task array memos, derive last-writer FIFO edges, and
/// renumber tasks topologically (Kahn with stable smallest-id
/// tie-break) so producers always precede consumers — atax groups
/// y={S0,S3} before tmp={S1,S2} in program order, but tmp feeds y; the
/// paper's Table 9 likewise lists atax as FT0:{S1,S2}, FT1:{S0,S3}.
pub fn fuse_with_plan(k: &Kernel, plan: &FusionPlan) -> Result<FusedGraph, String> {
    plan.validate(k)?;
    let n = plan.n_tasks();
    let mut owner = vec![0usize; k.statements.len()];
    for (pi, part) in plan.parts().iter().enumerate() {
        for &sid in part {
            owner[sid] = pi;
        }
    }
    let edges = task_flow_edges(k, &owner);
    let order = kahn_order(n, &edges)
        .ok_or_else(|| format!("fusion plan for {} induces a cyclic task graph", k.name))?;

    // order[new_id] = old_id; build the inverse map and renumber.
    let mut new_of_old = vec![0usize; n];
    for (new_id, &old_id) in order.iter().enumerate() {
        new_of_old[old_id] = new_id;
    }
    let mut tasks: Vec<FusedTask> = order
        .iter()
        .enumerate()
        .map(|(new_id, &old_id)| {
            let stmts = plan.parts()[old_id].clone();
            let output = k.statements[stmts[0]].write.array.clone();
            FusedTask { id: new_id, stmts, output, array_info: Vec::new() }
        })
        .collect();
    let edges: Vec<(usize, usize, String)> = {
        let mut e: Vec<(usize, usize, String)> = edges
            .into_iter()
            .map(|(s, d, a)| (new_of_old[s], new_of_old[d], a))
            .collect();
        e.sort();
        e
    };
    let mut stmt_task = vec![0usize; k.statements.len()];
    for t in &tasks {
        for &sid in &t.stmts {
            stmt_task[sid] = t.id;
        }
    }
    for t in &mut tasks {
        t.array_info = build_array_info(k, t);
    }
    let fg = FusedGraph { tasks, edges, stmt_task };
    debug_assert!(fg.is_acyclic());
    Ok(fg)
}

/// Build the per-array memo for one fused task: translate every access
/// onto the representative nest by iterator name (Eq 4 guarantees fused
/// statements share iterators) and record read/write membership.
fn build_array_info(k: &Kernel, task: &FusedTask) -> Vec<ArrayInfo> {
    let rep = task.representative(k);
    let rep_loops = &k.statements[rep].loops;
    let rep_pos_of = |sid: usize, pos: usize| -> Option<usize> {
        let name = &k.statements[sid].loops[pos].name;
        rep_loops.iter().position(|l| &l.name == name)
    };
    let translate = |sid: usize, acc: &crate::ir::Access| -> Vec<Option<usize>> {
        acc.idx
            .iter()
            .map(|ix| match ix {
                Index::Iter(p) => rep_pos_of(sid, *p),
                Index::Zero => None,
            })
            .collect()
    };
    let mut infos: Vec<ArrayInfo> = Vec::new();
    // rep statement first so its access translation wins
    let mut stmts: Vec<usize> = vec![rep];
    stmts.extend(task.stmts.iter().copied().filter(|&s| s != rep));
    // first-touch order must follow program order of the task's stmts
    for &sid in &task.stmts {
        let s = &k.statements[sid];
        for acc in std::iter::once(&s.write).chain(s.reads.iter()) {
            if !infos.iter().any(|i| i.name == acc.array) {
                // find the translation, preferring the rep statement
                let access = stmts
                    .iter()
                    .find_map(|&q| {
                        let qs = &k.statements[q];
                        if qs.write.array == acc.array {
                            return Some(translate(q, &qs.write));
                        }
                        qs.reads
                            .iter()
                            .find(|r| r.array == acc.array)
                            .map(|r| translate(q, r))
                    })
                    .unwrap_or_default();
                infos.push(ArrayInfo {
                    name: acc.array.clone(),
                    access,
                    writes: false,
                    reads: false,
                });
            }
        }
    }
    for &sid in &task.stmts {
        let s = &k.statements[sid];
        if let Some(i) = infos.iter_mut().find(|i| i.name == s.write.array) {
            i.writes = true;
        }
        for r in &s.reads {
            if let Some(i) = infos.iter_mut().find(|i| i.name == r.array) {
                i.reads = true;
            }
        }
    }
    infos
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    #[test]
    fn three_mm_fuses_to_three_tasks() {
        // Paper Listing 6: FT0={S0,S1}, FT1={S2,S3}, FT2={S4,S5}.
        let k = polybench::three_mm();
        let g = fuse(&k);
        assert_eq!(g.tasks.len(), 3);
        assert_eq!(g.tasks[0].stmts, vec![0, 1]);
        assert_eq!(g.tasks[1].stmts, vec![2, 3]);
        assert_eq!(g.tasks[2].stmts, vec![4, 5]);
        assert_eq!(g.tasks[0].output, "E");
        assert_eq!(g.tasks[2].output, "G");
        // FIFO edges: FT0 --E--> FT2, FT1 --F--> FT2.
        assert!(g.edges.iter().any(|(s, d, a)| (*s, *d, a.as_str()) == (0, 2, "E")));
        assert!(g.edges.iter().any(|(s, d, a)| (*s, *d, a.as_str()) == (1, 2, "F")));
        assert!(g.is_acyclic());
        assert_eq!(g.sinks(), vec![2]);
    }

    #[test]
    fn representative_is_deepest_compute() {
        let k = polybench::three_mm();
        let g = fuse(&k);
        assert_eq!(g.tasks[0].representative(&k), 1);
        assert_eq!(g.tasks[1].representative(&k), 3);
        assert_eq!(g.tasks[2].representative(&k), 5);
    }

    #[test]
    fn table5_comm_column() {
        // Paper Table 5: inter-task comm — 3mm: 2N² (E and F), atax: N
        // (tmp), bicg: 0, gesummv: 2N (tmp, y), 2-madd: N², 3-madd: 2N².
        let elems = |name: &str| {
            let k = polybench::by_name(name).unwrap();
            fuse(&k).inter_task_elems(&k)
        };
        assert_eq!(elems("bicg"), 0);
        assert_eq!(elems("madd"), 0);
        assert_eq!(elems("mvt"), 0);
        assert_eq!(elems("atax"), 390); // tmp[M]
        assert_eq!(elems("gesummv"), 2 * 250); // tmp + y
        assert_eq!(elems("2-madd"), 400 * 400);
        assert_eq!(elems("3-madd"), 2 * 400 * 400);
        assert_eq!(elems("3mm"), 180 * 190 + 190 * 210); // E + F
        assert_eq!(elems("2mm"), 180 * 190); // tmp
    }

    #[test]
    fn atax_tasks_renumbered_topologically() {
        // Paper Table 9: atax FT0 = {S1, S2} (tmp), FT1 = {S0, S3} (y).
        let k = polybench::atax();
        let g = fuse(&k);
        assert_eq!(g.tasks.len(), 2);
        assert_eq!(g.tasks[0].output, "tmp");
        assert_eq!(g.tasks[0].stmts, vec![1, 2]);
        assert_eq!(g.tasks[1].output, "y");
        assert_eq!(g.tasks[1].stmts, vec![0, 3]);
        assert!(g.is_acyclic());
        assert_eq!(g.partition_string(), "FT0 = {S1, S2}; FT1 = {S0, S3}");
    }

    #[test]
    fn mvt_tasks_stay_separate() {
        // mvt's two statements write different arrays -> 2 concurrent tasks.
        let k = polybench::mvt();
        let g = fuse(&k);
        assert_eq!(g.tasks.len(), 2);
        assert!(g.edges.is_empty());
    }

    #[test]
    fn every_stmt_in_exactly_one_task() {
        for k in polybench::all_kernels() {
            let g = fuse(&k);
            let mut seen = vec![0; k.statements.len()];
            for t in &g.tasks {
                for &s in &t.stmts {
                    seen[s] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "{}", k.name);
            // and the O(1) index agrees with membership
            for t in &g.tasks {
                for &s in &t.stmts {
                    assert_eq!(g.task_of_stmt(s), t.id, "{}", k.name);
                }
            }
        }
    }

    #[test]
    fn max_fusion_plan_round_trips() {
        for k in polybench::all_kernels() {
            let plan = FusionPlan::max_fusion(&k);
            plan.validate(&k).unwrap_or_else(|e| panic!("{e}"));
            let g = fuse_with_plan(&k, &plan).unwrap();
            assert_eq!(g.plan(), plan, "{}", k.name);
            // serde round-trip preserves the canonical encoding
            use serde::{Deserialize, Serialize};
            let back = FusionPlan::deserialize(&plan.serialize()).unwrap();
            assert_eq!(back, plan, "{}", k.name);
        }
    }

    #[test]
    fn enumerate_is_max_fusion_first_and_legal() {
        for k in polybench::all_kernels() {
            let variants = enumerate_fusions(&k);
            assert!(!variants.is_empty(), "{}", k.name);
            assert_eq!(variants[0], FusionPlan::max_fusion(&k), "{}", k.name);
            for plan in &variants {
                plan.validate(&k).unwrap_or_else(|e| panic!("{}: {e}", k.name));
            }
            // variants are distinct
            let set: BTreeSet<&FusionPlan> = variants.iter().collect();
            assert_eq!(set.len(), variants.len(), "{}", k.name);
        }
    }

    #[test]
    fn splittable_groups_yield_extra_variants() {
        // gemver's x = {S1, S2} (update + update), trmm's B = {S0, S1}
        // and symm's C = {S1, S2} are compute/compute chains: each
        // yields exactly one extra fission variant. Init/update kernels
        // stay single-variant.
        for (name, n) in [
            ("gemver", 2),
            ("trmm", 2),
            ("symm", 2),
            ("gemm", 1),
            ("3mm", 1),
            ("atax", 1),
            ("gesummv", 1),
            ("mvt", 1),
            ("3-madd", 1),
        ] {
            let k = polybench::by_name(name).unwrap();
            assert_eq!(enumerate_fusions(&k).len(), n, "{name}");
        }
    }

    #[test]
    fn split_variant_pipelines_over_a_fifo() {
        // gemver split: x's two updates become a producer/consumer pair
        // carrying x over a FIFO; the graph stays acyclic and
        // topologically numbered.
        let k = polybench::gemver();
        let variants = enumerate_fusions(&k);
        let split = &variants[1];
        assert_eq!(split.n_tasks(), 4);
        let g = fuse_with_plan(&k, split).unwrap();
        assert!(g.is_acyclic());
        let t1 = g.task_of_stmt(1);
        let t2 = g.task_of_stmt(2);
        assert_ne!(t1, t2);
        assert!(t1 < t2, "producer must be renumbered before consumer");
        assert!(
            g.edges.iter().any(|(s, d, a)| (*s, *d, a.as_str()) == (t1, t2, "x")),
            "x FIFO edge missing: {:?}",
            g.edges
        );
        // last-writer semantics: S3 (reads x) consumes from S2's task,
        // not from both updates
        let t3 = g.task_of_stmt(3);
        assert!(g.edges.iter().any(|(s, d, a)| (*s, *d, a.as_str()) == (t2, t3, "x")));
        assert!(!g.edges.iter().any(|(s, d, a)| (*s, *d, a.as_str()) == (t1, t3, "x")));
    }

    #[test]
    fn illegal_plans_are_rejected() {
        let k = polybench::gemm(); // C = {S0 init, S1 update}
        // splitting the init/update pair
        let split = FusionPlan::new(vec![vec![0], vec![1]]);
        assert!(split.validate(&k).unwrap_err().contains("init/update"));
        assert!(fuse_with_plan(&k, &split).is_err());
        // mixing output arrays in one task
        let k2 = polybench::mvt();
        let mixed = FusionPlan::new(vec![vec![0, 1]]);
        assert!(mixed.validate(&k2).unwrap_err().contains("output"));
        // missing / duplicated statements
        assert!(FusionPlan::new(vec![vec![0]]).validate(&k).is_err());
        assert!(FusionPlan::new(vec![vec![0, 1], vec![1]]).validate(&k).is_err());
        assert!(FusionPlan::new(vec![vec![0, 1, 2]]).validate(&k).is_err());
    }

    #[test]
    fn fissioned_bounds_the_space() {
        // For kernels with no same-array writers, fission == max fusion.
        let k = polybench::three_madd();
        assert_eq!(FusionPlan::fissioned(&k), FusionPlan::max_fusion(&k));
        let k2 = polybench::gemm();
        assert_ne!(FusionPlan::fissioned(&k2), FusionPlan::max_fusion(&k2));
    }
}
