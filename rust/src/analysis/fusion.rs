//! Output-stationary task fusion (paper §3.1): statements writing the same
//! array merge into one fused task, so every output tile is produced —
//! loaded, computed, stored or sent — exactly once.

use super::taskgraph::TaskGraph;
use crate::ir::access::Index;
use crate::ir::{Kernel, StmtKind};
use std::collections::BTreeSet;

/// Configuration-independent, per-array info of a fused task, computed
/// once at fusion time (the DSE constructs a geometry per design-point
/// evaluation — 10^5+ per solve — so this must not be rebuilt there; see
/// EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayInfo {
    pub name: String,
    /// Access function translated to representative-nest loop positions
    /// (None = dimension not indexed by a loop iterator).
    pub access: Vec<Option<usize>>,
    pub writes: bool,
    pub reads: bool,
}

/// A fused task: an ordered group of statement ids sharing one output
/// array (e.g. `FT0 = {S0, S1}` zero-init + MAC in 3mm).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusedTask {
    pub id: usize,
    /// Statement ids, program order. The *representative* statement (the
    /// one whose loop nest shapes the tiling space) is the compute
    /// statement with the deepest nest.
    pub stmts: Vec<usize>,
    /// The array this task produces.
    pub output: String,
    /// Memoized per-array info (first-touch order).
    pub array_info: Vec<ArrayInfo>,
}

impl FusedTask {
    /// The statement whose loop nest drives tiling/permutation choices:
    /// deepest compute statement of the group.
    pub fn representative(&self, k: &Kernel) -> usize {
        *self
            .stmts
            .iter()
            .max_by_key(|&&sid| {
                let s = &k.statements[sid];
                (s.loops.len(), s.kind == StmtKind::Compute, s.ops.total())
            })
            .expect("fused task is non-empty")
    }
}

/// The fused task graph: nodes are [`FusedTask`]s, edges carry the array
/// communicated over a FIFO between fused tasks.
#[derive(Debug, Clone)]
pub struct FusedGraph {
    pub tasks: Vec<FusedTask>,
    /// `(src_task, dst_task, array)` FIFO edges.
    pub edges: Vec<(usize, usize, String)>,
}

impl FusedGraph {
    pub fn task_of_stmt(&self, sid: usize) -> usize {
        self.tasks
            .iter()
            .position(|t| t.stmts.contains(&sid))
            .expect("statement belongs to a fused task")
    }

    pub fn predecessors(&self, t: usize) -> Vec<usize> {
        let mut p: Vec<usize> = self
            .edges
            .iter()
            .filter(|(_, d, _)| *d == t)
            .map(|(s, _, _)| *s)
            .collect();
        p.sort_unstable();
        p.dedup();
        p
    }

    pub fn sinks(&self) -> Vec<usize> {
        (0..self.tasks.len())
            .filter(|t| !self.edges.iter().any(|(s, _, _)| s == t))
            .collect()
    }

    /// Total elements communicated between fused tasks (Table 5, last
    /// column): for each FIFO edge, the footprint of the carried array.
    pub fn inter_task_elems(&self, k: &Kernel) -> u64 {
        let mut seen = BTreeSet::new();
        let mut total = 0;
        for (s, d, a) in &self.edges {
            if seen.insert((*s, *d, a.clone())) {
                total += k.array(a).map(|arr| arr.elems()).unwrap_or(0);
            }
        }
        total
    }

    pub fn is_acyclic(&self) -> bool {
        self.edges.iter().all(|(s, d, _)| s < d)
    }
}

/// Fuse statements of `k` into output-stationary tasks.
///
/// Legality: statements writing the same array are merged when every
/// statement between them (in program order) that also belongs to the group
/// chain preserves dependences — for the PolyBench zoo the groups are
/// exactly {init, update} pairs plus single compute statements, and merging
/// them is always legal because the init writes the same element the update
/// accumulates into (same output-stationary tile).
pub fn fuse(k: &Kernel) -> FusedGraph {
    let mut tasks: Vec<FusedTask> = Vec::new();
    for s in &k.statements {
        if let Some(t) = tasks.iter_mut().find(|t| t.output == s.write.array) {
            t.stmts.push(s.id);
        } else {
            tasks.push(FusedTask {
                id: tasks.len(),
                stmts: vec![s.id],
                output: s.write.array.clone(),
                array_info: Vec::new(),
            });
        }
    }
    for t in &mut tasks {
        t.array_info = build_array_info(k, t);
    }

    // FIFO edges: flow deps whose endpoints ended up in different tasks.
    let stmt_graph = TaskGraph::build(k);
    let task_of = |sid: usize| -> usize {
        tasks.iter().position(|t| t.stmts.contains(&sid)).unwrap()
    };
    let mut edges = BTreeSet::new();
    for (s, d, a) in &stmt_graph.edges {
        let (ts, td) = (task_of(*s), task_of(*d));
        if ts != td {
            edges.insert((ts, td, a.clone()));
        }
    }
    let edges: Vec<(usize, usize, String)> = edges.into_iter().collect();

    // Topologically renumber so producers always precede consumers (atax
    // groups y={S0,S3} before tmp={S1,S2} in program order, but tmp feeds
    // y — the paper's Table 9 likewise lists atax as FT0:{S1,S2},
    // FT1:{S0,S3}). Kahn's algorithm with stable (original-id) tie-break.
    let n = tasks.len();
    let mut indeg = vec![0usize; n];
    for (s, d, _) in &edges {
        if s != d {
            indeg[*d] += 1;
        }
    }
    let mut order = Vec::with_capacity(n);
    let mut ready: Vec<usize> = (0..n).filter(|&t| indeg[t] == 0).collect();
    while let Some(t) = ready.first().copied() {
        ready.remove(0);
        order.push(t);
        let mut unlocked = Vec::new();
        for (s, d, _) in &edges {
            if *s == t {
                indeg[*d] -= 1;
                if indeg[*d] == 0 && !unlocked.contains(d) {
                    unlocked.push(*d);
                }
            }
        }
        ready.extend(unlocked);
        ready.sort_unstable();
        ready.dedup();
    }
    debug_assert_eq!(order.len(), n, "fused task graph must be acyclic");
    // order[new_id] = old_id; build the inverse map and renumber.
    let mut new_of_old = vec![0usize; n];
    for (new_id, &old_id) in order.iter().enumerate() {
        new_of_old[old_id] = new_id;
    }
    let mut renumbered: Vec<FusedTask> = order
        .iter()
        .enumerate()
        .map(|(new_id, &old_id)| FusedTask { id: new_id, ..tasks[old_id].clone() })
        .collect();
    renumbered.sort_by_key(|t| t.id);
    let edges = edges
        .into_iter()
        .map(|(s, d, a)| (new_of_old[s], new_of_old[d], a))
        .collect();
    FusedGraph { tasks: renumbered, edges }
}

/// Build the per-array memo for one fused task: translate every access
/// onto the representative nest by iterator name (Eq 4 guarantees fused
/// statements share iterators) and record read/write membership.
fn build_array_info(k: &Kernel, task: &FusedTask) -> Vec<ArrayInfo> {
    let rep = task.representative(k);
    let rep_loops = &k.statements[rep].loops;
    let rep_pos_of = |sid: usize, pos: usize| -> Option<usize> {
        let name = &k.statements[sid].loops[pos].name;
        rep_loops.iter().position(|l| &l.name == name)
    };
    let translate = |sid: usize, acc: &crate::ir::Access| -> Vec<Option<usize>> {
        acc.idx
            .iter()
            .map(|ix| match ix {
                Index::Iter(p) => rep_pos_of(sid, *p),
                Index::Zero => None,
            })
            .collect()
    };
    let mut infos: Vec<ArrayInfo> = Vec::new();
    // rep statement first so its access translation wins
    let mut stmts: Vec<usize> = vec![rep];
    stmts.extend(task.stmts.iter().copied().filter(|&s| s != rep));
    // first-touch order must follow program order of the task's stmts
    for &sid in &task.stmts {
        let s = &k.statements[sid];
        for acc in std::iter::once(&s.write).chain(s.reads.iter()) {
            if !infos.iter().any(|i| i.name == acc.array) {
                // find the translation, preferring the rep statement
                let access = stmts
                    .iter()
                    .find_map(|&q| {
                        let qs = &k.statements[q];
                        if qs.write.array == acc.array {
                            return Some(translate(q, &qs.write));
                        }
                        qs.reads
                            .iter()
                            .find(|r| r.array == acc.array)
                            .map(|r| translate(q, r))
                    })
                    .unwrap_or_default();
                infos.push(ArrayInfo {
                    name: acc.array.clone(),
                    access,
                    writes: false,
                    reads: false,
                });
            }
        }
    }
    for &sid in &task.stmts {
        let s = &k.statements[sid];
        if let Some(i) = infos.iter_mut().find(|i| i.name == s.write.array) {
            i.writes = true;
        }
        for r in &s.reads {
            if let Some(i) = infos.iter_mut().find(|i| i.name == r.array) {
                i.reads = true;
            }
        }
    }
    infos
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    #[test]
    fn three_mm_fuses_to_three_tasks() {
        // Paper Listing 6: FT0={S0,S1}, FT1={S2,S3}, FT2={S4,S5}.
        let k = polybench::three_mm();
        let g = fuse(&k);
        assert_eq!(g.tasks.len(), 3);
        assert_eq!(g.tasks[0].stmts, vec![0, 1]);
        assert_eq!(g.tasks[1].stmts, vec![2, 3]);
        assert_eq!(g.tasks[2].stmts, vec![4, 5]);
        assert_eq!(g.tasks[0].output, "E");
        assert_eq!(g.tasks[2].output, "G");
        // FIFO edges: FT0 --E--> FT2, FT1 --F--> FT2.
        assert!(g.edges.iter().any(|(s, d, a)| (*s, *d, a.as_str()) == (0, 2, "E")));
        assert!(g.edges.iter().any(|(s, d, a)| (*s, *d, a.as_str()) == (1, 2, "F")));
        assert!(g.is_acyclic());
        assert_eq!(g.sinks(), vec![2]);
    }

    #[test]
    fn representative_is_deepest_compute() {
        let k = polybench::three_mm();
        let g = fuse(&k);
        assert_eq!(g.tasks[0].representative(&k), 1);
        assert_eq!(g.tasks[1].representative(&k), 3);
        assert_eq!(g.tasks[2].representative(&k), 5);
    }

    #[test]
    fn table5_comm_column() {
        // Paper Table 5: inter-task comm — 3mm: 2N² (E and F), atax: N
        // (tmp), bicg: 0, gesummv: 2N (tmp, y), 2-madd: N², 3-madd: 2N².
        let elems = |name: &str| {
            let k = polybench::by_name(name).unwrap();
            fuse(&k).inter_task_elems(&k)
        };
        assert_eq!(elems("bicg"), 0);
        assert_eq!(elems("madd"), 0);
        assert_eq!(elems("mvt"), 0);
        assert_eq!(elems("atax"), 390); // tmp[M]
        assert_eq!(elems("gesummv"), 2 * 250); // tmp + y
        assert_eq!(elems("2-madd"), 400 * 400);
        assert_eq!(elems("3-madd"), 2 * 400 * 400);
        assert_eq!(elems("3mm"), 180 * 190 + 190 * 210); // E + F
        assert_eq!(elems("2mm"), 180 * 190); // tmp
    }

    #[test]
    fn atax_tasks_renumbered_topologically() {
        // Paper Table 9: atax FT0 = {S1, S2} (tmp), FT1 = {S0, S3} (y).
        let k = polybench::atax();
        let g = fuse(&k);
        assert_eq!(g.tasks.len(), 2);
        assert_eq!(g.tasks[0].output, "tmp");
        assert_eq!(g.tasks[0].stmts, vec![1, 2]);
        assert_eq!(g.tasks[1].output, "y");
        assert_eq!(g.tasks[1].stmts, vec![0, 3]);
        assert!(g.is_acyclic());
    }

    #[test]
    fn mvt_tasks_stay_separate() {
        // mvt's two statements write different arrays -> 2 concurrent tasks.
        let k = polybench::mvt();
        let g = fuse(&k);
        assert_eq!(g.tasks.len(), 2);
        assert!(g.edges.is_empty());
    }

    #[test]
    fn every_stmt_in_exactly_one_task() {
        for k in polybench::all_kernels() {
            let g = fuse(&k);
            let mut seen = vec![0; k.statements.len()];
            for t in &g.tasks {
                for &s in &t.stmts {
                    seen[s] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "{}", k.name);
        }
    }
}
