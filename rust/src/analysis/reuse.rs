//! Reuse / boundedness classification (Table 5's `Reuse` column) and the
//! complexity strings reported alongside.

use crate::ir::Kernel;

/// Asymptotic reuse order of a kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReuseOrder {
    /// O(1): each input element used a constant number of times —
    /// memory-bound.
    Constant,
    /// O(N): each element reused ~N times — compute-bound with careful
    /// on-chip bufferization.
    Linear,
}

impl ReuseOrder {
    pub fn as_str(self) -> &'static str {
        match self {
            ReuseOrder::Constant => "O(1)",
            ReuseOrder::Linear => "O(N)",
        }
    }
}

/// Classify by arithmetic intensity: kernels whose FLOP/byte grows with N
/// land far above the O(1) band (intensity ≈ ops/footprint; the threshold
/// of 4 FLOP/byte cleanly separates the gemm family (≥25) from the
/// madd/mvt family (≤0.5) at medium sizes).
pub fn reuse_order(k: &Kernel) -> ReuseOrder {
    if k.arithmetic_intensity() > 4.0 {
        ReuseOrder::Linear
    } else {
        ReuseOrder::Constant
    }
}

/// `O(N^2)` / `O(N^3)` ops-complexity string from the deepest compute nest.
pub fn ops_complexity(k: &Kernel) -> String {
    let depth = k
        .statements
        .iter()
        .map(|s| s.loops.len())
        .max()
        .unwrap_or(0);
    format!("O(N^{depth})")
}

/// Memory complexity string: rank of the largest array.
pub fn mem_complexity(k: &Kernel) -> String {
    let rank = k.arrays.iter().map(|a| a.dims.len()).max().unwrap_or(0);
    format!("O(N^{rank})")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    #[test]
    fn table5_reuse_column() {
        // Exactly the paper's classification.
        let linear = ["2mm", "gemm", "syr2k", "syrk", "trmm", "3mm", "symm"];
        let constant =
            ["bicg", "madd", "mvt", "atax", "gesummv", "2-madd", "3-madd", "gemver"];
        for n in linear {
            let k = polybench::by_name(n).unwrap();
            assert_eq!(reuse_order(&k), ReuseOrder::Linear, "{n}");
        }
        for n in constant {
            let k = polybench::by_name(n).unwrap();
            assert_eq!(reuse_order(&k), ReuseOrder::Constant, "{n}");
        }
    }

    #[test]
    fn complexity_strings() {
        assert_eq!(ops_complexity(&polybench::gemm()), "O(N^3)");
        assert_eq!(ops_complexity(&polybench::madd()), "O(N^2)");
        assert_eq!(mem_complexity(&polybench::gemm()), "O(N^2)");
    }
}
