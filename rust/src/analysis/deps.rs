//! Statement-level dependence analysis.
//!
//! After maximal distribution every statement is its own loop nest, so the
//! dependences that matter for task construction are *array-level*: S_b
//! depends on S_a if S_a writes an array S_b reads (flow), writes an array
//! S_b writes (output), or reads an array S_b writes (anti). Program order
//! orients every edge (a < b). This is exactly the information PoCC's
//! dependence graph provides at task granularity for these kernels.

use crate::ir::Kernel;

/// Dependence kind, classic Bernstein classification.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DepKind {
    /// Read-after-write: the consumer needs the producer's data — this is
    /// the kind that becomes a FIFO edge in the dataflow design.
    Flow,
    /// Write-after-write (e.g. init statement then update).
    Output,
    /// Write-after-read.
    Anti,
}

/// One dependence edge between statements `src` → `dst` (program order,
/// `src.id < dst.id`) carried by `array`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DepEdge {
    pub src: usize,
    pub dst: usize,
    pub array: String,
    pub kind: DepKind,
}

/// Compute all statement-level dependences of `k`, in program order.
pub fn dependences(k: &Kernel) -> Vec<DepEdge> {
    let mut edges = Vec::new();
    for (bi, sb) in k.statements.iter().enumerate() {
        for sa in &k.statements[..bi] {
            // flow: sa writes, sb reads
            if sb.reads.iter().any(|r| r.array == sa.write.array) {
                edges.push(DepEdge {
                    src: sa.id,
                    dst: sb.id,
                    array: sa.write.array.clone(),
                    kind: DepKind::Flow,
                });
            }
            // output: both write the same array
            if sa.write.array == sb.write.array {
                edges.push(DepEdge {
                    src: sa.id,
                    dst: sb.id,
                    array: sa.write.array.clone(),
                    kind: DepKind::Output,
                });
            }
            // anti: sa reads what sb writes
            if sa.reads.iter().any(|r| r.array == sb.write.array) && sa.write.array != sb.write.array
            {
                edges.push(DepEdge {
                    src: sa.id,
                    dst: sb.id,
                    array: sb.write.array.clone(),
                    kind: DepKind::Anti,
                });
            }
        }
    }
    edges
}

/// True if the two statements can be freely reordered / run concurrently
/// (no dependence of any kind between them).
pub fn independent(k: &Kernel, a: usize, b: usize) -> bool {
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    !dependences(k).iter().any(|e| e.src == lo && e.dst == hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    #[test]
    fn three_mm_flow_structure() {
        let k = polybench::three_mm();
        let deps = dependences(&k);
        let flow: Vec<_> = deps.iter().filter(|e| e.kind == DepKind::Flow).collect();
        // S1 reads E written by S0 (init), S5 reads E (S0,S1) and F (S2,S3),
        // S5 reads G written by S4.
        assert!(flow.iter().any(|e| e.src == 1 && e.dst == 5 && e.array == "E"));
        assert!(flow.iter().any(|e| e.src == 3 && e.dst == 5 && e.array == "F"));
        assert!(flow.iter().any(|e| e.src == 0 && e.dst == 1 && e.array == "E"));
        // The two head multiplies are independent.
        assert!(independent(&k, 1, 3));
        assert!(independent(&k, 0, 2));
        assert!(!independent(&k, 1, 5));
    }

    #[test]
    fn two_madd_chain() {
        let k = polybench::two_madd();
        let deps = dependences(&k);
        assert!(deps
            .iter()
            .any(|e| e.src == 0 && e.dst == 1 && e.kind == DepKind::Flow && e.array == "T"));
        assert!(!independent(&k, 0, 1));
    }

    #[test]
    fn three_madd_heads_independent() {
        let k = polybench::three_madd();
        assert!(independent(&k, 0, 1));
        assert!(!independent(&k, 0, 2));
        assert!(!independent(&k, 1, 2));
    }

    #[test]
    fn output_dep_between_init_and_update() {
        let k = polybench::gemm();
        let deps = dependences(&k);
        assert!(deps
            .iter()
            .any(|e| e.src == 0 && e.dst == 1 && e.kind == DepKind::Output && e.array == "C"));
    }

    #[test]
    fn edges_respect_program_order() {
        for k in polybench::all_kernels() {
            for e in dependences(&k) {
                assert!(e.src < e.dst, "{}: {:?}", k.name, e);
            }
        }
    }
}
