//! The task-flow graph (paper Fig 3): one node per distributed statement,
//! flow edges only (FIFO candidates), acyclic by construction since edges
//! follow program order.

use super::deps::{dependences, DepKind};
use crate::ir::Kernel;
use std::collections::BTreeSet;

/// Task graph over statement ids.
#[derive(Debug, Clone)]
pub struct TaskGraph {
    pub n: usize,
    /// Flow edges `(src, dst, array)`, deduplicated.
    pub edges: Vec<(usize, usize, String)>,
}

impl TaskGraph {
    pub fn build(k: &Kernel) -> Self {
        let mut set = BTreeSet::new();
        for e in dependences(k) {
            if e.kind == DepKind::Flow {
                set.insert((e.src, e.dst, e.array));
            }
        }
        TaskGraph { n: k.statements.len(), edges: set.into_iter().collect() }
    }

    pub fn predecessors(&self, t: usize) -> Vec<usize> {
        let mut p: Vec<usize> = self
            .edges
            .iter()
            .filter(|(_, d, _)| *d == t)
            .map(|(s, _, _)| *s)
            .collect();
        p.sort_unstable();
        p.dedup();
        p
    }

    pub fn successors(&self, t: usize) -> Vec<usize> {
        let mut s: Vec<usize> = self
            .edges
            .iter()
            .filter(|(src, _, _)| *src == t)
            .map(|(_, d, _)| *d)
            .collect();
        s.sort_unstable();
        s.dedup();
        s
    }

    /// Sink tasks (no successors) — the `S` of Eq 13.
    pub fn sinks(&self) -> Vec<usize> {
        (0..self.n).filter(|t| self.successors(*t).is_empty()).collect()
    }

    /// Source tasks (no predecessors).
    pub fn sources(&self) -> Vec<usize> {
        (0..self.n).filter(|t| self.predecessors(*t).is_empty()).collect()
    }

    /// Topological order. The graph is acyclic by construction (edges go
    /// forward in program order), so plain id order is already topological;
    /// this method exists to make the invariant executable for tests.
    pub fn topo_order(&self) -> Vec<usize> {
        let order: Vec<usize> = (0..self.n).collect();
        debug_assert!(self.edges.iter().all(|(s, d, _)| s < d));
        order
    }

    /// Length (in nodes) of the longest dependence chain — the depth bound
    /// for concurrent execution.
    pub fn critical_path_len(&self) -> usize {
        let mut depth = vec![1usize; self.n];
        for t in 0..self.n {
            for p in self.predecessors(t) {
                depth[t] = depth[t].max(depth[p] + 1);
            }
        }
        depth.into_iter().max().unwrap_or(0)
    }

    /// Whether the graph is acyclic (always true by construction; checked
    /// in the property harness).
    pub fn is_acyclic(&self) -> bool {
        self.edges.iter().all(|(s, d, _)| s < d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::polybench;

    #[test]
    fn three_mm_graph_shape() {
        // Fig 3 of the paper: 6 tasks, E flows S0,S1 -> S5; F flows S2,S3 -> S5.
        let k = polybench::three_mm();
        let g = TaskGraph::build(&k);
        assert_eq!(g.n, 6);
        assert!(g.is_acyclic());
        assert_eq!(g.sinks(), vec![5]);
        assert!(g.sources().contains(&0));
        assert!(g.sources().contains(&2));
        // S5 consumes from both multiply chains.
        let p5 = g.predecessors(5);
        assert!(p5.contains(&1) && p5.contains(&3) && p5.contains(&4));
    }

    #[test]
    fn critical_path() {
        let k = polybench::three_madd();
        let g = TaskGraph::build(&k);
        // two independent adds then the final add = depth 2
        assert_eq!(g.critical_path_len(), 2);

        let k2 = polybench::two_madd();
        let g2 = TaskGraph::build(&k2);
        assert_eq!(g2.critical_path_len(), 2);
    }

    #[test]
    fn all_kernels_acyclic_topo() {
        for k in polybench::all_kernels() {
            let g = TaskGraph::build(&k);
            assert!(g.is_acyclic(), "{}", k.name);
            assert_eq!(g.topo_order().len(), g.n);
            assert!(!g.sinks().is_empty(), "{}", k.name);
        }
    }
}
