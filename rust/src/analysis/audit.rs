//! Independent static design auditor (DESIGN.md §12): re-verifies any
//! [`DesignConfig`] / [`ResolvedDesign`] from first principles, without
//! trusting the code that enumerated it.
//!
//! The solver's legality is *by construction* — `legal_orders`,
//! `FusionPlan::validate` and the stage-1/2 enumeration only ever
//! generate designs they believe legal. A bug there silently ships an
//! illegal design into the QoR DB and the bitstream. This module is the
//! differential oracle: it re-derives every obligation from the kernel
//! IR (`ir/access.rs` affine accesses) and the materialized fused graph,
//! and reports violations as structured [`Diagnostic`] values. The
//! flow runs it on every winning design (`flow.audit` span), the
//! `prometheus lint` CLI runs it on demand, and `prometheus db FILE
//! --verify` applies it to persisted QoR records.
//!
//! # Diagnostic taxonomy
//!
//! | Code  | Severity | Meaning |
//! |-------|----------|---------|
//! | PA001 | error    | config shape: task count/id coverage, vector lengths, kernel name, statement partition |
//! | PA002 | error    | `perm` is not a permutation of the representative nest |
//! | PA003 | error    | tiling: padded trip below the effective trip, or intra factor zero / not dividing padded |
//! | PA004 | error    | malformed per-array transfer plan (levels, buffers, bitwidth) |
//! | PA005 | error    | the design's fusion plan is not the plan the fused graph realizes |
//! | PA011 | error    | a dependence-carrying (reduction) loop is permuted outside a parallel loop |
//! | PA014 | error    | flow/anti dependence between same-part statements writing different arrays |
//! | PA015 | error    | peel ranges of a statement do not exactly tile its outer iteration space |
//! | PA020 | warning  | FIFO producer/consumer traverse the streamed array in different orders |
//! | PA021 | error*   | FIFO rate imbalance: producers emit fewer tokens than the consumer demands (starvation/deadlock); over-production (undrained stream) is a warning |
//! | PA030 | error    | FIFO edge set disagrees with re-derived last-writer flow semantics (missing or spurious edge) |
//! | PA031 | error    | the FIFO wait graph over tasks has a cycle (dataflow deadlock) |
//! | PA032 | error    | FIFO edge between peels of the same part (peels never exchange data) |
//! | PA040 | error    | per-region resource sum exceeds the scenario budget |
//! | PA041 | error    | task placed on an SLR outside the scenario's region count |
//! | PA042 | error    | array partition factor above the device maximum |
//! | PA050 | error    | emitted HLS FIFO stream declarations disagree with the fused graph edges |
//! | PA051 | error    | fused engine definitions/calls, top function or SLR wrappers inconsistent with the design |
//! | PA052 | error    | dataflow pragma or m_axi interface pragmas inconsistent with model/array roles |
//! | PA053 | error    | a produced array is not written exactly once per producing engine |
//! | PA054 | error    | intra-task engine names or `[lo:hi)` slice annotations disagree with the peel structure |
//!
//! PA020 is a *warning* by design: the stage-1 enumerator does not
//! co-constrain producer and consumer traversal orders (the
//! `fifo_compatible` predicate exists but is not wired into candidate
//! generation), so legal solver output can pair a `j`-major producer
//! with an `i`-major consumer. Until the enumerator enforces it, the
//! re-derived check reports rather than rejects. Every other re-derived
//! obligation is enforced by the solver stack, which is what makes the
//! zoo-wide invariant — *every solver-emitted design audits with zero
//! errors* — a meaningful differential property (pinned in
//! `tests/audit_mutations.rs`).
#![deny(missing_docs)]

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use crate::analysis::fusion::{FusedGraph, FusedTask};
use crate::codegen::generate_hls_resolved;
use crate::dse::config::{DesignConfig, ExecutionModel, TaskConfig};
use crate::dse::constraints::task_resources;
use crate::dse::eval::{GeometryCache, ResolvedDesign};
use crate::dse::solver::{region_budget, Scenario};
use crate::hw::{Device, ResourceVec};
use crate::ir::access::{Access, Index};
use crate::ir::Kernel;

/// How severe an audit finding is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Suspicious but not provably illegal; reported, never fatal.
    Warning,
    /// A violated correctness obligation; the design must not ship.
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Warning => write!(f, "warning"),
            Severity::Error => write!(f, "error"),
        }
    }
}

/// One structured audit finding.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Stable taxonomy code (`PA0xx`, table in the module docs).
    pub code: &'static str,
    /// Whether this finding blocks the design.
    pub severity: Severity,
    /// Where the finding anchors (`kernel/FT2`, `kernel/FT0->FT2:E`, …).
    pub location: String,
    /// Human-readable statement of the violated obligation.
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} {} [{}]: {}",
            self.code, self.severity, self.location, self.message
        )
    }
}

/// Whether any diagnostic in `diags` is an [`Severity::Error`].
pub fn has_errors(diags: &[Diagnostic]) -> bool {
    diags.iter().any(|d| d.severity == Severity::Error)
}

fn push(
    out: &mut Vec<Diagnostic>,
    code: &'static str,
    severity: Severity,
    location: String,
    message: String,
) {
    out.push(Diagnostic { code, severity, location, message });
}

/// Audit a design against its kernel, fused graph and geometry cache.
///
/// Runs every design-level pass (config shape, dependence legality,
/// peel coverage, FIFO deadlock-freedom/rate balance, resource budget)
/// and returns all findings, most severe obligations first violated
/// reported in pass order. Shape errors (PA001–PA005) abort the deeper
/// passes — a malformed config cannot be resolved safely.
pub fn audit_design(
    k: &Kernel,
    fg: &FusedGraph,
    cache: &GeometryCache,
    design: &DesignConfig,
    dev: &Device,
    scenario: Scenario,
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    audit_shape(k, fg, design, &mut out);
    if has_errors(&out) {
        return out;
    }
    audit_dependences(k, fg, design, &mut out);
    audit_coverage(k, fg, &mut out);
    audit_fifo(k, fg, cache, design, &mut out);
    let rd = ResolvedDesign::new(k, fg, cache, design);
    audit_resources(&rd, dev, scenario, &mut out);
    out
}

/// Audit a design end to end: [`audit_design`] plus the structural lint
/// of the HLS the code generator emits for it ([`lint_hls`]). The lint
/// is skipped when the design-level passes already found errors.
pub fn audit_all(
    k: &Kernel,
    fg: &FusedGraph,
    cache: &GeometryCache,
    design: &DesignConfig,
    dev: &Device,
    scenario: Scenario,
) -> Vec<Diagnostic> {
    let mut out = audit_design(k, fg, cache, design, dev, scenario);
    if !has_errors(&out) {
        let rd = ResolvedDesign::new(k, fg, cache, design);
        let hls = generate_hls_resolved(&rd);
        out.extend(lint_hls(&rd, &hls));
    }
    out
}

// ---- PA001..PA005: config shape -----------------------------------------

fn audit_shape(k: &Kernel, fg: &FusedGraph, design: &DesignConfig, out: &mut Vec<Diagnostic>) {
    let at = |t: usize| format!("{}/FT{}", k.name, t);
    if design.kernel != k.name {
        push(
            out,
            "PA001",
            Severity::Error,
            k.name.clone(),
            format!("design targets kernel `{}`, audited against `{}`", design.kernel, k.name),
        );
    }
    // Every statement must belong to exactly one fusion part.
    for s in &k.statements {
        let parts: BTreeSet<usize> = fg
            .tasks
            .iter()
            .filter(|t| t.stmts.contains(&s.id))
            .map(|t| t.part)
            .collect();
        if parts.len() != 1 {
            push(
                out,
                "PA001",
                Severity::Error,
                format!("{}/S{}", k.name, s.id),
                format!("statement belongs to {} fusion parts (expected exactly 1)", parts.len()),
            );
        }
    }
    if design.tasks.len() != fg.tasks.len() {
        push(
            out,
            "PA001",
            Severity::Error,
            k.name.clone(),
            format!(
                "design configures {} tasks, fused graph has {}",
                design.tasks.len(),
                fg.tasks.len()
            ),
        );
    }
    let mut seen = vec![false; fg.tasks.len()];
    for tc in &design.tasks {
        if tc.task >= fg.tasks.len() {
            push(
                out,
                "PA001",
                Severity::Error,
                at(tc.task),
                format!("task id {} out of range (graph has {} tasks)", tc.task, fg.tasks.len()),
            );
            continue;
        }
        if seen[tc.task] {
            push(
                out,
                "PA001",
                Severity::Error,
                at(tc.task),
                format!("task id {} configured more than once", tc.task),
            );
            continue;
        }
        seen[tc.task] = true;
        let fused = &fg.tasks[tc.task];
        let rep = fused.representative(k);
        let nl = k.statements[rep].loops.len();
        if tc.perm.len() != nl || tc.padded_trip.len() != nl || tc.intra.len() != nl {
            push(
                out,
                "PA001",
                Severity::Error,
                at(tc.task),
                format!(
                    "perm/padded/intra lengths {}/{}/{} disagree with the {}-deep representative nest",
                    tc.perm.len(),
                    tc.padded_trip.len(),
                    tc.intra.len(),
                    nl
                ),
            );
            continue;
        }
        let mut mask = vec![false; nl];
        let mut perm_ok = true;
        for &p in &tc.perm {
            if p >= nl || mask[p] {
                perm_ok = false;
                break;
            }
            mask[p] = true;
        }
        if !perm_ok {
            push(
                out,
                "PA002",
                Severity::Error,
                at(tc.task),
                format!("perm {:?} is not a permutation of 0..{}", tc.perm, nl),
            );
            continue;
        }
        for p in 0..nl {
            let declared = k.statements[rep].loops[p].trip;
            let eff = if p == 0 { fused.outer_span().unwrap_or(declared) } else { declared };
            if tc.padded_trip[p] < eff {
                push(
                    out,
                    "PA003",
                    Severity::Error,
                    at(tc.task),
                    format!(
                        "padded trip {} at loop {} below the effective trip {}",
                        tc.padded_trip[p], p, eff
                    ),
                );
            }
            if tc.intra[p] == 0 || tc.padded_trip[p] % tc.intra[p].max(1) != 0 {
                push(
                    out,
                    "PA003",
                    Severity::Error,
                    at(tc.task),
                    format!(
                        "intra factor {} at loop {} does not tile padded trip {}",
                        tc.intra[p], p, tc.padded_trip[p]
                    ),
                );
            }
        }
        for (a, plan) in &tc.plans {
            if let Err(e) = plan.validate() {
                push(
                    out,
                    "PA004",
                    Severity::Error,
                    format!("{}/FT{}:{}", k.name, tc.task, a),
                    format!("malformed transfer plan: {e}"),
                );
            }
        }
    }
    if design.tasks.len() == fg.tasks.len() {
        for (t, covered) in seen.iter().enumerate() {
            if !covered {
                push(
                    out,
                    "PA001",
                    Severity::Error,
                    at(t),
                    format!("task id {t} has no configuration"),
                );
            }
        }
    }
    if design.fusion != fg.plan() {
        push(
            out,
            "PA005",
            Severity::Error,
            k.name.clone(),
            "design's fusion plan differs from the plan the fused graph realizes".into(),
        );
    }
}

// ---- PA011, PA014: dependence legality -----------------------------------

/// The config of task `t`. Only called after the shape pass guaranteed
/// id coverage, so the lookup cannot fail.
fn cfg_of<'d>(design: &'d DesignConfig, t: usize) -> &'d TaskConfig {
    design
        .tasks
        .iter()
        .find(|tc| tc.task == t)
        .expect("shape pass guarantees task id coverage")
}

/// Re-derive, per task, which representative-nest loop positions carry a
/// dependence: a statement's local loop carries one exactly when the
/// statement's write does **not** index it (successive iterations then
/// read-modify-write the same element — distance vector `(0,…,+,…,0)`
/// with the `+` at that loop). This is computed from the affine accesses
/// alone, never from the IR's `reduction` flags.
fn derived_carried(k: &Kernel, fused: &FusedTask) -> BTreeSet<usize> {
    let rep = fused.representative(k);
    let rep_loops = &k.statements[rep].loops;
    let mut carried = BTreeSet::new();
    for &sid in &fused.stmts {
        let s = &k.statements[sid];
        for (lp, l) in s.loops.iter().enumerate() {
            let Some(rp) = rep_loops.iter().position(|rl| rl.name == l.name) else {
                continue;
            };
            if !s.write.uses_loop(lp) {
                carried.insert(rp);
            }
        }
    }
    carried
}

fn audit_dependences(
    k: &Kernel,
    fg: &FusedGraph,
    design: &DesignConfig,
    out: &mut Vec<Diagnostic>,
) {
    // PA011: in the executed loop order (the perm sequence), every
    // carried loop must run inside every non-carried loop. Permuting a
    // carried loop outward reorders the read-modify-write chain across
    // tile rows, which the unrolled engine does not preserve.
    for fused in &fg.tasks {
        let tc = cfg_of(design, fused.id);
        let rep = fused.representative(k);
        let nl = k.statements[rep].loops.len();
        let carried = derived_carried(k, fused);
        let mut place = vec![0usize; nl];
        for (i, &p) in tc.perm.iter().enumerate() {
            place[p] = i;
        }
        for &c in &carried {
            for n in (0..nl).filter(|p| !carried.contains(p)) {
                if place[c] < place[n] {
                    push(
                        out,
                        "PA011",
                        Severity::Error,
                        format!("{}/FT{}", k.name, fused.id),
                        format!(
                            "dependence-carrying loop `{}` permuted outside parallel loop `{}` (perm {:?})",
                            k.statements[rep].loops[c].name,
                            k.statements[rep].loops[n].name,
                            tc.perm
                        ),
                    );
                }
            }
        }
    }
    // PA014: Bernstein pairs inside one fusion part. Statements fused
    // into one engine execute under a single shared loop nest; a flow or
    // anti dependence between them on an array that is not the shared
    // output has no init/update glue and is not preserved.
    let mut parts: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
    for t in &fg.tasks {
        parts.entry(t.part).or_default().extend(t.stmts.iter().copied());
    }
    for (part, stmts) in &parts {
        let v: Vec<usize> = stmts.iter().copied().collect();
        for i in 0..v.len() {
            for j in (i + 1)..v.len() {
                let sa = &k.statements[v[i]];
                let sb = &k.statements[v[j]];
                if sa.write.array == sb.write.array {
                    continue; // init/update glue on the shared output
                }
                if sb.reads.iter().any(|r| r.array == sa.write.array) {
                    push(
                        out,
                        "PA014",
                        Severity::Error,
                        format!("{}/part{}", k.name, part),
                        format!(
                            "flow dependence S{} -> S{} on `{}` inside one fusion part",
                            sa.id, sb.id, sa.write.array
                        ),
                    );
                }
                if sa.reads.iter().any(|r| r.array == sb.write.array) {
                    push(
                        out,
                        "PA014",
                        Severity::Error,
                        format!("{}/part{}", k.name, part),
                        format!(
                            "anti dependence S{} -> S{} on `{}` inside one fusion part",
                            sa.id, sb.id, sb.write.array
                        ),
                    );
                }
            }
        }
    }
}

// ---- PA015: peel range coverage ------------------------------------------

fn audit_coverage(k: &Kernel, fg: &FusedGraph, out: &mut Vec<Diagnostic>) {
    for s in &k.statements {
        let Some(l0) = s.loops.first() else { continue };
        let trip = l0.trip;
        let mut iv: Vec<(u64, u64)> = fg
            .tasks
            .iter()
            .filter(|t| t.stmts.contains(&s.id))
            .map(|t| t.outer_range.unwrap_or((0, trip)))
            .collect();
        iv.sort_unstable();
        let mut cur = 0u64;
        let mut ok = true;
        for &(lo, hi) in &iv {
            if lo != cur || hi < lo {
                ok = false;
                break;
            }
            cur = hi;
        }
        if cur != trip {
            ok = false;
        }
        if !ok {
            push(
                out,
                "PA015",
                Severity::Error,
                format!("{}/S{}", k.name, s.id),
                format!(
                    "task ranges {:?} do not exactly tile the outer iteration space [0:{})",
                    iv, trip
                ),
            );
        }
    }
}

// ---- PA020, PA021, PA030..PA032: FIFO dataflow ---------------------------

/// The elements task `t` emits of `a` over a FIFO: its outer-range share
/// of the array footprint, scaled by the *writer statement's* outer
/// trip. Recomputed from the kernel IR — the cached
/// `fifo_out_elems_by_array` is the value under test.
fn emitted_of(k: &Kernel, t: &FusedTask, a: &str) -> u64 {
    let total = k.array(a).map(|x| x.elems()).unwrap_or(0);
    match t.outer_range {
        Some((lo, hi)) => {
            let wtrip = t
                .stmts
                .iter()
                .find(|&&s| k.statements[s].write.array == a)
                .and_then(|&s| k.statements[s].loops.first().map(|l| l.trip))
                .unwrap_or(0);
            if wtrip > 0 {
                total * (hi - lo).min(wtrip) / wtrip
            } else {
                total
            }
        }
        None => total,
    }
}

/// The order in which a task's engine visits the dimensions of `access`:
/// dimension indices sorted by the place of their indexing loop in the
/// executed loop order (non-reduction perm order, then reductions).
/// `None` when a loop cannot be mapped onto the representative nest.
fn traversal_sig(
    k: &Kernel,
    design: &DesignConfig,
    fused: &FusedTask,
    owner_sid: usize,
    access: &Access,
) -> Option<Vec<usize>> {
    let tc = design.tasks.iter().find(|c| c.task == fused.id)?;
    let rep = fused.representative(k);
    let rep_loops = &k.statements[rep].loops;
    let red: Vec<bool> = rep_loops.iter().map(|l| l.reduction).collect();
    let mut ord = tc.nonred_order(&red);
    ord.extend(tc.red_order(&red));
    let s = &k.statements[owner_sid];
    let mut dims: Vec<(usize, usize)> = Vec::new();
    for (d, ix) in access.idx.iter().enumerate() {
        if let Index::Iter(lp) = ix {
            let name = &s.loops[*lp].name;
            let rp = rep_loops.iter().position(|rl| &rl.name == name)?;
            let pl = ord.iter().position(|&p| p == rp)?;
            dims.push((pl, d));
        }
    }
    dims.sort_unstable();
    Some(dims.into_iter().map(|(_, d)| d).collect())
}

fn audit_fifo(
    k: &Kernel,
    fg: &FusedGraph,
    cache: &GeometryCache,
    design: &DesignConfig,
    out: &mut Vec<Diagnostic>,
) {
    let n = fg.tasks.len();
    let edge_at = |s: usize, d: usize, a: &str| format!("{}/FT{}->FT{}:{}", k.name, s, d, a);

    // Reject out-of-range edges before anything indexes by task id.
    let edges: Vec<&(usize, usize, String)> = fg
        .edges
        .iter()
        .filter(|(s, d, a)| {
            let ok = *s < n && *d < n;
            if !ok {
                push(
                    out,
                    "PA030",
                    Severity::Error,
                    edge_at(*s, *d, a),
                    format!("edge endpoints out of range (graph has {n} tasks)"),
                );
            }
            ok
        })
        .collect();

    // PA030: the edge set, re-derived under last-writer flow semantics.
    // A statement reading `a` consumes the latest program-order writer
    // of `a`; every task of *another* part containing that writer must
    // feed the reader's task. Peels of one part produce and consume
    // their disjoint outer ranges locally and never exchange data.
    let mut required: BTreeSet<(usize, usize, String)> = BTreeSet::new();
    for t in &fg.tasks {
        for &sid in &t.stmts {
            for r in &k.statements[sid].reads {
                let lw = k.statements[..sid]
                    .iter()
                    .rev()
                    .find(|s| s.write.array == r.array)
                    .map(|s| s.id);
                if let Some(lw) = lw {
                    for u in &fg.tasks {
                        if u.part != t.part && u.stmts.contains(&lw) {
                            required.insert((u.id, t.id, r.array.clone()));
                        }
                    }
                }
            }
        }
    }
    let actual: BTreeSet<(usize, usize, String)> = edges.iter().map(|e| (*e).clone()).collect();
    for (s, d, a) in required.difference(&actual) {
        push(
            out,
            "PA030",
            Severity::Error,
            edge_at(*s, *d, a),
            "required FIFO edge missing from the fused graph (consumer would read a stream nobody writes)".into(),
        );
    }
    for (s, d, a) in actual.difference(&required) {
        push(
            out,
            "PA030",
            Severity::Error,
            edge_at(*s, *d, a),
            "FIFO edge not derivable from last-writer flow semantics".into(),
        );
    }

    // PA032: peels of one part never exchange FIFO data.
    for &(s, d, ref a) in &actual {
        if fg.tasks[s].part == fg.tasks[d].part {
            push(
                out,
                "PA032",
                Severity::Error,
                edge_at(s, d, a),
                format!("FIFO edge between peels of part {}", fg.tasks[s].part),
            );
        }
    }

    // PA031: the wait graph over tasks must be acyclic, otherwise every
    // task on the cycle blocks on a token its predecessor never emits.
    let mut indeg = vec![0usize; n];
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut pairs = BTreeSet::new();
    for &(s, d, _) in &actual {
        if pairs.insert((s, d)) {
            adj[s].push(d);
            indeg[d] += 1;
        }
    }
    let mut queue: Vec<usize> = (0..n).filter(|&t| indeg[t] == 0).collect();
    let mut popped = 0usize;
    while let Some(t) = queue.pop() {
        popped += 1;
        for &d in &adj[t] {
            indeg[d] -= 1;
            if indeg[d] == 0 {
                queue.push(d);
            }
        }
    }
    if popped != n {
        let stuck: Vec<String> = (0..n)
            .filter(|&t| indeg[t] > 0)
            .map(|t| format!("FT{t}"))
            .collect();
        push(
            out,
            "PA031",
            Severity::Error,
            k.name.clone(),
            format!("FIFO wait graph has a cycle through {}", stuck.join(", ")),
        );
    }

    // PA021 (differential half): the cached per-edge emission must match
    // the recomputation from the kernel IR.
    for (t, st) in cache.tasks.iter().enumerate() {
        if t >= n {
            break;
        }
        for (a, cached) in &st.fifo_out_elems_by_array {
            let recomputed = emitted_of(k, &fg.tasks[t], a);
            if *cached != recomputed {
                push(
                    out,
                    "PA021",
                    Severity::Error,
                    format!("{}/FT{}:{}", k.name, t, a),
                    format!(
                        "cached FIFO emission {cached} disagrees with the recomputed {recomputed}"
                    ),
                );
            }
        }
    }

    // PA021 (balance half) + PA020 per consumer/array.
    let consumers: BTreeSet<(usize, String)> =
        actual.iter().map(|(_, d, a)| (*d, a.clone())).collect();
    for (d, a) in &consumers {
        let at = format!("{}/FT{}:{}", k.name, d, a);
        let producers: BTreeSet<usize> = actual
            .iter()
            .filter(|(_, dd, aa)| dd == d && aa == a)
            .map(|(s, _, _)| *s)
            .collect();
        let st = &cache.tasks[*d];
        let Some(ast) = st.array(a) else {
            push(
                out,
                "PA021",
                Severity::Error,
                at,
                "consumer task has no statics for the streamed array".into(),
            );
            continue;
        };
        let cached_prods: BTreeSet<usize> = ast.fifo_producers.iter().copied().collect();
        if cached_prods != producers {
            push(
                out,
                "PA021",
                Severity::Error,
                at.clone(),
                format!(
                    "cached producer set {:?} disagrees with the graph's {:?}",
                    cached_prods, producers
                ),
            );
        }
        // Consumer demand, exactly as the simulator gates tokens: the
        // whole footprint, narrowed to the task's outer-range share when
        // the ranged loop indexes the array.
        let outer_indexed = ast.access.iter().any(|p| *p == Some(0));
        let demand = match st.outer_range {
            Some((lo, hi)) if outer_indexed => {
                let full = k.statements[st.rep]
                    .loops
                    .first()
                    .map(|l| l.trip)
                    .unwrap_or(0);
                if full > 0 {
                    ast.total_elems * (hi - lo).min(full) / full
                } else {
                    ast.total_elems
                }
            }
            _ => ast.total_elems,
        };
        let produced: u64 = producers.iter().map(|&s| emitted_of(k, &fg.tasks[s], a)).sum();
        if produced < demand {
            push(
                out,
                "PA021",
                Severity::Error,
                at.clone(),
                format!(
                    "producers emit {produced} tokens, consumer demands {demand}: the consumer starves (deadlock)"
                ),
            );
        } else if produced > demand {
            push(
                out,
                "PA021",
                Severity::Warning,
                at.clone(),
                format!(
                    "producers emit {produced} tokens, consumer demands {demand}: the stream is never drained"
                ),
            );
        }
        // PA020: element traversal order, re-derived from the accesses
        // and the executed loop order on both sides.
        for &s in &producers {
            let prod = &fg.tasks[s];
            let Some(&wsid) = prod
                .stmts
                .iter()
                .find(|&&sid| k.statements[sid].write.array == *a)
            else {
                continue;
            };
            let psig = traversal_sig(k, design, prod, wsid, &k.statements[wsid].write);
            let cons = &fg.tasks[*d];
            let Some((rsid, raccess)) = cons.stmts.iter().find_map(|&sid| {
                k.statements[sid]
                    .reads
                    .iter()
                    .find(|r| r.array == *a)
                    .map(|r| (sid, r))
            }) else {
                continue;
            };
            let csig = traversal_sig(k, design, cons, rsid, raccess);
            if let (Some(p), Some(c)) = (psig, csig) {
                if !p.is_empty() && !c.is_empty() && p != c {
                    push(
                        out,
                        "PA020",
                        Severity::Warning,
                        edge_at(s, *d, a),
                        format!(
                            "producer streams dims in order {:?}, consumer reads in order {:?}",
                            p, c
                        ),
                    );
                }
            }
        }
    }
}

// ---- PA040..PA042: resources ---------------------------------------------

fn audit_resources(
    rd: &ResolvedDesign,
    dev: &Device,
    scenario: Scenario,
    out: &mut Vec<Diagnostic>,
) {
    let (regions, budget) = region_budget(dev, scenario);
    for rt in &rd.tasks {
        let t = rt.cfg().task;
        if rt.cfg().slr >= regions {
            push(
                out,
                "PA041",
                Severity::Error,
                format!("{}/FT{}", rd.k.name, t),
                format!(
                    "task placed on SLR{} but scenario {} has {} region(s)",
                    rt.cfg().slr,
                    scenario,
                    regions
                ),
            );
        }
        for (ast, rp) in rt.arrays() {
            if rp.partitions > dev.max_partition {
                push(
                    out,
                    "PA042",
                    Severity::Error,
                    format!("{}/FT{}:{}", rd.k.name, t, ast.name),
                    format!(
                        "partition factor {} above the device maximum {}",
                        rp.partitions, dev.max_partition
                    ),
                );
            }
        }
    }
    let mut usage = vec![ResourceVec::ZERO; dev.slrs];
    for rt in &rd.tasks {
        usage[rt.cfg().slr.min(dev.slrs - 1)] += task_resources(rt, dev);
    }
    for (region, u) in usage.iter().enumerate() {
        if !u.fits(&budget) {
            push(
                out,
                "PA040",
                Severity::Error,
                format!("{}/SLR{}", rd.k.name, region),
                format!(
                    "region resource sum exceeds the scenario budget (peak utilization {:.2}x)",
                    u.utilization(&budget)
                ),
            );
        }
    }
}

// ---- PA050..PA054: structural HLS lint -----------------------------------

/// Structurally lint emitted HLS against the resolved design it was
/// generated from: stream declarations vs. graph edges, engine
/// definitions/calls, interface pragmas, per-output write calls and the
/// peeled engine names/slice annotations.
pub fn lint_hls(rd: &ResolvedDesign, hls: &str) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let k = rd.k;
    let fg = rd.fg;
    let design = rd.design;
    let at = |t: usize| format!("{}/FT{}", k.name, t);

    // PA050: one static stream per graph edge, no extras.
    for (s, d, a) in &fg.edges {
        let needle = format!("static hls::stream<float16> fifo_{a}_FT{s}_to_FT{d};");
        if !hls.contains(&needle) {
            push(
                &mut out,
                "PA050",
                Severity::Error,
                format!("{}/FT{}->FT{}:{}", k.name, s, d, a),
                "FIFO edge has no stream declaration in the emitted top".into(),
            );
        }
    }
    let decls = hls.matches("static hls::stream<").count();
    if decls != fg.edges.len() {
        push(
            &mut out,
            "PA050",
            Severity::Error,
            k.name.clone(),
            format!("top declares {} streams, fused graph has {} edges", decls, fg.edges.len()),
        );
    }

    // PA051: engines, calls, top and SLR wrappers.
    for t in &fg.tasks {
        let def = format!("void fused_task_{}(/* streams */)", t.id);
        let n = hls.matches(def.as_str()).count();
        if n != 1 {
            push(
                &mut out,
                "PA051",
                Severity::Error,
                at(t.id),
                format!("expected exactly one engine definition, found {n}"),
            );
        }
        if let Some(tc) = design.tasks.iter().find(|c| c.task == t.id) {
            let call = format!("fused_task_{}(/* SLR{} */);", t.id, tc.slr);
            if !hls.contains(&call) {
                push(
                    &mut out,
                    "PA051",
                    Severity::Error,
                    at(t.id),
                    format!("top does not invoke the engine on SLR{}", tc.slr),
                );
            }
        }
    }
    if !hls.contains(&format!("extern \"C\" void {}_top(", k.name)) {
        push(
            &mut out,
            "PA051",
            Severity::Error,
            k.name.clone(),
            "top function missing".into(),
        );
    }
    let slrs: BTreeSet<usize> = design.tasks.iter().map(|t| t.slr).collect();
    let want_wrappers = slrs.len() > 1;
    for &slr in &slrs {
        let wrapper = format!("extern \"C\" void {}_slr{}(", k.name, slr);
        if want_wrappers != hls.contains(&wrapper) {
            push(
                &mut out,
                "PA051",
                Severity::Error,
                format!("{}/SLR{}", k.name, slr),
                if want_wrappers {
                    "multi-SLR design lacks its per-SLR wrapper".into()
                } else {
                    "single-SLR design emits a spurious SLR wrapper".into()
                },
            );
        }
    }

    // PA052: dataflow pragma iff the dataflow model; m_axi iff external.
    let has_dataflow = hls.contains("#pragma HLS dataflow");
    if (design.model == ExecutionModel::Dataflow) != has_dataflow {
        push(
            &mut out,
            "PA052",
            Severity::Error,
            k.name.clone(),
            format!(
                "dataflow pragma {} under the {:?} execution model",
                if has_dataflow { "present" } else { "absent" },
                design.model
            ),
        );
    }
    for a in &k.arrays {
        let needle = format!(
            "#pragma HLS interface m_axi port={} offset=slave bundle=gmem_{}",
            a.name, a.name
        );
        let external = a.is_input || a.is_output;
        if external != hls.contains(&needle) {
            push(
                &mut out,
                "PA052",
                Severity::Error,
                format!("{}/{}", k.name, a.name),
                if external {
                    "external array has no m_axi interface pragma".into()
                } else {
                    "on-chip intermediate array exposes an m_axi interface".into()
                },
            );
        }
    }

    // PA053: exactly one write call per produced array per engine.
    for rt in &rd.tasks {
        let t = rt.cfg().task;
        for a in &rt.statics().outputs {
            let call = format!("write_{a}_FT{t}(/*store|send*/);");
            let n = hls.matches(call.as_str()).count();
            if n != 1 {
                push(
                    &mut out,
                    "PA053",
                    Severity::Error,
                    format!("{}/FT{}:{}", k.name, t, a),
                    format!("produced array written {n} times (expected exactly 1)"),
                );
            }
        }
    }

    // PA054: peeled engine names and outer-slice annotations.
    for rt in &rd.tasks {
        let st = rt.statics();
        for &sid in &st.stmts {
            let name = match st.outer_range {
                Some((lo, hi)) => format!("task{sid}_r{lo}_{hi}"),
                None => format!("task{sid}"),
            };
            let def = format!("void {name}(/* partitioned tile buffers */)");
            let n = hls.matches(def.as_str()).count();
            if n != 1 {
                push(
                    &mut out,
                    "PA054",
                    Severity::Error,
                    format!("{}/FT{}/S{}", k.name, st.task, sid),
                    format!("expected exactly one intra engine `{name}`, found {n}"),
                );
            }
        }
        if let Some((lo, hi)) = st.outer_range {
            if !st.red_mask.first().copied().unwrap_or(false) {
                if let Some(l0) = rd.k.statements[st.rep].loops.first() {
                    let ann = format!(" over {} in [{}:{})", l0.name, lo, hi);
                    if !hls.contains(&ann) {
                        push(
                            &mut out,
                            "PA054",
                            Severity::Error,
                            at(st.task),
                            format!(
                                "ranged engine lacks its `[{lo}:{hi})` outer-slice annotation"
                            ),
                        );
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::fusion::fuse;
    use crate::dse::solver::{solve, SolverOptions};
    use crate::ir::polybench;
    use crate::ir::{Access, ArrayDecl, Loop, OpCounts, Statement, StmtKind};

    fn quick() -> SolverOptions {
        SolverOptions {
            max_factor_per_loop: 16,
            max_unroll: 256,
            beam: 4,
            ..SolverOptions::default()
        }
    }

    #[test]
    fn gemm_winning_design_audits_clean() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &quick()).expect("solve");
        let cache = GeometryCache::new(&k, &r.fused);
        let diags =
            audit_all(&k, &r.fused, &cache, &r.design, &dev, Scenario::Rtl);
        let errs: Vec<String> =
            diags.iter().filter(|d| d.severity == Severity::Error).map(|d| d.to_string()).collect();
        assert!(errs.is_empty(), "gemm winner should audit clean: {errs:?}");
    }

    #[test]
    fn reduction_loop_permuted_outward_fires_pa011() {
        let k = polybench::gemm();
        let dev = Device::u55c();
        let r = solve(&k, &dev, &quick()).expect("solve");
        let cache = GeometryCache::new(&k, &r.fused);
        let mut design = r.design.clone();
        // gemm's representative nest is (i, j, k-reduction): putting k
        // first is exactly the "swap a reduction loop outward" mutation.
        design.tasks[0].perm = vec![2, 0, 1];
        let diags = audit_design(&k, &r.fused, &cache, &design, &dev, Scenario::Rtl);
        assert!(
            diags.iter().any(|d| d.code == "PA011" && d.severity == Severity::Error),
            "expected PA011, got {diags:?}"
        );
    }

    #[test]
    fn transposed_consumer_fires_pa020_warning_only() {
        // Producer writes T[i][j] row-major; the consumer reads T[j][i]
        // under the same loop order — a transposed stream traversal. The
        // enumerator does not co-constrain the two orders, so the audit
        // reports a warning, not an error.
        let mk = |id: usize, kind: StmtKind, write: Access, reads: Vec<Access>| Statement {
            id,
            kind,
            loops: vec![Loop::new("i", 8, false), Loop::new("j", 8, false)],
            write,
            reads,
            ops: OpCounts::new(1, 0),
        };
        let k = Kernel {
            name: "synth_transpose".into(),
            description: String::new(),
            arrays: vec![
                ArrayDecl::new("A", &[8, 8], true, false),
                ArrayDecl::new("T", &[8, 8], false, false),
                ArrayDecl::new("O", &[8, 8], false, true),
            ],
            statements: vec![
                mk(0, StmtKind::Compute, Access::new("T", &[0, 1]), vec![Access::new("A", &[0, 1])]),
                mk(1, StmtKind::Compute, Access::new("O", &[0, 1]), vec![Access::new("T", &[1, 0])]),
            ],
        };
        let fg = fuse(&k);
        let cache = GeometryCache::new(&k, &fg);
        let tasks = (0..fg.tasks.len())
            .map(|t| TaskConfig {
                task: t,
                perm: vec![0, 1],
                padded_trip: vec![8, 8],
                intra: vec![1, 1],
                ii: 1,
                plans: Default::default(),
                slr: 0,
            })
            .collect();
        let design = DesignConfig {
            kernel: k.name.clone(),
            model: ExecutionModel::Dataflow,
            overlap: false,
            fusion: fg.plan(),
            tasks,
        };
        let dev = Device::u55c();
        let diags = audit_design(&k, &fg, &cache, &design, &dev, Scenario::Rtl);
        assert!(
            diags.iter().any(|d| d.code == "PA020" && d.severity == Severity::Warning),
            "expected a PA020 warning, got {diags:?}"
        );
        assert!(!has_errors(&diags), "transposed traversal must not be an error: {diags:?}");
    }

    #[test]
    fn severity_and_display_are_stable() {
        assert!(Severity::Error > Severity::Warning);
        let d = Diagnostic {
            code: "PA001",
            severity: Severity::Error,
            location: "gemm/FT0".into(),
            message: "boom".into(),
        };
        assert_eq!(d.to_string(), "PA001 error [gemm/FT0]: boom");
    }
}
