//! Program analysis: dependences, task-graph construction, fusion and
//! reuse classification (paper §3.1, Fig 3, Table 5's last two columns).

pub mod deps;
pub mod fusion;
pub mod reuse;
pub mod taskgraph;

pub use deps::{DepEdge, DepKind};
pub use fusion::{
    enumerate_fusions, fuse, fuse_with_plan, FusedGraph, FusedTask, FusionPlan, PeelRole,
};
pub use taskgraph::TaskGraph;
