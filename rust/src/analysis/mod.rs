//! Program analysis: dependences, task-graph construction, fusion and
//! reuse classification (paper §3.1, Fig 3, Table 5's last two columns),
//! plus the independent static design auditor (`audit`, DESIGN.md §12)
//! that re-verifies solver output without trusting the enumerators.

pub mod audit;
pub mod deps;
pub mod fusion;
pub mod reuse;
pub mod taskgraph;

pub use audit::{audit_all, audit_design, lint_hls, Diagnostic, Severity};
pub use deps::{DepEdge, DepKind};
pub use fusion::{
    enumerate_fusions, fuse, fuse_with_plan, FusedGraph, FusedTask, FusionPlan, PeelRole,
};
pub use taskgraph::TaskGraph;
