//! Design regeneration (paper §5.7 / §6.2): when the board model rejects
//! a design (congestion → no bitstream), tighten the resource constraint
//! for the offending region and re-solve, retaining the rest of the
//! configuration. The paper does this manually ("Atax and Bicg ...
//! required regeneration with a 55% constraint"); here it is the
//! automated loop.

use crate::dse::solver::{solve, Scenario, SolverOptions, SolverResult};
use crate::hw::Device;
use crate::ir::Kernel;
use crate::sim::board::{board_eval, BoardReport};

/// Outcome of the regeneration loop.
pub struct RegenOutcome {
    pub result: SolverResult,
    pub board: BoardReport,
    /// Utilization fractions attempted, in order (e.g. [0.60, 0.55]).
    pub attempts: Vec<f64>,
}

/// Solve for `slrs`×`frac`, evaluate on the board model, and tighten the
/// budget by `step` until the bitstream succeeds (or `min_frac` is hit,
/// in which case the last attempt is returned). Errs when a tightened
/// budget becomes infeasible for the solver — tightening further could
/// only make that worse, so regeneration cannot recover.
pub fn regenerate_until_feasible(
    k: &Kernel,
    dev: &Device,
    base: &SolverOptions,
    slrs: usize,
    mut frac: f64,
    step: f64,
    min_frac: f64,
) -> anyhow::Result<RegenOutcome> {
    let mut attempts = Vec::new();
    loop {
        attempts.push(frac);
        let opts = SolverOptions {
            scenario: Scenario::OnBoard { slrs, frac },
            ..base.clone()
        };
        let result = solve(k, dev, &opts)
            .map_err(|e| anyhow::anyhow!("{}: regeneration at {frac:.2}: {e}", k.name))?;
        let budget = dev.slr.scaled(frac);
        // evaluate against the winning variant's own graph — a tighter
        // budget may flip the chosen fusion between attempts
        let board = board_eval(k, &result.fused, &result.design, dev, &budget);
        if board.bitstream_ok || frac - step < min_frac {
            return Ok(RegenOutcome { result, board, attempts });
        }
        frac -= step;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::flow::quick_solver;
    use crate::ir::polybench;

    #[test]
    fn regen_terminates_and_is_feasible() {
        let k = polybench::atax();
        let dev = Device::u55c();
        let out =
            regenerate_until_feasible(&k, &dev, &quick_solver(), 1, 0.60, 0.05, 0.15).unwrap();
        assert!(!out.attempts.is_empty());
        assert!(out.attempts.len() <= 10);
        // either feasible or we hit the floor
        assert!(out.board.bitstream_ok || *out.attempts.last().unwrap() <= 0.20);
    }

    #[test]
    fn attempts_strictly_decrease() {
        let k = polybench::bicg();
        let dev = Device::u55c();
        let out =
            regenerate_until_feasible(&k, &dev, &quick_solver(), 1, 0.60, 0.05, 0.30).unwrap();
        for w in out.attempts.windows(2) {
            assert!(w[1] < w[0]);
        }
    }
}
