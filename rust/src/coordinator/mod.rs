//! The end-to-end coordinator: analyze → fuse → solve → generate →
//! simulate → (board-model) → validate, plus the design-regeneration
//! loop of paper §5.7.

pub mod flow;
pub mod regen;

pub use flow::{
    optimize_kernel, optimize_kernel_cached, optimize_kernel_stored, CacheStatus, OptimizeOptions,
    OptimizedKernel,
};
pub use regen::regenerate_until_feasible;
