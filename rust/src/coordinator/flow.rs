//! The Prometheus flow (paper Fig 2): from kernel IR to an optimized,
//! simulated, optionally hardware-validated design.

use crate::analysis::fusion::{fuse, FusedGraph};
use crate::codegen::{generate_hls, generate_host};
use crate::dse::config::DesignConfig;
use crate::dse::cost::{gflops, graph_latency};
use crate::dse::solver::{solve, Scenario, SolverOptions, SolverResult};
use crate::hw::Device;
use crate::ir::Kernel;
use crate::sim::board::{board_eval, BoardReport};
use crate::sim::engine::{simulate, SimReport};
use anyhow::Result;
use std::path::Path;
use std::time::Duration;

/// Options for one optimization run.
#[derive(Debug, Clone)]
pub struct OptimizeOptions {
    pub scenario: Scenario,
    pub solver: SolverOptions,
    /// Emit HLS-C++/host sources into this directory (None = skip).
    pub emit_dir: Option<std::path::PathBuf>,
    /// Validate numerics through the PJRT artifact if present here.
    pub artifacts_dir: Option<std::path::PathBuf>,
}

impl Default for OptimizeOptions {
    fn default() -> Self {
        OptimizeOptions {
            scenario: Scenario::Rtl,
            solver: SolverOptions::default(),
            emit_dir: None,
            artifacts_dir: None,
        }
    }
}

/// Everything the flow produces for one kernel.
pub struct OptimizedKernel {
    pub kernel: Kernel,
    pub fused: FusedGraph,
    pub result: SolverResult,
    pub sim: SimReport,
    /// Board model result for on-board scenarios.
    pub board: Option<BoardReport>,
    /// Max relative error of the PJRT functional validation, if run.
    pub validation_rel_err: Option<f64>,
    /// Simulated throughput (GF/s) at the scenario's achieved clock.
    pub gflops: f64,
}

/// Run the full flow for `kernel_name`.
pub fn optimize_kernel(
    kernel_name: &str,
    dev: &Device,
    opts: &OptimizeOptions,
) -> Result<OptimizedKernel> {
    let kernel = crate::ir::polybench::by_name(kernel_name)
        .ok_or_else(|| anyhow::anyhow!("unknown kernel {kernel_name}"))?;
    let fused = fuse(&kernel);

    // 1. solve the design space
    let mut solver = opts.solver.clone();
    solver.scenario = opts.scenario;
    let result = solve(&kernel, dev, &solver);
    result
        .design
        .validate(&kernel, &fused, dev.slrs)
        .map_err(|e| anyhow::anyhow!("solver produced invalid design: {e}"))?;

    // 2. simulate (RTL-equivalent)
    let sim = simulate(&kernel, &fused, &result.design, dev);

    // 3. board model where applicable
    let (board, gf) = match opts.scenario {
        Scenario::Rtl => (None, sim.gflops(&kernel, dev)),
        Scenario::OnBoard { frac, .. } => {
            let budget = dev.slr.scaled(frac);
            let b = board_eval(&kernel, &fused, &result.design, dev, &budget);
            let g = b.gflops;
            (Some(b), g)
        }
    };

    // 4. codegen
    if let Some(dir) = &opts.emit_dir {
        std::fs::create_dir_all(dir)?;
        let hls = generate_hls(&kernel, &result.design);
        let host = generate_host(&kernel, &result.design);
        std::fs::write(dir.join(format!("{}_kernel.cpp", kernel.name.replace('-', "_"))), hls)?;
        std::fs::write(dir.join(format!("{}_host.cpp", kernel.name.replace('-', "_"))), host)?;
    }

    // 5. functional validation through the PJRT artifact
    let validation_rel_err = match &opts.artifacts_dir {
        Some(root) if artifact_exists(root, &kernel.name) => {
            let exe = crate::runtime::Executor::load(root, &kernel.name)?;
            Some(exe.validate()?)
        }
        _ => None,
    };

    Ok(OptimizedKernel {
        kernel,
        fused,
        result,
        sim,
        board,
        validation_rel_err,
        gflops: gf,
    })
}

fn artifact_exists(root: &Path, kernel: &str) -> bool {
    crate::runtime::artifact_path(root, kernel).exists()
}

/// Convenience: analytic GF/s of an existing design (used by reports).
pub fn design_gflops(k: &Kernel, fg: &FusedGraph, design: &DesignConfig, dev: &Device) -> f64 {
    gflops(k, graph_latency(k, fg, design, dev).total, dev)
}

/// Fast solver options for tests and examples (same space, smaller beam).
pub fn quick_solver() -> SolverOptions {
    SolverOptions {
        beam: 12,
        max_factor_per_loop: 32,
        max_unroll: 1024,
        timeout: Duration::from_secs(30),
        ..SolverOptions::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flow_runs_rtl() {
        let dev = Device::u55c();
        let opts = OptimizeOptions { solver: quick_solver(), ..OptimizeOptions::default() };
        let r = optimize_kernel("gemm", &dev, &opts).unwrap();
        assert!(r.gflops > 10.0);
        assert!(r.board.is_none());
        assert!(r.validation_rel_err.is_none()); // no artifacts dir given
    }

    #[test]
    fn flow_runs_onboard_with_codegen() {
        let dev = Device::u55c();
        let dir = std::env::temp_dir().join("prom_test_emit");
        let opts = OptimizeOptions {
            scenario: Scenario::OnBoard { slrs: 1, frac: 0.6 },
            solver: quick_solver(),
            emit_dir: Some(dir.clone()),
            artifacts_dir: None,
        };
        let r = optimize_kernel("bicg", &dev, &opts).unwrap();
        let b = r.board.expect("board report");
        assert!(b.bitstream_ok);
        assert!(dir.join("bicg_kernel.cpp").exists());
        assert!(dir.join("bicg_host.cpp").exists());
    }

    #[test]
    fn unknown_kernel_errors() {
        let dev = Device::u55c();
        assert!(optimize_kernel("nope", &dev, &OptimizeOptions::default()).is_err());
    }
}
