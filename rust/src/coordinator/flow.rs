//! The Prometheus flow (paper Fig 2): from kernel IR to an optimized,
//! simulated, optionally hardware-validated design. The flow builds
//! each kernel's [`FusionSpace`] (every legal fusion variant — partial
//! loop-range and cross-array variants included — with its
//! [`GeometryCache`]) once, solves fusion jointly with the rest of the
//! space, and threads the **winning variant's** fused graph and cache
//! through every evaluation stage — simulation, board model and
//! generated HLS all derive from the same resolved design of the same
//! fusion (peeled sub-tasks included), never from a recomputed
//! `fuse()`. A QoR-cache hit re-materializes exactly the record's own
//! variant through `fuse_with_plan`, so ranged designs replay their
//! peels bit-identically.

use crate::analysis::audit;
use crate::analysis::fusion::FusedGraph;
use crate::codegen::{generate_hls_resolved, generate_host};
use crate::dse::config::DesignConfig;
use crate::dse::cost::{gflops, graph_latency, graph_latency_resolved};
use crate::dse::eval::{FusionSpace, FusionVariant, GeometryCache, ResolvedDesign};
use crate::dse::solver::{solve_space, Scenario, SolverOptions, SolverResult};
use crate::hw::Device;
use crate::ir::Kernel;
use crate::obs;
use crate::sim::board::{board_eval_resolved, BoardReport};
use crate::sim::engine::{simulate_resolved, SimReport};
use anyhow::Result;
use std::path::Path;
use std::time::Duration;

/// Options for one optimization run.
#[derive(Debug, Clone)]
pub struct OptimizeOptions {
    pub scenario: Scenario,
    pub solver: SolverOptions,
    /// Emit HLS-C++/host sources into this directory (None = skip).
    pub emit_dir: Option<std::path::PathBuf>,
    /// Validate numerics through the PJRT artifact if present here.
    pub artifacts_dir: Option<std::path::PathBuf>,
}

impl Default for OptimizeOptions {
    fn default() -> Self {
        OptimizeOptions {
            scenario: Scenario::Rtl,
            solver: SolverOptions::default(),
            emit_dir: None,
            artifacts_dir: None,
        }
    }
}

/// Everything the flow produces for one kernel.
pub struct OptimizedKernel {
    pub kernel: Kernel,
    /// The winning fusion variant's task graph (== `result.fused`).
    pub fused: FusedGraph,
    pub result: SolverResult,
    pub sim: SimReport,
    /// Board model result for on-board scenarios.
    pub board: Option<BoardReport>,
    /// Max relative error of the PJRT functional validation, if run.
    pub validation_rel_err: Option<f64>,
    /// Simulated throughput (GF/s) at the scenario's achieved clock.
    pub gflops: f64,
}

/// Run the full flow for `kernel_name`.
pub fn optimize_kernel(
    kernel_name: &str,
    dev: &Device,
    opts: &OptimizeOptions,
) -> Result<OptimizedKernel> {
    let kernel = crate::ir::polybench::by_name(kernel_name)
        .ok_or_else(|| anyhow::anyhow!("unknown kernel {kernel_name}"))?;

    // 1. solve the design space — fusion jointly with everything else
    let mut solver = opts.solver.clone();
    solver.scenario = opts.scenario;
    let mut space = build_space(&kernel, solver.explore_fusion);
    let result = solve_validated(&kernel, &space, dev, &solver)?;
    let FusionVariant { fg: fused, cache, .. } = take_winning_variant(&mut space, &result)?;

    finish_flow(kernel, fused, cache, result, dev, opts)
}

/// Stage 1 of the flow: solve and structurally validate the winner
/// against its own fusion variant. Shared by [`optimize_kernel`] and
/// the miss path of [`optimize_kernel_cached`]. An infeasible budget is
/// a clean request error (`SolverError::Infeasible`), not a panic.
fn solve_validated(
    kernel: &Kernel,
    space: &FusionSpace,
    dev: &Device,
    solver: &SolverOptions,
) -> Result<SolverResult> {
    let _span = obs::span("flow", "flow.solve")
        .map(|s| s.arg("kernel", obs::ArgVal::Str(kernel.name.clone())));
    let result = solve_space(kernel, space, dev, solver)
        .map_err(|e| anyhow::anyhow!("{}: {e}", kernel.name))?;
    result
        .design
        .validate(kernel, &result.fused, dev.slrs)
        .map_err(|e| anyhow::anyhow!("solver produced invalid design: {e}"))?;
    Ok(result)
}

/// Pull the winning variant (the one `result.design.fusion` realizes)
/// out of the space, so the rest of the flow reuses its graph and
/// geometry cache instead of recomputing fusion.
fn take_winning_variant(space: &mut FusionSpace, result: &SolverResult) -> Result<FusionVariant> {
    let win = space
        .variant_of(&result.design.fusion)
        .ok_or_else(|| anyhow::anyhow!("solver returned a fusion variant outside its space"))?;
    Ok(space.take_variant(win))
}

/// [`FusionSpace::for_solver`] under a `flow.fusion_space` span, so the
/// variant-enumeration + geometry-cache phase shows up in traces.
fn build_space(kernel: &Kernel, explore_fusion: bool) -> FusionSpace {
    let _span = obs::span("flow", "flow.fusion_space")
        .map(|s| s.arg("kernel", obs::ArgVal::Str(kernel.name.clone())));
    FusionSpace::for_solver(kernel, explore_fusion)
}

/// Stages 2–5 of the flow (simulate → board model → codegen → optional
/// PJRT validation), shared by the solve path and the QoR-cache hit path
/// so the two can never drift apart.
fn finish_flow(
    kernel: Kernel,
    fused: FusedGraph,
    cache: GeometryCache,
    result: SolverResult,
    dev: &Device,
    opts: &OptimizeOptions,
) -> Result<OptimizedKernel> {
    audit_winner(&kernel, &fused, &cache, &result.design, dev, opts.scenario)?;

    // 2. simulate (RTL-equivalent) + 3. board model where applicable,
    //    both reading the one resolved design
    let rd = ResolvedDesign::new(&kernel, &fused, &cache, &result.design);
    let sim = {
        let _span = obs::span("flow", "flow.sim");
        simulate_resolved(&rd, dev)
    };
    trace_sim_stalls(&sim);
    let (board, gf) = {
        let _span = obs::span("flow", "flow.board");
        scenario_eval_resolved(&rd, dev, opts.scenario, &sim)
    };
    drop(rd);

    finish_flow_with(kernel, fused, &cache, result, sim, board, gf, opts)
}

/// Independent static audit of a winning design (DESIGN.md §12,
/// `analysis/audit.rs`): every design the flow is about to ship —
/// freshly solved, cache-hit, or about to be recorded — is re-verified
/// from first principles, and audit *errors* abort the flow. Warnings
/// (e.g. the PA020 traversal-order note) are emitted as trace instants
/// and never fatal.
fn audit_winner(
    kernel: &Kernel,
    fused: &FusedGraph,
    cache: &GeometryCache,
    design: &DesignConfig,
    dev: &Device,
    scenario: Scenario,
) -> Result<()> {
    let _span = obs::span("flow", "flow.audit")
        .map(|s| s.arg("kernel", obs::ArgVal::Str(kernel.name.clone())));
    let diags = audit::audit_all(kernel, fused, cache, design, dev, scenario);
    let mut errors = Vec::new();
    for d in &diags {
        match d.severity {
            audit::Severity::Error => errors.push(d.to_string()),
            audit::Severity::Warning => obs::instant(
                "flow",
                "flow.audit.warning",
                vec![("diag".to_string(), obs::ArgVal::Str(d.to_string()))],
            ),
        }
    }
    if !errors.is_empty() {
        return Err(anyhow::anyhow!(
            "{}: winning design failed the static audit: {}",
            kernel.name,
            errors.join("; ")
        ));
    }
    Ok(())
}

/// Emit the final simulation's per-FIFO stall attribution as trace
/// instant events (no-op unless tracing is on). Only the *winning*
/// design's simulation is traced — the solver's leaf simulations never
/// collect attribution in the first place.
fn trace_sim_stalls(sim: &SimReport) {
    for fs in &sim.fifo_stalls {
        obs::instant(
            "sim",
            "sim.fifo_stall",
            vec![
                ("array".to_string(), obs::ArgVal::Str(fs.array.clone())),
                ("producer".to_string(), obs::ArgVal::Int(fs.producer as i128)),
                ("consumer".to_string(), obs::ArgVal::Int(fs.consumer as i128)),
                ("cycles".to_string(), obs::ArgVal::Int(fs.cycles as i128)),
            ],
        );
    }
}

/// Stages 4–5 with the evaluation products already computed — lets the
/// cached flow record a solve (which needs the same sim/GF/s) without
/// evaluating the design twice.
#[allow(clippy::too_many_arguments)]
fn finish_flow_with(
    kernel: Kernel,
    fused: FusedGraph,
    cache: &GeometryCache,
    result: SolverResult,
    sim: SimReport,
    board: Option<BoardReport>,
    gf: f64,
    opts: &OptimizeOptions,
) -> Result<OptimizedKernel> {
    // 4. codegen, off the same resolved design the evaluation used
    if let Some(dir) = &opts.emit_dir {
        let _span = obs::span("flow", "flow.codegen");
        std::fs::create_dir_all(dir)?;
        let rd = ResolvedDesign::new(&kernel, &fused, cache, &result.design);
        let hls = generate_hls_resolved(&rd);
        drop(rd);
        let host = generate_host(&kernel, &result.design);
        std::fs::write(dir.join(format!("{}_kernel.cpp", kernel.name.replace('-', "_"))), hls)?;
        std::fs::write(dir.join(format!("{}_host.cpp", kernel.name.replace('-', "_"))), host)?;
    }

    // 5. functional validation through the PJRT artifact (skipped when
    //    the runtime is not compiled in — validation is optional here,
    //    unlike the explicit `validate` CLI path)
    let validation_rel_err = match &opts.artifacts_dir {
        Some(root)
            if crate::runtime::Executor::available() && artifact_exists(root, &kernel.name) =>
        {
            let _span = obs::span("flow", "flow.validate");
            let exe = crate::runtime::Executor::load(root, &kernel.name)?;
            Some(exe.validate()?)
        }
        _ => None,
    };

    Ok(OptimizedKernel {
        kernel,
        fused,
        result,
        sim,
        board,
        validation_rel_err,
        gflops: gf,
    })
}

fn artifact_exists(root: &Path, kernel: &str) -> bool {
    crate::runtime::artifact_path(root, kernel).exists()
}

/// Scenario-consistent evaluation of a solved design: the board model
/// (and its derated GF/s) for on-board scenarios, the simulator's GF/s
/// at the target clock for RTL. The single source of truth for "what
/// throughput do we report for this request" — the flow and the batch
/// orchestrator both call it, so their numbers cannot drift apart.
pub fn scenario_eval_resolved(
    rd: &ResolvedDesign,
    dev: &Device,
    scenario: Scenario,
    sim: &SimReport,
) -> (Option<BoardReport>, f64) {
    match scenario {
        Scenario::Rtl => (None, sim.gflops(rd.k, dev)),
        Scenario::OnBoard { frac, .. } => {
            let budget = dev.slr.scaled(frac);
            let b = board_eval_resolved(rd, dev, &budget);
            let g = b.gflops;
            (Some(b), g)
        }
    }
}

/// [`scenario_eval_resolved`] with cold resolution, for callers that
/// hold only the design.
pub fn scenario_eval(
    k: &Kernel,
    fg: &FusedGraph,
    design: &DesignConfig,
    dev: &Device,
    scenario: Scenario,
    sim: &SimReport,
) -> (Option<BoardReport>, f64) {
    let cache = GeometryCache::new(k, fg);
    let rd = ResolvedDesign::new(k, fg, &cache, design);
    scenario_eval_resolved(&rd, dev, scenario, sim)
}

/// How `optimize_kernel_cached` answered a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheStatus {
    /// Exact QoR-DB hit: the solver was skipped entirely.
    Hit,
    /// Miss, but a related record warm-started the solver.
    WarmMiss,
    /// Miss with no usable incumbent.
    ColdMiss,
}

impl CacheStatus {
    pub fn as_str(self) -> &'static str {
        match self {
            CacheStatus::Hit => "hit",
            CacheStatus::WarmMiss => "warm miss",
            CacheStatus::ColdMiss => "cold miss",
        }
    }
}

/// Knowledge-base polymorphism for the cached flow: the same hit/miss
/// logic runs over the legacy in-memory [`crate::service::QorDb`]
/// (infallible, caller-persisted) and the concurrent, durable
/// [`crate::service::QorStore`] (fsync'd log; evict/record can fail
/// with I/O errors). Private — callers pick a backend through
/// [`optimize_kernel_cached`] or [`optimize_kernel_stored`].
trait QorBackend {
    /// Exact-hit lookup by canonical key.
    fn lookup(&self, canon: &str) -> Option<crate::service::QorRecord>;
    /// Drop a stale record (tombstone it, for durable backends).
    fn evict(&mut self, canon: &str) -> Result<()>;
    /// Best related record's design for warm-starting, restricted to
    /// fusion plans `usable` accepts.
    fn incumbent(
        &self,
        kernel: &str,
        model: crate::dse::config::ExecutionModel,
        overlap: bool,
        usable: &dyn Fn(&crate::analysis::fusion::FusionPlan) -> bool,
    ) -> Option<DesignConfig>;
    /// Record a completed solve (never-worse merge on both backends).
    fn record(&mut self, canon: String, rec: crate::service::QorRecord) -> Result<()>;
}

impl QorBackend for crate::service::QorDb {
    fn lookup(&self, canon: &str) -> Option<crate::service::QorRecord> {
        self.get_canonical(canon).cloned()
    }

    fn evict(&mut self, canon: &str) -> Result<()> {
        self.remove_canonical(canon);
        Ok(())
    }

    fn incumbent(
        &self,
        kernel: &str,
        model: crate::dse::config::ExecutionModel,
        overlap: bool,
        usable: &dyn Fn(&crate::analysis::fusion::FusionPlan) -> bool,
    ) -> Option<DesignConfig> {
        self.incumbent_for_space(kernel, model, overlap, |p| usable(p))
            .map(|rec| rec.design.clone())
    }

    fn record(&mut self, canon: String, rec: crate::service::QorRecord) -> Result<()> {
        self.insert_canonical(canon, rec);
        Ok(())
    }
}

impl QorBackend for &crate::service::QorStore {
    fn lookup(&self, canon: &str) -> Option<crate::service::QorRecord> {
        self.get_canonical(canon)
    }

    fn evict(&mut self, canon: &str) -> Result<()> {
        self.remove_canonical(canon)?;
        Ok(())
    }

    fn incumbent(
        &self,
        kernel: &str,
        model: crate::dse::config::ExecutionModel,
        overlap: bool,
        usable: &dyn Fn(&crate::analysis::fusion::FusionPlan) -> bool,
    ) -> Option<DesignConfig> {
        self.incumbent_for_space(kernel, model, overlap, |p| usable(p)).map(|rec| rec.design)
    }

    fn record(&mut self, canon: String, rec: crate::service::QorRecord) -> Result<()> {
        self.insert_canonical(&canon, rec)?;
        Ok(())
    }
}

/// The flow, fronted by the QoR knowledge base (service layer).
///
/// On an exact key hit the solver is skipped: the cached design is
/// re-validated, re-simulated (cheap — the simulator is the flow's
/// authority anyway) and the rest of the flow (board model, codegen,
/// PJRT validation) runs as usual. On a miss the solver runs —
/// warm-started from the best related record when one exists — and the
/// winning design is inserted into `db`. The caller owns persistence
/// ([`crate::service::QorDb::load`] / [`crate::service::QorDb::save`]).
pub fn optimize_kernel_cached(
    kernel_name: &str,
    dev: &Device,
    opts: &OptimizeOptions,
    db: &mut crate::service::QorDb,
) -> Result<(OptimizedKernel, CacheStatus)> {
    optimize_kernel_backend(kernel_name, dev, opts, db)
}

/// [`optimize_kernel_cached`] against the concurrent, durable
/// [`crate::service::QorStore`]: a cache hit, a stale-record eviction
/// and a recorded solve all go through the store's fsync'd append log,
/// so a completed solve survives the process (no save step to forget,
/// no whole-file lost-update window). This is the backend `prometheus
/// optimize --db` and `prometheus batch` use; the serve daemon holds
/// the same store for its whole lifetime.
pub fn optimize_kernel_stored(
    kernel_name: &str,
    dev: &Device,
    opts: &OptimizeOptions,
    store: &crate::service::QorStore,
) -> Result<(OptimizedKernel, CacheStatus)> {
    let mut backend = store;
    optimize_kernel_backend(kernel_name, dev, opts, &mut backend)
}

fn optimize_kernel_backend(
    kernel_name: &str,
    dev: &Device,
    opts: &OptimizeOptions,
    db: &mut dyn QorBackend,
) -> Result<(OptimizedKernel, CacheStatus)> {
    let mut solver = opts.solver.clone();
    solver.scenario = opts.scenario;
    solver.incumbent = None;
    let key = crate::service::DesignKey::new(kernel_name, dev, &solver);
    let canon = key.canonical();
    let kernel = crate::ir::polybench::by_name(kernel_name)
        .ok_or_else(|| anyhow::anyhow!("unknown kernel {kernel_name}"))?;

    // Exact hit: rebuild the flow products around the cached design,
    // evaluated against the record's *own* fusion variant. The hit path
    // materializes exactly that one variant (fuse_with_plan + one
    // GeometryCache) — never the whole fusion space; enumerating and
    // caching every variant is solver work the cache exists to skip.
    let mut stale_hit = false;
    let lookup_span = obs::span("flow", "flow.qor_db")
        .map(|s| s.arg("op", obs::ArgVal::Str("lookup".to_string())));
    if let Some(rec) = db.lookup(&canon) {
        // A record from an incompatible (older) code or resource model
        // (same on-disk version), or whose fusion partition is no
        // longer legal for the kernel, is a miss, not an error: drop
        // through to a fresh solve and evict it. Same predicate as the
        // solver's warm-start gate (`design.validate`'s fusion check
        // keeps cached designs from crossing partitions).
        let variant = crate::analysis::fusion::fuse_with_plan(&kernel, &rec.design.fusion)
            .ok()
            .map(|fg| {
                let cache = GeometryCache::new(&kernel, &fg);
                (fg, cache)
            })
            .filter(|(fg, cache)| {
                crate::dse::solver::design_usable_with_cache(
                    &kernel,
                    fg,
                    cache,
                    &rec.design,
                    dev,
                    opts.scenario,
                )
            });
        match variant {
            None => stale_hit = true,
            Some((fused, cache)) => {
                let design = rec.design.clone();
                let latency = {
                    let rd = ResolvedDesign::new(&kernel, &fused, &cache, &design);
                    graph_latency_resolved(&rd, dev)
                };
                // the recorded solve weighed the whole space; count the
                // plans (cheap — no graphs or caches are built) so the
                // hit reports the same variant count the miss did
                let fusion_variants = if solver.explore_fusion {
                    crate::analysis::fusion::enumerate_fusions(&kernel).len()
                } else {
                    1
                };
                let result = SolverResult {
                    gflops: gflops(&kernel, latency.total, dev),
                    fused: fused.clone(),
                    fusion_variants,
                    design,
                    latency,
                    solve_time: std::time::Duration::ZERO,
                    explored: 0,
                    timed_out: false,
                    warm_started: false,
                    telemetry: obs::SolveTelemetry::default(),
                };
                drop(lookup_span);
                let r = finish_flow(kernel, fused, cache, result, dev, opts)?;
                return Ok((r, CacheStatus::Hit));
            }
        }
    }
    if stale_hit {
        db.evict(&canon)?;
    }
    drop(lookup_span);

    // Miss: build the full fusion space once, for the solve.
    let mut space = build_space(&kernel, solver.explore_fusion);

    // Miss: solve (warm-started when the KB has a related design whose
    // fusion plan is a variant of *this* solve's space — the solver
    // additionally binds the incumbent to that variant's graph, so a
    // warm start can never cross incompatible partitions).
    // `warm_started` comes from the solver, the only party that knows
    // whether the incumbent was actually usable under this scenario.
    solver.incumbent =
        db.incumbent(kernel_name, solver.model, solver.overlap, &|p| space.variant_of(p).is_some());
    let result = solve_validated(&kernel, &space, dev, &solver)?;
    let status =
        if result.warm_started { CacheStatus::WarmMiss } else { CacheStatus::ColdMiss };
    // Evaluate once, then record the solve *before* the fallible finish
    // stages (codegen emit, PJRT validation): a completed solve must
    // never be lost to an unwritable emit dir. The caller persists the
    // db even when this function errors.
    let FusionVariant { fg: fused, cache, .. } = take_winning_variant(&mut space, &result)?;
    // Audit before the record is inserted: an illegal design must never
    // enter the knowledge base, where it would warm-start future solves.
    audit_winner(&kernel, &fused, &cache, &result.design, dev, opts.scenario)?;
    let rd = ResolvedDesign::new(&kernel, &fused, &cache, &result.design);
    let sim = {
        let _span = obs::span("flow", "flow.sim");
        simulate_resolved(&rd, dev)
    };
    trace_sim_stalls(&sim);
    let (board, gf) = {
        let _span = obs::span("flow", "flow.board");
        scenario_eval_resolved(&rd, dev, opts.scenario, &sim)
    };
    drop(rd);
    {
        let _span = obs::span("flow", "flow.qor_db")
            .map(|s| s.arg("op", obs::ArgVal::Str("insert".to_string())));
        db.record(canon, crate::service::QorRecord::from_products(&result, &sim, gf))?;
    }
    let r = finish_flow_with(kernel, fused, &cache, result, sim, board, gf, opts)?;
    Ok((r, status))
}

/// Convenience: analytic GF/s of an existing design (used by reports).
pub fn design_gflops(k: &Kernel, fg: &FusedGraph, design: &DesignConfig, dev: &Device) -> f64 {
    gflops(k, graph_latency(k, fg, design, dev).total, dev)
}

/// Fast solver options for tests and examples (same space, smaller beam).
pub fn quick_solver() -> SolverOptions {
    SolverOptions {
        beam: 12,
        max_factor_per_loop: 32,
        max_unroll: 1024,
        timeout: Duration::from_secs(30),
        ..SolverOptions::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flow_runs_rtl() {
        let dev = Device::u55c();
        let opts = OptimizeOptions { solver: quick_solver(), ..OptimizeOptions::default() };
        let r = optimize_kernel("gemm", &dev, &opts).unwrap();
        assert!(r.gflops > 10.0);
        assert!(r.board.is_none());
        assert!(r.validation_rel_err.is_none()); // no artifacts dir given
    }

    #[test]
    fn flow_runs_onboard_with_codegen() {
        let dev = Device::u55c();
        let dir = std::env::temp_dir().join("prom_test_emit");
        let opts = OptimizeOptions {
            scenario: Scenario::OnBoard { slrs: 1, frac: 0.6 },
            solver: quick_solver(),
            emit_dir: Some(dir.clone()),
            artifacts_dir: None,
        };
        let r = optimize_kernel("bicg", &dev, &opts).unwrap();
        let b = r.board.expect("board report");
        assert!(b.bitstream_ok);
        assert!(dir.join("bicg_kernel.cpp").exists());
        assert!(dir.join("bicg_host.cpp").exists());
    }

    #[test]
    fn unknown_kernel_errors() {
        let dev = Device::u55c();
        assert!(optimize_kernel("nope", &dev, &OptimizeOptions::default()).is_err());
    }

    #[test]
    fn cached_flow_hits_on_second_call() {
        let dev = Device::u55c();
        let opts = OptimizeOptions { solver: quick_solver(), ..OptimizeOptions::default() };
        let mut db = crate::service::QorDb::new();
        let (first, st1) = optimize_kernel_cached("madd", &dev, &opts, &mut db).unwrap();
        assert_eq!(st1, CacheStatus::ColdMiss);
        assert_eq!(db.len(), 1);
        let (second, st2) = optimize_kernel_cached("madd", &dev, &opts, &mut db).unwrap();
        assert_eq!(st2, CacheStatus::Hit);
        // the cached answer is the same design, solved in ~zero time
        assert_eq!(second.result.design, first.result.design);
        assert_eq!(second.sim.cycles, first.sim.cycles);
        assert_eq!(second.result.explored, 0);
        // a different scenario is a different key -> a miss, not a hit
        // (warm or cold depends on whether the RTL design fits the
        // on-board budget; either way it must solve and land in the db)
        let onboard = OptimizeOptions {
            scenario: Scenario::OnBoard { slrs: 1, frac: 0.6 },
            solver: quick_solver(),
            ..OptimizeOptions::default()
        };
        let (_, st3) = optimize_kernel_cached("madd", &dev, &onboard, &mut db).unwrap();
        assert_ne!(st3, CacheStatus::Hit);
        assert_eq!(db.len(), 2);
    }

    #[test]
    fn stored_flow_matches_cached_flow() {
        let dev = Device::u55c();
        let opts = OptimizeOptions { solver: quick_solver(), ..OptimizeOptions::default() };
        let store = crate::service::QorStore::in_memory();
        let (first, st1) = optimize_kernel_stored("madd", &dev, &opts, &store).unwrap();
        assert_eq!(st1, CacheStatus::ColdMiss);
        assert_eq!(store.len(), 1);
        let (second, st2) = optimize_kernel_stored("madd", &dev, &opts, &store).unwrap();
        assert_eq!(st2, CacheStatus::Hit);
        assert_eq!(second.result.design, first.result.design);
        // both backends run the identical flow, so they agree bit-for-bit
        let mut db = crate::service::QorDb::new();
        let (legacy, _) = optimize_kernel_cached("madd", &dev, &opts, &mut db).unwrap();
        assert_eq!(legacy.result.design, first.result.design);
        assert_eq!(legacy.sim.cycles, first.sim.cycles);
    }
}
