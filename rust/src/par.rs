//! The one in-tree scoped worker-pool primitive (rayon is not vendored
//! in this offline environment, matching the criterion/proptest
//! stand-in policy). Both layers of parallelism use it: the solver's
//! intra-solve fan-out (`dse::solver`, stage-1 enumeration units and
//! stage-3 DFS prefixes) and the batch orchestrator's inter-request
//! fan-out (`service::batch`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(0..n)` across `jobs` scoped workers and return the results in
/// index order. Work is pulled from an atomic cursor, so which worker
/// runs which index is racy — but every result lands in its own slot,
/// keeping the output order (and everything downstream) deterministic.
/// `jobs <= 1` (or a single item) runs inline without spawning.
pub fn run_indexed<T: Send>(n: usize, jobs: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    if jobs <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..jobs.min(n) {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_in_index_order_for_any_worker_count() {
        for jobs in [1usize, 2, 7, 32] {
            let out = run_indexed(23, jobs, |i| i * i);
            assert_eq!(out, (0..23).map(|i| i * i).collect::<Vec<_>>(), "jobs={jobs}");
        }
        assert!(run_indexed(0, 4, |i| i).is_empty());
    }
}
