//! Code generation (paper §5): HLS-C++ (Vitis-flavoured dataflow top,
//! load/read/write/store FIFO helpers, fully unrolled intra-tile tasks)
//! and the OpenCL host program. The output is textual — this environment
//! has no Vitis — but structurally mirrors Listings 6–9, serving as the
//! executable specification the simulator runs and as golden-test
//! material.

pub mod hls;
pub mod host;

pub use hls::{generate_hls, generate_hls_resolved};
pub use host::generate_host;
