//! OpenCL host-program emitter (paper §5: "generating optimized HLS-C++
//! code ... alongside OpenCL host code"). The host follows the Vitis
//! flow: load xclbin, create buffers for every off-chip array, migrate,
//! enqueue the kernel, read results back, verify against a software
//! reference.

use crate::dse::config::DesignConfig;
use crate::ir::Kernel;
use std::fmt::Write as _;

/// Generate the OpenCL host .cpp for `design`.
pub fn generate_host(k: &Kernel, design: &DesignConfig) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "// Prometheus host program for `{}` ({} fused tasks)\n\
         #include <CL/cl2.hpp>\n\
         #include <vector>\n\
         #include <iostream>\n\
         #include \"xcl2.hpp\"\n",
        k.name,
        design.tasks.len()
    );
    let _ = writeln!(out, "int main(int argc, char **argv) {{");
    let _ = writeln!(
        out,
        "  auto devices = xcl::get_xil_devices();\n\
         \x20 auto fileBuf = xcl::read_binary_file(argv[1]);\n\
         \x20 cl::Program::Binaries bins{{{{fileBuf.data(), fileBuf.size()}}}};\n\
         \x20 cl::Context context(devices[0]);\n\
         \x20 cl::CommandQueue q(context, devices[0], CL_QUEUE_PROFILING_ENABLE);\n\
         \x20 cl::Program program(context, {{devices[0]}}, bins);\n\
         \x20 cl::Kernel krnl(program, \"{}_top\");\n",
        k.name
    );

    let mut arg = 0usize;
    for a in k.arrays.iter().filter(|a| a.is_input || a.is_output) {
        let elems = a.elems();
        let dir = match (a.is_input, a.is_output) {
            (true, true) => "CL_MEM_READ_WRITE",
            (true, false) => "CL_MEM_READ_ONLY",
            _ => "CL_MEM_WRITE_ONLY",
        };
        let _ = writeln!(
            out,
            "  std::vector<float> h_{n}({elems});\n\
             \x20 cl::Buffer d_{n}(context, {dir} | CL_MEM_USE_HOST_PTR, {elems} * sizeof(float), h_{n}.data());\n\
             \x20 krnl.setArg({arg}, d_{n});",
            n = a.name
        );
        arg += 1;
    }
    let inputs: Vec<String> = k
        .arrays
        .iter()
        .filter(|a| a.is_input)
        .map(|a| format!("d_{}", a.name))
        .collect();
    let outputs: Vec<String> = k
        .arrays
        .iter()
        .filter(|a| a.is_output)
        .map(|a| format!("d_{}", a.name))
        .collect();
    let _ = writeln!(
        out,
        "\n  q.enqueueMigrateMemObjects({{{}}}, 0 /* host->device */);\n\
         \x20 cl::Event ev;\n\
         \x20 q.enqueueTask(krnl, nullptr, &ev);\n\
         \x20 q.enqueueMigrateMemObjects({{{}}}, CL_MIGRATE_MEM_OBJECT_HOST);\n\
         \x20 q.finish();",
        inputs.join(", "),
        outputs.join(", ")
    );
    let _ = writeln!(
        out,
        "  cl_ulong t0 = ev.getProfilingInfo<CL_PROFILING_COMMAND_START>();\n\
         \x20 cl_ulong t1 = ev.getProfilingInfo<CL_PROFILING_COMMAND_END>();\n\
         \x20 double ms = (t1 - t0) * 1e-6;\n\
         \x20 double gflops = {:.1} / (ms * 1e6);\n\
         \x20 std::cout << \"{}: \" << ms << \" ms, \" << gflops << \" GF/s\\n\";\n\
         \x20 return 0;\n}}",
        k.total_flops() as f64 / 1e3,
        k.name
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::config::ExecutionModel;
    use crate::ir::polybench;

    fn dummy_design(k: &Kernel) -> DesignConfig {
        DesignConfig {
            kernel: k.name.clone(),
            model: ExecutionModel::Dataflow,
            overlap: true,
            fusion: crate::analysis::fusion::FusionPlan::max_fusion(k),
            tasks: vec![],
        }
    }

    #[test]
    fn host_has_all_offchip_buffers() {
        let k = polybench::three_mm();
        let host = generate_host(&k, &dummy_design(&k));
        for a in ["A", "B", "C", "D", "G"] {
            assert!(host.contains(&format!("d_{a}")), "missing buffer {a}");
        }
        // intermediates never get host buffers
        assert!(!host.contains("d_E"));
        assert!(!host.contains("d_F"));
        assert!(host.contains("3mm_top"));
        assert!(host.contains("enqueueMigrateMemObjects"));
    }

    #[test]
    fn kernel_arg_indices_are_dense() {
        let k = polybench::gemm();
        let host = generate_host(&k, &dummy_design(&k));
        assert!(host.contains("setArg(0,"));
        assert!(host.contains("setArg(1,"));
        assert!(host.contains("setArg(2,"));
        assert!(!host.contains("setArg(3,"));
    }
}
