//! Regenerates **Table 5**: benchmark kernels with computational/memory
//! complexity, data-reuse order and inter-task communication volume —
//! all *computed* from the IR and the fused task graph, not hand-written.
//!
//! ```bash
//! cargo bench --bench table5_kernels
//! ```

use prometheus::analysis::fusion::fuse;
use prometheus::analysis::reuse;
use prometheus::ir::polybench;
use prometheus::report::Table;

/// Paper's Comm.-Between-Tasks column, in N-parametrized form, for the
/// shape check (N = the relevant PolyBench dimension).
fn paper_comm(name: &str) -> &'static str {
    match name {
        "bicg" | "madd" | "mvt" => "0",
        "atax" => "N",
        "gesummv" => "2N",
        "2-madd" | "2mm" | "gemm" | "syr2k" | "syrk" | "trmm" => "N^2",
        "3-madd" | "gemver" | "3mm" | "symm" => "2N^2",
        _ => "?",
    }
}

fn main() {
    println!("== Table 5: benchmark kernel characteristics ==\n");
    let mut t = Table::new(&[
        "Benchmark", "Description", "Ops", "Mem", "Reuse", "Comm. between tasks", "(paper)",
    ]);
    for k in polybench::all_kernels() {
        let fg = fuse(&k);
        t.row(vec![
            k.name.clone(),
            k.description.clone(),
            reuse::ops_complexity(&k),
            reuse::mem_complexity(&k),
            reuse::reuse_order(&k).as_str().into(),
            fg.inter_task_elems(&k).to_string(),
            paper_comm(&k.name).into(),
        ]);
    }
    print!("{}", t.render());
}
