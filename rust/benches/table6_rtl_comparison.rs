//! Regenerates **Table 6**: RTL-simulation throughput of the 11-kernel
//! PolyBench subset across all six frameworks, with the paper's PI
//! (performance improvement) average and geometric-mean rows.
//!
//! ```bash
//! cargo bench --bench table6_rtl_comparison
//! ```

use prometheus::baselines::{streamhls, Framework};
use prometheus::hw::Device;
use prometheus::ir::polybench;
use prometheus::report::{gfs, gmean, mean, ratio, Table};
use prometheus::sim::engine::simulate;

fn main() {
    let dev = Device::u55c();
    let kernels = polybench::table6_kernels();
    let frameworks = [
        Framework::Prometheus,
        Framework::Sisyphus,
        Framework::ScaleHls,
        Framework::Allo,
        Framework::AutoDse,
        Framework::StreamHls,
    ];

    println!("== Table 6: RTL throughput comparison (GF/s) ==\n");
    let mut t = Table::new(&[
        "Kernel", "Ours", "Sisyphus", "ScaleHLS", "Allo", "AutoDSE", "Stream-HLS",
    ]);
    // per-framework PI samples (ours / theirs)
    let mut pi: Vec<Vec<f64>> = vec![Vec::new(); frameworks.len()];
    for k in &kernels {
        let mut cells = vec![k.name.clone()];
        let mut ours = 0.0f64;
        for (fi, fw) in frameworks.iter().enumerate() {
            if !fw.supports_triangular() && streamhls::unsupported(k) {
                cells.push("N/A".into());
                continue;
            }
            let r = fw.optimize(k, &dev);
            let sim = simulate(k, &r.fused, &r.design, &dev);
            let g = sim.gflops(k, &dev);
            if fi == 0 {
                ours = g;
            } else if g > 0.0 {
                pi[fi].push(ours / g);
            }
            cells.push(gfs(g));
        }
        t.row(cells);
    }
    // PI rows
    let mut avg_row = vec!["PI (Avg)".to_string(), "1.00x".to_string()];
    let mut gm_row = vec!["PI (gmean)".to_string(), "1.00x".to_string()];
    for fi in 1..frameworks.len() {
        avg_row.push(ratio(mean(&pi[fi])));
        gm_row.push(ratio(gmean(&pi[fi])));
    }
    t.row(avg_row);
    t.row(gm_row);
    print!("{}", t.render());
    println!(
        "\npaper PI(gmean): Sisyphus 2.03x, ScaleHLS 48.03x, Allo 4.92x, AutoDSE 25.82x, Stream-HLS 2.71x\n\
         shape check: Prometheus ≥ every framework on every kernel; ScaleHLS collapses on\n\
         triangular kernels; Stream-HLS N/A there."
    );
}
