//! Regenerates **Table 8**: on-board evaluation — Sisyphus (1 SLR),
//! AutoDSE (1 SLR), Ours (1 SLR), Ours (3 SLR) on 2mm/3mm/atax/bicg,
//! reporting execution time, GF/s, resources and achieved frequency
//! through the board model, with the §5.7 regeneration loop standing in
//! for the paper's manual constraint tightening (60% → 55%; AutoDSE 3mm
//! needed 15%).
//!
//! ```bash
//! cargo bench --bench table8_onboard
//! ```

use prometheus::baselines::{autodse, sisyphus};
use prometheus::coordinator::flow::quick_solver;
use prometheus::coordinator::regen::regenerate_until_feasible;
use prometheus::dse::constraints::total_usage;
use prometheus::dse::solver::SolverOptions;
use prometheus::hw::Device;
use prometheus::ir::polybench;
use prometheus::report::Table;
use prometheus::sim::board::board_eval;

const KERNELS: &[&str] = &["2mm", "3mm", "atax", "bicg"];

fn main() {
    let dev = Device::u55c();
    println!("== Table 8: on-board evaluation (board model) ==\n");
    let mut t = Table::new(&[
        "Config", "Kernel", "T (ms)", "GF/s", "DSP", "BRAM", "L(K)", "FF(K)", "F (MHz)", "bitstream",
    ]);

    // baselines: solve for 60% of one SLR, evaluate, regenerate if needed
    for (label, which) in [("1 SLR Sisyphus", 0usize), ("1 SLR AutoDSE", 1)] {
        for name in KERNELS {
            let k = polybench::by_name(name).unwrap();
            let mut frac = 0.60;
            loop {
                let r = match which {
                    0 => sisyphus::optimize_onboard(&k, &dev, frac),
                    _ => autodse::optimize_onboard(&k, &dev, frac),
                };
                let budget = dev.slr.scaled(frac);
                let b = board_eval(&k, &r.fused, &r.design, &dev, &budget);
                if b.bitstream_ok || frac <= 0.15 {
                    let u = total_usage(&k, &r.fused, &r.design, &dev);
                    t.row(vec![
                        label.into(),
                        k.name.clone(),
                        format!("{:.3}", b.time_ms),
                        format!("{:.2}", b.gflops),
                        format!("{:.0}", u.dsp),
                        format!("{:.0}", u.bram18 / 2.0), // report as BRAM36
                        format!("{:.0}", u.lut / 1e3),
                        format!("{:.0}", u.ff / 1e3),
                        format!("{:.0}", b.fmhz),
                        if b.bitstream_ok { format!("OK@{:.0}%", frac * 100.0) } else { "FAIL".into() },
                    ]);
                    break;
                }
                frac -= 0.05;
            }
        }
    }

    // ours: 1 SLR and 3 SLR with the automated regeneration loop
    let base = SolverOptions { ..quick_solver() };
    for (label, slrs) in [("1 SLR Ours", 1usize), ("3 SLR Ours", 3)] {
        for name in KERNELS {
            let k = polybench::by_name(name).unwrap();
            let out = regenerate_until_feasible(&k, &dev, &base, slrs, 0.60, 0.05, 0.15)
                .expect("Table 8 regeneration stays feasible down to the 15% floor");
            let u = total_usage(&k, &out.result.fused, &out.result.design, &dev);
            t.row(vec![
                label.into(),
                k.name.clone(),
                format!("{:.3}", out.board.time_ms),
                format!("{:.2}", out.board.gflops),
                format!("{:.0}", u.dsp),
                format!("{:.0}", u.bram18 / 2.0),
                format!("{:.0}", u.lut / 1e3),
                format!("{:.0}", u.ff / 1e3),
                format!("{:.0}", out.board.fmhz),
                format!(
                    "OK@{:.0}%",
                    out.attempts.last().copied().unwrap_or(0.6) * 100.0
                ),
            ]);
        }
    }
    print!("{}", t.render());
    println!(
        "\nshape check (paper Table 8): Ours-1SLR beats Sisyphus and AutoDSE on every kernel;\n\
         Ours-3SLR improves 2mm/3mm substantially (more resources) but atax/bicg only\n\
         marginally (memory-bound); multi-SLR designs close timing below 220 MHz."
    );
}
