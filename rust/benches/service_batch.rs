//! Service-layer benchmark: cold vs. warm batch throughput through the
//! QoR knowledge base, parallel fan-out scaling, and the warm-start
//! effect on a single solve. Hand-rolled harness (criterion is not
//! vendored in this environment), same as the other bench targets.
//!
//! ```bash
//! cargo bench --bench service_batch
//! ```

use prometheus::coordinator::flow::quick_solver;
use prometheus::dse::solver::{solve, Scenario, SolverOptions};
use prometheus::hw::Device;
use prometheus::ir::polybench;
use prometheus::service::batch::{run_batch, BatchOptions, BatchRequest};
use prometheus::service::QorStore;
use std::time::Instant;

fn requests() -> Vec<BatchRequest> {
    let kernels = ["gemm", "2mm", "3mm", "bicg", "atax", "mvt", "madd", "gesummv"];
    let scenarios = [
        Scenario::Rtl,
        Scenario::OnBoard { slrs: 1, frac: 0.6 },
        Scenario::OnBoard { slrs: 3, frac: 0.6 },
    ];
    let mut reqs = Vec::new();
    for k in kernels {
        for s in scenarios {
            reqs.push(BatchRequest::new(k, s));
        }
    }
    reqs
}

fn main() {
    let dev = Device::u55c();
    let reqs = requests();
    let nproc = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    println!(
        "== service_batch: {} requests (8 kernels x 3 scenarios), {} hw threads ==\n",
        reqs.len(),
        nproc
    );

    // 1. serial vs parallel cold batch (fan-out scaling)
    let serial_opts = BatchOptions { solver: quick_solver(), jobs: 1 };
    let db_serial = QorStore::in_memory();
    let t0 = Instant::now();
    run_batch(&reqs, &dev, &db_serial, &serial_opts).unwrap();
    let serial = t0.elapsed();
    println!(
        "cold batch, 1 worker:   {serial:>10.2?}  ({:.2} req/s)",
        reqs.len() as f64 / serial.as_secs_f64()
    );

    let par_opts = BatchOptions { solver: quick_solver(), jobs: nproc };
    let db = QorStore::in_memory();
    let t1 = Instant::now();
    let cold = run_batch(&reqs, &dev, &db, &par_opts).unwrap();
    let cold_t = t1.elapsed();
    println!(
        "cold batch, {nproc} workers: {cold_t:>10.2?}  ({:.2} req/s, {:.2}x vs serial)",
        reqs.len() as f64 / cold_t.as_secs_f64(),
        serial.as_secs_f64() / cold_t.as_secs_f64()
    );

    // 2. warm batch: every request a knowledge-base hit
    let t2 = Instant::now();
    let warm = run_batch(&reqs, &dev, &db, &par_opts).unwrap();
    let warm_t = t2.elapsed();
    println!(
        "warm batch (all hits):  {warm_t:>10.2?}  ({:.0} req/s, {:.0}x vs cold)\n",
        reqs.len() as f64 / warm_t.as_secs_f64(),
        cold_t.as_secs_f64() / warm_t.as_secs_f64()
    );
    println!("{}", cold.render());
    println!("cold: {}", cold.summary());
    println!("warm: {}", warm.summary());

    // 3. warm-start effect on a fresh solve: incumbent-seeded
    //    branch-and-bound vs cold branch-and-bound on the same kernel
    let k = polybench::by_name("3mm").unwrap();
    let base = quick_solver();
    let t3 = Instant::now();
    let cold_solve = solve(&k, &dev, &base).unwrap();
    let cold_solve_t = t3.elapsed();
    let t4 = Instant::now();
    let warm_solve = solve(
        &k,
        &dev,
        &SolverOptions { incumbent: Some(cold_solve.design.clone()), ..base },
    )
    .unwrap();
    let warm_solve_t = t4.elapsed();
    println!(
        "\nsolver warm start (3mm): cold {cold_solve_t:.2?} ({} pts) -> warm {warm_solve_t:.2?} \
         ({} pts), {:.2}x",
        cold_solve.explored,
        warm_solve.explored,
        cold_solve_t.as_secs_f64() / warm_solve_t.as_secs_f64()
    );
}
