//! Hot-path microbenchmarks for the performance pass (EXPERIMENTS.md
//! §Perf): cost-model evaluation, simulator throughput, and whole-solver
//! latency. Hand-rolled timing harness (criterion is not vendored in
//! this environment): N warmup + M measured iterations, median reported.
//!
//! ```bash
//! cargo bench --bench perf_hotpath
//! ```

use prometheus::dse::cost::{graph_latency, task_latency};
use prometheus::dse::eval::{resolve_task, GeometryCache, ResolvedDesign};
use prometheus::dse::solver::{solve, SolverOptions};
use prometheus::hw::Device;
use prometheus::ir::polybench;
use prometheus::sim::engine::{simulate, simulate_resolved};
use std::time::Instant;

fn bench<F: FnMut() -> u64>(name: &str, iters: usize, mut f: F) {
    // warmup
    let mut sink = 0u64;
    for _ in 0..iters / 5 + 1 {
        sink = sink.wrapping_add(f());
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        sink = sink.wrapping_add(f());
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[samples.len() / 2];
    let p95 = samples[(samples.len() as f64 * 0.95) as usize];
    println!("{name:<46} median {med:>10.2} µs   p95 {p95:>10.2} µs   (sink {sink})");
}

fn main() {
    let dev = Device::u55c();
    println!("== perf_hotpath: solver/simulator/cost microbenchmarks ==\n");

    // 1. cost-model single evaluation (the solver's inner loop)
    {
        let k = polybench::three_mm();
        let r = solve(&k, &dev, &SolverOptions::default()).unwrap();
        let fg = r.fused.clone();
        let cache = GeometryCache::new(&k, &fg);
        let cfgs = r.design.tasks.clone();
        bench("eval::resolve + cost::task_latency (3mm FT0)", 20_000, || {
            let rt = resolve_task(&k, &cache.tasks[0], &cfgs[0]);
            task_latency(&rt, &dev, true)
        });
        let design = r.design.clone();
        bench("cost::graph_latency cold (3mm, 3 tasks)", 5_000, || {
            graph_latency(&k, &fg, &design, &dev).total
        });
        bench("sim::simulate cold (3mm dataflow)", 2_000, || {
            simulate(&k, &fg, &design, &dev).cycles
        });
        bench("sim::simulate_resolved warm (3mm dataflow)", 2_000, || {
            let rd = ResolvedDesign::new(&k, &fg, &cache, &design);
            simulate_resolved(&rd, &dev).cycles
        });
    }

    // 2. whole-solver latency per kernel (the Table 10 quantity)
    for name in ["gemm", "3mm", "bicg"] {
        let k = polybench::by_name(name).unwrap();
        bench(&format!("solver::solve ({name})"), 5, || {
            solve(&k, &dev, &SolverOptions::default()).unwrap().latency.total
        });
    }

    // 3. simulator scaling: steps/second on a fine-tiled design
    {
        let k = polybench::madd();
        let r = solve(
            &k,
            &dev,
            &SolverOptions { max_unroll: 16, max_factor_per_loop: 4, ..SolverOptions::default() },
        )
        .unwrap();
        let fg = r.fused.clone();
        let sim = simulate(&k, &fg, &r.design, &dev);
        let t0 = Instant::now();
        let reps = 200;
        for _ in 0..reps {
            std::hint::black_box(simulate(&k, &fg, &r.design, &dev));
        }
        let el = t0.elapsed().as_secs_f64();
        println!(
            "\nsimulator throughput: {:.2e} tile-steps/s ({} steps/run)",
            sim.steps as f64 * reps as f64 / el,
            sim.steps
        );
    }
}
