//! Regenerates **Table 3**: measured throughput of the 3mm kernel across
//! frameworks (GF/s, RTL-equivalent simulation).
//!
//! ```bash
//! cargo bench --bench table3_3mm
//! ```

use prometheus::baselines::Framework;
use prometheus::hw::Device;
use prometheus::ir::polybench;
use prometheus::report::{gfs, Table};
use prometheus::sim::engine::simulate;
use std::time::Instant;

/// Paper values for side-by-side comparison.
const PAPER: &[(&str, f64)] = &[
    ("Prometheus", 368.36),
    ("Sisyphus", 178.97),
    ("Stream-HLS", 174.00),
    ("Allo", 60.40),
    ("ScaleHLS", 43.04),
    ("AutoDSE", 1.74),
];

fn main() {
    let dev = Device::u55c();
    let k = polybench::three_mm();


    println!("== Table 3: 3mm throughput across frameworks (GF/s) ==\n");
    let mut t = Table::new(&["Framework", "GF/s (ours)", "GF/s (paper)", "Bench time"]);
    let mut ours_prom = 0.0;
    for (fw, &(pname, pval)) in [
        Framework::Prometheus,
        Framework::Sisyphus,
        Framework::StreamHls,
        Framework::Allo,
        Framework::ScaleHls,
        Framework::AutoDse,
    ]
    .iter()
    .zip(PAPER.iter())
    {
        assert_eq!(fw.name(), pname);
        let t0 = Instant::now();
        let r = fw.optimize(&k, &dev);
        let sim = simulate(&k, &r.fused, &r.design, &dev);
        let g = sim.gflops(&k, &dev);
        if *fw == Framework::Prometheus {
            ours_prom = g;
        }
        t.row(vec![
            fw.name().into(),
            gfs(g),
            gfs(pval),
            format!("{:.2?}", t0.elapsed()),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nshape check: Prometheus leads every framework (paper headline) — ours {:.1} GF/s",
        ours_prom
    );
}
