//! Ablation study over the unified design space: start from full
//! Prometheus and remove one optimization at a time (dataflow
//! concurrency, computation/communication overlap, padding, permutation,
//! tiling, fusion exploration), quantifying each feature's contribution
//! — the experimental backing for the paper's "interdependent
//! transformations" claim (§1.2).
//!
//! Part 2 isolates the fusion dimension (ISSUE 4, enlarged to
//! partial/loop-range + cross-array fusion by ISSUE 5): fusion-explored
//! vs fixed max-fusion solves, with the simulated-latency delta per
//! kernel. Kernels whose fusion space is a single variant report a
//! 0.0% delta by construction; gemver, trmm and symm carry split
//! variants, and mvt, gesummv, 3-madd and symm additionally weigh a
//! cross-array merge of their sibling nests into one engine. The
//! never-worse assertion below is the acceptance gate: the explored
//! winner's simulated cycles must not exceed the fixed-space winner's
//! on any of the 15 kernels.
//!
//! ```bash
//! cargo bench --bench ablation_features
//! ```

use prometheus::dse::config::ExecutionModel;
use prometheus::dse::solver::{solve, SolverOptions};
use prometheus::hw::Device;
use prometheus::ir::polybench;
use prometheus::report::{gfs, Table};
use prometheus::sim::engine::simulate;

fn variants() -> Vec<(&'static str, SolverOptions)> {
    let full = SolverOptions::default();
    vec![
        ("full Prometheus", full.clone()),
        (
            "- dataflow (sequential tasks)",
            SolverOptions { model: ExecutionModel::Sequential, ..full.clone() },
        ),
        ("- overlap (no ping-pong)", SolverOptions { overlap: false, ..full.clone() }),
        ("- padding", SolverOptions { max_pad: 0, ..full.clone() }),
        ("- permutation", SolverOptions { permute: false, ..full.clone() }),
        ("- tiling (all-or-nothing)", SolverOptions { tiling: false, ..full.clone() }),
        (
            "- fusion exploration (fixed max fusion)",
            SolverOptions { explore_fusion: false, ..full.clone() },
        ),
    ]
}

fn main() {
    let dev = Device::u55c();
    println!("== Ablation: contribution of each optimization (GF/s, RTL) ==\n");
    let kernels = ["gemm", "3mm", "3-madd", "bicg", "atax"];
    let mut t = Table::new(&{
        let mut h = vec!["Variant"];
        h.extend(kernels);
        h
    });
    for (name, opts) in variants() {
        let mut row = vec![name.to_string()];
        for kn in kernels {
            let k = polybench::by_name(kn).unwrap();
            let r = solve(&k, &dev, &opts).expect("ablation variants stay feasible at RTL");
            // evaluate against the winning fusion variant's own graph
            let g = simulate(&k, &r.fused, &r.design, &dev).gflops(&k, &dev);
            row.push(gfs(g));
        }
        t.row(row);
    }
    print!("{}", t.render());
    println!(
        "\nreading: dataflow matters most for multi-task kernels (3mm, 3-madd);\n\
         overlap matters for memory-bound kernels; padding/permutation refine\n\
         compute-bound kernels; removing tiling collapses everything with\n\
         off-chip data.\n"
    );

    // ---- part 2: fusion-explored vs fixed-fusion, per kernel -----------
    println!("== Ablation: fusion explored vs fixed max fusion (simulated cycles) ==\n");
    let mut ft = Table::new(&[
        "Kernel", "Variants", "Fixed cycles", "Explored cycles", "Delta", "Chosen fusion",
    ]);
    for k in polybench::all_kernels() {
        let fixed = solve(
            &k,
            &dev,
            &SolverOptions { explore_fusion: false, ..SolverOptions::default() },
        )
        .expect("RTL is feasible");
        let explored = solve(&k, &dev, &SolverOptions::default()).expect("RTL is feasible");
        let fixed_cycles = simulate(&k, &fixed.fused, &fixed.design, &dev).cycles;
        let explored_cycles = simulate(&k, &explored.fused, &explored.design, &dev).cycles;
        // never-worse holds for completed searches (the explored space
        // is a superset scored by the same simulator); a timed-out
        // anytime result is exempt
        if !fixed.timed_out && !explored.timed_out {
            assert!(
                explored_cycles <= fixed_cycles,
                "{}: exploring fusion must never lose ({} > {})",
                k.name,
                explored_cycles,
                fixed_cycles
            );
        }
        // signed difference: a timed-out explored solve may legitimately
        // be slower (the never-worse assert above is gated on that)
        let delta = if fixed_cycles == 0 {
            0.0
        } else {
            100.0 * (fixed_cycles as f64 - explored_cycles as f64) / fixed_cycles as f64
        };
        ft.row(vec![
            k.name.clone(),
            explored.fusion_variants.to_string(),
            fixed_cycles.to_string(),
            explored_cycles.to_string(),
            format!("{delta:.1}%"),
            explored.fused.partition_string(),
        ]);
    }
    print!("{}", ft.render());
    println!(
        "\nreading: single-variant kernels score 0.0% by construction;\n\
         gemver/trmm/symm weigh a pipelined split of their update chains\n\
         against the fused form, and mvt/gesummv/3-madd/symm additionally\n\
         weigh merging their independent sibling nests into one engine\n\
         (cross-array fusion). Partial (loop-range) variants print with\n\
         the `Sj[lo:hi]` suffix when chosen."
    );
}
