//! Ablation study over the unified design space: start from full
//! Prometheus and remove one optimization at a time (dataflow
//! concurrency, computation/communication overlap, padding, permutation,
//! tiling), quantifying each feature's contribution — the experimental
//! backing for the paper's "interdependent transformations" claim (§1.2).
//!
//! ```bash
//! cargo bench --bench ablation_features
//! ```

use prometheus::analysis::fusion::fuse;
use prometheus::dse::config::ExecutionModel;
use prometheus::dse::solver::{solve, SolverOptions};
use prometheus::hw::Device;
use prometheus::ir::polybench;
use prometheus::report::{gfs, Table};
use prometheus::sim::engine::simulate;

fn variants() -> Vec<(&'static str, SolverOptions)> {
    let full = SolverOptions::default();
    vec![
        ("full Prometheus", full.clone()),
        (
            "- dataflow (sequential tasks)",
            SolverOptions { model: ExecutionModel::Sequential, ..full.clone() },
        ),
        ("- overlap (no ping-pong)", SolverOptions { overlap: false, ..full.clone() }),
        ("- padding", SolverOptions { max_pad: 0, ..full.clone() }),
        ("- permutation", SolverOptions { permute: false, ..full.clone() }),
        ("- tiling (all-or-nothing)", SolverOptions { tiling: false, ..full.clone() }),
    ]
}

fn main() {
    let dev = Device::u55c();
    println!("== Ablation: contribution of each optimization (GF/s, RTL) ==\n");
    let kernels = ["gemm", "3mm", "3-madd", "bicg", "atax"];
    let mut t = Table::new(&{
        let mut h = vec!["Variant"];
        h.extend(kernels);
        h
    });
    for (name, opts) in variants() {
        let mut row = vec![name.to_string()];
        for kn in kernels {
            let k = polybench::by_name(kn).unwrap();
            let fg = fuse(&k);
            let r = solve(&k, &dev, &opts).expect("ablation variants stay feasible at RTL");
            let g = simulate(&k, &fg, &r.design, &dev).gflops(&k, &dev);
            row.push(gfs(g));
        }
        t.row(row);
    }
    print!("{}", t.render());
    println!(
        "\nreading: dataflow matters most for multi-task kernels (3mm, 3-madd);\n\
         overlap matters for memory-bound kernels; padding/permutation refine\n\
         compute-bound kernels; removing tiling collapses everything with\n\
         off-chip data."
    );
}
