//! Regenerates **Table 9**: the parameters the NLP found for the 1-SLR
//! on-board designs of 2mm/3mm/atax/bicg — statement fusion, loop order
//! and data-tile sizes.
//!
//! ```bash
//! cargo bench --bench table9_nlp_params
//! ```

use prometheus::dse::eval::{GeometryCache, ResolvedDesign};
use prometheus::dse::solver::{solve, Scenario, SolverOptions};
use prometheus::hw::Device;
use prometheus::ir::polybench;
use prometheus::report::Table;

const KERNELS: &[&str] = &["2mm", "3mm", "atax", "bicg"];

fn main() {
    let dev = Device::u55c();
    println!("== Table 9: fusion, loop order and data-tile sizes found by the NLP (1 SLR) ==\n");
    let mut t = Table::new(&["Kernel", "Fused statements", "Loop order", "Data tile sizes"]);
    for name in KERNELS {
        let k = polybench::by_name(name).unwrap();
        let r = solve(
            &k,
            &dev,
            &SolverOptions {
                scenario: Scenario::OnBoard { slrs: 1, frac: 0.6 },
                ..SolverOptions::default()
            },
        )
        .expect("Table 9's 1-SLR/60% scenario is feasible for the zoo");
        // the partition the solver *chose* (the paper's FTi = {Sj, ...}
        // column), not a recomputed max fusion
        let fg = &r.fused;
        let fused = fg.partition_string();
        let cache = GeometryCache::new(&k, fg);
        let rd = ResolvedDesign::new(&k, fg, &cache, &r.design);
        let mut orders = Vec::new();
        let mut tiles = Vec::new();
        for rt in &rd.tasks {
            let tc = rt.cfg();
            let rep = rt.geo.rep_stmt();
            let names: Vec<&str> =
                tc.perm.iter().map(|&p| rep.loops[p].name.as_str()).collect();
            orders.push(format!("FT{}: {}", tc.task, names.join(",")));
            for (a, rp) in rt.arrays() {
                let dims_s: Vec<String> = rp.tile_dims.iter().map(u64::to_string).collect();
                tiles.push(format!("{}(FT{}): {}", a.name, tc.task, dims_s.join("x")));
            }
        }
        t.row(vec![
            k.name.clone(),
            fused,
            orders.join("  "),
            tiles.join(", "),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nshape check (paper Table 9): atax/bicg fuse into (tmp|s)-then-(y|q) task pairs\n\
         with permuted orders between the two; MM kernels keep k innermost and pick\n\
         per-task tile sizes; arrays consumed by two tasks get distinct tile sizes."
    );
}
