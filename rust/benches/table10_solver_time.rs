//! Regenerates **Table 10**: time for the NLP solver to find a solution,
//! Sisyphus vs Prometheus, across the 11 Table-6 kernels.
//!
//! Prometheus times are measured directly. Sisyphus times use the §6.4
//! methodology: its shared-buffer formulation couples all statements'
//! permutations and tilings into one joint problem, so we measure the
//! evaluation rate and project it over the joint space, capping at the
//! timeout (the paper used 14,400 s; we scale to 60 s to keep the bench
//! fast — the 3mm blow-up is 7+ orders of magnitude, far beyond any cap).
//!
//! ```bash
//! cargo bench --bench table10_solver_time
//! ```

use prometheus::baselines::sisyphus;
use prometheus::dse::solver::{solve, SolverOptions};
use prometheus::hw::Device;
use prometheus::ir::polybench;
use prometheus::report::{gmean, mean, Table};
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(60);

fn main() {
    let dev = Device::u55c();
    println!(
        "== Table 10: NLP solve time (s) — Sisyphus (joint space, timeout {}s) vs Prometheus ==\n",
        TIMEOUT.as_secs()
    );
    let mut t = Table::new(&["Benchmark", "Sisyphus (s)", "Prometheus (s)", "Sis. joint space"]);
    let (mut sis_all, mut prom_all) = (Vec::new(), Vec::new());
    for k in polybench::table6_kernels() {
        let (sis_s, timed_out) = sisyphus::probe_solver_time(&k, &dev, TIMEOUT);
        let t0 = std::time::Instant::now();
        let _ = solve(&k, &dev, &SolverOptions::default());
        let prom_s = t0.elapsed().as_secs_f64();
        sis_all.push(sis_s);
        prom_all.push(prom_s);
        t.row(vec![
            k.name.clone(),
            if timed_out { format!("{sis_s:.2} (TIMEOUT)") } else { format!("{sis_s:.2}") },
            format!("{prom_s:.2}"),
            format!("{:.2e}", sisyphus::joint_space_size(&k, &dev)),
        ]);
    }
    t.row(vec![
        "Average".into(),
        format!("{:.2}", mean(&sis_all)),
        format!("{:.2}", mean(&prom_all)),
        String::new(),
    ]);
    t.row(vec![
        "Geo Mean".into(),
        format!("{:.2}", gmean(&sis_all)),
        format!("{:.2}", gmean(&prom_all)),
        String::new(),
    ]);
    print!("{}", t.render());
    println!(
        "\nshape check (paper Table 10): 3mm times out for Sisyphus while Prometheus solves\n\
         in seconds; all other kernels are seconds-scale for both."
    );
}
