//! Regenerates **Table 7**: Sisyphus vs Prometheus on throughput AND
//! resource utilization (BRAM/DSP/FF/LUT as % of the U55C) for the
//! madd-family + MM kernels + gemver/mvt.
//!
//! ```bash
//! cargo bench --bench table7_sisyphus_vs_prometheus
//! ```

use prometheus::baselines::Framework;
use prometheus::dse::constraints::total_usage;
use prometheus::hw::Device;
use prometheus::ir::polybench;
use prometheus::report::{gfs, Table};
use prometheus::sim::engine::simulate;

const KERNELS: &[&str] = &["madd", "2-madd", "3-madd", "2mm", "3mm", "gemm", "gemver", "mvt"];

fn main() {
    let dev = Device::u55c();
    let total = dev.total();
    println!("== Table 7: Sisyphus vs Prometheus — throughput and resources ==\n");
    let mut t = Table::new(&[
        "Kernel",
        "Sis GF/s", "Sis BRAM%", "Sis DSP%", "Sis FF%", "Sis LUT%",
        "Prom GF/s", "Prom BRAM%", "Prom DSP%", "Prom FF%", "Prom LUT%",
    ]);
    let pct = |x: f64, cap: u64| format!("{:.0}", 100.0 * x / cap as f64);
    let mut speedups = Vec::new();
    for name in KERNELS {
        let k = polybench::by_name(name).unwrap();
        let mut cells = vec![k.name.clone()];
        let mut gf = [0.0f64; 2];
        for (i, fw) in [Framework::Sisyphus, Framework::Prometheus].iter().enumerate() {
            let r = fw.optimize(&k, &dev);
            let sim = simulate(&k, &r.fused, &r.design, &dev);
            gf[i] = sim.gflops(&k, &dev);
            let u = total_usage(&k, &r.fused, &r.design, &dev);
            cells.push(gfs(gf[i]));
            cells.push(pct(u.bram18, total.bram18));
            cells.push(pct(u.dsp, total.dsp));
            cells.push(pct(u.ff, total.ff));
            cells.push(pct(u.lut, total.lut));
        }
        speedups.push(gf[1] / gf[0].max(1e-9));
        t.row(cells);
    }
    print!("{}", t.render());
    println!(
        "\nPrometheus/Sisyphus speedups: {:?}",
        speedups.iter().map(|s| format!("{s:.2}x")).collect::<Vec<_>>()
    );
    println!(
        "shape check (paper): Prometheus wins everywhere; the 3-madd gain is the largest of\n\
         the madd family (independent-task concurrency); BRAM is higher for Prometheus\n\
         (double buffering), other resources generally lower or comparable."
    );
}
